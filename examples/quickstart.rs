//! Quickstart: the MPJ-IO essentials in one file.
//!
//! Four "ranks" (threads) collectively open a shared file, install
//! interleaved file views, write collectively, read each other's data
//! back, then use shared file pointers for a log-style append — the
//! paper's §3.6 test-case repertoire in miniature.
//!
//! Run: `cargo run --example quickstart`

use jpio::comm::datatype::Datatype;
use jpio::comm::{threads, Comm};
use jpio::io::{amode, File, Info};

fn main() {
    let path = format!("/tmp/jpio-quickstart-{}.dat", std::process::id());
    let log_path = format!("/tmp/jpio-quickstart-{}.log", std::process::id());

    threads::run(4, |c| {
        let n = c.size();
        let r = c.rank();

        // --- 1. Collective open (MPI_FILE_OPEN) --------------------------
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null())
            .expect("collective open");

        // --- 2. Interleaved file views (MPI_FILE_SET_VIEW) ---------------
        // Rank r sees ints at positions r, r+n, r+2n, ... of the file.
        let slot = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
        let filetype = Datatype::resized(&slot, 0, (n * 4) as i64).unwrap();
        f.set_view((r * 4) as i64, &Datatype::INT, &filetype, "native", &Info::null())
            .unwrap();

        // --- 3. Collective write (MPI_FILE_WRITE_ALL) --------------------
        let mine: Vec<i32> = (0..8).map(|i| (i * n + r) as i32).collect();
        let st = f.write_all(mine.as_slice(), 0, 8, &Datatype::INT).unwrap();
        assert_eq!(st.count(&Datatype::INT), Some(8));
        c.barrier();

        // --- 4. Verify through a flat view (MPI_FILE_READ_AT) ------------
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let mut all = vec![0i32; 8 * n];
        f.read_at(0, all.as_mut_slice(), 0, 8 * n, &Datatype::INT).unwrap();
        assert_eq!(all, (0..(8 * n) as i32).collect::<Vec<_>>());
        if r == 0 {
            println!("interleaved collective write verified: {:?}...", &all[..8]);
        }
        f.close().unwrap();

        // --- 5. Shared file pointer appends (MPI_FILE_WRITE_SHARED) ------
        let log = File::open(c, &log_path, amode::RDWR | amode::CREATE, Info::null())
            .unwrap();
        let entry = vec![r as i32; 4];
        log.write_shared(entry.as_slice(), 0, 4, &Datatype::INT).unwrap();
        c.barrier();
        if r == 0 {
            let pos = log.get_position_shared().unwrap();
            println!("shared pointer after {} appends: {} etypes", n, pos);
            assert_eq!(pos, (n * 16) as i64); // BYTE etype: 16 bytes per entry
        }
        log.close().unwrap();
    });

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    let _ = std::fs::remove_file(&log_path);
    let _ = std::fs::remove_file(format!("{log_path}.jpio-sfp"));
    println!("quickstart OK");
}
