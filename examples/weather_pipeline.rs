//! End-to-end driver: the full three-layer system on a real workload.
//!
//! N ranks run a heat-diffusion simulation (the paper's motivating
//! "climate modeling" application class):
//!
//! * **L1/L2** — each simulation step is one PJRT dispatch of the fused
//!   `tick` artifact (Pallas stencil + checksum, AOT-compiled from JAX);
//! * **comm** — halo exchange between neighbour ranks every step;
//! * **io (the paper's system)** — every `--checkpoint-every` steps, the
//!   distributed field is written with one collective `write_at_all`
//!   through subarray file views; at the end every rank *cross-reads* a
//!   peer's block from the file and validates it against the peer's PJRT
//!   checksum.
//!
//! Reports step latency, checkpoint write/read bandwidth, and the
//! field-decay curve (the "loss curve" of this workload). Results are
//! recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example weather_pipeline -- [--ranks 4]
//!       [--steps 12] [--checkpoint-every 4] [--backend nfs]`

use std::time::Instant;

use jpio::cli::Args;
use jpio::comm::{threads, Comm, ReduceOp};
use jpio::coordinator::{Checkpointer, HaloGrid, Metrics};
use jpio::io::{amode, File, Info};
use jpio::runtime::{Runtime, TensorF32};

const BLOCK: usize = 256; // must match `make artifacts` --block

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let ranks = args.get_or("ranks", 4usize);
    let steps = args.get_or("steps", 12usize);
    let ckpt_every = args.get_or("checkpoint-every", 4usize);
    let backend = args.get("backend").unwrap_or("local").to_string();
    let path = format!("/tmp/jpio-weather-{}.ckpt", std::process::id());

    println!(
        "weather_pipeline: {ranks} ranks, {steps} steps, checkpoint every {ckpt_every}, \
         backend {backend}, block {BLOCK}x{BLOCK}"
    );

    let path_c = path.clone();
    threads::run(ranks, move |c| {
        let metrics = Metrics::new();
        let r = c.rank();
        let n = c.size();
        let rt = metrics.time("runtime.load", || Runtime::load("artifacts"))
            .expect("artifacts missing — run `make artifacts`");
        let grid = HaloGrid::new(r, n, (BLOCK, BLOCK));
        let ck = Checkpointer::new(grid.clone());
        let (gy, gx) = grid.coords;

        // Initial condition from the PJRT `init` artifact.
        let mut state = rt.exec_init(gy as i32, gx as i32).unwrap();
        assert_eq!(state.dims, vec![BLOCK + 2, BLOCK + 2]);

        let info = Info::from([("jpio_backend", backend.as_str())]);
        let file = File::open(c, &path_c, amode::RDWR | amode::CREATE, info).unwrap();

        let mut my_checksum = [0f32; 2];
        let mut frames = 0usize;
        let sim_start = Instant::now();
        for step in 0..steps {
            // Halo exchange (comm layer).
            metrics.time("halo.exchange", || grid.exchange(c, &mut state.data));
            // One fused PJRT dispatch: stencil + checksum (L1/L2).
            let out = metrics
                .time("pjrt.tick", || rt.exec_f32("tick", &[state.clone()]))
                .unwrap();
            let interior = &out[0];
            my_checksum = [out[1].data[0], out[1].data[1]];
            // Re-embed the interior into the halo-extended state.
            let rebuilt = metrics
                .time("pjrt.unpack", || {
                    rt.exec_f32("unpack", &[state.clone(), interior.clone()])
                })
                .unwrap();
            state = rebuilt.into_iter().next().unwrap();

            // Field decay curve (the workload's "loss curve").
            let local_max =
                state.data.iter().fold(0f32, |m, &v| m.max(v)) as f64;
            let global_max = c.allreduce_f64(ReduceOp::Max, local_max);
            if r == 0 {
                println!("step {step:>3}: field max = {global_max:.4}");
            }

            // Periodic collective checkpoint (the paper's system at work).
            if (step + 1) % ckpt_every == 0 {
                let t = Instant::now();
                metrics.time("ckpt.write", || {
                    ck.write(&file, frames, &interior.data).unwrap()
                });
                let dt = t.elapsed();
                let global_bytes = ck.frame_bytes();
                if r == 0 {
                    println!(
                        "  checkpoint frame {frames}: {:.1} MB in {dt:?} ({:.1} MB/s aggregate)",
                        global_bytes as f64 / 1e6,
                        global_bytes as f64 / 1e6 / dt.as_secs_f64()
                    );
                }
                frames += 1;
            }
        }
        let sim_wall = sim_start.elapsed();

        // ---- Cross-decomposition validation ----------------------------
        // Rank r reads the block of rank (r+1)%n from the last frame and
        // checks it against that rank's PJRT checksum.
        c.barrier();
        let sums = c.allgather(
            &my_checksum.iter().flat_map(|v| v.to_le_bytes()).collect::<Vec<_>>(),
        );
        let peer = (r + 1) % n;
        let peer_grid = HaloGrid::new(peer, n, (BLOCK, BLOCK));
        let peer_ck = Checkpointer::new(peer_grid);
        let t = Instant::now();
        let peer_block = metrics
            .time("ckpt.read", || peer_ck.read(&file, frames.saturating_sub(1)))
            .unwrap();
        let read_dt = t.elapsed();
        let got = rt
            .exec_f32("checksum", &[TensorF32::new(peer_block, vec![BLOCK, BLOCK])])
            .unwrap();
        let want: Vec<f32> = sums[peer]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        assert_eq!(got[0].data, want, "rank {r}: peer {peer} checksum mismatch");
        c.barrier();
        if r == 0 {
            let frame_mb = ck.frame_bytes() as f64 / 1e6;
            println!(
                "cross-decomposition read-back validated on all ranks \
                 ({frame_mb:.1} MB frame read in {read_dt:?})"
            );
            println!(
                "simulated {steps} steps in {sim_wall:?} \
                 ({:.1} ms/step incl. checkpoints)",
                sim_wall.as_secs_f64() * 1e3 / steps as f64
            );
            println!("\nper-rank metrics (rank 0):\n{}", metrics.report());
            println!("PJRT dispatches: {:?}", rt.dispatch_counts());
        }
        file.close().unwrap();
    });

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    println!("weather_pipeline OK");
}
