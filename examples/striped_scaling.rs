//! Striped-storage scaling: the weather-pipeline checkpoint workload on
//! 1 vs 4 striped NFS servers.
//!
//! The I/O phase of `weather_pipeline` — every rank collectively writing
//! its block of the distributed field through a subarray file view
//! ([`Checkpointer`]) — is rerun here against [`StripedBackend`]s of
//! increasing stripe count. One modelled NFS server caps aggregate write
//! bandwidth at its ingest rate (the paper's Fig 4-4/4-5 plateau);
//! declustering the checkpoint file round-robin over N servers lifts the
//! cap N-fold, and the stripe-aligned two-phase file domains keep each
//! aggregator on its own server. No PJRT artifacts are needed: the
//! compute phase is replaced by synthetic field data, the I/O path is the
//! real thing.
//!
//! Run: `cargo run --release --example striped_scaling --
//!       [--ranks 4] [--frames 4] [--block 256] [--stripe-unit 256k]`
//!
//! [`Checkpointer`]: jpio::coordinator::Checkpointer
//! [`StripedBackend`]: jpio::storage::striped::StripedBackend

use std::sync::Arc;
use std::time::Instant;

use jpio::cli::Args;
use jpio::comm::{threads, Comm};
use jpio::coordinator::{Checkpointer, HaloGrid};
use jpio::io::{amode, File, Info};
use jpio::storage::nfs::NfsConfig;
use jpio::storage::striped::StripedBackend;
use jpio::storage::Backend;

/// One checkpoint campaign: `frames` collective frame writes + one
/// read-back validation, on `servers` striped NFS servers. Returns the
/// modelled aggregate write bandwidth in MB/s.
fn run_case(ranks: usize, frames: usize, block: usize, servers: usize, unit: u64) -> f64 {
    let path = format!("/tmp/jpio-striped-scaling-{}-{servers}.ckpt", std::process::id());
    let backend: Arc<dyn Backend> =
        Arc::new(StripedBackend::nfs(servers, unit, NfsConfig::rcms()));
    let frame_bytes = {
        // Global field size from any rank's grid.
        let ck = Checkpointer::new(HaloGrid::new(0, ranks, (block, block)));
        ck.frame_bytes()
    };
    let start = Instant::now();
    {
        let path = &path;
        let backend = &backend;
        threads::run(ranks, move |c| {
            let r = c.rank();
            let grid = HaloGrid::new(r, c.size(), (block, block));
            let ck = Checkpointer::new(grid);
            let file = File::open_with_backend(
                c,
                path,
                amode::RDWR | amode::CREATE,
                Info::null(),
                backend.clone(),
            )
            .unwrap();
            let field: Vec<f32> = (0..block * block).map(|i| (r * 7 + i) as f32).collect();
            for frame in 0..frames {
                ck.write(&file, frame, &field).unwrap();
            }
            file.sync().unwrap();
            c.barrier();
            // Read-back validation of the last frame.
            let back = ck.read(&file, frames - 1).unwrap();
            assert_eq!(back, field, "rank {r}: checkpoint corrupted");
            file.close().unwrap();
        });
    }
    let wall = start.elapsed();
    let total_bytes = frames * frame_bytes;
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    backend.delete(&path).unwrap();
    total_bytes as f64 / 1e6 / wall.as_secs_f64()
}

fn main() {
    let args = Args::from_env();
    let ranks = args.get_or("ranks", 4usize);
    let frames = args.get_or("frames", 4usize).max(1);
    let block = args.get_or("block", 256usize);
    let unit = args.get_size_or("stripe-unit", 256 << 10);

    println!(
        "striped_scaling: {ranks} ranks × {block}x{block} f32 blocks, {frames} frames, \
         stripe unit {unit} B, NFS servers (RCMS model)"
    );
    let mut base = 0.0;
    for servers in [1usize, 2, 4] {
        let mbs = run_case(ranks, frames, block, servers, unit);
        if servers == 1 {
            base = mbs;
        }
        println!(
            "  {servers} server(s): {mbs:8.1} MB/s modelled aggregate checkpoint bandwidth \
             ({:.2}x vs 1 server)",
            mbs / base
        );
    }
    println!("striped_scaling OK");
}
