//! Legacy-file conversion: a raw striped seismic trace file becomes a
//! self-describing dataset container (the Parallel netCDF direction) —
//! named dimensions, a record variable over the unlimited trace axis,
//! provenance attributes, and the portable big-endian `external32`
//! on-disk representation. The payload is checksum-verified across the
//! conversion: same values in, same values out, now with metadata.
//!
//! Three collective phases over 4 ranks:
//!
//! 1. **acquire** — write the legacy artifact: a flat binary file of
//!    gain-corrected traces, collective `write_at_all` per rank block.
//! 2. **convert** — define the container (`trace` unlimited × `sample`),
//!    then each round every rank appends one whole trace record with
//!    [`Dataset::append_records`].
//! 3. **verify** — reopen the container read-only, read every record
//!    back, compare against the legacy bytes and the value checksum.
//!
//! Run: `cargo run --release --example seismic_to_dataset`

use jpio::comm::datatype::Datatype;
use jpio::comm::{threads, Comm};
use jpio::dataset::header::UNLIMITED;
use jpio::dataset::Dataset;
use jpio::io::{amode, File, Info};

const TRACE_SAMPLES: usize = 512;
const N_TRACES: usize = 64;
const RANKS: usize = 4;

/// One gain-corrected trace, as the acquisition system wrote it.
fn make_trace(id: usize) -> Vec<i32> {
    (0..TRACE_SAMPLES).map(|i| (((id * 7 + i) % 100) as i32 - 50) * 3).collect()
}

/// Order-independent value checksum (FNV-1a over the sample stream).
fn checksum(values: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in values {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0100_0000_01b3);
        }
    }
    h
}

fn main() {
    let raw_path = format!("/tmp/jpio-seis2ds-{}.traces", std::process::id());
    let ds_path = format!("/tmp/jpio-seis2ds-{}.jpds", std::process::id());

    {
        let raw_path = &raw_path;
        let ds_path = &ds_path;
        threads::run(RANKS, move |c| {
            let r = c.rank();
            let per_rank = N_TRACES / RANKS;

            // ---- 1. acquire: the legacy flat trace file -----------------
            let f = File::open(c, raw_path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let mut block = Vec::with_capacity(per_rank * TRACE_SAMPLES);
            for t in 0..per_rank {
                block.extend(make_trace(r * per_rank + t));
            }
            let off = (r * per_rank * TRACE_SAMPLES * 4) as i64;
            f.write_at_all(off, block.as_slice(), 0, block.len(), &Datatype::INT).unwrap();
            f.close().unwrap();

            // ---- 2. convert: raw blocks → self-describing records -------
            let legacy = File::open(c, raw_path, amode::RDONLY, Info::null()).unwrap();
            let out = File::open(c, ds_path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let ds = Dataset::create(out).unwrap();
            let trace = ds.def_dim("trace", UNLIMITED).unwrap();
            let sample = ds.def_dim("sample", TRACE_SAMPLES as u64).unwrap();
            let v = ds.def_var("samples", &Datatype::INT, "external32", &[trace, sample]).unwrap();
            ds.put_att("source", raw_path.as_bytes()).unwrap();
            ds.put_att("title", b"seismic trace archive").unwrap();
            ds.put_var_att(v, "gain", b"x3").unwrap();
            ds.enddef().unwrap();
            // Each append round moves one whole trace per rank: rank r
            // carries legacy trace `round * RANKS + r` into the record
            // of the same index.
            for round in 0..N_TRACES / RANKS {
                let id = round * RANKS + r;
                let mut buf = vec![0i32; TRACE_SAMPLES];
                let at = (id * TRACE_SAMPLES * 4) as i64;
                legacy.read_at(at, buf.as_mut_slice(), 0, TRACE_SAMPLES, &Datatype::INT).unwrap();
                ds.append_records(v, buf.as_slice()).unwrap();
            }
            assert_eq!(ds.num_records(), N_TRACES as u64);
            let pc = ds.file().plan_cache_stats();
            ds.close().unwrap();
            legacy.close().unwrap();
            if r == 0 {
                println!("convert: {N_TRACES} traces appended (plan cache {pc:?})");
            }

            // ---- 3. verify: records match the legacy values -------------
            let f = File::open(c, ds_path, amode::RDONLY, Info::null()).unwrap();
            let ds = Dataset::open(f).unwrap();
            assert_eq!(ds.num_records(), N_TRACES as u64);
            assert_eq!(ds.get_att("title").unwrap(), b"seismic trace archive");
            let v = ds.find_var("samples").unwrap();
            assert_eq!(ds.var_shape(v).unwrap(), vec![N_TRACES as u64, TRACE_SAMPLES as u64]);
            let mut all = vec![0i32; N_TRACES * TRACE_SAMPLES];
            ds.get_vara(v, &[0, 0], &[N_TRACES, TRACE_SAMPLES], all.as_mut_slice()).unwrap();
            let mut want = Vec::with_capacity(N_TRACES * TRACE_SAMPLES);
            for id in 0..N_TRACES {
                want.extend(make_trace(id));
            }
            assert_eq!(all, want, "rank {r}: converted values drifted");
            assert_eq!(checksum(&all), checksum(&want));
            ds.close().unwrap();
            if r == 0 {
                println!("verify: checksum {:#018x} matches on every rank", checksum(&want));
            }
        });
    }

    // The container holds the same values in a different on-disk shape:
    // same checksum, different (big-endian, self-describing) bytes.
    let raw = std::fs::read(&raw_path).unwrap();
    let container = std::fs::read(&ds_path).unwrap();
    assert!(container.len() > raw.len(), "container must carry header metadata");
    for p in [&raw_path, &ds_path] {
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_file(format!("{p}.jpio-sfp"));
        let _ = std::fs::remove_file(format!("{p}.jpio-cache-lease"));
    }
    println!("seismic_to_dataset OK");
}
