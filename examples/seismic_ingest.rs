//! Streaming ingest: a bounded-queue pipeline feeding shared-pointer
//! writes — the "JavaSeis-style" workload of the paper's related work
//! (§2.4: seismic data stores were among the few real Java parallel I/O
//! users).
//!
//! Traces arrive from an acquisition source, flow through a transform
//! stage (gain + byte-order normalization to external32), and a writer
//! stage appends them to a shared trace file with `write_shared` — the
//! atomic shared-file-pointer reservation is what lets multiple writer
//! workers append concurrently without coordination. Backpressure from
//! the bounded queues throttles the source when storage lags.
//!
//! Afterwards the file is scanned and every trace is validated (count,
//! header id, payload checksum).
//!
//! Run: `cargo run --release --example seismic_ingest`

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use jpio::comm::datatype::Datatype;
use jpio::comm::threads;
use jpio::coordinator::Pipeline;
use jpio::io::{amode, File, Info};

const TRACE_SAMPLES: usize = 512;
const N_TRACES: usize = 400;

/// One seismic trace: header id + samples.
struct Trace {
    id: u32,
    samples: Vec<f32>,
}

fn make_trace(id: u32) -> Trace {
    let samples =
        (0..TRACE_SAMPLES).map(|i| ((id as usize * 7 + i) % 100) as f32 * 0.5).collect();
    Trace { id, samples }
}

/// Serialized trace record: [id (int)] [gain-corrected samples...].
fn encode(t: &Trace) -> Vec<i32> {
    let mut rec = Vec::with_capacity(1 + TRACE_SAMPLES);
    rec.push(t.id as i32);
    rec.extend(t.samples.iter().map(|&s| (s * 2.0) as i32)); // gain stage
    rec
}

fn main() {
    let path = format!("/tmp/jpio-seismic-{}.traces", std::process::id());
    let written = Arc::new(AtomicU64::new(0));

    let p = path.clone();
    let written_c = written.clone();
    // One communicator rank hosts the ingest pipeline (the pipeline's own
    // worker threads provide the concurrency; write_shared's sidecar
    // fetch-and-add keeps appends atomic across them).
    threads::run(1, move |c| {
        let f = File::open(c, &p, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let f = &f;
        let written = written_c.clone();
        let stats = Pipeline::new(8)
            .stage("acquire", 2, |id: u32| Some(id))
            .stage("validate", 2, |id| {
                // Drop corrupt shots (multiples of 97 are "bad").
                (id % 97 != 0).then_some(id)
            })
            .run(0..N_TRACES as u32, |id| {
                // Writer sink: transform + shared-pointer append.
                let rec = encode(&make_trace(id));
                f.write_shared(rec.as_slice(), 0, rec.len(), &Datatype::INT).unwrap();
                written.fetch_add(1, Ordering::Relaxed);
            });
        println!(
            "pipeline: {} acquired, {} dropped, {} delivered in {:?}",
            stats.stages[0].processed,
            stats.stages[1].dropped,
            stats.delivered,
            stats.elapsed
        );
        let rec_ints = 1 + TRACE_SAMPLES;
        let mb = (stats.delivered as usize * rec_ints * 4) as f64 / 1e6;
        println!(
            "ingest throughput: {:.1} MB/s ({:.1} traces/s)",
            mb / stats.elapsed.as_secs_f64(),
            stats.delivered as f64 / stats.elapsed.as_secs_f64()
        );

        // ---- Scan + validate the trace file ----------------------------
        let total = f.get_size().unwrap() as usize / 4;
        assert_eq!(total % rec_ints, 0, "torn trace record!");
        let n_written = total / rec_ints;
        assert_eq!(n_written as u64, written.load(Ordering::Relaxed));
        let mut all = vec![0i32; total];
        f.read_at(0, all.as_mut_slice(), 0, total, &Datatype::INT).unwrap();
        let mut seen = vec![false; N_TRACES];
        for rec in all.chunks_exact(rec_ints) {
            let id = rec[0] as u32;
            assert!(id % 97 != 0, "dropped trace {id} reached the file");
            assert!(!seen[id as usize], "trace {id} duplicated");
            seen[id as usize] = true;
            let want = encode(&make_trace(id));
            assert_eq!(rec, want.as_slice(), "trace {id} corrupted");
        }
        let expected = (0..N_TRACES as u32).filter(|i| i % 97 != 0).count();
        assert_eq!(n_written, expected);
        println!("scan: {n_written} traces intact, none torn, none duplicated");
        f.close().unwrap();
    });

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    println!("seismic_ingest OK");
}
