//! Measured compute/I-O overlap with the MPI-3.1 nonblocking collectives.
//!
//! Four ranks write their blocks of a shared file on a cost-modelled NFS
//! backend, then run a fixed compute spin. Blocking (`write_at_all`) pays
//! I/O and compute back-to-back; nonblocking (`iwrite_at_all`) registers
//! the operation and returns — the aggregator exchange *and* the storage
//! I/O run on the per-rank progress thread (DESIGN.md §2) while the
//! compute spins, so the wall-clock approaches `max(io, compute)` instead
//! of `io + compute`.
//!
//! Run: `cargo run --release --example overlap_compute_io`

use std::sync::Arc;
use std::time::{Duration, Instant};

use jpio::comm::datatype::Datatype;
use jpio::comm::{threads, Comm};
use jpio::io::{amode, File, Info};
use jpio::storage::nfs::NfsBackend;

const RANKS: usize = 4;
const PER_RANK: usize = 2 << 20; // bytes each rank writes
const COMPUTE_MS: u64 = 40; // per-rank compute spin

/// Fixed spin standing in for application compute between the call and
/// the wait.
fn compute() -> u64 {
    let end = Instant::now() + Duration::from_millis(COMPUTE_MS);
    let mut acc = 0u64;
    while Instant::now() < end {
        for i in 0..10_000u64 {
            acc = acc.wrapping_mul(31).wrapping_add(i);
        }
    }
    acc
}

/// One collective write + compute round across all ranks; returns the
/// wall-clock of the whole world.
fn round(path: &str, nonblocking: bool) -> Duration {
    let start = Instant::now();
    threads::run(RANKS, |c| {
        let backend: Arc<dyn jpio::storage::Backend> = Arc::new(NfsBackend::barq());
        let f = File::open_with_backend(c, path, amode::RDWR | amode::CREATE, Info::null(), backend)
            .unwrap();
        let r = c.rank();
        let mine = vec![r as u8; PER_RANK];
        let off = (r * PER_RANK) as i64;
        if nonblocking {
            let req =
                f.iwrite_at_all(off, mine.as_slice(), 0, PER_RANK, &Datatype::BYTE).unwrap();
            std::hint::black_box(compute()); // overlaps exchange + storage I/O
            let (st, ()) = req.wait().unwrap();
            assert_eq!(st.bytes, PER_RANK);
        } else {
            let st = f.write_at_all(off, mine.as_slice(), 0, PER_RANK, &Datatype::BYTE).unwrap();
            assert_eq!(st.bytes, PER_RANK);
            std::hint::black_box(compute());
        }
        f.close().unwrap();
    });
    start.elapsed()
}

fn main() {
    let path = format!("/tmp/jpio-overlap-{}.dat", std::process::id());
    println!(
        "compute/I-O overlap: {} ranks x {} MiB on modelled NFS, {} ms compute each",
        RANKS,
        PER_RANK >> 20,
        COMPUTE_MS
    );

    // Warm-up: file creation, worker/progress-thread spawn.
    let _ = round(&path, true);

    let blocking = round(&path, false);
    let overlapped = round(&path, true);
    println!("  write_at_all  + compute (back-to-back): {blocking:>10.2?}");
    println!("  iwrite_at_all + compute (overlapped):   {overlapped:>10.2?}");
    let saved = blocking.saturating_sub(overlapped);
    let pct = 100.0 * saved.as_secs_f64() / blocking.as_secs_f64().max(1e-9);
    println!("  overlap hides {saved:.2?} of the blocking wall-clock ({pct:.0}%)");
    if overlapped >= blocking {
        println!("  (no overlap measured on this machine/profile — try JPIO_BENCH_FULL sizes)");
    }

    // Read side: the whole collective read (request exchange, aggregator
    // sieve, reply exchange, scatter) also runs off-caller.
    let start = Instant::now();
    threads::run(RANKS, |c| {
        let backend: Arc<dyn jpio::storage::Backend> = Arc::new(NfsBackend::barq());
        let f = File::open_with_backend(c, &path, amode::RDONLY, Info::null(), backend).unwrap();
        let r = c.rank();
        let req = f
            .iread_at_all((r * PER_RANK) as i64, vec![0u8; PER_RANK], 0, PER_RANK, &Datatype::BYTE)
            .unwrap();
        std::hint::black_box(compute());
        let (st, back) = req.wait().unwrap();
        assert_eq!(st.bytes, PER_RANK);
        assert!(back.iter().all(|&b| b == r as u8), "rank {r} read someone else's block");
        f.close().unwrap();
    });
    println!("  iread_at_all  + compute (overlapped):   {:>10.2?}  (data verified)", start.elapsed());

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    println!("overlap_compute_io OK");
}
