//! Double buffering with split collective I/O — the paper's §7.2.9.1
//! example, executed for real and *measured*.
//!
//! Two buffers alternate: while buffer A's collective write runs on the
//! I/O engine (`write_all_begin`), the ranks compute the next results
//! into buffer B; `write_all_end` then reaps the overlap. The example
//! reports the wall-clock of the overlapped pipeline against the naive
//! compute-then-write sequence on the same workload.
//!
//! Run: `cargo run --release --example double_buffering`

use std::time::{Duration, Instant};

use jpio::comm::datatype::Datatype;
use jpio::comm::{threads, Comm, ReduceOp};
use jpio::io::{amode, File, Info};

const COUNT: usize = 1 << 20; // floats per buffer per rank (4 MiB)
const ROUNDS: usize = 6;

/// The "computation" the write overlaps with: produce the next buffer.
/// Deliberately CPU-bound (the paper's doubleBuffer computeBuffer()) and
/// sized so one round of compute is comparable to one round of device
/// write — the regime where double buffering pays.
fn compute_buffer(round: usize, rank: usize, out: &mut [f32]) {
    let seed = (round * 31 + rank) as f32;
    for (i, v) in out.iter_mut().enumerate() {
        let mut x = seed + i as f32 * 1e-6;
        // A short fixed-point iteration the optimizer cannot discard.
        for _ in 0..6 {
            x = x * 0.99 + (x * 0.5).sin() * 0.01;
        }
        *v = x;
    }
}

/// The Barq local-disk profile (~94 MB/s device) so the write cost is
/// realistic — overlapping free writes gains nothing.
fn open_modeled<'c>(c: &'c dyn Comm, path: &str) -> File<'c> {
    let info = Info::from([("jpio_backend_profile", "barq")]);
    File::open(c, path, amode::RDWR | amode::CREATE, info).unwrap()
}

fn run_naive(c: &dyn Comm, path: &str) -> Duration {
    let f = open_modeled(c, path);
    f.set_view(0, &Datatype::FLOAT, &Datatype::FLOAT, "native", &Info::null()).unwrap();
    f.seek((c.rank() * ROUNDS * COUNT) as i64, jpio::io::seek::SET).unwrap();
    let mut buf = vec![0f32; COUNT];
    let start = Instant::now();
    for round in 0..ROUNDS {
        compute_buffer(round, c.rank(), &mut buf);
        f.write_all(buf.as_slice(), 0, COUNT, &Datatype::FLOAT).unwrap();
    }
    let dt = start.elapsed();
    f.close().unwrap();
    dt
}

fn run_double_buffered(c: &dyn Comm, path: &str) -> Duration {
    let f = open_modeled(c, path);
    f.set_view(0, &Datatype::FLOAT, &Datatype::FLOAT, "native", &Info::null()).unwrap();
    f.seek((c.rank() * ROUNDS * COUNT) as i64, jpio::io::seek::SET).unwrap();
    let mut write_buf = vec![0f32; COUNT];
    let mut compute_buf = vec![0f32; COUNT];
    let start = Instant::now();
    // Prolog: compute round 0, start writing it.
    compute_buffer(0, c.rank(), &mut write_buf);
    f.write_all_begin(write_buf.as_slice(), 0, COUNT, &Datatype::FLOAT).unwrap();
    for round in 1..ROUNDS {
        // Steady state: overlap compute of `round` with the pending write.
        compute_buffer(round, c.rank(), &mut compute_buf);
        f.write_all_end().unwrap();
        std::mem::swap(&mut write_buf, &mut compute_buf);
        f.write_all_begin(write_buf.as_slice(), 0, COUNT, &Datatype::FLOAT).unwrap();
    }
    // Epilog.
    f.write_all_end().unwrap();
    let dt = start.elapsed();
    f.close().unwrap();
    dt
}

fn main() {
    let ranks = 4;
    let p1 = format!("/tmp/jpio-dbuf-naive-{}.dat", std::process::id());
    let p2 = format!("/tmp/jpio-dbuf-split-{}.dat", std::process::id());

    let (p1c, p2c) = (p1.clone(), p2.clone());
    threads::run(ranks, move |c| {
        let naive = run_naive(c, &p1c);
        c.barrier();
        let overlapped = run_double_buffered(c, &p2c);
        // Both files must be identical (same data, different schedule).
        c.barrier();
        if c.rank() == 0 {
            let a = std::fs::read(&p1c).unwrap();
            let b = std::fs::read(&p2c).unwrap();
            assert_eq!(a, b, "double buffering changed the file contents!");
            let naive_s = c.allreduce_f64(ReduceOp::Max, naive.as_secs_f64());
            let over_s = c.allreduce_f64(ReduceOp::Max, overlapped.as_secs_f64());
            let mb = (ranks * ROUNDS * COUNT * 4) as f64 / 1e6;
            println!("workload: {mb:.0} MB total, {ROUNDS} rounds x {ranks} ranks");
            println!("naive    compute-then-write: {naive_s:>8.3}s");
            println!("split-collective overlapped: {over_s:>8.3}s");
            println!("overlap gain: {:.1}%", (1.0 - over_s / naive_s) * 100.0);
        } else {
            c.allreduce_f64(ReduceOp::Max, naive.as_secs_f64());
            c.allreduce_f64(ReduceOp::Max, overlapped.as_secs_f64());
        }
    });

    for p in [&p1, &p2] {
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_file(format!("{p}.jpio-sfp"));
    }
    println!("double_buffering OK");
}
