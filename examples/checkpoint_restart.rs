//! Checkpoint/restart: surviving a failure through the parallel file.
//!
//! Phase 1 runs the distributed producer for a few frames and then
//! "crashes" (drops everything). Phase 2 starts a *fresh* world — new
//! communicator, new file handles — locates the last complete frame, and
//! restarts the computation from it, proving the checkpoint file is a
//! complete, self-describing recovery point (the core operational promise
//! of a parallel I/O library).
//!
//! Also demonstrates `MODE_EXCL`, `preallocate`, and `get_size`.
//!
//! Run: `cargo run --release --example checkpoint_restart`

use jpio::comm::{threads, Comm};
use jpio::coordinator::{Checkpointer, HaloGrid};
use jpio::io::{amode, File, Info};

const BLOCK: (usize, usize) = (64, 64);

/// Deterministic state of `rank` at `step`: cell i = f(rank, step, i).
fn state_at(rank: usize, step: usize, cells: usize) -> Vec<f32> {
    (0..cells).map(|i| (rank * 1000 + step * 10) as f32 + (i % 7) as f32).collect()
}

fn main() {
    let ranks = 4;
    let path = format!("/tmp/jpio-restart-{}.ckpt", std::process::id());
    let frames_before_crash = 3;

    // ---- Phase 1: produce, checkpoint, crash ---------------------------
    let p = path.clone();
    threads::run(ranks, move |c| {
        let grid = HaloGrid::new(c.rank(), c.size(), BLOCK);
        let ck = Checkpointer::new(grid);
        let f = File::open(
            c,
            &p,
            amode::RDWR | amode::CREATE | amode::EXCL,
            Info::null(),
        )
        .unwrap();
        // Preallocate all frames up front (MPI_FILE_PREALLOCATE).
        f.preallocate((ck.frame_bytes() * 8) as i64).unwrap();
        for step in 0..frames_before_crash {
            let state = state_at(c.rank(), step, BLOCK.0 * BLOCK.1);
            ck.write(&f, step, &state).unwrap();
            f.sync().unwrap(); // durable frame
        }
        // Simulated crash: no clean close bookkeeping beyond this point.
        f.close().unwrap();
        if c.rank() == 0 {
            println!("phase 1: wrote {frames_before_crash} durable frames, then crashed");
        }
    });

    // ---- Phase 2: fresh world, recover, continue -----------------------
    let p = path.clone();
    threads::run(ranks, move |c| {
        let grid = HaloGrid::new(c.rank(), c.size(), BLOCK);
        let ck = Checkpointer::new(grid);
        let f = File::open(c, &p, amode::RDWR, Info::null()).unwrap();
        // Locate the last complete frame from the file size alone.
        let frames = (f.get_size().unwrap() as usize) / ck.frame_bytes();
        assert!(frames >= frames_before_crash, "lost durable frames!");
        let last = frames_before_crash - 1; // preallocation padded the size
        let recovered = ck.read(&f, last).unwrap();
        let expect = state_at(c.rank(), last, BLOCK.0 * BLOCK.1);
        assert_eq!(recovered, expect, "rank {} recovered wrong state", c.rank());
        if c.rank() == 0 {
            println!("phase 2: recovered frame {last} intact on all ranks");
        }
        // Continue the run from the recovered state.
        for step in last + 1..last + 3 {
            let state = state_at(c.rank(), step, BLOCK.0 * BLOCK.1);
            ck.write(&f, step, &state).unwrap();
        }
        c.barrier();
        let final_frame = ck.read(&f, last + 2).unwrap();
        assert_eq!(final_frame, state_at(c.rank(), last + 2, BLOCK.0 * BLOCK.1));
        if c.rank() == 0 {
            println!("phase 2: resumed and wrote frames {}..{}", last + 1, last + 2);
        }
        f.close().unwrap();
    });

    File::delete(&path, &Info::null()).unwrap();
    println!("checkpoint_restart OK");
}
