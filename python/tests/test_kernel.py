"""Kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes (and for byteswap, dtypes); every Pallas kernel
must agree with its `ref.py` oracle. Stencil/pack/unpack/byteswap are
copies/elementwise and must match exactly; checksum accumulates per tile
so it gets an allclose with tight tolerance.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import byteswap as byteswap_k
from compile.kernels import checksum as checksum_k
from compile.kernels import pack as pack_k
from compile.kernels import ref
from compile.kernels import stencil as stencil_k

hypothesis.settings.register_profile(
    "jpio", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("jpio")


def rand(shape, dtype=jnp.float32, seed=0):
    k = jax.random.PRNGKey(seed)
    if dtype == jnp.float32:
        return jax.random.normal(k, shape, dtype)
    return jax.random.randint(k, shape, -(2**31), 2**31 - 1, jnp.int32).astype(dtype)


dims = st.integers(min_value=1, max_value=40)


@given(h=dims, w=dims, seed=st.integers(0, 2**16))
def test_stencil_matches_ref(h, w, seed):
    x = rand((h + 2, w + 2), seed=seed)
    got = stencil_k.stencil_step(x)
    want = ref.stencil_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(h=dims, w=dims, seed=st.integers(0, 2**16))
def test_pack_matches_ref(h, w, seed):
    x = rand((h + 2, w + 2), seed=seed)
    np.testing.assert_array_equal(
        np.asarray(pack_k.pack(x)), np.asarray(ref.pack_ref(x))
    )


@given(h=dims, w=dims, seed=st.integers(0, 2**16))
def test_unpack_matches_ref(h, w, seed):
    base = rand((h + 2, w + 2), seed=seed)
    block = rand((h, w), seed=seed + 1)
    np.testing.assert_array_equal(
        np.asarray(pack_k.unpack(base, block)),
        np.asarray(ref.unpack_ref(base, block)),
    )


@given(h=dims, w=dims, seed=st.integers(0, 2**16))
def test_pack_unpack_roundtrip(h, w, seed):
    base = rand((h + 2, w + 2), seed=seed)
    block = np.asarray(pack_k.pack(base))
    rebuilt = pack_k.unpack(base, jnp.asarray(block))
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(base))


@given(
    h=dims,
    w=dims,
    dtype=st.sampled_from([jnp.float32, jnp.int32, jnp.uint32]),
    seed=st.integers(0, 2**16),
)
def test_byteswap_matches_ref_and_involutes(h, w, dtype, seed):
    x = rand((h, w), dtype=dtype, seed=seed)
    got = byteswap_k.byteswap32(x)
    want = ref.byteswap32_ref(x)
    np.testing.assert_array_equal(
        np.asarray(got).view(np.uint32), np.asarray(want).view(np.uint32)
    )
    # Involution: swapping twice is the identity.
    twice = byteswap_k.byteswap32(got)
    np.testing.assert_array_equal(
        np.asarray(twice).view(np.uint32), np.asarray(x).view(np.uint32)
    )


def test_byteswap_known_value():
    x = jnp.array([[0x01020304]], dtype=jnp.uint32)
    got = np.asarray(byteswap_k.byteswap32(x))
    assert got[0, 0] == 0x04030201


@given(h=dims, w=dims, seed=st.integers(0, 2**16))
def test_checksum_matches_ref(h, w, seed):
    x = rand((h, w), seed=seed)
    got = np.asarray(checksum_k.checksum(x))
    want = np.asarray(ref.checksum_ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_checksum_is_deterministic_across_runs():
    x = rand((64, 48), seed=7)
    a = np.asarray(checksum_k.checksum(x))
    b = np.asarray(checksum_k.checksum(x))
    np.testing.assert_array_equal(a, b)


def test_checksum_detects_single_element_corruption():
    x = rand((32, 32), seed=3)
    a = np.asarray(checksum_k.checksum(x))
    y = np.asarray(x).copy()
    y[17, 5] += 1.0
    b = np.asarray(checksum_k.checksum(jnp.asarray(y)))
    assert not np.array_equal(a, b)


@pytest.mark.parametrize("tile_rows", [1, 2, 8, 32])
def test_stencil_tiling_invariance(tile_rows):
    x = rand((66, 34), seed=11)
    got = stencil_k.stencil_step(x, tile_rows=tile_rows)
    want = ref.stencil_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_stencil_physics_conserves_constant_field():
    # A constant field is a fixed point of the Jacobi average.
    x = jnp.full((34, 34), 3.5, jnp.float32)
    out = np.asarray(stencil_k.stencil_step(x))
    np.testing.assert_allclose(out, 3.5, rtol=1e-6)
