"""L2 model composition + AOT lowering tests.

Verifies the fused `tick` graphs agree with their unfused composition and
that every artifact lowers to parseable HLO text of the expected arity —
the compile-path contract the Rust runtime depends on.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def rand_halo(h=34, w=34, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (h, w), jnp.float32)


def test_tick_equals_stencil_plus_checksum():
    x = rand_halo()
    nxt, cs = model.tick(x)
    (nxt2,) = model.stencil(x)
    (cs2,) = model.checksum(nxt2)
    np.testing.assert_array_equal(np.asarray(nxt), np.asarray(nxt2))
    np.testing.assert_array_equal(np.asarray(cs), np.asarray(cs2))


def test_tick_external32_payload_is_swapped_next_state():
    x = rand_halo(seed=4)
    nxt, _cs, swapped = model.tick_external32(x)
    want = ref.byteswap32_ref(nxt)
    np.testing.assert_array_equal(
        np.asarray(swapped).view(np.uint32), np.asarray(want).view(np.uint32)
    )


def test_init_blocks_differ_by_rank():
    f = model.make_init((34, 34))
    (a,) = f(jnp.array([0, 0], jnp.int32))
    (b,) = f(jnp.array([1, 0], jnp.int32))
    assert not np.array_equal(np.asarray(a), np.asarray(b))
    assert np.asarray(a).max() > 1.0  # bump is present


def test_all_artifacts_lower_to_hlo_text():
    for name, fn, ex in aot.artifact_set(block=16):
        text = aot.to_hlo_text(fn, *ex)
        assert text.startswith("HloModule"), name
        assert "ROOT" in text, name


def test_stencil_convergence_over_steps():
    # Repeated diffusion with zero halo shrinks the field's max — a sanity
    # check on the physics the end-to-end example logs.
    f = model.make_init((34, 34))
    (state,) = f(jnp.array([0, 0], jnp.int32))
    m0 = float(jnp.max(state))
    for _ in range(5):
        interior = model.stencil(state)[0]
        state = state.at[1:-1, 1:-1].set(interior)
        # zero halo (absorbing boundary)
        state = state.at[0, :].set(0).at[-1, :].set(0)
        state = state.at[:, 0].set(0).at[:, -1].set(0)
    assert float(jnp.max(state)) < m0
