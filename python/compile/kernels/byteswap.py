"""L1 Pallas kernel: external32 byte-order conversion (§7.2.5.2).

File interoperability requires the canonical big-endian "external32"
representation; on little-endian hosts every 32-bit element must be
byte-reversed on the way to/from the file. Pallas has no bswap intrinsic,
so the kernel does it with shifts and masks on a uint32 bitcast —
elementwise VPU work, one VMEM tile per grid step.

The Rust io layer has its own scalar byteswap (`io::datarep`); this kernel
is the accelerated alternative used when conversion fuses with the
producer compute (see `model.tick_external32` and the `ablations` bench).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _byteswap_kernel(x_ref, o_ref, *, tile_rows, width):
    i = pl.program_id(0)
    base = i * tile_rows
    tile = pl.load(x_ref, (pl.dslice(base, tile_rows), pl.dslice(0, width)))
    u = tile.view(jnp.uint32)
    pl.store(
        o_ref,
        (pl.dslice(base, tile_rows), pl.dslice(0, width)),
        ref.bswap32_u32(u).view(tile.dtype),
    )


def byteswap32(x, *, tile_rows=32):
    """Byte-reverse each 32-bit element of a 2-D array."""
    h = x.shape[0]
    if h % tile_rows != 0:
        tile_rows = 1
    kernel = functools.partial(_byteswap_kernel, tile_rows=tile_rows, width=x.shape[1])
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(h // tile_rows,),
        interpret=True,
    )(x)
