"""L1 Pallas kernels: subarray pack/unpack — the derived-datatype hot path.

ROMIO's derived-datatype flattening (gathering a process's file-view
elements into one contiguous I/O buffer) is the per-byte hot loop of every
MPI-IO implementation; the paper's §2.3.1 found the Java equivalent
(byte-array staging) to be the make-or-break of Java I/O performance.
Here the gather/scatter runs as a Pallas kernel so checkpoint staging
composes with the producer compute inside a single XLA program.

``pack`` extracts the interior of a halo-extended ``(H+2, W+2)`` block
(i.e. the subarray ``starts=(1,1), subsizes=(H,W)``); ``unpack`` is its
inverse into an existing base block. Row tiles keep each HBM→VMEM copy
contiguous — the TPU analogue of the paper's bulk-transfer finding.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(x_ref, o_ref, *, tile_rows, width):
    i = pl.program_id(0)
    base = i * tile_rows
    tile = pl.load(x_ref, (pl.dslice(base + 1, tile_rows), pl.dslice(1, width)))
    pl.store(o_ref, (pl.dslice(base, tile_rows), pl.dslice(0, width)), tile)


def pack(x, *, tile_rows=32):
    """Interior ``(H, W)`` of a halo-extended ``(H+2, W+2)`` block."""
    h, w = x.shape[0] - 2, x.shape[1] - 2
    if h % tile_rows != 0:
        tile_rows = 1
    kernel = functools.partial(_pack_kernel, tile_rows=tile_rows, width=w)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), x.dtype),
        grid=(h // tile_rows,),
        interpret=True,
    )(x)


def _unpack_kernel(base_ref, block_ref, o_ref, *, height, width):
    # Copy the halo frame, then overwrite the interior with the block —
    # two whole-region VMEM writes, no per-row control flow.
    o_ref[...] = base_ref[...]
    o_ref[1 : height + 1, 1 : width + 1] = block_ref[...]


def unpack(base, block):
    """Place ``block`` (H, W) into the interior of ``base`` (H+2, W+2)."""
    hh, ww = base.shape
    h, w = block.shape
    assert (hh, ww) == (h + 2, w + 2), (base.shape, block.shape)
    kernel = functools.partial(_unpack_kernel, height=h, width=w)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(base.shape, base.dtype),
        interpret=True,
    )(base, block.astype(base.dtype))
