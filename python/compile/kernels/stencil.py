"""L1 Pallas kernel: 5-point Jacobi stencil step (the producer compute).

The scientific workload whose checkpoints the MPJ-IO layer moves — the
"climate modeling / turbulence" application class the paper's introduction
motivates. The kernel consumes a halo-extended ``(H+2, W+2)`` block and
produces the ``(H, W)`` interior of the next state.

TPU structure (DESIGN.md §Hardware-Adaptation): the grid iterates over row
tiles of ``tile_rows`` rows; each step loads a ``(tile_rows+2, W+2)`` slab
(the HBM→VMEM window, expressed with ``pl.load``/``pl.dslice``) and stores
a ``(tile_rows, W)`` output tile. For the default 256-column block and
f32, a slab is ``(34, 258)·4B ≈ 35 KiB`` — comfortably VMEM-resident with
double buffering. All arithmetic is elementwise VPU work.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls (see /opt/xla-example/README.md); real-TPU numbers are
estimated in DESIGN.md from the VMEM footprint.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stencil_kernel(x_ref, o_ref, *, tile_rows, width):
    """One grid step: rows [i*tile_rows, (i+1)*tile_rows) of the output."""
    i = pl.program_id(0)
    base = i * tile_rows
    # Slab of input needed for this output tile (tile_rows + 2 halo rows).
    slab = pl.load(x_ref, (pl.dslice(base, tile_rows + 2), pl.dslice(0, width + 2)))
    up = slab[:-2, 1:-1]
    down = slab[2:, 1:-1]
    left = slab[1:-1, :-2]
    right = slab[1:-1, 2:]
    pl.store(
        o_ref,
        (pl.dslice(base, tile_rows), pl.dslice(0, width)),
        0.25 * (up + down + left + right),
    )


def stencil_step(x, *, tile_rows=32):
    """Next-state interior of a halo-extended block ``x`` of ``(H+2, W+2)``."""
    h = x.shape[0] - 2
    w = x.shape[1] - 2
    if h % tile_rows != 0:
        tile_rows = 1  # degenerate tiling for odd test shapes
    kernel = functools.partial(_stencil_kernel, tile_rows=tile_rows, width=w)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        grid=(h // tile_rows,),
        interpret=True,
    )(x.astype(jnp.float32))
