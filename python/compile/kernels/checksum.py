"""L1 Pallas kernel: blocked checksum reduction for end-to-end validation.

The weather-pipeline example checksums every block it writes and verifies
the checksum after the collective read-back; both sides run this same
kernel on the same PJRT backend, so float summation order is identical and
equality is exact.

Structure: the grid iterates row tiles; each step accumulates the tile's
two partial sums (`sum(x)` and `sum(x*w)`) into a (2,)-element output —
the standard Pallas grid-accumulation idiom (output revisited by every
grid step, initialized at step 0).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _checksum_kernel(x_ref, w_ref, o_ref, *, tile_rows, width):
    i = pl.program_id(0)
    base = i * tile_rows
    idx = (pl.dslice(base, tile_rows), pl.dslice(0, width))
    x = pl.load(x_ref, idx)
    w = pl.load(w_ref, idx)
    s = jnp.stack([jnp.sum(x), jnp.sum(x * w)])

    @pl.when(i == 0)
    def _init():
        o_ref[:] = jnp.zeros_like(o_ref)

    o_ref[:] += s


def checksum(x, *, tile_rows=32):
    """Checksum pair ``[sum(x), sum(x*w)]`` of a 2-D float32 array."""
    h = x.shape[0]
    if h % tile_rows != 0:
        tile_rows = 1
    w = ref.checksum_weights(x.shape)
    kernel = functools.partial(_checksum_kernel, tile_rows=tile_rows, width=x.shape[1])
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((2,), jnp.float32),
        grid=(h // tile_rows,),
        interpret=True,
    )(x.astype(jnp.float32), w)
