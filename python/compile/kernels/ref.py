"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function here is the semantic definition; the Pallas kernels in the
sibling modules must match these bit-for-bit (same op order, same dtypes)
so pytest can assert exact equality under interpret=True.
"""

import jax.numpy as jnp


def stencil_ref(x):
    """5-point Jacobi step on a halo-extended block.

    ``x`` is ``(H+2, W+2)``; returns the ``(H, W)`` interior of the next
    state: ``0.25 * (up + down + left + right)``.
    """
    up = x[:-2, 1:-1]
    down = x[2:, 1:-1]
    left = x[1:-1, :-2]
    right = x[1:-1, 2:]
    return 0.25 * (up + down + left + right)


def pack_ref(x):
    """Subarray pack: extract the interior of a halo-extended block."""
    return x[1:-1, 1:-1]


def unpack_ref(base, block):
    """Subarray unpack: place ``block`` into the interior of ``base``."""
    return base.at[1:-1, 1:-1].set(block)


def bswap32_u32(u):
    """Byte-reverse each element of a uint32 array (shared helper)."""
    return (
        ((u & jnp.uint32(0x000000FF)) << 24)
        | ((u & jnp.uint32(0x0000FF00)) << 8)
        | ((u & jnp.uint32(0x00FF0000)) >> 8)
        | ((u & jnp.uint32(0xFF000000)) >> 24)
    )


def byteswap32_ref(x):
    """external32 conversion of a 32-bit array (int32/uint32/float32):
    reverse each element's bytes, bitcasting through uint32."""
    x = jnp.asarray(x)
    return bswap32_u32(x.view(jnp.uint32)).view(x.dtype)


def checksum_weights(shape):
    """Deterministic per-position checksum weights."""
    n = 1
    for d in shape:
        n *= d
    return (jnp.arange(n, dtype=jnp.float32) % 97.0 + 1.0).reshape(shape)


def checksum_ref(x):
    """Checksum pair over a float32 array: ``[sum(x), sum(x * w)]``.

    Write path and read path compute it with the same kernel on the same
    values, so equality is exact (no cross-implementation float drift).
    """
    x = jnp.asarray(x, jnp.float32)
    w = checksum_weights(x.shape)
    return jnp.stack([jnp.sum(x), jnp.sum(x * w)])
