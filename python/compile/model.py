"""L2: the JAX compute graph composed from the L1 Pallas kernels.

This is the "scientific application" side of the paper's system: a
heat-diffusion producer whose checkpoints the MPJ-IO layer writes and
reads. Each function here is AOT-lowered by `aot.py` to one HLO-text
artifact that the Rust runtime loads at startup; Python never runs on the
I/O path.

Artifacts (for a rank-local block of H×W with a 1-cell halo):

* ``stencil``  — one Jacobi step: (H+2, W+2) → (H, W)
* ``pack``     — interior extraction: (H+2, W+2) → (H, W)
* ``unpack``   — interior placement: (H+2, W+2), (H, W) → (H+2, W+2)
* ``byteswap`` — external32 conversion: (H, W) → (H, W)
* ``checksum`` — validation pair: (H, W) → (2,)
* ``tick``     — the fused fast path: stencil ∘ checksum in one program
* ``tick_external32`` — tick + byteswapped payload for external32 files
* ``init``     — deterministic initial condition for a rank's block
"""

import jax
import jax.numpy as jnp

from .kernels import byteswap as byteswap_k
from .kernels import checksum as checksum_k
from .kernels import pack as pack_k
from .kernels import stencil as stencil_k


def stencil(x):
    """One Jacobi step on a halo-extended block; returns the interior."""
    return (stencil_k.stencil_step(x),)


def pack(x):
    """Extract the interior (checkpoint payload) of a halo block."""
    return (pack_k.pack(x),)


def unpack(base, block):
    """Place a checkpoint payload back into a halo block."""
    return (pack_k.unpack(base, block),)


def byteswap(x):
    """external32 conversion of a float32 block (bitcast byte reverse)."""
    return (byteswap_k.byteswap32(x),)


def checksum(x):
    """Checksum pair of a block."""
    return (checksum_k.checksum(x),)


def tick(x):
    """The fused per-step fast path: advance the state one stencil step
    and checksum the new interior, in a single XLA program (one PJRT
    dispatch per simulation step on the Rust side)."""
    nxt = stencil_k.stencil_step(x)
    cs = checksum_k.checksum(nxt)
    return (nxt, cs)


def tick_external32(x):
    """``tick`` plus the external32-encoded payload, for checkpoints
    written through an external32 file view with kernel-side conversion."""
    nxt = stencil_k.stencil_step(x)
    cs = checksum_k.checksum(nxt)
    swapped = byteswap_k.byteswap32(nxt)
    return (nxt, cs, swapped)


def init(rank_xy, shape):
    """Deterministic initial condition for a rank's halo block.

    ``rank_xy`` is a (2,) int32 array (grid coordinates); the pattern is a
    smooth bump whose position depends on the rank so blocks differ.
    """
    h, w = shape
    r = rank_xy.astype(jnp.float32)
    ys = jnp.arange(h, dtype=jnp.float32)[:, None]
    xs = jnp.arange(w, dtype=jnp.float32)[None, :]
    cy = (h / 4.0) * (1.0 + r[0])
    cx = (w / 4.0) * (1.0 + r[1])
    return (100.0 * jnp.exp(-((ys - cy) ** 2 + (xs - cx) ** 2) / (0.02 * h * w)),)


def make_init(shape):
    """Close ``init`` over a static shape for lowering."""

    def f(rank_xy):
        return init(rank_xy, shape)

    return f
