"""AOT lowering: JAX → HLO *text* artifacts for the Rust PJRT runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids, which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run as ``python -m compile.aot --out ../artifacts`` (the Makefile's
``artifacts`` target). Python runs once here and never on the I/O path.

Artifacts are emitted for the default example geometry (BLOCK×BLOCK
rank-local blocks, halo 1). ``--block`` overrides.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, *example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_set(block):
    """(name, fn, example_args) for every artifact at a block size."""
    h = w = block
    halo = (h + 2, w + 2)
    interior = (h, w)
    return [
        ("stencil", model.stencil, (spec(halo),)),
        ("pack", model.pack, (spec(halo),)),
        ("unpack", model.unpack, (spec(halo), spec(interior))),
        ("byteswap", model.byteswap, (spec(interior),)),
        ("checksum", model.checksum, (spec(interior),)),
        ("tick", model.tick, (spec(halo),)),
        ("tick_external32", model.tick_external32, (spec(halo),)),
        ("init", model.make_init(halo), (spec((2,), jnp.int32),)),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--block", type=int, default=256, help="rank-local block size")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"block": args.block, "artifacts": {}}
    for name, fn, ex in artifact_set(args.block):
        text = to_hlo_text(fn, *ex)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256_16": digest,
            "inputs": [list(map(int, a.shape)) for a in ex],
        }
        print(f"  {name:>16}: {len(text):>8} chars  {digest}")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
