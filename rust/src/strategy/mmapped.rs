//! Mapped-mode strategy — the `FileChannel.map(MappedByteBuffer)`
//! analogue (§3.2.4).
//!
//! "The memory mapping is done and a portion of memory is brought into
//! memory so we can create and edit large files. It gives illusion of file
//! existence in memory." On the local backend this is a real `mmap`;
//! on the NFS backend it is the demand-paged emulation whose per-page
//! costs produce the paper's Fig 4-4 mapped-mode collapse.

use super::{check_total, AccessStrategy};
use crate::io::errors::Result;
use crate::storage::StorageFile;

/// Access through a memory-mapped region spanning the runs.
pub struct MappedStrategy;

impl MappedStrategy {
    fn region_bounds(runs: &[(u64, usize)]) -> (u64, usize) {
        let start = runs.iter().map(|&(o, _)| o).min().unwrap_or(0);
        let end = runs.iter().map(|&(o, l)| o + l as u64).max().unwrap_or(start);
        (start, (end - start) as usize)
    }
}

impl AccessStrategy for MappedStrategy {
    fn name(&self) -> &'static str {
        "mapped"
    }

    fn read(
        &self,
        file: &dyn StorageFile,
        runs: &[(u64, usize)],
        buf: &mut [u8],
    ) -> Result<usize> {
        check_total(runs, buf.len())?;
        if runs.is_empty() {
            return Ok(0);
        }
        let (start, span) = Self::region_bounds(runs);
        // Clamp to EOF: mapping past end is not readable.
        let fsize = file.size()?;
        if start >= fsize {
            return Ok(0);
        }
        let span = span.min((fsize - start) as usize);
        if span == 0 {
            return Ok(0);
        }
        let mut region = file.map(start, span, false)?;
        let mut pos = 0;
        let mut total = 0;
        for &(off, len) in runs {
            let roff = (off - start) as usize;
            let avail = span.saturating_sub(roff).min(len);
            if avail > 0 {
                region.read(roff, &mut buf[pos..pos + avail])?;
            }
            pos += len;
            total += avail;
        }
        Ok(total)
    }

    fn write(&self, file: &dyn StorageFile, runs: &[(u64, usize)], buf: &[u8]) -> Result<usize> {
        check_total(runs, buf.len())?;
        if runs.is_empty() {
            return Ok(0);
        }
        let (start, span) = Self::region_bounds(runs);
        let mut region = file.map(start, span, true)?;
        let mut pos = 0;
        for &(off, len) in runs {
            let roff = (off - start) as usize;
            region.write(roff, &buf[pos..pos + len])?;
            pos += len;
        }
        region.flush()?;
        Ok(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::local::LocalBackend;
    use crate::storage::nfs::NfsBackend;
    use crate::storage::{Backend, OpenOptions};
    use crate::strategy::testutil::roundtrip;

    #[test]
    fn mapped_roundtrip_local() {
        roundtrip(&MappedStrategy);
    }

    #[test]
    fn mapped_roundtrip_nfs_emulation() {
        let b = NfsBackend::instant();
        let path = format!("/tmp/jpio-mapped-nfs-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.set_size(8192).unwrap();
        let runs = [(4000u64, 32usize), (100, 8)];
        let data: Vec<u8> = (0..40u8).collect();
        MappedStrategy.write(f.as_ref(), &runs, &data).unwrap();
        let mut back = vec![0u8; 40];
        MappedStrategy.read(f.as_ref(), &runs, &mut back).unwrap();
        assert_eq!(back, data);
        b.delete(&path).unwrap();
    }

    #[test]
    fn mapped_read_clamps_at_eof() {
        let b = LocalBackend::instant();
        let path = format!("/tmp/jpio-mapped-eof-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &[7u8; 100]).unwrap();
        let mut buf = [0u8; 64];
        // Run extends past EOF: read what exists.
        let got = MappedStrategy.read(f.as_ref(), &[(80, 64)], &mut buf).unwrap();
        assert_eq!(got, 20);
        assert_eq!(&buf[..20], &[7u8; 20]);
        // Entirely past EOF.
        assert_eq!(MappedStrategy.read(f.as_ref(), &[(500, 8)], &mut buf).unwrap(), 0);
        b.delete(&path).unwrap();
    }

    #[test]
    fn mapped_write_extends_file() {
        let b = LocalBackend::instant();
        let path = format!("/tmp/jpio-mapped-extend-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        MappedStrategy.write(f.as_ref(), &[(10000, 16)], &[3u8; 16]).unwrap();
        assert!(f.size().unwrap() >= 10016);
        let mut buf = [0u8; 16];
        f.read_at(10000, &mut buf).unwrap();
        assert_eq!(buf, [3u8; 16]);
        b.delete(&path).unwrap();
    }
}
