//! Bulk strategy — the `BulkRandomAccessFiles` analogue (§3.2.1).
//!
//! The Berkeley "Bulk File I/O Extensions to Java" class the paper cites
//! performs one native read/write per whole array. The Rust analogue is
//! simply one positioned syscall per contiguous run: no staging copy, no
//! per-element overhead.

use super::{check_total, AccessStrategy};
use crate::io::errors::Result;
use crate::storage::StorageFile;

/// One positioned transfer per run.
pub struct BulkStrategy;

impl AccessStrategy for BulkStrategy {
    fn name(&self) -> &'static str {
        "bulk"
    }

    fn read(
        &self,
        file: &dyn StorageFile,
        runs: &[(u64, usize)],
        buf: &mut [u8],
    ) -> Result<usize> {
        check_total(runs, buf.len())?;
        file.read_runs(runs, buf)
    }

    fn write(&self, file: &dyn StorageFile, runs: &[(u64, usize)], buf: &[u8]) -> Result<usize> {
        check_total(runs, buf.len())?;
        file.write_runs(runs, buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategy::testutil::roundtrip;

    #[test]
    fn bulk_roundtrip() {
        roundtrip(&BulkStrategy);
    }

    #[test]
    fn bulk_rejects_short_buffer() {
        let b = crate::storage::local::LocalBackend::instant();
        let path = format!("/tmp/jpio-bulk-short-{}", std::process::id());
        let f = crate::storage::Backend::open(&b, &path, crate::storage::OpenOptions::rw_create())
            .unwrap();
        let mut small = [0u8; 2];
        assert!(BulkStrategy.read(f.as_ref(), &[(0, 10)], &mut small).is_err());
        crate::storage::Backend::delete(&b, &path).unwrap();
    }
}
