//! View-buffer strategy — the `FileChannel` + view buffer analogue
//! (§3.2.3), the approach the paper recommends and builds MPJ-IO on.
//!
//! "A view buffer is simply another buffer whose content is backed by the
//! byte buffer. We exploit this functionality ... to perform memory
//! operations on the view buffer and use the backing ByteBuffer object for
//! I/O operations on a file using the FileChannel object."
//!
//! The Rust analogue: a reusable typed staging buffer. Runs are packed
//! into (or unpacked from) the staging buffer in memory; the file sees
//! large aligned bulk transfers of up to `stage_size` bytes, and adjacent
//! runs are coalesced into single transfers. This is also the substrate
//! the data-sieving path of collective I/O reuses.

use super::{check_total, AccessStrategy};
use crate::io::errors::Result;
use crate::io::plan::batch_runs;
use crate::storage::StorageFile;

/// Typed staging buffer strategy.
pub struct ViewBufStrategy {
    /// Staging buffer capacity (one bulk transfer at most this large).
    pub stage_size: usize,
}

impl Default for ViewBufStrategy {
    fn default() -> Self {
        // 8 MiB: the figure-bench sweet spot; configurable via the
        // `cb_buffer_size`-style Info hint at the io layer.
        ViewBufStrategy { stage_size: 8 << 20 }
    }
}

impl ViewBufStrategy {
    /// Strategy with an explicit staging capacity.
    pub fn with_stage(stage_size: usize) -> Self {
        assert!(stage_size > 0);
        ViewBufStrategy { stage_size }
    }
}

impl AccessStrategy for ViewBufStrategy {
    fn name(&self) -> &'static str {
        "view_buffer"
    }

    fn read(
        &self,
        file: &dyn StorageFile,
        runs: &[(u64, usize)],
        buf: &mut [u8],
    ) -> Result<usize> {
        check_total(runs, buf.len())?;
        // Single contiguous run: the staging buffer adds nothing.
        if let [(off, len)] = runs {
            return file.read_at(*off, &mut buf[..*len]);
        }
        let mut stage = vec![0u8; self.stage_size.min(span(runs))];
        let mut pos = 0;
        let mut total = 0;
        for b in batch_runs(runs, self.stage_size) {
            let (first, count, start, span_len) = (b.first, b.count, b.start, b.span);
            if span_len <= stage.len() {
                // One bulk read covering the whole batch span, then
                // scatter from the staging buffer.
                let got = file.read_at(start, &mut stage[..span_len])?;
                for &(off, len) in &runs[first..first + count] {
                    let s = (off - start) as usize;
                    let avail = got.saturating_sub(s).min(len);
                    buf[pos..pos + avail].copy_from_slice(&stage[s..s + avail]);
                    pos += len;
                    total += avail;
                }
            } else {
                // A single run larger than the stage: stream it in
                // stage-size chunks.
                for &(off, len) in &runs[first..first + count] {
                    let mut done = 0;
                    while done < len {
                        let n = stage.len().min(len - done);
                        let got = file.read_at(off + done as u64, &mut stage[..n])?;
                        buf[pos..pos + got].copy_from_slice(&stage[..got]);
                        pos += n;
                        done += n;
                        total += got;
                        if got < n {
                            return Ok(total);
                        }
                    }
                }
            }
        }
        Ok(total)
    }

    fn write(&self, file: &dyn StorageFile, runs: &[(u64, usize)], buf: &[u8]) -> Result<usize> {
        check_total(runs, buf.len())?;
        if let [(off, len)] = runs {
            return file.write_at(*off, &buf[..*len]);
        }
        let mut stage = vec![0u8; self.stage_size.min(span(runs))];
        let mut pos = 0;
        for b in batch_runs(runs, self.stage_size) {
            let (first, count, start, span_len) = (b.first, b.count, b.start, b.span);
            let contiguous =
                count == 1 || runs[first..first + count].windows(2).all(|w| w[0].0 + w[0].1 as u64 == w[1].0);
            if span_len <= stage.len() && contiguous {
                // Gather the batch into the staging buffer, one bulk write.
                let mut s = 0;
                for &(_, len) in &runs[first..first + count] {
                    stage[s..s + len].copy_from_slice(&buf[pos..pos + len]);
                    s += len;
                    pos += len;
                }
                file.write_at(start, &stage[..span_len])?;
            } else {
                // Holes inside the span: writing the span would clobber
                // bytes between runs, so fall back to per-run writes
                // (write data sieving needs read-modify-write + locking —
                // that lives in the collective layer).
                for &(off, len) in &runs[first..first + count] {
                    let mut done = 0;
                    while done < len {
                        let n = stage.len().min(len - done);
                        stage[..n].copy_from_slice(&buf[pos..pos + n]);
                        file.write_at(off + done as u64, &stage[..n])?;
                        pos += n;
                        done += n;
                    }
                }
            }
        }
        Ok(pos)
    }
}

fn span(runs: &[(u64, usize)]) -> usize {
    let start = runs.iter().map(|&(o, _)| o).min();
    let end = runs.iter().map(|&(o, l)| o + l as u64).max();
    match (start, end) {
        (Some(s), Some(e)) => (e - s).max(1) as usize,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::local::LocalBackend;
    use crate::storage::{Backend, OpenOptions};
    use crate::strategy::testutil::roundtrip;
    use crate::testing::{forall, Config};

    #[test]
    fn viewbuf_roundtrip() {
        roundtrip(&ViewBufStrategy::default());
    }

    #[test]
    fn tiny_stage_still_correct() {
        roundtrip(&ViewBufStrategy::with_stage(8));
    }

    #[test]
    fn shared_batching_groups_within_stage() {
        // The grouping arithmetic lives in io::plan::batch_runs (shared
        // with the sieve strategy); this asserts the strategy's view.
        let runs = [(0u64, 10usize), (20, 10), (200, 10), (250, 10)];
        let b = batch_runs(&runs, 100);
        assert_eq!(b.len(), 2);
        assert_eq!((b[0].first, b[0].count, b[0].start, b[0].span), (0, 2, 0, 30));
        assert_eq!((b[1].first, b[1].count, b[1].start, b[1].span), (2, 2, 200, 60));
    }

    #[test]
    fn write_with_holes_does_not_clobber_gaps() {
        let backend = LocalBackend::instant();
        let path = format!("/tmp/jpio-viewbuf-holes-{}", std::process::id());
        let f = backend.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &[0xFFu8; 64]).unwrap();
        let s = ViewBufStrategy::with_stage(64);
        // Two runs with a hole [8,16).
        s.write(f.as_ref(), &[(0, 8), (16, 8)], &[0u8; 16]).unwrap();
        let mut all = [0u8; 24];
        f.read_at(0, &mut all).unwrap();
        assert_eq!(&all[0..8], &[0u8; 8]);
        assert_eq!(&all[8..16], &[0xFFu8; 8], "hole was clobbered");
        assert_eq!(&all[16..24], &[0u8; 8]);
        backend.delete(&path).unwrap();
    }

    #[test]
    fn prop_matches_bulk_strategy() {
        use crate::strategy::BulkStrategy;
        let backend = LocalBackend::instant();
        let path = format!("/tmp/jpio-viewbuf-prop-{}", std::process::id());
        let f = backend.open(&path, OpenOptions::rw_create()).unwrap();
        f.set_size(4096).unwrap();
        forall(
            Config::default().cases(60),
            |r| {
                // Sorted disjoint runs within 4 KiB.
                let n = r.range(1, 8);
                let mut runs = Vec::new();
                let mut cursor = 0u64;
                for _ in 0..n {
                    let gap = r.range(0, 64) as u64;
                    let len = r.range(1, 256);
                    if cursor + gap + len as u64 > 4096 {
                        break;
                    }
                    runs.push((cursor + gap, len));
                    cursor += gap + len as u64;
                }
                if runs.is_empty() {
                    runs.push((0, 16));
                }
                let total: usize = runs.iter().map(|&(_, l)| l).sum();
                let mut data = vec![0u8; total];
                r.fill_bytes(&mut data);
                (runs, data, r.range(8, 512))
            },
            |(runs, data, stage)| {
                let vb = ViewBufStrategy::with_stage(*stage);
                vb.write(f.as_ref(), runs, data).unwrap();
                let mut got_vb = vec![0u8; data.len()];
                vb.read(f.as_ref(), runs, &mut got_vb).unwrap();
                let mut got_bulk = vec![0u8; data.len()];
                BulkStrategy.read(f.as_ref(), runs, &mut got_bulk).unwrap();
                got_vb == *data && got_bulk == *data
            },
        );
        backend.delete(&path).unwrap();
    }
}
