//! Per-item strategy — the `RandomAccessFile`/DataStream analogue (§3.2.2).
//!
//! "RandomAccessFiles ... provides I/O methods for primitive data types
//! only one element at a time which is an overhead". The paper (and the
//! Dickens/Thakur study it builds on) found this the *worst* performer:
//! one syscall per 4-byte element. We reproduce it faithfully — one
//! positioned transfer per element — so the ablation bench can regenerate
//! the DataStream-vs-bulk gap of §2.3.1.

use super::{check_total, AccessStrategy};
use crate::io::errors::Result;
use crate::storage::StorageFile;

/// One positioned transfer per `item_size`-byte element.
pub struct PerItemStrategy {
    /// Element size in bytes (4 = the paper's `writeInt` case).
    pub item_size: usize,
}

impl Default for PerItemStrategy {
    fn default() -> Self {
        PerItemStrategy { item_size: 4 }
    }
}

impl AccessStrategy for PerItemStrategy {
    fn name(&self) -> &'static str {
        "per_item"
    }

    fn read(
        &self,
        file: &dyn StorageFile,
        runs: &[(u64, usize)],
        buf: &mut [u8],
    ) -> Result<usize> {
        check_total(runs, buf.len())?;
        let mut pos = 0;
        let mut total = 0;
        for &(off, len) in runs {
            let mut done = 0;
            while done < len {
                let n = self.item_size.min(len - done);
                let got = file.read_at(off + done as u64, &mut buf[pos..pos + n])?;
                pos += n;
                done += n;
                total += got;
                if got < n {
                    return Ok(total); // EOF
                }
            }
        }
        Ok(total)
    }

    fn write(&self, file: &dyn StorageFile, runs: &[(u64, usize)], buf: &[u8]) -> Result<usize> {
        check_total(runs, buf.len())?;
        let mut pos = 0;
        for &(off, len) in runs {
            let mut done = 0;
            while done < len {
                let n = self.item_size.min(len - done);
                file.write_at(off + done as u64, &buf[pos..pos + n])?;
                pos += n;
                done += n;
            }
        }
        Ok(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategy::testutil::roundtrip;

    #[test]
    fn per_item_roundtrip() {
        roundtrip(&PerItemStrategy::default());
    }

    #[test]
    fn per_item_respects_odd_run_lengths() {
        // 7-byte run with 4-byte items: 4 + 3.
        let b = crate::storage::local::LocalBackend::instant();
        let path = format!("/tmp/jpio-peritem-odd-{}", std::process::id());
        let f = crate::storage::Backend::open(&b, &path, crate::storage::OpenOptions::rw_create())
            .unwrap();
        let s = PerItemStrategy::default();
        s.write(f.as_ref(), &[(3, 7)], b"oddrun!").unwrap();
        let mut back = [0u8; 7];
        assert_eq!(s.read(f.as_ref(), &[(3, 7)], &mut back).unwrap(), 7);
        assert_eq!(&back, b"oddrun!");
        crate::storage::Backend::delete(&b, &path).unwrap();
    }
}
