//! Write data sieving — ROMIO's other signature optimization (§2.2.1:
//! "ROMIO is optimized for noncontiguous access patterns").
//!
//! A strided write of many small pieces touches the file once per piece.
//! Data sieving instead reads the whole span into a staging buffer,
//! patches the pieces in memory, and writes the span back with one large
//! transfer — a read-modify-write that must hold the file lock so
//! concurrent writers cannot be clobbered by the write-back of stale gap
//! bytes.
//!
//! Enabled per-file with the `romio_ds_write = enable` hint; the
//! `ablations` bench measures the crossover against per-run writes.

use super::{check_total, AccessStrategy, ViewBufStrategy};
use crate::io::errors::Result;
use crate::io::plan::batch_runs;
use crate::storage::StorageFile;

/// Read-modify-write sieving strategy for noncontiguous writes.
/// Reads delegate to [`ViewBufStrategy`] (read sieving is its batching).
pub struct SieveStrategy {
    /// Maximum span handled by one read-modify-write round.
    pub stage_size: usize,
}

impl Default for SieveStrategy {
    fn default() -> Self {
        SieveStrategy { stage_size: 8 << 20 }
    }
}

impl SieveStrategy {
    /// Strategy with an explicit staging capacity.
    pub fn with_stage(stage_size: usize) -> Self {
        assert!(stage_size > 0);
        SieveStrategy { stage_size }
    }
}

impl AccessStrategy for SieveStrategy {
    fn name(&self) -> &'static str {
        "data_sieving"
    }

    fn read(
        &self,
        file: &dyn StorageFile,
        runs: &[(u64, usize)],
        buf: &mut [u8],
    ) -> Result<usize> {
        ViewBufStrategy::with_stage(self.stage_size).read(file, runs, buf)
    }

    fn write(&self, file: &dyn StorageFile, runs: &[(u64, usize)], buf: &[u8]) -> Result<usize> {
        check_total(runs, buf.len())?;
        if runs.is_empty() {
            return Ok(0);
        }
        // Fast path: contiguous single run needs no sieve.
        if let [(off, len)] = runs {
            return file.write_at(*off, &buf[..*len]);
        }
        let mut pos = 0;
        let mut stage = Vec::new();
        // Span grouping shared with the view-buffer strategy
        // (io::plan::batch_runs) — one RMW round per in-stage span.
        for b in batch_runs(runs, self.stage_size) {
            let (i, j, start, span) = (b.first, b.first + b.count, b.start, b.span);
            if b.count == 1 {
                // Lone run: direct write.
                let (o, l) = runs[i];
                file.write_at(o, &buf[pos..pos + l])?;
                pos += l;
            } else {
                stage.clear();
                stage.resize(span, 0);
                // Read-modify-write under the file lock: the gap bytes we
                // read back must not race concurrent writers.
                let _guard = file.lock_exclusive()?;
                let got = file.read_at(start, &mut stage[..span])?;
                // Bytes past EOF read as zero — already the case since
                // the stage is zero-filled and read_at is short at EOF.
                let _ = got;
                for &(o, l) in &runs[i..j] {
                    let s = (o - start) as usize;
                    stage[s..s + l].copy_from_slice(&buf[pos..pos + l]);
                    pos += l;
                }
                file.write_at(start, &stage[..span])?;
            }
        }
        Ok(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::local::LocalBackend;
    use crate::storage::{Backend, OpenOptions};
    use crate::strategy::testutil::roundtrip;
    use crate::testing::{forall, Config};

    #[test]
    fn sieve_roundtrip() {
        roundtrip(&SieveStrategy::default());
    }

    #[test]
    fn sieve_preserves_gap_bytes() {
        let b = LocalBackend::instant();
        let path = format!("/tmp/jpio-sieve-gaps-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &[0xEEu8; 256]).unwrap();
        let s = SieveStrategy::with_stage(256);
        // Pieces at 10, 50, 90 — gaps must keep 0xEE.
        s.write(f.as_ref(), &[(10, 8), (50, 8), (90, 8)], &[1u8; 24]).unwrap();
        let mut all = [0u8; 128];
        f.read_at(0, &mut all).unwrap();
        for (i, &v) in all.iter().enumerate() {
            let inside = (10..18).contains(&i) || (50..58).contains(&i) || (90..98).contains(&i);
            assert_eq!(v, if inside { 1 } else { 0xEE }, "byte {i}");
        }
        b.delete(&path).unwrap();
    }

    #[test]
    fn sieve_extends_past_eof() {
        let b = LocalBackend::instant();
        let path = format!("/tmp/jpio-sieve-eof-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let s = SieveStrategy::default();
        // File is empty; sieved RMW of pieces beyond EOF must still land.
        s.write(f.as_ref(), &[(100, 4), (200, 4)], &[9u8; 8]).unwrap();
        let mut back = [0u8; 4];
        f.read_at(200, &mut back).unwrap();
        assert_eq!(back, [9u8; 4]);
        let mut gap = [0xFFu8; 4];
        f.read_at(150, &mut gap).unwrap();
        assert_eq!(gap, [0u8; 4], "gap must be zero-filled, not garbage");
        b.delete(&path).unwrap();
    }

    #[test]
    fn concurrent_sieved_writers_do_not_clobber() {
        // Two threads sieve-write interleaved pieces of the same span;
        // without the RMW lock one's write-back would erase the other's.
        let b = LocalBackend::instant();
        let path = format!("/tmp/jpio-sieve-race-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.set_size(4096).unwrap();
        std::thread::scope(|scope| {
            for t in 0..2u8 {
                let f = &f;
                scope.spawn(move || {
                    let s = SieveStrategy::with_stage(4096);
                    // Thread t owns pieces at offsets ≡ t (mod 2) * 64.
                    for round in 0..20 {
                        let runs: Vec<(u64, usize)> = (0..16)
                            .map(|k| ((k * 128 + t as u64 * 64), 64usize))
                            .collect();
                        let payload = vec![t + 1 + (round % 2) as u8 * 0; 16 * 64];
                        s.write(f.as_ref(), &runs, &payload).unwrap();
                    }
                });
            }
        });
        let mut all = vec![0u8; 2048];
        f.read_at(0, &mut all).unwrap();
        for (i, chunk) in all.chunks_exact(64).enumerate() {
            let want = (i % 2) as u8 + 1;
            assert!(chunk.iter().all(|&v| v == want), "piece {i} clobbered: {:?}", &chunk[..4]);
        }
        b.delete(&path).unwrap();
    }

    #[test]
    fn prop_sieve_equals_bulk_on_disjoint_runs() {
        use crate::strategy::BulkStrategy;
        let b = LocalBackend::instant();
        let path = format!("/tmp/jpio-sieve-prop-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.set_size(8192).unwrap();
        forall(
            Config::default().cases(40),
            |r| {
                let n = r.range(1, 10);
                let mut runs = Vec::new();
                let mut cursor = 0u64;
                for _ in 0..n {
                    let gap = r.range(0, 100) as u64;
                    let len = r.range(1, 300);
                    if cursor + gap + len as u64 > 8192 {
                        break;
                    }
                    runs.push((cursor + gap, len));
                    cursor += gap + len as u64;
                }
                if runs.is_empty() {
                    runs.push((0, 32));
                }
                let total = runs.iter().map(|&(_, l)| l).sum();
                let mut data = vec![0u8; total];
                r.fill_bytes(&mut data);
                (runs, data, r.range(64, 4096))
            },
            |(runs, data, stage)| {
                let s = SieveStrategy::with_stage(*stage);
                s.write(f.as_ref(), runs, data).unwrap();
                let mut got = vec![0u8; data.len()];
                BulkStrategy.read(f.as_ref(), runs, &mut got).unwrap();
                got == *data
            },
        );
        b.delete(&path).unwrap();
    }
}
