//! # jpio — an MPI-IO style parallel I/O library in Rust
//!
//! Reproduction of *"Design and Development of a Java Parallel I/O
//! Library"* (MPJ-IO). The crate provides:
//!
//! * [`comm`] — an MPI-like communicator substrate (the MPJ Express
//!   analogue): derived datatypes with holes, point-to-point messaging,
//!   collectives, thread-based (shared-memory) and process-based
//!   (distributed-memory) communicators, and a per-world progress
//!   engine ([`comm::progress`]) that drives nonblocking collective
//!   I/O entirely off the calling thread.
//! * [`io`] — the paper's contribution: the full MPJ-IO v0.1 API surface
//!   (all 52 MPI-2.2 chapter-13 data-access routines plus the MPI-3.1
//!   nonblocking collectives, file views, consistency semantics,
//!   collective two-phase I/O, split collectives, shared file pointers,
//!   nonblocking requests, Info hints, data representations, error
//!   classes), with every data-access routine a thin wrapper over the
//!   orthogonal [`io::AccessOp`] descriptor core (`io/op.rs`): one
//!   submit path compiles each access into an [`io::IoPlan`] and
//!   executes it on the `io::schedule::IoScheduler` (with plan caching
//!   for repeated same-shape accesses).
//! * [`dataset`] — a structured dataset layer over [`io::File`]
//!   (Parallel netCDF direction): self-describing containers of named
//!   N-D variables whose collective `put_vara`/`get_vara` subarray
//!   accesses compile onto `Datatype::subarray` file views and ride the
//!   unchanged `AccessOp` core.
//! * [`strategy`] — the four file-access strategies the paper evaluates
//!   (per-item, bulk, view-buffer, memory-mapped).
//! * [`storage`] — storage substrates: local disk, a simulated NFS
//!   server (the paper's NFS storage), a SAN model (RCMS cluster), and a
//!   striped parallel-file-system backend ([`storage::striped`]) that
//!   declusters a logical file round-robin over N child backends with
//!   stripe-aligned collective I/O (the ViPIOS/PVFS direction the paper's
//!   related work points at).
//! * [`runtime`] — PJRT artifact loading/execution for the AOT-compiled
//!   JAX/Pallas compute layer (build-time Python, never on the I/O path).
//! * [`coordinator`] — a data-pipeline orchestrator (stage graph,
//!   sharding, backpressure) used by the examples.
//! * [`bench`] — the measurement harness that regenerates every table
//!   and figure of the paper's evaluation chapter.
//!
//! A narrative walkthrough with runnable snippets lives in the
//! [`guide`] module (compiled from `docs/GUIDE.md`).
//!
//! ## Quickstart
//!
//! ```no_run
//! use jpio::comm::{self, Comm};
//! use jpio::io::{File, amode};
//! use jpio::comm::datatype::Datatype;
//!
//! // 4 "ranks" as threads (the paper's shared-memory configuration).
//! comm::threads::run(4, |comm| {
//!     let file = File::open(comm, "/tmp/jpio-quickstart.dat",
//!                           amode::RDWR | amode::CREATE,
//!                           Default::default()).unwrap();
//!     let rank = comm.rank() as i32;
//!     let buf = vec![rank; 1024];
//!     // Disjoint per-rank partitions of the shared file.
//!     file.write_at((rank as i64) * 4096, buf.as_slice(), 0, 1024, &Datatype::INT).unwrap();
//!     file.close().unwrap();
//! });
//! ```

#![warn(missing_docs)]

pub mod bench;
pub mod cli;
pub mod comm;
pub mod coordinator;
pub mod dataset;
pub mod io;
pub mod runtime;
pub mod storage;
pub mod strategy;
pub mod testing;

#[doc = include_str!("../../docs/GUIDE.md")]
///
/// ---
///
/// *(This page is compiled from `docs/GUIDE.md`; its code blocks run
/// under `cargo test --doc`, so the guide cannot drift from the API.)*
pub mod guide {}

/// Crate-wide result alias using the MPJ-IO error classes of §7.2.8.
pub type Result<T> = std::result::Result<T, io::errors::IoError>;
