//! Bounded-queue stage pipeline with backpressure.
//!
//! The streaming-orchestrator piece of the data-pipeline domain: a linear
//! graph of stages connected by bounded channels. A slow stage (e.g. the
//! MPJ-IO write stage of the seismic example) backpressures producers
//! instead of letting queues grow without bound.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One stage definition.
struct StageDef<T> {
    name: String,
    workers: usize,
    f: Arc<dyn Fn(T) -> Option<T> + Send + Sync>,
}

/// Per-stage runtime stats.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageStats {
    /// Stage name.
    pub name: String,
    /// Items that entered the stage.
    pub processed: u64,
    /// Items the stage dropped (`f` returned `None`).
    pub dropped: u64,
}

/// Pipeline run outcome.
#[derive(Debug)]
pub struct PipelineStats {
    /// Per-stage stats, in stage order.
    pub stages: Vec<StageStats>,
    /// Items that reached the sink.
    pub delivered: u64,
    /// Wall-clock of the run.
    pub elapsed: std::time::Duration,
}

/// A linear stage pipeline over items of type `T`.
pub struct Pipeline<T> {
    capacity: usize,
    stages: Vec<StageDef<T>>,
}

impl<T: Send + 'static> Pipeline<T> {
    /// New pipeline; `capacity` bounds every inter-stage queue (the
    /// backpressure depth).
    pub fn new(capacity: usize) -> Pipeline<T> {
        assert!(capacity > 0);
        Pipeline { capacity, stages: Vec::new() }
    }

    /// Append a stage of `workers` parallel workers applying `f`.
    /// Returning `None` drops the item (filtering).
    pub fn stage(
        mut self,
        name: impl Into<String>,
        workers: usize,
        f: impl Fn(T) -> Option<T> + Send + Sync + 'static,
    ) -> Self {
        assert!(workers > 0);
        self.stages.push(StageDef { name: name.into(), workers, f: Arc::new(f) });
        self
    }

    /// Drive `source` through all stages into `sink`; blocks until
    /// everything drains.
    pub fn run(
        self,
        source: impl Iterator<Item = T>,
        mut sink: impl FnMut(T),
    ) -> PipelineStats {
        let start = Instant::now();
        let n = self.stages.len();
        // Channels: source -> s0 -> s1 -> ... -> sink.
        let mut senders: Vec<SyncSender<T>> = Vec::with_capacity(n + 1);
        let mut receivers: Vec<Arc<Mutex<Receiver<T>>>> = Vec::with_capacity(n + 1);
        for _ in 0..=n {
            let (tx, rx) = sync_channel::<T>(self.capacity);
            senders.push(tx);
            receivers.push(Arc::new(Mutex::new(rx)));
        }
        let processed: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let dropped: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let delivered = AtomicU64::new(0);

        std::thread::scope(|scope| {
            // Stage workers.
            for (i, stage) in self.stages.iter().enumerate() {
                for _ in 0..stage.workers {
                    let rx = receivers[i].clone();
                    let tx = senders[i + 1].clone();
                    let f = stage.f.clone();
                    let processed = &processed[i];
                    let dropped = &dropped[i];
                    scope.spawn(move || loop {
                        let item = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match item {
                            Ok(item) => {
                                processed.fetch_add(1, Ordering::Relaxed);
                                match f(item) {
                                    Some(out) => {
                                        if tx.send(out).is_err() {
                                            break;
                                        }
                                    }
                                    None => {
                                        dropped.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                            }
                            Err(_) => break, // upstream closed and drained
                        }
                    });
                }
            }
            // Drop our copies of intermediate senders so stage exit
            // cascades once upstream closes.
            let first_tx = senders.remove(0);
            let sink_rx = receivers.last().unwrap().clone();
            drop(senders);

            // Sink drains on its own thread so the source can block on
            // backpressure without deadlocking the drain.
            let delivered = &delivered;
            let sink_handle = scope.spawn(move || {
                let mut out: Vec<T> = Vec::new();
                loop {
                    let item = {
                        let guard = sink_rx.lock().unwrap();
                        guard.recv()
                    };
                    match item {
                        Ok(v) => {
                            delivered.fetch_add(1, Ordering::Relaxed);
                            out.push(v);
                        }
                        Err(_) => break,
                    }
                }
                out
            });

            // Feed the source (blocking on backpressure).
            for item in source {
                if first_tx.send(item).is_err() {
                    break;
                }
            }
            drop(first_tx);
            for item in sink_handle.join().expect("sink thread") {
                sink(item);
            }
        });

        PipelineStats {
            stages: self
                .stages
                .iter()
                .enumerate()
                .map(|(i, s)| StageStats {
                    name: s.name.clone(),
                    processed: processed[i].load(Ordering::Relaxed),
                    dropped: dropped[i].load(Ordering::Relaxed),
                })
                .collect(),
            delivered: delivered.load(Ordering::Relaxed),
            elapsed: start.elapsed(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn items_flow_through_all_stages() {
        let p = Pipeline::new(4)
            .stage("double", 2, |x: i64| Some(x * 2))
            .stage("inc", 1, |x| Some(x + 1));
        let mut out = Vec::new();
        let stats = p.run(0..100, |v| out.push(v));
        out.sort_unstable();
        let want: Vec<i64> = (0..100).map(|x| x * 2 + 1).collect();
        assert_eq!(out, want);
        assert_eq!(stats.delivered, 100);
        assert_eq!(stats.stages[0].processed, 100);
        assert_eq!(stats.stages[1].processed, 100);
    }

    #[test]
    fn filtering_stage_drops() {
        let p = Pipeline::new(2).stage("evens", 3, |x: i64| (x % 2 == 0).then_some(x));
        let mut count = 0u64;
        let stats = p.run(0..50, |_| count += 1);
        assert_eq!(count, 25);
        assert_eq!(stats.stages[0].dropped, 25);
        assert_eq!(stats.delivered, 25);
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        use std::sync::atomic::{AtomicI64, Ordering};
        // Slow consumer stage; watermark tracks source-minus-consumed —
        // bounded queues keep it ≤ capacity*2 + workers.
        static IN_FLIGHT: AtomicI64 = AtomicI64::new(0);
        static MAX_SEEN: AtomicI64 = AtomicI64::new(0);
        let p = Pipeline::new(2).stage("slow", 1, |x: i64| {
            std::thread::sleep(std::time::Duration::from_micros(200));
            let v = IN_FLIGHT.fetch_sub(1, Ordering::SeqCst);
            let _ = v;
            Some(x)
        });
        let source = (0..200).map(|x| {
            let v = IN_FLIGHT.fetch_add(1, Ordering::SeqCst) + 1;
            MAX_SEEN.fetch_max(v, Ordering::SeqCst);
            x
        });
        let stats = p.run(source, |_| {});
        assert_eq!(stats.delivered, 200);
        // capacity 2 on both queues + 1 worker + sink slack.
        assert!(
            MAX_SEEN.load(Ordering::SeqCst) <= 8,
            "backpressure failed: {} items in flight",
            MAX_SEEN.load(Ordering::SeqCst)
        );
    }

    #[test]
    fn empty_source_terminates() {
        let p = Pipeline::new(1).stage("s", 1, Some::<u8>);
        let stats = p.run(std::iter::empty(), |_| {});
        assert_eq!(stats.delivered, 0);
    }
}
