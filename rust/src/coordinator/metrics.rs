//! Metrics registry: counters and timers, reported at the end of every
//! example/bench run.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A thread-safe counters + timers registry.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    timers: Mutex<BTreeMap<String, (Duration, u64)>>,
}

impl Metrics {
    /// New empty registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Add `n` to counter `name`.
    pub fn add(&self, name: &str, n: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += n;
    }

    /// Read a counter.
    pub fn get(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Time a closure under timer `name`.
    pub fn time<R>(&self, name: &str, f: impl FnOnce() -> R) -> R {
        let start = Instant::now();
        let r = f();
        self.record(name, start.elapsed());
        r
    }

    /// Record an externally-measured duration.
    pub fn record(&self, name: &str, d: Duration) {
        let mut t = self.timers.lock().unwrap();
        let e = t.entry(name.to_string()).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Total time of a timer.
    pub fn total(&self, name: &str) -> Duration {
        self.timers.lock().unwrap().get(name).map(|e| e.0).unwrap_or(Duration::ZERO)
    }

    /// Number of samples of a timer.
    pub fn samples(&self, name: &str) -> u64 {
        self.timers.lock().unwrap().get(name).map(|e| e.1).unwrap_or(0)
    }

    /// Render a report table.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.lock().unwrap();
        let timers = self.timers.lock().unwrap();
        if !counters.is_empty() {
            out.push_str("counters:\n");
            for (k, v) in counters.iter() {
                out.push_str(&format!("  {k:<40} {v}\n"));
            }
        }
        if !timers.is_empty() {
            out.push_str("timers:\n");
            for (k, (total, n)) in timers.iter() {
                let avg = if *n > 0 { *total / *n as u32 } else { Duration::ZERO };
                out.push_str(&format!(
                    "  {k:<40} total {:>10.3?}  n {n:>6}  avg {avg:>10.3?}\n",
                    total
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.add("writes", 3);
        m.add("writes", 4);
        assert_eq!(m.get("writes"), 7);
        assert_eq!(m.get("nonexistent"), 0);
    }

    #[test]
    fn timers_accumulate_and_count() {
        let m = Metrics::new();
        let out = m.time("op", || {
            std::thread::sleep(Duration::from_millis(2));
            42
        });
        assert_eq!(out, 42);
        m.record("op", Duration::from_millis(5));
        assert_eq!(m.samples("op"), 2);
        assert!(m.total("op") >= Duration::from_millis(7));
        let rep = m.report();
        assert!(rep.contains("op"));
    }
}
