//! Metrics registry, re-exported from its new home in the I/O
//! instrumentation subsystem ([`crate::io::stats`]). Kept as a shim so
//! `coordinator::Metrics` consumers (examples, benches) keep compiling.

pub use crate::io::stats::Metrics;
