//! Collective checkpointing through MPJ-IO subarray file views.
//!
//! Each rank owns one block of the global field ([`HaloGrid`]); the
//! checkpoint file stores the field in row-major global order. The file
//! view is the subarray filetype of the rank's block (§7.2.9.2 — the
//! appendix's "Subarray Filetype Constructor" example, used for real),
//! so a single collective write/read moves the whole distributed field.

use crate::comm::datatype::{ArrayOrder, Datatype};
use crate::comm::Status;
use crate::io::errors::{err_arg, Result};
use crate::io::{File, Info};

use super::grid::HaloGrid;

/// Checkpoint writer/reader for one decomposition.
#[derive(Clone, Debug)]
pub struct Checkpointer {
    grid: HaloGrid,
}

impl Checkpointer {
    /// Build for a rank's grid placement.
    pub fn new(grid: HaloGrid) -> Checkpointer {
        Checkpointer { grid }
    }

    /// The subarray filetype of this rank's block within the global field.
    pub fn filetype(&self) -> Result<Datatype> {
        let (gh, gw) = self.grid.global_shape();
        let (bh, bw) = self.grid.block;
        let (cy, cx) = self.grid.coords;
        Datatype::subarray(
            &[gh, gw],
            &[bh, bw],
            &[cy * bh, cx * bw],
            ArrayOrder::C,
            &Datatype::FLOAT,
        )
        .map_err(|e| err_arg(format!("checkpoint filetype: {e}")))
    }

    /// Bytes of one full checkpoint frame (the global field).
    pub fn frame_bytes(&self) -> usize {
        let (gh, gw) = self.grid.global_shape();
        gh * gw * 4
    }

    /// Install the checkpoint view on `file`, with the frame displacement
    /// for checkpoint number `frame`.
    pub fn set_view(&self, file: &File<'_>, frame: usize) -> Result<()> {
        let ft = self.filetype()?;
        file.set_view(
            (frame * self.frame_bytes()) as i64,
            &Datatype::FLOAT,
            &ft,
            "native",
            &Info::null(),
        )
    }

    /// Collectively write this rank's interior block as checkpoint frame
    /// `frame`. `interior` is row-major `block.0 × block.1`.
    pub fn write(&self, file: &File<'_>, frame: usize, interior: &[f32]) -> Result<Status> {
        let (bh, bw) = self.grid.block;
        if interior.len() != bh * bw {
            return Err(err_arg(format!(
                "checkpoint payload {} != block {}x{}",
                interior.len(),
                bh,
                bw
            )));
        }
        self.set_view(file, frame)?;
        file.write_at_all(0, interior, 0, interior.len(), &Datatype::FLOAT)
    }

    /// Collectively read checkpoint frame `frame` back into this rank's
    /// block layout.
    pub fn read(&self, file: &File<'_>, frame: usize) -> Result<Vec<f32>> {
        let (bh, bw) = self.grid.block;
        let n = bh * bw;
        let mut out = vec![0f32; n];
        self.set_view(file, frame)?;
        let st = file.read_at_all(0, out.as_mut_slice(), 0, n, &Datatype::FLOAT)?;
        if st.bytes != out.len() * 4 {
            return Err(crate::io::errors::err_io(format!(
                "short checkpoint read: {} of {} bytes",
                st.bytes,
                out.len() * 4
            )));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::threads;
    use crate::comm::Comm;
    use crate::io::{amode, File, Info};

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-ckpt-{}-{name}", std::process::id())
    }

    #[test]
    fn distributed_checkpoint_roundtrip() {
        let path = tmp("rt");
        threads::run(4, |c| {
            let grid = HaloGrid::new(c.rank(), c.size(), (8, 8));
            let ck = Checkpointer::new(grid);
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            // Each cell stores its global (row*1000 + col) id.
            let (cy, cx) = ck.grid.coords;
            let mine: Vec<f32> = (0..64)
                .map(|i| {
                    let gr = cy * 8 + i / 8;
                    let gc = cx * 8 + i % 8;
                    (gr * 1000 + gc) as f32
                })
                .collect();
            ck.write(&f, 0, &mine).unwrap();
            c.barrier();
            let back = ck.read(&f, 0).unwrap();
            assert_eq!(back, mine);
            f.close().unwrap();
        });
        // The raw file must be the global row-major field.
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(raw.len(), 16 * 16 * 4);
        let vals: Vec<f32> =
            raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
        for r in 0..16 {
            for cc in 0..16 {
                assert_eq!(vals[r * 16 + cc], (r * 1000 + cc) as f32, "cell ({r},{cc})");
            }
        }
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn multiple_frames_use_displacements() {
        let path = tmp("frames");
        threads::run(2, |c| {
            let grid = HaloGrid::new(c.rank(), c.size(), (4, 4));
            let ck = Checkpointer::new(grid);
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            for frame in 0..3 {
                let mine = vec![(frame * 10 + c.rank()) as f32; 16];
                ck.write(&f, frame, &mine).unwrap();
            }
            c.barrier();
            for frame in 0..3 {
                let back = ck.read(&f, frame).unwrap();
                assert!(back.iter().all(|&v| v == (frame * 10 + c.rank()) as f32));
            }
            f.close().unwrap();
        });
        let len = std::fs::metadata(&path).unwrap().len();
        assert_eq!(len, 3 * 4 * 8 * 4); // 3 frames of 4x8 f32
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn wrong_payload_size_is_arg_error() {
        let path = tmp("badsize");
        threads::run(1, |c| {
            let ck = Checkpointer::new(HaloGrid::new(0, 1, (4, 4)));
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let err = ck.write(&f, 0, &[0.0; 3]).unwrap_err();
            assert_eq!(err.class, crate::io::errors::ErrorClass::Arg);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }
}
