//! 2-D domain decomposition with halo exchange.
//!
//! A global `GH × GW` field is block-distributed over a `py × px` process
//! grid (the same block layout as [`crate::comm::datatype::Datatype::darray_block`],
//! so the checkpoint file view and the compute decomposition agree by
//! construction). Each rank holds its block plus a 1-cell halo; `exchange`
//! fills the halo from the four neighbours over the communicator.

use crate::comm::Comm;

/// Internal tags for the four halo directions.
const T_HALO: i32 = crate::comm::INTERNAL_TAG_BASE + 100;

/// A rank's place in the decomposition.
#[derive(Debug, Clone)]
pub struct HaloGrid {
    /// Process-grid shape (rows, cols).
    pub pgrid: (usize, usize),
    /// This rank's coordinates.
    pub coords: (usize, usize),
    /// Block shape (rows, cols), halo excluded.
    pub block: (usize, usize),
}

impl HaloGrid {
    /// Choose a near-square process grid for `n` ranks and build the
    /// layout for `rank`. `block` is the per-rank interior shape.
    pub fn new(rank: usize, n: usize, block: (usize, usize)) -> HaloGrid {
        let pgrid = Self::choose_pgrid(n);
        let coords = (rank / pgrid.1, rank % pgrid.1);
        HaloGrid { pgrid, coords, block }
    }

    /// Near-square factorization of `n` (rows ≤ cols).
    pub fn choose_pgrid(n: usize) -> (usize, usize) {
        let mut best = (1, n);
        let mut d = 1;
        while d * d <= n {
            if n % d == 0 {
                best = (d, n / d);
            }
            d += 1;
        }
        best
    }

    /// Global field shape.
    pub fn global_shape(&self) -> (usize, usize) {
        (self.block.0 * self.pgrid.0, self.block.1 * self.pgrid.1)
    }

    /// Rank of the neighbour at relative grid offset, if it exists.
    pub fn neighbor(&self, dy: i64, dx: i64) -> Option<usize> {
        let ny = self.coords.0 as i64 + dy;
        let nx = self.coords.1 as i64 + dx;
        if ny < 0 || nx < 0 || ny >= self.pgrid.0 as i64 || nx >= self.pgrid.1 as i64 {
            return None;
        }
        Some(ny as usize * self.pgrid.1 + nx as usize)
    }

    /// Exchange the 1-cell halo of `state` (a halo-extended row-major
    /// `(block.0+2) × (block.1+2)` f32 buffer) with the four neighbours.
    /// Boundary edges (no neighbour) are left untouched (the examples use
    /// them as fixed boundary conditions).
    pub fn exchange(&self, comm: &dyn Comm, state: &mut [f32]) {
        let (h, w) = self.block;
        let (hh, ww) = (h + 2, w + 2);
        assert_eq!(state.len(), hh * ww, "state must be halo-extended");
        let row = |state: &[f32], r: usize| -> Vec<u8> {
            let s = &state[r * ww + 1..r * ww + 1 + w];
            s.iter().flat_map(|v| v.to_le_bytes()).collect()
        };
        let col = |state: &[f32], c: usize| -> Vec<u8> {
            (1..=h).flat_map(|r| state[r * ww + c].to_le_bytes()).collect()
        };
        let put_row = |state: &mut [f32], r: usize, bytes: &[u8]| {
            for (i, chunk) in bytes.chunks_exact(4).enumerate() {
                state[r * ww + 1 + i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
        };
        let put_col = |state: &mut [f32], c: usize, bytes: &[u8]| {
            for (i, chunk) in bytes.chunks_exact(4).enumerate() {
                state[(1 + i) * ww + c] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
        };
        // Four directions; tag per direction. Send first (mailbox /
        // progress-engine transports buffer), then receive.
        let dirs: [(i64, i64, i32); 4] = [
            (-1, 0, T_HALO),     // up
            (1, 0, T_HALO + 1),  // down
            (0, -1, T_HALO + 2), // left
            (0, 1, T_HALO + 3),  // right
        ];
        for &(dy, dx, tag) in &dirs {
            if let Some(peer) = self.neighbor(dy, dx) {
                let payload = match (dy, dx) {
                    (-1, 0) => row(state, 1),     // my top interior row
                    (1, 0) => row(state, h),      // my bottom interior row
                    (0, -1) => col(state, 1),     // my left interior col
                    (0, 1) => col(state, w),      // my right interior col
                    _ => unreachable!(),
                };
                comm.send(peer, tag, &payload);
            }
        }
        for &(dy, dx, tag) in &dirs {
            // My halo on side (dy,dx) is filled by the peer's *opposite*
            // direction send, which used the opposite tag.
            if let Some(peer) = self.neighbor(dy, dx) {
                let opposite = match (dy, dx) {
                    (-1, 0) => T_HALO + 1, // peer sent "down"
                    (1, 0) => T_HALO,      // peer sent "up"
                    (0, -1) => T_HALO + 3, // peer sent "right"
                    (0, 1) => T_HALO + 2,  // peer sent "left"
                    _ => unreachable!(),
                };
                let _ = tag;
                let bytes = comm.recv(peer, opposite);
                match (dy, dx) {
                    (-1, 0) => put_row(state, 0, &bytes),
                    (1, 0) => put_row(state, h + 1, &bytes),
                    (0, -1) => put_col(state, 0, &bytes),
                    (0, 1) => put_col(state, w + 1, &bytes),
                    _ => unreachable!(),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::threads;

    #[test]
    fn pgrid_is_near_square_factorization() {
        assert_eq!(HaloGrid::choose_pgrid(1), (1, 1));
        assert_eq!(HaloGrid::choose_pgrid(4), (2, 2));
        assert_eq!(HaloGrid::choose_pgrid(6), (2, 3));
        assert_eq!(HaloGrid::choose_pgrid(7), (1, 7));
        assert_eq!(HaloGrid::choose_pgrid(24), (4, 6));
    }

    #[test]
    fn neighbors_respect_boundaries() {
        let g = HaloGrid::new(0, 4, (4, 4)); // 2x2 grid, corner rank
        assert_eq!(g.neighbor(-1, 0), None);
        assert_eq!(g.neighbor(0, -1), None);
        assert_eq!(g.neighbor(1, 0), Some(2));
        assert_eq!(g.neighbor(0, 1), Some(1));
    }

    #[test]
    fn halo_exchange_moves_edge_rows() {
        // 2x2 grid of 4x4 blocks; every cell holds its owner's rank.
        threads::run(4, |c| {
            let g = HaloGrid::new(c.rank(), 4, (4, 4));
            let mut state = vec![c.rank() as f32; 6 * 6];
            g.exchange(c, &mut state);
            // Check halos against the neighbour ranks.
            let at = |r: usize, cc: usize| state[r * 6 + cc];
            if let Some(p) = g.neighbor(-1, 0) {
                assert_eq!(at(0, 2), p as f32, "rank {} up halo", c.rank());
            }
            if let Some(p) = g.neighbor(1, 0) {
                assert_eq!(at(5, 2), p as f32);
            }
            if let Some(p) = g.neighbor(0, -1) {
                assert_eq!(at(2, 0), p as f32);
            }
            if let Some(p) = g.neighbor(0, 1) {
                assert_eq!(at(2, 5), p as f32);
            }
            // Interior untouched.
            assert_eq!(at(2, 2), c.rank() as f32);
        });
    }
}
