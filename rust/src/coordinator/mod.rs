//! Data-pipeline orchestrator — the L3 coordination layer the examples
//! drive.
//!
//! The paper's "performance hungry applications" are data-parallel
//! producers (climate/turbulence codes) whose state must flow to and from
//! a shared file. This module supplies the pieces a downstream user needs
//! to build such an application on jpio:
//!
//! * [`grid`] — N-rank domain decomposition over a 2-D process grid with
//!   halo exchange (pure `comm`, no storage);
//! * [`checkpoint`] — collective checkpoint write/restore through MPJ-IO
//!   subarray file views, with PJRT checksum validation;
//! * [`pipeline`] — a bounded-queue stage graph with backpressure for
//!   streaming ingest workloads (the seismic example);
//! * [`metrics`] — counters/timers every layer reports into.

pub mod checkpoint;
pub mod grid;
pub mod metrics;
pub mod pipeline;

pub use checkpoint::Checkpointer;
pub use grid::HaloGrid;
pub use metrics::Metrics;
pub use pipeline::Pipeline;
