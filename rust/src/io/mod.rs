//! MPJ-IO: the paper's Java parallel I/O API, in Rust.
//!
//! The module layout mirrors the MPJ-IO v0.1 specification (Appendix A of
//! the paper, itself laid out as MPI-2.2 chapter 13):
//!
//! | Spec section | Module |
//! |---|---|
//! | §7.2.2 file manipulation | [`file`] |
//! | §7.2.3 file views | [`view`] |
//! | §7.2.4 data access — the orthogonal descriptor core | [`op`] |
//! | §7.2.4.2 explicit offsets, §7.2.4.3 individual pointers | [`access`] |
//! | §7.2.4.4 shared file pointers | [`shared`] |
//! | §7.2.4.5 split collectives | [`split`] |
//! | `*_ALL` collective routines + two-phase optimization | [`collective`] |
//! | stripe-aligned file domains (striped storage) | [`collective`], [`crate::storage::striped`] |
//! | §7.2.5 file interoperability (datareps) | [`datarep`] |
//! | §7.2.6 consistency & semantics | [`file`] (atomicity/sync) |
//! | §7.2.7/8 error handling & classes | [`errors`] |
//! | Info hints | [`hints`] |
//! | unified access-plan compiler | [`plan`] |
//! | client-side page cache + write-behind | [`cache`] |
//! | plan execution (sync / engine / two-phase) + plan cache | [`schedule`] |
//! | nonblocking request engine | [`engine`] |
//! | Darshan-style instrumentation (counters, phase timers, traces) | [`stats`] |
//!
//! Every data-access routine — explicit-offset, individual-pointer,
//! shared-pointer, collective, ordered, and split/nonblocking — is a thin
//! wrapper constructing an [`op::AccessOp`] descriptor for its cell of
//! the (positioning × coordination × synchronism) matrix and delegating
//! to the core entry points [`File::submit_read`] / [`File::submit_write`]
//! / [`File::submit_read_owned`]; the core compiles one
//! [`plan::IoPlan`] and executes it on the [`schedule::IoScheduler`]. No
//! access family keeps a private pipeline.
//!
//! The paper's prototype implemented 19 of the 52 data-access routines;
//! this implementation covers the full matrix plus the four MPI-3.1
//! nonblocking collectives (`jpio routines` prints all 56, and the
//! transfer half of the table is *derived* from the op dimensions by
//! [`op::access_cells`] so it cannot drift from the implementation).

pub mod access;
pub mod cache;
pub mod collective;
pub mod datarep;
pub mod engine;
pub mod errors;
pub mod file;
pub mod hints;
pub mod op;
pub mod plan;
pub mod schedule;
pub mod shared;
pub mod split;
pub mod stats;
pub mod view;

pub use datarep::{register_datarep, DataRep};
pub use engine::Request;
pub use errors::{ErrorClass, IoError};
pub use file::{amode, seek, File};
pub use hints::Info;
pub use op::{
    access_cells, AccessCell, AccessOp, Coordination, Direction, Positioning, PositioningKind,
    SplitPhase, Submission, Synchronism,
};
pub use plan::IoPlan;
pub use stats::{PhaseStat, PlanCacheStats, ProgressStats, Reduced, StatsReport, TraceEvent};
pub use view::FileView;

use crate::comm::datatype::Datatype;

/// `MPI_FILE_GET_TYPE_EXTENT` (§7.2.5.1): the extent of a datatype in the
/// file's current data representation. For `native` and `external32` the
/// extents coincide with memory extents for all supported primitives.
pub fn get_type_extent(_file: &File<'_>, datatype: &Datatype) -> i64 {
    datatype.extent()
}

/// The 22 file-manipulation and query routines of the matrix — the
/// non-transfer half, which has no op dimensions to derive from.
const MANIPULATION_ROUTINES: [(&str, &str); 22] = [
    ("MPI_FILE_OPEN", "File::open"),
    ("MPI_FILE_CLOSE", "File::close"),
    ("MPI_FILE_DELETE", "File::delete"),
    ("MPI_FILE_SET_SIZE", "File::set_size"),
    ("MPI_FILE_PREALLOCATE", "File::preallocate"),
    ("MPI_FILE_GET_SIZE", "File::get_size"),
    ("MPI_FILE_GET_GROUP", "File::get_group"),
    ("MPI_FILE_GET_AMODE", "File::get_amode"),
    ("MPI_FILE_SET_INFO", "File::set_info"),
    ("MPI_FILE_GET_INFO", "File::get_info"),
    ("MPI_FILE_SET_VIEW", "File::set_view"),
    ("MPI_FILE_GET_VIEW", "File::get_view"),
    ("MPI_FILE_SEEK", "File::seek"),
    ("MPI_FILE_GET_POSITION", "File::get_position"),
    ("MPI_FILE_GET_BYTE_OFFSET", "File::get_byte_offset"),
    ("MPI_FILE_SEEK_SHARED", "File::seek_shared"),
    ("MPI_FILE_GET_POSITION_SHARED", "File::get_position_shared"),
    ("MPI_FILE_SET_ATOMICITY", "File::set_atomicity"),
    ("MPI_FILE_GET_ATOMICITY", "File::get_atomicity"),
    ("MPI_FILE_SYNC", "File::sync"),
    ("MPI_FILE_GET_TYPE_EXTENT", "io::get_type_extent"),
    ("MPI_REGISTER_DATAREP", "io::register_datarep"),
];

/// The full 52-routine data-access matrix of Table 3-1 / 7-1 plus the
/// four MPI-3.1 nonblocking collectives, with the jpio binding of each
/// routine (all implemented). The 34 transfer routines are *derived*
/// from the [`op::AccessOp`] dimensions ([`op::access_cells`]), so this
/// table cannot drift from the implementation; the 22 manipulation
/// routines are the static remainder. Used by the `jpio routines` CLI
/// command (whose `--check` flag additionally dispatches every derived
/// cell through its public wrapper) and the docs.
pub fn routine_matrix() -> Vec<(String, String)> {
    let mut out: Vec<(String, String)> = MANIPULATION_ROUTINES
        .iter()
        .map(|&(mpi, method)| (mpi.to_string(), method.to_string()))
        .collect();
    out.extend(op::access_cells().into_iter().map(|c| (c.mpi_name(), c.method_name())));
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn routine_matrix_covers_the_spec() {
        let m = super::routine_matrix();
        // 52 MPI-2.2 routines + 4 MPI-3.1 nonblocking collectives.
        assert_eq!(m.len(), 56);
        // No duplicates on either column.
        let mut names: Vec<_> = m.iter().map(|(mpi, _)| mpi.clone()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 56);
        let mut methods: Vec<_> = m.iter().map(|(_, method)| method.clone()).collect();
        methods.sort_unstable();
        methods.dedup();
        assert_eq!(methods.len(), 56);
    }

    #[test]
    fn derived_half_matches_the_mpi_table() {
        // Spot-check that the derivation produces the exact routine names
        // of the MPI table (the property test in rust/tests/op_matrix.rs
        // dispatches each one).
        let m = super::routine_matrix();
        for (mpi, method) in [
            ("MPI_FILE_READ_AT", "File::read_at"),
            ("MPI_FILE_WRITE_AT_ALL", "File::write_at_all"),
            ("MPI_FILE_IREAD", "File::iread"),
            ("MPI_FILE_IWRITE_ALL", "File::iwrite_all"),
            ("MPI_FILE_READ_SHARED", "File::read_shared"),
            ("MPI_FILE_WRITE_ORDERED_BEGIN", "File::write_ordered_begin"),
            ("MPI_FILE_READ_ALL_END", "File::read_all_end"),
        ] {
            assert!(
                m.iter().any(|(a, b)| a == mpi && b == method),
                "matrix is missing {mpi} -> {method}"
            );
        }
    }
}
