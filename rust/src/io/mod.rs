//! MPJ-IO: the paper's Java parallel I/O API, in Rust.
//!
//! The module layout mirrors the MPJ-IO v0.1 specification (Appendix A of
//! the paper, itself laid out as MPI-2.2 chapter 13):
//!
//! | Spec section | Module |
//! |---|---|
//! | §7.2.2 file manipulation | [`file`] |
//! | §7.2.3 file views | [`view`] |
//! | §7.2.4.2 explicit offsets, §7.2.4.3 individual pointers | [`access`] |
//! | §7.2.4.4 shared file pointers | [`shared`] |
//! | §7.2.4.5 split collectives | [`split`] |
//! | `*_ALL` collective routines + two-phase optimization | [`collective`] |
//! | stripe-aligned file domains (striped storage) | [`collective`], [`crate::storage::striped`] |
//! | §7.2.5 file interoperability (datareps) | [`datarep`] |
//! | §7.2.6 consistency & semantics | [`file`] (atomicity/sync) |
//! | §7.2.7/8 error handling & classes | [`errors`] |
//! | Info hints | [`hints`] |
//! | unified access-plan compiler | [`plan`] |
//! | plan execution (sync / engine / two-phase) | [`schedule`] |
//! | nonblocking request engine | [`engine`] |
//!
//! Every data-access family — explicit-offset, individual-pointer,
//! shared-pointer, collective, and split/nonblocking — compiles its
//! request into an [`plan::IoPlan`] and executes it on the
//! [`schedule::IoScheduler`]; no access path flattens view runs on its
//! own.
//!
//! The paper's prototype implemented 19 of the 52 data-access routines;
//! this implementation covers the full matrix plus the four MPI-3.1
//! nonblocking collectives (`jpio routines` prints all 56).

pub mod access;
pub mod collective;
pub mod datarep;
pub mod engine;
pub mod errors;
pub mod file;
pub mod hints;
pub mod plan;
pub mod schedule;
pub mod shared;
pub mod split;
pub mod view;

pub use datarep::{register_datarep, DataRep};
pub use engine::Request;
pub use errors::{ErrorClass, IoError};
pub use file::{amode, seek, File};
pub use hints::Info;
pub use plan::IoPlan;
pub use view::FileView;

use crate::comm::datatype::Datatype;

/// `MPI_FILE_GET_TYPE_EXTENT` (§7.2.5.1): the extent of a datatype in the
/// file's current data representation. For `native` and `external32` the
/// extents coincide with memory extents for all supported primitives.
pub fn get_type_extent(_file: &File<'_>, datatype: &Datatype) -> i64 {
    datatype.extent()
}

/// The full 52-routine data-access matrix of Table 3-1 / 7-1 plus the
/// four MPI-3.1 nonblocking collectives, with the implementation status
/// of each routine (all implemented). Used by the `jpio routines` CLI
/// command and the docs.
pub fn routine_matrix() -> Vec<(&'static str, &'static str)> {
    // (MPI routine, jpio method)
    vec![
        ("MPI_FILE_OPEN", "File::open"),
        ("MPI_FILE_CLOSE", "File::close"),
        ("MPI_FILE_DELETE", "File::delete"),
        ("MPI_FILE_SET_SIZE", "File::set_size"),
        ("MPI_FILE_PREALLOCATE", "File::preallocate"),
        ("MPI_FILE_GET_SIZE", "File::get_size"),
        ("MPI_FILE_GET_GROUP", "File::get_group"),
        ("MPI_FILE_GET_AMODE", "File::get_amode"),
        ("MPI_FILE_SET_INFO", "File::set_info"),
        ("MPI_FILE_GET_INFO", "File::get_info"),
        ("MPI_FILE_SET_VIEW", "File::set_view"),
        ("MPI_FILE_GET_VIEW", "File::get_view"),
        ("MPI_FILE_READ_AT", "File::read_at"),
        ("MPI_FILE_READ_AT_ALL", "File::read_at_all"),
        ("MPI_FILE_WRITE_AT", "File::write_at"),
        ("MPI_FILE_WRITE_AT_ALL", "File::write_at_all"),
        ("MPI_FILE_IREAD_AT", "File::iread_at"),
        ("MPI_FILE_IWRITE_AT", "File::iwrite_at"),
        ("MPI_FILE_READ", "File::read"),
        ("MPI_FILE_READ_ALL", "File::read_all"),
        ("MPI_FILE_WRITE", "File::write"),
        ("MPI_FILE_WRITE_ALL", "File::write_all"),
        ("MPI_FILE_IREAD", "File::iread"),
        ("MPI_FILE_IWRITE", "File::iwrite"),
        ("MPI_FILE_IREAD_AT_ALL", "File::iread_at_all"),
        ("MPI_FILE_IWRITE_AT_ALL", "File::iwrite_at_all"),
        ("MPI_FILE_IREAD_ALL", "File::iread_all"),
        ("MPI_FILE_IWRITE_ALL", "File::iwrite_all"),
        ("MPI_FILE_SEEK", "File::seek"),
        ("MPI_FILE_GET_POSITION", "File::get_position"),
        ("MPI_FILE_GET_BYTE_OFFSET", "File::get_byte_offset"),
        ("MPI_FILE_READ_SHARED", "File::read_shared"),
        ("MPI_FILE_WRITE_SHARED", "File::write_shared"),
        ("MPI_FILE_IREAD_SHARED", "File::iread_shared"),
        ("MPI_FILE_IWRITE_SHARED", "File::iwrite_shared"),
        ("MPI_FILE_READ_ORDERED", "File::read_ordered"),
        ("MPI_FILE_WRITE_ORDERED", "File::write_ordered"),
        ("MPI_FILE_SEEK_SHARED", "File::seek_shared"),
        ("MPI_FILE_GET_POSITION_SHARED", "File::get_position_shared"),
        ("MPI_FILE_READ_AT_ALL_BEGIN", "File::read_at_all_begin"),
        ("MPI_FILE_READ_AT_ALL_END", "File::read_at_all_end"),
        ("MPI_FILE_WRITE_AT_ALL_BEGIN", "File::write_at_all_begin"),
        ("MPI_FILE_WRITE_AT_ALL_END", "File::write_at_all_end"),
        ("MPI_FILE_READ_ALL_BEGIN", "File::read_all_begin"),
        ("MPI_FILE_READ_ALL_END", "File::read_all_end"),
        ("MPI_FILE_WRITE_ALL_BEGIN", "File::write_all_begin"),
        ("MPI_FILE_WRITE_ALL_END", "File::write_all_end"),
        ("MPI_FILE_READ_ORDERED_BEGIN", "File::read_ordered_begin"),
        ("MPI_FILE_READ_ORDERED_END", "File::read_ordered_end"),
        ("MPI_FILE_WRITE_ORDERED_BEGIN", "File::write_ordered_begin"),
        ("MPI_FILE_WRITE_ORDERED_END", "File::write_ordered_end"),
        ("MPI_FILE_SET_ATOMICITY", "File::set_atomicity"),
        ("MPI_FILE_GET_ATOMICITY", "File::get_atomicity"),
        ("MPI_FILE_SYNC", "File::sync"),
        ("MPI_FILE_GET_TYPE_EXTENT", "io::get_type_extent"),
        ("MPI_REGISTER_DATAREP", "io::register_datarep"),
    ]
}

#[cfg(test)]
mod tests {
    #[test]
    fn routine_matrix_covers_the_spec() {
        let m = super::routine_matrix();
        // 52 MPI-2.2 routines + 4 MPI-3.1 nonblocking collectives.
        assert_eq!(m.len(), 56);
        // No duplicates.
        let mut names: Vec<_> = m.iter().map(|(mpi, _)| *mpi).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 56);
    }
}
