//! Data representations (§7.2.5 — file interoperability).
//!
//! * `"native"` — bytes as in memory (no conversion);
//! * `"external32"` — the MPI canonical big-endian representation
//!   (§7.2.5.2): multi-byte primitives are byte-swapped on little-endian
//!   hosts so files interoperate across architectures;
//! * user-defined representations (§7.2.5.3) registered through
//!   [`register_datarep`], each supplying read/write conversion functions.
//!
//! ROMIO itself never implemented file interoperability ("File
//! interoperability is not yet implemented even in ROMIO" — §5); this
//! module is the paper's named future-work item, built.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use once_cell::sync::Lazy;

use crate::comm::datatype::Prim;
use crate::io::errors::{err_dup_datarep, err_unsupported_datarep, Result};

/// A conversion applied to one homogeneous element run in the packed
/// payload buffer. `prim` names the element type; the slice length is a
/// multiple of `prim.size()`.
pub type ConvertFn = dyn Fn(&mut [u8], Prim) + Send + Sync;

/// A resolved data representation.
#[derive(Clone)]
pub enum DataRep {
    /// No conversion.
    Native,
    /// Canonical big-endian.
    External32,
    /// User-registered conversion pair.
    User {
        /// Registered name.
        name: String,
        /// Applied after reading file bytes (file → memory).
        read: Arc<ConvertFn>,
        /// Applied before writing file bytes (memory → file).
        write: Arc<ConvertFn>,
    },
}

impl std::fmt::Debug for DataRep {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DataRep::Native => write!(f, "native"),
            DataRep::External32 => write!(f, "external32"),
            DataRep::User { name, .. } => write!(f, "user({name})"),
        }
    }
}

impl DataRep {
    /// The datarep string as passed to `setView`.
    pub fn name(&self) -> &str {
        match self {
            DataRep::Native => "native",
            DataRep::External32 => "external32",
            DataRep::User { name, .. } => name,
        }
    }

    /// Resolve a datarep string (§7.2.5.4 matching).
    pub fn resolve(name: &str) -> Result<DataRep> {
        match name {
            "native" => Ok(DataRep::Native),
            "external32" | "internal" => Ok(DataRep::External32),
            other => {
                let reg = REGISTRY.read().unwrap();
                reg.get(other).cloned().ok_or_else(|| {
                    err_unsupported_datarep(format!("unknown datarep {other:?}"))
                })
            }
        }
    }

    /// True if no byte transformation is needed.
    pub fn is_identity(&self) -> bool {
        matches!(self, DataRep::Native)
    }

    /// Convert a packed payload in place for *writing* (memory → file).
    /// `elems` describes the payload as (prim, count) runs in order.
    pub fn encode(&self, payload: &mut [u8], elems: &[(Prim, usize)]) {
        match self {
            DataRep::Native => {}
            DataRep::External32 => for_each_run(payload, elems, byteswap_run),
            DataRep::User { write, .. } => {
                for_each_run(payload, elems, |bytes, prim| write(bytes, prim))
            }
        }
    }

    /// Convert a packed payload in place after *reading* (file → memory).
    pub fn decode(&self, payload: &mut [u8], elems: &[(Prim, usize)]) {
        match self {
            DataRep::Native => {}
            DataRep::External32 => for_each_run(payload, elems, byteswap_run),
            DataRep::User { read, .. } => {
                for_each_run(payload, elems, |bytes, prim| read(bytes, prim))
            }
        }
    }
}

fn for_each_run(payload: &mut [u8], elems: &[(Prim, usize)], f: impl Fn(&mut [u8], Prim)) {
    let mut pos = 0;
    for &(prim, count) in elems {
        let len = prim.size() * count;
        if pos + len > payload.len() {
            // Short transfer (EOF): convert what exists, element-aligned.
            let avail = (payload.len() - pos) / prim.size() * prim.size();
            f(&mut payload[pos..pos + avail], prim);
            return;
        }
        f(&mut payload[pos..pos + len], prim);
        pos += len;
    }
}

/// Swap a run of `prim`-sized elements between host and big-endian. On a
/// big-endian host this would be the identity; the image is x86-64
/// (little-endian), so it always swaps for multi-byte prims.
pub fn byteswap_run(bytes: &mut [u8], prim: Prim) {
    let sz = prim.size();
    if sz == 1 || cfg!(target_endian = "big") {
        return;
    }
    for chunk in bytes.chunks_exact_mut(sz) {
        chunk.reverse();
    }
}

static REGISTRY: Lazy<RwLock<HashMap<String, DataRep>>> = Lazy::new(|| RwLock::new(HashMap::new()));

/// Register a user-defined data representation
/// (`MPI_REGISTER_DATAREP`, §7.2.5.3). `read` converts file→memory,
/// `write` memory→file; both receive one homogeneous element run at a
/// time. Fails with `MPI_ERR_DUP_DATAREP` if the name is taken (including
/// the predefined names).
pub fn register_datarep(
    name: &str,
    read: Arc<ConvertFn>,
    write: Arc<ConvertFn>,
) -> Result<()> {
    if name == "native" || name == "external32" || name == "internal" {
        return Err(err_dup_datarep(format!("{name:?} is predefined")));
    }
    let mut reg = REGISTRY.write().unwrap();
    if reg.contains_key(name) {
        return Err(err_dup_datarep(format!("{name:?} already registered")));
    }
    reg.insert(
        name.to_string(),
        DataRep::User { name: name.to_string(), read, write },
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_predefined() {
        assert!(DataRep::resolve("native").unwrap().is_identity());
        assert_eq!(DataRep::resolve("external32").unwrap().name(), "external32");
        assert!(DataRep::resolve("martian").is_err());
    }

    #[test]
    fn external32_swaps_and_roundtrips() {
        let vals: Vec<i32> = vec![0x0102_0304, -1, 7];
        let mut bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let rep = DataRep::External32;
        rep.encode(&mut bytes, &[(Prim::Int, 3)]);
        // First element must now be big-endian.
        assert_eq!(&bytes[..4], &[0x01, 0x02, 0x03, 0x04]);
        rep.decode(&mut bytes, &[(Prim::Int, 3)]);
        let back: Vec<i32> = bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(back, vals);
    }

    #[test]
    fn bytes_are_not_swapped() {
        let mut b = vec![1u8, 2, 3];
        DataRep::External32.encode(&mut b, &[(Prim::Byte, 3)]);
        assert_eq!(b, vec![1, 2, 3]);
    }

    #[test]
    fn heterogeneous_runs() {
        // int then double: each run swapped at its own width.
        let mut bytes = vec![0u8; 12];
        bytes[..4].copy_from_slice(&0x0A0B_0C0Di32.to_le_bytes());
        bytes[4..].copy_from_slice(&1.0f64.to_le_bytes());
        DataRep::External32.encode(&mut bytes, &[(Prim::Int, 1), (Prim::Double, 1)]);
        assert_eq!(&bytes[..4], &[0x0A, 0x0B, 0x0C, 0x0D]);
        assert_eq!(&bytes[4..], &1.0f64.to_be_bytes());
    }

    #[test]
    fn short_payload_converts_whole_elements_only() {
        let mut bytes = vec![1u8, 2, 3, 4, 5, 6]; // 1.5 ints
        DataRep::External32.decode(&mut bytes, &[(Prim::Int, 2)]);
        assert_eq!(bytes, vec![4, 3, 2, 1, 5, 6]);
    }

    #[test]
    fn user_datarep_registration_and_conversion() {
        // A trivial "xor32" rep: xor every byte with 0x5A.
        let xor = Arc::new(|bytes: &mut [u8], _p: Prim| {
            for b in bytes {
                *b ^= 0x5A;
            }
        });
        register_datarep("xor32-test", xor.clone(), xor).unwrap();
        // Duplicate registration fails.
        let dup = Arc::new(|_: &mut [u8], _: Prim| {});
        assert!(register_datarep("xor32-test", dup.clone(), dup.clone()).is_err());
        assert!(register_datarep("native", dup.clone(), dup).is_err());

        let rep = DataRep::resolve("xor32-test").unwrap();
        let mut data = vec![0u8, 1, 2, 3];
        rep.encode(&mut data, &[(Prim::Int, 1)]);
        assert_eq!(data, vec![0x5A, 0x5B, 0x58, 0x59]);
        rep.decode(&mut data, &[(Prim::Int, 1)]);
        assert_eq!(data, vec![0, 1, 2, 3]);
    }
}
