//! Data access with explicit offsets and individual file pointers
//! (§7.2.4.2 / §7.2.4.3), blocking and nonblocking.
//!
//! Every routine here is a thin wrapper: it names its cell of the
//! data-access matrix as an [`AccessOp`] descriptor and delegates to the
//! core entry points [`File::submit_read`] / [`File::submit_write`] /
//! [`File::submit_read_owned`] in [`crate::io::op`], which own argument
//! validation, pointer bookkeeping, payload pack/unpack, plan
//! compilation, and scheduler dispatch. The pointer-manipulation
//! routines (`seek`, `get_position`, `get_byte_offset`) also live here.

use crate::comm::datatype::{Datatype, IoBuf, IoBufMut, Offset};
use crate::comm::Status;
use crate::io::engine::Request;
use crate::io::errors::{err_arg, Result};
use crate::io::file::{seek, File};
use crate::io::op::{AccessOp, Coordination, Positioning, Synchronism};

impl File<'_> {
    // ------------------------------------------------------------------
    // §7.2.4.2 Explicit offsets — blocking, noncollective
    // ------------------------------------------------------------------

    /// `MPI_FILE_READ_AT`: blocking noncollective read at an explicit
    /// etype offset.
    pub fn read_at(
        &self,
        offset: Offset,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::read(
            Positioning::Explicit(offset),
            Coordination::Independent,
            Synchronism::Blocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_read(&op, buf)
    }

    /// `MPI_FILE_WRITE_AT`: blocking noncollective write at an explicit
    /// etype offset.
    pub fn write_at(
        &self,
        offset: Offset,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::write(
            Positioning::Explicit(offset),
            Coordination::Independent,
            Synchronism::Blocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.status()
    }

    // ------------------------------------------------------------------
    // §7.2.4.2 Explicit offsets — nonblocking
    // ------------------------------------------------------------------

    /// `MPI_FILE_IREAD_AT`: nonblocking read at an explicit offset. Takes
    /// ownership of the buffer; [`Request::wait`] returns it filled.
    pub fn iread_at<T>(
        &self,
        offset: Offset,
        buf: Vec<T>,
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Request<Vec<T>>>
    where
        T: Send + 'static,
        [T]: IoBufMut,
    {
        let op = AccessOp::read(
            Positioning::Explicit(offset),
            Coordination::Independent,
            Synchronism::Nonblocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_read_owned(&op, buf)
    }

    /// `MPI_FILE_IWRITE_AT`: nonblocking write at an explicit offset.
    /// The data is snapshotted; the buffer is returned immediately usable.
    pub fn iwrite_at(
        &self,
        offset: Offset,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Request<()>> {
        let op = AccessOp::write(
            Positioning::Explicit(offset),
            Coordination::Independent,
            Synchronism::Nonblocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.request()
    }

    // ------------------------------------------------------------------
    // §7.2.4.3 Individual file pointers
    // ------------------------------------------------------------------

    /// `MPI_FILE_READ`: blocking noncollective read at the individual
    /// file pointer; the pointer advances by the etypes actually read.
    pub fn read(
        &self,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::read(
            Positioning::Individual,
            Coordination::Independent,
            Synchronism::Blocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_read(&op, buf)
    }

    /// `MPI_FILE_WRITE`: blocking noncollective write at the individual
    /// file pointer.
    pub fn write(
        &self,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::write(
            Positioning::Individual,
            Coordination::Independent,
            Synchronism::Blocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.status()
    }

    /// `MPI_FILE_IREAD`: nonblocking read at the individual pointer. The
    /// pointer advances immediately by the full request size (MPI
    /// semantics: the pointer update is not deferred to completion).
    pub fn iread<T>(
        &self,
        buf: Vec<T>,
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Request<Vec<T>>>
    where
        T: Send + 'static,
        [T]: IoBufMut,
    {
        let op = AccessOp::read(
            Positioning::Individual,
            Coordination::Independent,
            Synchronism::Nonblocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_read_owned(&op, buf)
    }

    /// `MPI_FILE_IWRITE`: nonblocking write at the individual pointer.
    pub fn iwrite(
        &self,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Request<()>> {
        let op = AccessOp::write(
            Positioning::Individual,
            Coordination::Independent,
            Synchronism::Nonblocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.request()
    }

    /// `MPI_FILE_SEEK`: update the individual pointer (etype units).
    pub fn seek(&self, offset: Offset, whence: i32) -> Result<()> {
        self.check_open()?;
        let mut ptr = self.indiv_ptr.lock().unwrap();
        let new = match whence {
            seek::SET => offset,
            seek::CUR => *ptr + offset,
            seek::END => self.etypes_in_file()? + offset,
            w => return Err(err_arg(format!("seek: invalid whence {w}"))),
        };
        if new < 0 {
            return Err(err_arg(format!("seek: resulting offset {new} is negative")));
        }
        *ptr = new;
        Ok(())
    }

    /// `MPI_FILE_GET_POSITION`: the individual pointer, in etype units.
    pub fn get_position(&self) -> Result<Offset> {
        self.check_open()?;
        Ok(*self.indiv_ptr.lock().unwrap())
    }

    /// `MPI_FILE_GET_BYTE_OFFSET`: view-relative etype offset → absolute
    /// byte position.
    pub fn get_byte_offset(&self, offset: Offset) -> Result<Offset> {
        self.check_open()?;
        self.view_snapshot().byte_offset(offset)
    }

    /// Number of whole etypes of this view that currently fit in the file
    /// (the EOF position used by `SEEK_END`).
    pub(crate) fn etypes_in_file(&self) -> Result<i64> {
        let view = self.view_snapshot();
        let fsize = self.storage.size()? as i64;
        // Binary-search the largest etype offset whose byte offset is
        // within the file.
        let esz = view.etype_size() as i64;
        let (mut lo, mut hi) = (0i64, (fsize / esz) + 1);
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            // byte_offset(mid) is the position of the first byte of etype
            // #mid; etype mid-1 fits if its end is within the file.
            let pos = view.byte_offset(mid - 1).unwrap_or(i64::MAX);
            if pos + esz <= fsize {
                lo = mid;
            } else {
                hi = mid - 1;
            }
            if lo == hi {
                break;
            }
        }
        Ok(lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::threads;
    use crate::comm::Comm;
    use crate::io::errors::ErrorClass;
    use crate::io::file::amode;
    use crate::io::hints::Info;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-access-{}-{name}", std::process::id())
    }

    fn open1<'c>(c: &'c dyn crate::comm::Comm, path: &str) -> File<'c> {
        File::open(c, path, amode::RDWR | amode::CREATE, Info::null()).unwrap()
    }

    #[test]
    fn write_read_at_ints() {
        let path = tmp("ints");
        threads::run(1, |c| {
            let f = open1(c, &path);
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let data: Vec<i32> = (0..100).collect();
            let st = f.write_at(0, data.as_slice(), 0, 100, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, 400);
            assert_eq!(st.count(&Datatype::INT), Some(100));
            let mut back = vec![0i32; 100];
            let st = f.read_at(0, back.as_mut_slice(), 0, 100, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, 400);
            assert_eq!(back, data);
            // Offset is in etypes (ints), not bytes.
            let mut one = vec![0i32; 1];
            f.read_at(7, one.as_mut_slice(), 0, 1, &Datatype::INT).unwrap();
            assert_eq!(one[0], 7);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn buf_offset_is_element_offset() {
        let path = tmp("bufoff");
        threads::run(1, |c| {
            let f = open1(c, &path);
            let data: Vec<f64> = vec![-1.0, 1.5, 2.5, -1.0];
            f.write_at(0, data.as_slice(), 1, 2, &Datatype::DOUBLE).unwrap();
            let mut back = vec![0f64; 4];
            let st = f.read_at(0, back.as_mut_slice(), 2, 2, &Datatype::DOUBLE).unwrap();
            assert_eq!(st.bytes, 16);
            assert_eq!(&back[2..], &[1.5, 2.5]);
            assert_eq!(&back[..2], &[0.0, 0.0]);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn individual_pointer_advances_and_seeks() {
        let path = tmp("ptr");
        threads::run(1, |c| {
            let f = open1(c, &path);
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let a: Vec<i32> = (0..8).collect();
            f.write(a.as_slice(), 0, 8, &Datatype::INT).unwrap();
            assert_eq!(f.get_position().unwrap(), 8);
            f.seek(2, seek::SET).unwrap();
            let mut b = vec![0i32; 3];
            f.read(b.as_mut_slice(), 0, 3, &Datatype::INT).unwrap();
            assert_eq!(b, vec![2, 3, 4]);
            assert_eq!(f.get_position().unwrap(), 5);
            f.seek(-2, seek::CUR).unwrap();
            assert_eq!(f.get_position().unwrap(), 3);
            f.seek(0, seek::END).unwrap();
            assert_eq!(f.get_position().unwrap(), 8);
            assert!(f.seek(-100, seek::CUR).is_err());
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn get_byte_offset_through_strided_view() {
        let path = tmp("gbo");
        threads::run(1, |c| {
            let f = open1(c, &path);
            let ft = Datatype::vector(1, 2, 4, &Datatype::INT).unwrap();
            let ft = Datatype::resized(&ft, 0, 16).unwrap();
            f.set_view(100, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
            assert_eq!(f.get_byte_offset(0).unwrap(), 100);
            assert_eq!(f.get_byte_offset(1).unwrap(), 104);
            assert_eq!(f.get_byte_offset(2).unwrap(), 116); // next instance
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn short_read_at_eof_reports_partial_count() {
        let path = tmp("short");
        threads::run(1, |c| {
            let f = open1(c, &path);
            let a: Vec<i32> = vec![1, 2, 3];
            f.write_at(0, a.as_slice(), 0, 3, &Datatype::INT).unwrap();
            let mut b = vec![0i32; 10];
            let st = f.read_at(0, b.as_mut_slice(), 0, 10, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, 12);
            assert_eq!(st.count(&Datatype::INT), Some(3));
            assert_eq!(&b[..3], &[1, 2, 3]);
            assert_eq!(&b[3..], &[0; 7]);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn interleaved_views_partition_the_file() {
        let path = tmp("interleave");
        threads::run(4, |c| {
            let f = open1(c, &path);
            let n = c.size();
            let r = c.rank();
            // filetype: 1 int at position r of each n-int frame.
            let ft = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
            let ft = Datatype::resized(&ft, 0, (n * 4) as i64).unwrap();
            f.set_view((r * 4) as i64, &Datatype::INT, &ft, "native", &Info::null())
                .unwrap();
            let mine: Vec<i32> = (0..16).map(|i| (i * n + r) as i32).collect();
            f.write_at(0, mine.as_slice(), 0, 16, &Datatype::INT).unwrap();
            c.barrier();
            f.close().unwrap();
            // Every rank verifies the interleaving through a flat view.
            let f2 = File::open(c, &path, amode::RDONLY, Info::null()).unwrap();
            let mut all = vec![0i32; 16 * n];
            f2.read_at(0, all.as_mut_slice(), 0, 16 * n * 4, &Datatype::BYTE)
                .map(|_| ())
                .unwrap_err(); // datatype mismatch: BYTE vs i32 buffer
            f2.read_at(0, all.as_mut_slice(), 0, 16 * n, &Datatype::INT).unwrap();
            let want: Vec<i32> = (0..16 * n as i32).collect();
            assert_eq!(all, want);
            f2.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn nonblocking_roundtrip() {
        let path = tmp("nb");
        threads::run(2, |c| {
            let f = open1(c, &path);
            let data: Vec<i64> = (0..64).map(|i| i + c.rank() as i64 * 1000).collect();
            let req = f
                .iwrite_at((c.rank() * 64) as i64 * 8, data.as_slice(), 0, 64, &Datatype::LONG)
                .unwrap();
            let (st, ()) = req.wait().unwrap();
            assert_eq!(st.bytes, 512);
            c.barrier();
            let req = f
                .iread_at(0, vec![0i64; 64], 0, 64, &Datatype::LONG)
                .unwrap();
            let (st, buf) = req.wait().unwrap();
            assert_eq!(st.bytes, 512);
            assert_eq!(buf[5], 5);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn external32_view_roundtrips_and_is_big_endian_on_disk() {
        let path = tmp("ext32");
        threads::run(1, |c| {
            let f = open1(c, &path);
            f.set_view(0, &Datatype::INT, &Datatype::INT, "external32", &Info::null())
                .unwrap();
            let data: Vec<i32> = vec![0x0102_0304, 0x0A0B_0C0D];
            f.write_at(0, data.as_slice(), 0, 2, &Datatype::INT).unwrap();
            let mut back = vec![0i32; 2];
            f.read_at(0, back.as_mut_slice(), 0, 2, &Datatype::INT).unwrap();
            assert_eq!(back, data);
            f.close().unwrap();
        });
        // Raw file bytes are big-endian.
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(&raw[..4], &[0x01, 0x02, 0x03, 0x04]);
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn noncontiguous_memory_datatype_packs() {
        let path = tmp("memtype");
        threads::run(1, |c| {
            let f = open1(c, &path);
            // Memory: every other int of the buffer (vector blocklen 1
            // stride 2); file: contiguous.
            let mem = Datatype::vector(4, 1, 2, &Datatype::INT).unwrap();
            let data: Vec<i32> = (0..8).collect(); // take 0,2,4,6
            f.write_at(0, data.as_slice(), 0, 1, &mem).unwrap();
            let mut back = vec![0i32; 4];
            f.read_at(0, back.as_mut_slice(), 0, 4, &Datatype::INT).unwrap();
            assert_eq!(back, vec![0, 2, 4, 6]);
            // Read back through the same strided memory type.
            let mut strided = vec![-1i32; 8];
            f.read_at(0, strided.as_mut_slice(), 0, 1, &mem).unwrap();
            assert_eq!(strided, vec![0, -1, 2, -1, 4, -1, 6, -1]);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn wronly_rejects_reads_and_rdonly_rejects_writes() {
        let path = tmp("modes");
        std::fs::write(&path, vec![0u8; 64]).unwrap();
        threads::run(1, |c| {
            let f = File::open(c, &path, amode::WRONLY, Info::null()).unwrap();
            let mut b = vec![0u8; 4];
            assert_eq!(
                f.read_at(0, b.as_mut_slice(), 0, 4, &Datatype::BYTE).unwrap_err().class,
                ErrorClass::Amode
            );
            f.close().unwrap();
            let f = File::open(c, &path, amode::RDONLY, Info::null()).unwrap();
            assert_eq!(
                f.write_at(0, b.as_slice(), 0, 4, &Datatype::BYTE).unwrap_err().class,
                ErrorClass::ReadOnly
            );
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn buffer_too_small_is_arg_error() {
        let path = tmp("toosmall");
        threads::run(1, |c| {
            let f = open1(c, &path);
            let d = vec![1i32; 4];
            assert_eq!(
                f.write_at(0, d.as_slice(), 0, 8, &Datatype::INT).unwrap_err().class,
                ErrorClass::Arg
            );
            assert_eq!(
                f.write_at(0, d.as_slice(), 2, 3, &Datatype::INT).unwrap_err().class,
                ErrorClass::Arg
            );
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }
}
