//! Data access with shared file pointers (§7.2.4.4).
//!
//! One shared pointer exists per collectively-opened file. It lives in a
//! sidecar file (`<name>.jpio-sfp`) updated under an OS file lock, which
//! makes the fetch-and-add atomic across *threads and processes alike* —
//! the property the noncollective `readShared`/`writeShared` need
//! ("serialization ... is guaranteed, but the order is nondeterministic").
//!
//! The ordered collectives (`READ_ORDERED`/`WRITE_ORDERED`) instead give
//! each rank the prefix-sum offset of the ranks before it (rank order), a
//! deterministic single pass over the pointer.
//!
//! The data-access routines are thin wrappers over the [`AccessOp`] core
//! ([`crate::io::op`]): pointer reservation (sidecar fetch-and-add or the
//! ordered prefix-sum pass below) happens inside the core's
//! offset-resolution stage; this module owns only the sidecar mechanism
//! and the pointer-manipulation routines.

use std::os::unix::io::AsRawFd;

use crate::comm::datatype::{Datatype, IoBuf, IoBufMut, Offset};
use crate::comm::Status;
use crate::io::engine::Request;
use crate::io::errors::{err_arg, IoError, Result};
use crate::io::file::{seek, File};
use crate::io::op::{AccessOp, Coordination, Positioning, Synchronism};

impl File<'_> {
    /// Atomically fetch the shared pointer (etype units) and advance it by
    /// `delta` etypes. Cross-process safe via flock on the sidecar.
    pub(crate) fn sfp_fetch_add(&self, delta: i64) -> Result<i64> {
        let f = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(&self.sfp_path)
            .map_err(|e| IoError::from_os(e, "shared pointer sidecar"))?;
        let fd = f.as_raw_fd();
        if unsafe { libc::flock(fd, libc::LOCK_EX) } != 0 {
            return Err(crate::io::errors::err_io("flock shared pointer"));
        }
        let result = (|| -> Result<i64> {
            use std::os::unix::fs::FileExt;
            let mut buf = [0u8; 8];
            f.read_exact_at(&mut buf, 0)
                .map_err(|e| IoError::from_os(e, "shared pointer read"))?;
            let cur = i64::from_le_bytes(buf);
            f.write_all_at(&(cur + delta).to_le_bytes(), 0)
                .map_err(|e| IoError::from_os(e, "shared pointer write"))?;
            Ok(cur)
        })();
        unsafe { libc::flock(fd, libc::LOCK_UN) };
        result
    }

    /// Offsets for an ordered collective: returns this rank's prefix-sum
    /// offset (etypes) and advances the shared pointer by the global
    /// total (once).
    pub(crate) fn ordered_offsets(&self, my_etypes: i64) -> Result<i64> {
        // Base: rank 0 reads the pointer; everyone gets base + prefix.
        let mut base_bytes = if self.comm.rank() == 0 {
            self.read_sfp()?.to_le_bytes().to_vec()
        } else {
            vec![0u8; 8]
        };
        self.comm.bcast(0, &mut base_bytes);
        let base = i64::from_le_bytes(base_bytes[..8].try_into().unwrap());
        let prefix = self.comm.exscan_sum_i64(my_etypes);
        let total = self.comm.allreduce_i64(crate::comm::ReduceOp::Sum, my_etypes);
        // Advance once: rank 0, after everyone has the base.
        self.comm.barrier();
        if self.comm.rank() == 0 {
            self.write_sfp(base + total)?;
        }
        Ok(base + prefix)
    }

    /// `MPI_FILE_READ_SHARED`: blocking noncollective read at the shared
    /// pointer; the pointer advances by the requested etype count.
    pub fn read_shared(
        &self,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::read(
            Positioning::Shared,
            Coordination::Independent,
            Synchronism::Blocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_read(&op, buf)
    }

    /// `MPI_FILE_WRITE_SHARED`: blocking noncollective write at the
    /// shared pointer.
    pub fn write_shared(
        &self,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::write(
            Positioning::Shared,
            Coordination::Independent,
            Synchronism::Blocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.status()
    }

    /// `MPI_FILE_IREAD_SHARED`: nonblocking shared-pointer read. Pointer
    /// reservation is immediate (ordering guarantee); only the transfer
    /// is asynchronous.
    pub fn iread_shared<T>(
        &self,
        buf: Vec<T>,
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Request<Vec<T>>>
    where
        T: Send + 'static,
        [T]: IoBufMut,
    {
        let op = AccessOp::read(
            Positioning::Shared,
            Coordination::Independent,
            Synchronism::Nonblocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_read_owned(&op, buf)
    }

    /// `MPI_FILE_IWRITE_SHARED`: nonblocking shared-pointer write.
    pub fn iwrite_shared(
        &self,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Request<()>> {
        let op = AccessOp::write(
            Positioning::Shared,
            Coordination::Independent,
            Synchronism::Nonblocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.request()
    }

    /// `MPI_FILE_READ_ORDERED`: collective shared-pointer read in rank
    /// order.
    pub fn read_ordered(
        &self,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::read(
            Positioning::Shared,
            Coordination::Ordered,
            Synchronism::Blocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_read(&op, buf)
    }

    /// `MPI_FILE_WRITE_ORDERED`: collective shared-pointer write in rank
    /// order.
    pub fn write_ordered(
        &self,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::write(
            Positioning::Shared,
            Coordination::Ordered,
            Synchronism::Blocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.status()
    }

    /// `MPI_FILE_SEEK_SHARED`: collective seek of the shared pointer. All
    /// ranks must pass identical arguments.
    pub fn seek_shared(&self, offset: Offset, whence: i32) -> Result<()> {
        self.check_open()?;
        let mut sig = offset.to_le_bytes().to_vec();
        sig.extend_from_slice(&whence.to_le_bytes());
        let all = self.comm.allgather(&sig);
        if all.iter().any(|s| *s != sig) {
            return Err(crate::io::errors::err_not_same(
                "seekShared: offset/whence differ across ranks",
            ));
        }
        if self.comm.rank() == 0 {
            let new = match whence {
                seek::SET => offset,
                seek::CUR => self.read_sfp()? + offset,
                seek::END => self.etypes_in_file()? + offset,
                w => return Err(err_arg(format!("seekShared: invalid whence {w}"))),
            };
            if new < 0 {
                return Err(err_arg(format!("seekShared: negative position {new}")));
            }
            self.write_sfp(new)?;
        }
        self.comm.barrier();
        Ok(())
    }

    /// `MPI_FILE_GET_POSITION_SHARED`: current shared pointer (etypes).
    pub fn get_position_shared(&self) -> Result<Offset> {
        self.check_open()?;
        self.read_sfp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::threads;
    use crate::comm::Comm;
    use crate::io::file::amode;
    use crate::io::hints::Info;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-shared-{}-{name}", std::process::id())
    }

    #[test]
    fn shared_writes_never_overlap() {
        let path = tmp("nooverlap");
        threads::run(4, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            // Each rank writes 50 ints of its rank id, 4 times, racing.
            let mine = vec![c.rank() as i32; 50];
            for _ in 0..4 {
                f.write_shared(mine.as_slice(), 0, 50, &Datatype::INT).unwrap();
            }
            c.barrier();
            assert_eq!(f.get_position_shared().unwrap(), 4 * 4 * 50);
            f.close().unwrap();
        });
        // The file must consist of 16 runs of 50 equal ints, 4 per rank.
        let raw = std::fs::read(&path).unwrap();
        let ints: Vec<i32> =
            raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
        assert_eq!(ints.len(), 800);
        let mut counts = [0usize; 4];
        for chunk in ints.chunks_exact(50) {
            assert!(chunk.iter().all(|&v| v == chunk[0]), "interleaved run: {chunk:?}");
            counts[chunk[0] as usize] += 1;
        }
        assert_eq!(counts, [4, 4, 4, 4]);
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn ordered_write_is_rank_ordered() {
        let path = tmp("ordered");
        threads::run(4, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            // Variable sizes per rank: rank r writes r+1 ints of value r.
            let mine = vec![c.rank() as i32; c.rank() + 1];
            f.write_ordered(mine.as_slice(), 0, c.rank() + 1, &Datatype::INT).unwrap();
            c.barrier();
            // Second round: ordered reads see rank-ordered data.
            f.seek_shared(0, seek::SET).unwrap();
            let mut back = vec![-1i32; c.rank() + 1];
            f.read_ordered(back.as_mut_slice(), 0, c.rank() + 1, &Datatype::INT).unwrap();
            assert_eq!(back, mine);
            f.close().unwrap();
        });
        let raw = std::fs::read(&path).unwrap();
        let ints: Vec<i32> =
            raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
        assert_eq!(ints, vec![0, 1, 1, 2, 2, 2, 3, 3, 3, 3]);
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn seek_shared_and_position() {
        let path = tmp("seek");
        threads::run(2, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            f.seek_shared(10, seek::SET).unwrap();
            assert_eq!(f.get_position_shared().unwrap(), 10);
            f.seek_shared(-3, seek::CUR).unwrap();
            assert_eq!(f.get_position_shared().unwrap(), 7);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn nonblocking_shared_ops() {
        let path = tmp("nbshared");
        threads::run(2, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let mine = vec![(c.rank() + 7) as i32; 32];
            let req = f.iwrite_shared(mine.as_slice(), 0, 32, &Datatype::INT).unwrap();
            let (st, ()) = req.wait().unwrap();
            assert_eq!(st.bytes, 128);
            c.barrier();
            f.seek_shared(0, seek::SET).unwrap();
            let req = f.iread_shared(vec![0i32; 32], 0, 32, &Datatype::INT).unwrap();
            let (st, buf) = req.wait().unwrap();
            assert_eq!(st.bytes, 128);
            assert!(buf.iter().all(|&v| v == 7 || v == 8));
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }
}
