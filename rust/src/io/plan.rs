//! The `IoPlan` compiler — the single representation every data-access
//! path lowers to before touching storage.
//!
//! The MPJ-IO surface spans five access families (§7.2.4): explicit
//! offsets, individual pointers, shared pointers, collectives, and
//! split/nonblocking operations. Before this module existed each family
//! re-derived its own flatten → pack → dispatch pipeline; ROMIO's lesson
//! (Thakur, Gropp & Lusk, "Optimizing Noncontiguous Accesses in MPI-IO")
//! is that *one* shared flattened-request representation is what lets data
//! sieving, two-phase aggregation and coalescing compose. An [`IoPlan`]
//! is that representation:
//!
//! * the view-flattened **absolute byte runs** of the access, sorted and
//!   adjacent-coalesced;
//! * the **packed-payload map** (`positions[i]` = payload byte where run
//!   `i`'s data starts);
//! * the **data representation** and element primitive (for
//!   encode/decode at the payload boundary);
//! * the **atomicity** of the operation (whether execution must hold the
//!   whole-file lock, §7.2.6.1).
//!
//! Plans are *compiled* here and *executed* by
//! [`IoScheduler`](crate::io::schedule::IoScheduler) — synchronously, on
//! the request engine, or phase-by-phase for two-phase collectives. The
//! collective layer additionally slices plans into aggregator file
//! domains ([`IoPlan::clip`]), and the staging strategies share one
//! span-batching helper ([`batch_runs`]) instead of each re-implementing
//! the grouping arithmetic.

use crate::comm::datatype::Prim;
use crate::io::datarep::DataRep;
use crate::io::errors::Result;
use crate::io::view::FileView;

/// One compiled data access: where the bytes live in the file, how the
/// packed payload maps onto those runs, and how execution must behave.
#[derive(Clone, Debug)]
pub struct IoPlan {
    /// Absolute `(byte_offset, len)` runs, sorted and adjacent-coalesced.
    pub runs: Vec<(u64, usize)>,
    /// Payload byte position of each run (prefix sums of run lengths).
    pub positions: Vec<usize>,
    /// Total payload bytes the plan moves.
    pub bytes: usize,
    /// File data representation (datarep conversion at the payload edge).
    pub datarep: DataRep,
    /// Element primitive of the view (unit of datarep conversion).
    pub prim: Prim,
    /// Whether execution must hold the whole-file lock (atomic mode).
    pub atomic: bool,
}

impl IoPlan {
    /// Compile an access of `payload_bytes` at view-relative etype offset
    /// `etype_off` through `view` into absolute byte runs.
    pub fn compile(
        view: &FileView,
        atomic: bool,
        etype_off: i64,
        payload_bytes: usize,
    ) -> Result<IoPlan> {
        // Gap-free views (the common case) compile to a single run
        // without walking the filetype map or the coalesce pass.
        if let Some((off, len)) = view.contiguous_run(etype_off, payload_bytes) {
            if len == 0 {
                return Ok(IoPlan::assemble(Vec::new(), view.datarep.clone(), view.prim(), atomic));
            }
            return Ok(IoPlan {
                runs: vec![(off, len)],
                positions: vec![0],
                bytes: len,
                datarep: view.datarep.clone(),
                prim: view.prim(),
                atomic,
            });
        }
        let runs = view.runs(etype_off, payload_bytes)?;
        Ok(IoPlan::assemble(runs, view.datarep.clone(), view.prim(), atomic))
    }

    /// A plan over pre-flattened absolute runs (aggregator-side plans in
    /// the I/O phase of two-phase collectives, where the payload is
    /// already in file representation).
    pub fn from_runs(runs: Vec<(u64, usize)>, atomic: bool) -> IoPlan {
        IoPlan::assemble(runs, DataRep::Native, Prim::Byte, atomic)
    }

    /// Coalesce adjacent sorted runs and compute the payload map.
    fn assemble(runs: Vec<(u64, usize)>, datarep: DataRep, prim: Prim, atomic: bool) -> IoPlan {
        let mut coalesced: Vec<(u64, usize)> = Vec::with_capacity(runs.len());
        for (off, len) in runs {
            if len == 0 {
                continue;
            }
            if let Some(last) = coalesced.last_mut() {
                if last.0 + last.1 as u64 == off {
                    last.1 += len;
                    continue;
                }
            }
            coalesced.push((off, len));
        }
        let mut positions = Vec::with_capacity(coalesced.len());
        let mut acc = 0usize;
        for &(_, len) in &coalesced {
            positions.push(acc);
            acc += len;
        }
        IoPlan { runs: coalesced, positions, bytes: acc, datarep, prim, atomic }
    }

    /// True when the plan moves no bytes.
    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    /// Iterate the plan's segments as `(file_off, len, payload_pos)` —
    /// the runs zipped with their payload positions, in file order.
    pub fn segments(&self) -> impl Iterator<Item = (u64, usize, usize)> + '_ {
        self.runs.iter().zip(&self.positions).map(|(&(off, len), &pos)| (off, len, pos))
    }

    /// The file byte range `[min, max)` the plan touches, `None` when
    /// empty. Runs are sorted, so this is first-start .. last-end.
    pub fn bounds(&self) -> Option<(u64, u64)> {
        match (self.runs.first(), self.runs.last()) {
            (Some(&(lo, _)), Some(&(o, l))) => Some((lo, o + l as u64)),
            _ => None,
        }
    }

    /// The pieces of this plan inside the byte domain `[domain.0,
    /// domain.1)`, as `(file_off, len, payload_pos)` — the unit the
    /// exchange phase of two-phase collectives ships to each aggregator.
    pub fn clip(&self, domain: (u64, u64)) -> Vec<(u64, usize, usize)> {
        let mut out = Vec::new();
        for (i, &(off, len)) in self.runs.iter().enumerate() {
            let end = off + len as u64;
            let s = off.max(domain.0);
            let e = end.min(domain.1);
            if s < e {
                let head = (s - off) as usize;
                out.push((s, (e - s) as usize, self.positions[i] + head));
            }
        }
        out
    }

    /// The `(prim, count)` element runs describing `payload_bytes` of the
    /// packed payload — input to datarep conversion. Views enforce
    /// homogeneity at construction, so this is one run.
    pub fn decode_elems(&self, payload_bytes: usize) -> Vec<(Prim, usize)> {
        vec![(self.prim, payload_bytes / self.prim.size())]
    }

    /// True when the payload needs datarep conversion at the file edge.
    pub fn needs_convert(&self) -> bool {
        !self.datarep.is_identity()
    }
}

/// A group of consecutive runs whose file span fits one staging buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RunBatch {
    /// Index of the first run in the batch.
    pub first: usize,
    /// Number of runs in the batch.
    pub count: usize,
    /// File offset of the batch span start.
    pub start: u64,
    /// Length of the batch span (last run end − span start).
    pub span: usize,
}

/// Group consecutive sorted runs into batches whose file span is at most
/// `stage_size` bytes — the shared grouping arithmetic of the view-buffer
/// and data-sieving strategies. Unsorted inputs degrade to one batch per
/// run (never incorrect, only unbatched). Zero-length runs move no bytes
/// and never seed a batch: a zero-length run at a batch boundary would
/// otherwise emit an empty `RunBatch` that the sieve stage treats as a
/// full read-modify-write round (and compiled `IoPlan`s drop them, but
/// this helper also sees raw caller runs). In-order zero-length runs
/// inside a batch are absorbed so batch index ranges stay contiguous.
pub fn batch_runs(runs: &[(u64, usize)], stage_size: usize) -> Vec<RunBatch> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < runs.len() {
        let (start, len) = runs[i];
        if len == 0 {
            i += 1;
            continue;
        }
        let mut end = start + len as u64;
        let mut j = i + 1;
        while j < runs.len() {
            let (o, l) = runs[j];
            if l == 0 {
                // A zero-length run within the batch's span keeps the
                // [first, first+count) range contiguous without moving
                // bytes; out-of-order ones end the batch (and are then
                // skipped by the outer loop).
                if o >= start && o <= end {
                    j += 1;
                    continue;
                }
                break;
            }
            let new_end = o + l as u64;
            if o < end || new_end - start > stage_size as u64 {
                break;
            }
            end = new_end;
            j += 1;
        }
        out.push(RunBatch { first: i, count: j - i, start, span: (end - start) as usize });
        i = j;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::datatype::Datatype;

    #[test]
    fn contiguous_view_compiles_to_one_run() {
        let v = FileView::default();
        let p = IoPlan::compile(&v, false, 25, 100).unwrap();
        assert_eq!(p.runs, vec![(25, 100)]);
        assert_eq!(p.positions, vec![0]);
        assert_eq!(p.bytes, 100);
        assert!(!p.atomic);
        assert_eq!(p.bounds(), Some((25, 125)));
    }

    #[test]
    fn strided_view_compiles_with_payload_map() {
        let ft = Datatype::vector(1, 2, 4, &Datatype::INT).unwrap();
        let ft = Datatype::resized(&ft, 0, 16).unwrap();
        let v = FileView::new(0, Datatype::INT, ft, DataRep::Native).unwrap();
        let p = IoPlan::compile(&v, true, 0, 16).unwrap();
        assert_eq!(p.runs, vec![(0, 8), (16, 8)]);
        assert_eq!(p.positions, vec![0, 8]);
        assert_eq!(p.bytes, 16);
        assert!(p.atomic);
    }

    #[test]
    fn negative_offset_is_rejected() {
        let v = FileView::default();
        assert!(IoPlan::compile(&v, false, -1, 4).is_err());
    }

    #[test]
    fn empty_plan_has_no_bounds() {
        let v = FileView::default();
        let p = IoPlan::compile(&v, false, 0, 0).unwrap();
        assert!(p.is_empty());
        assert_eq!(p.bounds(), None);
        assert_eq!(p.clip((0, 100)), vec![]);
    }

    #[test]
    fn assemble_coalesces_adjacent_and_drops_empty() {
        let p = IoPlan::from_runs(vec![(0, 4), (4, 4), (10, 0), (12, 4)], false);
        assert_eq!(p.runs, vec![(0, 8), (12, 4)]);
        assert_eq!(p.positions, vec![0, 8]);
        assert_eq!(p.bytes, 12);
    }

    #[test]
    fn clip_slices_runs_to_domains() {
        let p = IoPlan::from_runs(vec![(0, 10), (20, 10)], false);
        // Domain [5, 25): tail of run 0, head of run 1.
        assert_eq!(p.clip((5, 25)), vec![(5, 5, 5), (20, 5, 10)]);
        // Full cover.
        assert_eq!(p.clip((0, 100)), vec![(0, 10, 0), (20, 10, 10)]);
        // Disjoint.
        assert_eq!(p.clip((40, 50)), vec![]);
    }

    #[test]
    fn batch_runs_groups_within_stage() {
        let runs = [(0u64, 10usize), (20, 10), (200, 10), (250, 10)];
        let b = batch_runs(&runs, 100);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0], RunBatch { first: 0, count: 2, start: 0, span: 30 });
        assert_eq!(b[1], RunBatch { first: 2, count: 2, start: 200, span: 60 });
        // A stage smaller than any span: one batch per run.
        let b = batch_runs(&runs, 5);
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|x| x.count == 1));
    }

    #[test]
    fn batch_runs_never_emits_empty_batches() {
        // Regression (PR 3): a zero-length run at a batch boundary used
        // to seed a RunBatch with span 0, which the sieve stage treats
        // as a full read-modify-write round.
        // Leading, trailing, and lone zero-length runs:
        assert_eq!(batch_runs(&[(0, 0)], 100), vec![]);
        assert_eq!(batch_runs(&[(0, 0), (5, 0)], 100), vec![]);
        let b = batch_runs(&[(0, 0), (10, 4), (20, 4), (30, 0)], 100);
        assert_eq!(b.len(), 1);
        // The trailing zero-length run sits past the batch span and is
        // dropped rather than emitted as an empty batch.
        assert_eq!(b[0], RunBatch { first: 1, count: 2, start: 10, span: 14 });
        assert!(b.iter().all(|x| x.span > 0));
        // A zero-length run exactly at a stage boundary between two
        // batches must not become its own empty batch.
        let b = batch_runs(&[(0, 10), (10, 0), (200, 10)], 16);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0], RunBatch { first: 0, count: 2, start: 0, span: 10 });
        assert_eq!(b[1], RunBatch { first: 2, count: 1, start: 200, span: 10 });
        // In-span zero-length runs are absorbed so index ranges stay
        // contiguous.
        let b = batch_runs(&[(0, 4), (4, 0), (8, 4)], 100);
        assert_eq!(b, vec![RunBatch { first: 0, count: 3, start: 0, span: 12 }]);
    }

    #[test]
    fn batch_runs_unsorted_inputs_stay_safe() {
        // Unsorted runs degrade to smaller batches without panicking on
        // the span arithmetic (an out-of-order run behind the batch
        // start must not underflow `new_end - start`).
        let b = batch_runs(&[(100, 10), (0, 10), (50, 10)], 1000);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0], RunBatch { first: 0, count: 1, start: 100, span: 10 });
        assert_eq!(b[1], RunBatch { first: 1, count: 2, start: 0, span: 60 });
        // Out-of-order zero-length runs end the batch and vanish.
        let b = batch_runs(&[(100, 10), (0, 0), (120, 10)], 1000);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0], RunBatch { first: 0, count: 1, start: 100, span: 10 });
        assert_eq!(b[1], RunBatch { first: 2, count: 1, start: 120, span: 10 });
    }
}
