//! Darshan-style per-file I/O instrumentation.
//!
//! Darshan characterizes an HPC application's I/O with per-file counters
//! recorded at every rank and *shared-file records* reduced across ranks
//! when the file closes. jpio mirrors that design at the [`AccessOp`]
//! choke point: every data-access routine of the 56-routine matrix
//! funnels through `File::submit_read`/`submit_write`, so one
//! [`FileStats`] per handle can classify every operation — its cell
//! (positioning × coordination × synchronism), run shape, datarep, and
//! byte counts — without touching any access family's code.
//!
//! Three layers, by cost:
//!
//! * **Counters** — always on: relaxed atomic adds (a handful of
//!   uncontended `fetch_add`s per op), like Darshan's always-on counter
//!   mode. Queried per-rank at any time via `File::stats`.
//! * **Phase timers** — gated on the `jpio_stats` hint: wall-clock spans
//!   for the *validate*, pointer-*resolve*, collective *exchange*,
//!   *storage* I/O, request-*wait*, and progress-lane *queue* phases.
//!   When the hint is off, [`FileStats::start`] returns `None` and no
//!   clock is ever read — the timers are compiled in but fully skipped.
//! * **Trace events** — gated on `jpio_stats_trace = <path>`: one JSONL
//!   line per op and per phase span (world rank, op cell, offset, bytes,
//!   microseconds), written to `<path>.<rank>` for offline timeline
//!   analysis. The schema is [`TraceEvent`]; `TraceEvent::parse` is the
//!   reference decoder the CI smoke validates emitted logs against.
//!
//! At `File::close` the per-rank records are reduced collectively
//! (min/max/sum over the world, like Darshan's shared-file records) into
//! a [`StatsReport`], which `File::stats` serves after close; the
//! `jpio stats` CLI command renders one. The report also folds in the
//! plan-cache counters ([`PlanCacheStats`]), the progress-lane job
//! counters ([`ProgressStats`]), and the striped backend's degraded-mode
//! counters ([`BackendCounters`](crate::storage::BackendCounters)).

use std::collections::BTreeMap;
use std::io::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::comm::Comm as _;
use crate::io::errors::Result;
use crate::io::file::File;
use crate::io::hints::{keys, Info};
use crate::io::op::{AccessOp, Coordination, Direction, Positioning, Synchronism};
use crate::io::plan::IoPlan;

// ----------------------------------------------------------------------
// Counter and phase vocabularies
// ----------------------------------------------------------------------

/// The always-on per-op counters (the Darshan `*_COUNT` analogues).
/// Indexes into the [`FileStats`] counter array; the wire/report name of
/// each is [`Counter::name`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Counter {
    /// Read data-access submissions.
    ReadOps,
    /// Write data-access submissions.
    WriteOps,
    /// Independent-coordination ops.
    IndependentOps,
    /// Collective-coordination ops.
    CollectiveOps,
    /// Ordered (shared-pointer collective) ops.
    OrderedOps,
    /// Blocking-synchronism ops.
    BlockingOps,
    /// Nonblocking ops (`i*` routines).
    NonblockingOps,
    /// Split-collective ops (counted at `*_begin`).
    SplitOps,
    /// Explicit-offset (`*_at*`) positioning.
    ExplicitOffsetOps,
    /// Individual-pointer positioning.
    IndividualPtrOps,
    /// Shared-pointer positioning.
    SharedPtrOps,
    /// Compiled plans with a single file run (contiguous access shape).
    ContiguousPlans,
    /// Compiled plans with multiple file runs (strided access shape).
    StridedPlans,
    /// Total file runs across all compiled plans.
    PlanRuns,
    /// Payload bytes requested by the application.
    BytesRequested,
    /// File bytes the compiled plans move (after view mapping).
    BytesMoved,
    /// Ops whose data representation required conversion (non-`native`).
    DatarepConvertedOps,
    /// Degraded-mode advisories drained through `File::take_advisories`.
    DegradedAdvisories,
    /// Payload bytes the collective write phase copied through staging
    /// buffers (0 when the zero-copy piece dispatch served the op).
    StagingCopyBytes,
    /// Bytes served from resident page-cache data (no storage access).
    CacheHitBytes,
    /// Bytes whose pages had to be fetched from storage on access.
    CacheMissBytes,
    /// Dirty bytes the write-behind cache flushed to storage.
    WriteBehindFlushBytes,
    /// Read-modify-write cycles: page pre-reads forced by partial dirty
    /// data (cache) — folded with the parity small-write RMWs in the
    /// striped backend's own counter.
    RmwCycles,
    /// Dataset container header bytes written (enddef/sync persists) and
    /// re-read (open/sync coherence refreshes).
    DatasetHeaderBytes,
    /// Dataset `put_vara`/`iput_vara`/`append_records` variable writes.
    VarPutOps,
    /// Dataset `get_vara`/`iget_vara` variable reads.
    VarGetOps,
    /// Collective file-domain assignments steered away from a known-dead
    /// stripe server (elastic membership, DESIGN.md §1c): one count per
    /// plan piece whose home server was dead and whose aggregator was
    /// remapped to the next healthy server's domain.
    DegradedDomainAvoidances,
}

impl Counter {
    /// Every counter, in wire order (the close-time reduction serializes
    /// values in this order, so it must be identical on all ranks).
    pub(crate) const ALL: [Counter; 27] = [
        Counter::ReadOps,
        Counter::WriteOps,
        Counter::IndependentOps,
        Counter::CollectiveOps,
        Counter::OrderedOps,
        Counter::BlockingOps,
        Counter::NonblockingOps,
        Counter::SplitOps,
        Counter::ExplicitOffsetOps,
        Counter::IndividualPtrOps,
        Counter::SharedPtrOps,
        Counter::ContiguousPlans,
        Counter::StridedPlans,
        Counter::PlanRuns,
        Counter::BytesRequested,
        Counter::BytesMoved,
        Counter::DatarepConvertedOps,
        Counter::DegradedAdvisories,
        Counter::StagingCopyBytes,
        Counter::CacheHitBytes,
        Counter::CacheMissBytes,
        Counter::WriteBehindFlushBytes,
        Counter::RmwCycles,
        Counter::DatasetHeaderBytes,
        Counter::VarPutOps,
        Counter::VarGetOps,
        Counter::DegradedDomainAvoidances,
    ];

    /// The report/trace name of the counter.
    pub(crate) fn name(self) -> &'static str {
        match self {
            Counter::ReadOps => "read_ops",
            Counter::WriteOps => "write_ops",
            Counter::IndependentOps => "independent_ops",
            Counter::CollectiveOps => "collective_ops",
            Counter::OrderedOps => "ordered_ops",
            Counter::BlockingOps => "blocking_ops",
            Counter::NonblockingOps => "nonblocking_ops",
            Counter::SplitOps => "split_ops",
            Counter::ExplicitOffsetOps => "explicit_offset_ops",
            Counter::IndividualPtrOps => "individual_ptr_ops",
            Counter::SharedPtrOps => "shared_ptr_ops",
            Counter::ContiguousPlans => "contiguous_plans",
            Counter::StridedPlans => "strided_plans",
            Counter::PlanRuns => "plan_runs",
            Counter::BytesRequested => "bytes_requested",
            Counter::BytesMoved => "bytes_moved",
            Counter::DatarepConvertedOps => "datarep_converted_ops",
            Counter::DegradedAdvisories => "degraded_advisories",
            Counter::StagingCopyBytes => "staging_copy_bytes",
            Counter::CacheHitBytes => "cache_hit_bytes",
            Counter::CacheMissBytes => "cache_miss_bytes",
            Counter::WriteBehindFlushBytes => "write_behind_flush_bytes",
            Counter::RmwCycles => "rmw_cycles",
            Counter::DatasetHeaderBytes => "dataset_header_bytes",
            Counter::VarPutOps => "var_put_ops",
            Counter::VarGetOps => "var_get_ops",
            Counter::DegradedDomainAvoidances => "degraded_domain_avoidances",
        }
    }
}

/// The pipeline phases the hint-gated timers span. Recorded in `op.rs`
/// (validate, resolve, wait, queue), `schedule.rs` (storage), and
/// `collective.rs` (exchange) — see DESIGN.md "Instrumentation points".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Phase {
    /// The validation prologue (handle state, amode×op legality).
    Validate,
    /// File-pointer resolution (individual/shared/ordered offset).
    Resolve,
    /// Collective exchange rounds (the two-phase alltoalls).
    Exchange,
    /// Storage I/O (plan execution on the scheduler).
    Storage,
    /// Request wait-time (`MPI_Wait` / split `*_end` blocking).
    Wait,
    /// Progress-lane queue latency (submit → job start).
    Queue,
}

impl Phase {
    /// Every phase, in wire order (must match on all ranks, like
    /// [`Counter::ALL`]).
    pub(crate) const ALL: [Phase; 6] = [
        Phase::Validate,
        Phase::Resolve,
        Phase::Exchange,
        Phase::Storage,
        Phase::Wait,
        Phase::Queue,
    ];

    /// The report/trace name of the phase.
    pub(crate) fn name(self) -> &'static str {
        match self {
            Phase::Validate => "validate",
            Phase::Resolve => "resolve",
            Phase::Exchange => "exchange",
            Phase::Storage => "storage",
            Phase::Wait => "wait",
            Phase::Queue => "queue",
        }
    }
}

const N_COUNTERS: usize = Counter::ALL.len();
const N_PHASES: usize = Phase::ALL.len();

// ----------------------------------------------------------------------
// Named counter pairs (satellite structs)
// ----------------------------------------------------------------------

/// Plan-cache counters of one file handle (`File::plan_cache_stats`): a
/// hit means a repeated same-shape access reused its compiled
/// [`IoPlan`] at the scheduler instead of re-flattening the view.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that compiled a fresh plan.
    pub misses: u64,
}

/// Progress-lane job counters of one rank's engine
/// ([`ProgressEngine::stats`](crate::comm::progress::ProgressEngine::stats)):
/// `queued > completed` means work is in flight on the progress thread.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProgressStats {
    /// Jobs submitted to the progress thread.
    pub queued: usize,
    /// Jobs the progress thread has finished.
    pub completed: usize,
}

// ----------------------------------------------------------------------
// FileStats: the per-handle, per-rank record
// ----------------------------------------------------------------------

/// Per-file, per-rank instrumentation record (the Darshan file record
/// analogue). One lives on every open [`File`] handle; a clone of its
/// `Arc` travels with each transfer snapshot so the scheduler, the
/// collective phase drivers, and progress-lane jobs record into it
/// without borrowing the handle.
pub struct FileStats {
    /// Phase timers + tracing on (`jpio_stats` hint). Counters are
    /// always on regardless.
    enabled: bool,
    /// World rank of the owning handle (stamped into trace events).
    rank: usize,
    counters: [AtomicU64; N_COUNTERS],
    phase_nanos: [AtomicU64; N_PHASES],
    phase_samples: [AtomicU64; N_PHASES],
    /// JSONL trace sink (`jpio_stats_trace` hint), one file per rank.
    trace: Option<Mutex<std::io::BufWriter<std::fs::File>>>,
}

impl FileStats {
    /// Build a record from the open-time hints: `jpio_stats` turns the
    /// phase timers on, `jpio_stats_trace = <path>` additionally streams
    /// trace events to `<path>.<rank>` (per MPI hint semantics an
    /// unopenable path disables tracing rather than failing the open).
    pub(crate) fn from_info(info: &Info, rank: usize) -> Arc<FileStats> {
        let enabled = info.get_flag(keys::STATS).unwrap_or(false);
        let trace = if enabled {
            info.get(keys::STATS_TRACE).and_then(|base| {
                std::fs::File::create(format!("{base}.{rank}"))
                    .ok()
                    .map(|f| Mutex::new(std::io::BufWriter::new(f)))
            })
        } else {
            None
        };
        Arc::new(FileStats {
            enabled,
            rank,
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            phase_nanos: std::array::from_fn(|_| AtomicU64::new(0)),
            phase_samples: std::array::from_fn(|_| AtomicU64::new(0)),
            trace,
        })
    }

    /// A hint-off record (counters only) — the default for contexts
    /// constructed outside a `File` handle (scheduler unit tests).
    pub(crate) fn disabled() -> Arc<FileStats> {
        Self::from_info(&Info::null(), 0)
    }

    /// Whether the phase timers (and tracing, if hinted) are on.
    pub(crate) fn enabled(&self) -> bool {
        self.enabled
    }

    /// Add `n` to a counter. Always on; a single relaxed `fetch_add`.
    pub(crate) fn add(&self, c: Counter, n: u64) {
        self.counters[c as usize].fetch_add(n, Ordering::Relaxed);
    }

    /// Read a counter.
    pub(crate) fn value(&self, c: Counter) -> u64 {
        self.counters[c as usize].load(Ordering::Relaxed)
    }

    /// Start a phase span: `Some(now)` when timers are on, `None`
    /// otherwise — the hint-off path never reads the clock.
    pub(crate) fn start(&self) -> Option<Instant> {
        if self.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a phase span opened by [`FileStats::start`]; a `None` start
    /// (timers off) records nothing.
    pub(crate) fn record(&self, p: Phase, t0: Option<Instant>) {
        if let Some(t0) = t0 {
            self.record_span(p, t0.elapsed());
        }
    }

    /// Record an externally-measured phase duration.
    pub(crate) fn record_span(&self, p: Phase, d: Duration) {
        self.phase_nanos[p as usize].fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.phase_samples[p as usize].fetch_add(1, Ordering::Relaxed);
        if self.trace.is_none() {
            return;
        }
        self.emit(&TraceEvent {
            rank: self.rank,
            kind: "phase".into(),
            name: p.name().into(),
            offset: 0,
            bytes: 0,
            micros: d.as_micros() as u64,
        });
    }

    /// Classify one data-access submission: its op cell along every
    /// descriptor dimension plus requested bytes and datarep conversion.
    /// Called once per transfer submission (split collectives count at
    /// BEGIN), after offset resolution so the trace event carries the
    /// resolved etype offset.
    pub(crate) fn note_op(&self, op: &AccessOp, offset: i64, converted: bool) {
        self.add(
            match op.direction {
                Direction::Read => Counter::ReadOps,
                Direction::Write => Counter::WriteOps,
            },
            1,
        );
        self.add(
            match op.coordination {
                Coordination::Independent => Counter::IndependentOps,
                Coordination::Collective => Counter::CollectiveOps,
                Coordination::Ordered => Counter::OrderedOps,
            },
            1,
        );
        self.add(
            match op.synchronism {
                Synchronism::Blocking => Counter::BlockingOps,
                Synchronism::Nonblocking => Counter::NonblockingOps,
                Synchronism::Split(_) => Counter::SplitOps,
            },
            1,
        );
        self.add(
            match op.positioning {
                Positioning::Explicit(_) => Counter::ExplicitOffsetOps,
                Positioning::Individual => Counter::IndividualPtrOps,
                Positioning::Shared => Counter::SharedPtrOps,
            },
            1,
        );
        self.add(Counter::BytesRequested, op.payload_len() as u64);
        if converted {
            self.add(Counter::DatarepConvertedOps, 1);
        }
        if self.trace.is_some() {
            self.emit(&TraceEvent {
                rank: self.rank,
                kind: "op".into(),
                name: op.cell().stem(),
                offset,
                bytes: op.payload_len() as u64,
                micros: 0,
            });
        }
    }

    /// Classify a compiled plan's run shape: contiguous (single run) vs
    /// strided, run count, and the file bytes it moves.
    pub(crate) fn note_plan(&self, plan: &IoPlan) {
        let moved: u64 = plan.runs.iter().map(|&(_, len)| len as u64).sum();
        self.add(Counter::BytesMoved, moved);
        self.add(Counter::PlanRuns, plan.runs.len() as u64);
        self.add(
            if plan.runs.len() <= 1 { Counter::ContiguousPlans } else { Counter::StridedPlans },
            1,
        );
    }

    fn emit(&self, ev: &TraceEvent) {
        if let Some(sink) = &self.trace {
            if let Ok(mut w) = sink.lock() {
                let _ = writeln!(w, "{}", ev.to_json());
            }
        }
    }

    /// Flush the trace sink (called at `File::close` so offline tools
    /// can read the stream immediately).
    pub(crate) fn flush_trace(&self) {
        if let Some(sink) = &self.trace {
            if let Ok(mut w) = sink.lock() {
                let _ = w.flush();
            }
        }
    }
}

// ----------------------------------------------------------------------
// Trace events (JSONL schema)
// ----------------------------------------------------------------------

/// One line of the `jpio_stats_trace` JSONL stream.
///
/// Two kinds share the schema: `"op"` events (one per data-access
/// submission; `name` is the op cell, `offset`/`bytes` the resolved
/// etype offset and requested payload) and `"phase"` events (one per
/// timed phase span; `name` is the phase, `micros` the duration).
/// `TraceEvent::parse` is the reference decoder; the CI smoke parses
/// every emitted line with it, so schema drift fails the build.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// World rank that recorded the event.
    pub rank: usize,
    /// Event kind: `"op"` or `"phase"`.
    pub kind: String,
    /// Op cell label (the routine stem, e.g. `"write_at_all"`) or phase
    /// name (`"storage"`).
    pub name: String,
    /// Resolved etype offset (op events; 0 for phase events).
    pub offset: i64,
    /// Requested payload bytes (op events; 0 for phase events).
    pub bytes: u64,
    /// Span duration in microseconds (phase events; 0 for op events).
    pub micros: u64,
}

impl TraceEvent {
    /// Serialize to one JSON object (no trailing newline). The `kind`
    /// and `name` vocabularies contain no characters needing escapes,
    /// so the encoder is a plain format.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"rank\":{},\"kind\":\"{}\",\"name\":\"{}\",\"offset\":{},\"bytes\":{},\"micros\":{}}}",
            self.rank, self.kind, self.name, self.offset, self.bytes, self.micros
        )
    }

    /// Parse one JSONL line; `None` if any schema field is missing or
    /// malformed. The reference decoder for the trace stream.
    pub fn parse(line: &str) -> Option<TraceEvent> {
        fn field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
            let tag = format!("\"{key}\":");
            let rest = &line[line.find(&tag)? + tag.len()..];
            let rest = rest.trim_start();
            if let Some(stripped) = rest.strip_prefix('"') {
                stripped.split('"').next()
            } else {
                Some(rest.split([',', '}']).next()?.trim())
            }
        }
        Some(TraceEvent {
            rank: field(line, "rank")?.parse().ok()?,
            kind: field(line, "kind")?.to_string(),
            name: field(line, "name")?.to_string(),
            offset: field(line, "offset")?.parse().ok()?,
            bytes: field(line, "bytes")?.parse().ok()?,
            micros: field(line, "micros")?.parse().ok()?,
        })
    }
}

// ----------------------------------------------------------------------
// Reduced reports
// ----------------------------------------------------------------------

/// One value reduced across the ranks of the world (Darshan shared-file
/// record semantics): the per-rank minimum, maximum, and sum.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Reduced {
    /// Smallest per-rank value.
    pub min: u64,
    /// Largest per-rank value.
    pub max: u64,
    /// Sum over all ranks.
    pub sum: u64,
}

impl Reduced {
    fn of(v: u64) -> Reduced {
        Reduced { min: v, max: v, sum: v }
    }

    fn fold(&mut self, v: u64) {
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.sum = self.sum.wrapping_add(v);
    }
}

/// One phase timer reduced across ranks: total nanoseconds and sample
/// count, each with min/max/sum.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseStat {
    /// Total nanoseconds spent in the phase.
    pub nanos: Reduced,
    /// Number of recorded spans.
    pub samples: Reduced,
}

impl PhaseStat {
    /// The summed-across-ranks phase time as a `Duration`.
    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.nanos.sum)
    }
}

/// A file's instrumentation report: every [`Counter`], every [`Phase`]
/// timer, plus the plan-cache, progress-lane, and backend counters, each
/// reduced over `ranks` ranks. Before `File::close` the report is the
/// local rank's snapshot (`ranks == 1`); at close it is reduced
/// collectively across the world and served unchanged afterwards.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StatsReport {
    /// Number of ranks reduced into the report.
    pub ranks: usize,
    counters: BTreeMap<String, Reduced>,
    phases: BTreeMap<String, PhaseStat>,
}

impl StatsReport {
    /// A counter by report name (zero if never recorded). Besides the
    /// per-op counters this includes `plan_cache_hits`/`_misses`,
    /// `progress_jobs_queued`/`_completed`, and the striped backend's
    /// `degraded_reconstructed_reads`, `parity_rmw_cycles`,
    /// `fanout_bytes`, `rebuild_bytes_reconstructed`, and
    /// `restripe_rows_migrated`.
    pub fn counter(&self, name: &str) -> Reduced {
        self.counters.get(name).copied().unwrap_or_default()
    }

    /// A phase timer by name (`validate`, `resolve`, `exchange`,
    /// `storage`, `wait`, `queue`); zero if never recorded.
    pub fn phase(&self, name: &str) -> PhaseStat {
        self.phases.get(name).copied().unwrap_or_default()
    }

    /// Iterate `(name, value)` over all counters, sorted by name.
    pub fn counters(&self) -> impl Iterator<Item = (&str, Reduced)> {
        self.counters.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Iterate `(name, stat)` over all phase timers, in pipeline order.
    pub fn phases(&self) -> impl Iterator<Item = (&str, PhaseStat)> {
        Phase::ALL.into_iter().map(move |p| (p.name(), self.phase(p.name())))
    }

    /// Render the report as the `jpio stats` CLI table.
    pub fn render(&self) -> String {
        let mut out = format!("jpio file statistics ({} rank{})\n", self.ranks, plural(self.ranks));
        out.push_str(&format!(
            "  {:<28} {:>12} {:>12} {:>14}\n",
            "counter", "min", "max", "sum"
        ));
        for (name, v) in self.counters() {
            if v.sum == 0 {
                continue;
            }
            out.push_str(&format!("  {:<28} {:>12} {:>12} {:>14}\n", name, v.min, v.max, v.sum));
        }
        out.push_str(&format!(
            "  {:<28} {:>12} {:>12} {:>14}\n",
            "phase", "samples", "max/rank", "total"
        ));
        for (name, p) in self.phases() {
            if p.samples.sum == 0 {
                continue;
            }
            out.push_str(&format!(
                "  {:<28} {:>12} {:>12} {:>14}\n",
                name,
                p.samples.sum,
                format_nanos(p.nanos.max),
                format_nanos(p.nanos.sum),
            ));
        }
        out
    }

    /// Fold one rank's wire record into the report.
    fn fold_wire(&mut self, values: &[u64], first: bool) {
        let mut i = 0usize;
        let mut next = || {
            let v = values.get(i).copied().unwrap_or(0);
            i += 1;
            v
        };
        for c in Counter::ALL {
            fold_entry(&mut self.counters, c.name(), next(), first);
        }
        for name in EXTRA_COUNTERS {
            fold_entry(&mut self.counters, name, next(), first);
        }
        for p in Phase::ALL {
            let nanos = next();
            let samples = next();
            let e = self.phases.entry(p.name().to_string()).or_default();
            if first {
                e.nanos = Reduced::of(nanos);
                e.samples = Reduced::of(samples);
            } else {
                e.nanos.fold(nanos);
                e.samples.fold(samples);
            }
        }
    }
}

fn fold_entry(map: &mut BTreeMap<String, Reduced>, name: &str, v: u64, first: bool) {
    let e = map.entry(name.to_string()).or_default();
    if first {
        *e = Reduced::of(v);
    } else {
        e.fold(v);
    }
}

fn plural(n: usize) -> &'static str {
    if n == 1 {
        ""
    } else {
        "s"
    }
}

fn format_nanos(n: u64) -> String {
    format!("{:.3?}", Duration::from_nanos(n))
}

/// Non-op counters appended to the wire record after [`Counter::ALL`],
/// sourced from the plan cache, the progress lane, and the storage
/// backend at snapshot time. Order is part of the wire format.
const EXTRA_COUNTERS: [&str; 9] = [
    "plan_cache_hits",
    "plan_cache_misses",
    "progress_jobs_queued",
    "progress_jobs_completed",
    "degraded_reconstructed_reads",
    "parity_rmw_cycles",
    "fanout_bytes",
    "rebuild_bytes_reconstructed",
    "restripe_rows_migrated",
];

// ----------------------------------------------------------------------
// File integration: snapshot, collective reduction, query
// ----------------------------------------------------------------------

impl File<'_> {
    /// This rank's wire record: every counter (op counters, then the
    /// plan-cache / progress / backend extras), then `(nanos, samples)`
    /// per phase — fixed order, so the allgathered records of all ranks
    /// fold positionally.
    fn stats_wire(&self) -> Vec<u64> {
        let mut out: Vec<u64> =
            Counter::ALL.iter().map(|&c| self.stats.value(c)).collect();
        let pc = self.plan_cache_stats();
        let ps = self.progress_stats();
        let bc = self.storage.backend_counters();
        out.extend([
            pc.hits,
            pc.misses,
            ps.queued as u64,
            ps.completed as u64,
            bc.degraded_reads,
            bc.parity_rmw_cycles,
            bc.fanout_bytes,
            bc.rebuild_bytes_reconstructed,
            bc.restripe_rows_migrated,
        ]);
        for p in Phase::ALL {
            out.push(self.stats.phase_nanos[p as usize].load(Ordering::Relaxed));
            out.push(self.stats.phase_samples[p as usize].load(Ordering::Relaxed));
        }
        out
    }

    /// The file's instrumentation report (Darshan-style). After a
    /// `jpio_stats`-enabled `File::close` this is the collectively
    /// reduced shared-file record (identical on every rank); before
    /// close — or when the hint is off — it is this rank's local
    /// snapshot with `ranks == 1`.
    pub fn stats(&self) -> StatsReport {
        if let Some(r) = self.reduced_stats.lock().unwrap().as_ref() {
            return r.clone();
        }
        let mut report = StatsReport { ranks: 1, ..Default::default() };
        report.fold_wire(&self.stats_wire(), true);
        report
    }

    /// The close-time collective reduction (runs on every rank while
    /// the handle is still open; `jpio_stats` must be set uniformly
    /// across the world, like every collective hint). Each rank
    /// allgathers its wire record and folds min/max/sum locally, so all
    /// ranks hold the identical reduced report without a broadcast.
    pub(crate) fn reduce_stats(&self) -> Result<()> {
        let wire = self.stats_wire();
        let bytes: Vec<u8> = wire.iter().flat_map(|v| v.to_le_bytes()).collect();
        let all = self.comm.allgather(&bytes);
        let mut report = StatsReport { ranks: all.len(), ..Default::default() };
        for (i, rec) in all.iter().enumerate() {
            let values: Vec<u64> = rec
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().expect("8-byte chunk")))
                .collect();
            report.fold_wire(&values, i == 0);
        }
        *self.reduced_stats.lock().unwrap() = Some(report);
        self.stats.flush_trace();
        Ok(())
    }

    /// This rank's progress-lane job counters ([`ProgressStats`]);
    /// zeros when the transport has no lane or the
    /// `jpio_progress_threads` hint disables it.
    pub fn progress_stats(&self) -> ProgressStats {
        self.progress_lane_for(0).map(|l| l.engine.stats()).unwrap_or_default()
    }
}

// ----------------------------------------------------------------------
// Metrics registry (folded in from coordinator/metrics.rs)
// ----------------------------------------------------------------------

/// A thread-safe counters + timers registry for ad-hoc labels — the
/// bench harness and examples report through this; the per-file
/// instrumentation above is the structured, reducible form. (Formerly
/// `coordinator::metrics::Metrics`; re-exported there for
/// compatibility.)
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    timers: Mutex<BTreeMap<String, (Duration, u64)>>,
}

impl Metrics {
    /// New empty registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Add `n` to counter `name`.
    pub fn add(&self, name: &str, n: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += n;
    }

    /// Read a counter.
    pub fn get(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Time a closure under timer `name`.
    pub fn time<R>(&self, name: &str, f: impl FnOnce() -> R) -> R {
        let start = Instant::now();
        let r = f();
        self.record(name, start.elapsed());
        r
    }

    /// Record an externally-measured duration.
    pub fn record(&self, name: &str, d: Duration) {
        let mut t = self.timers.lock().unwrap();
        let e = t.entry(name.to_string()).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Total time of a timer.
    pub fn total(&self, name: &str) -> Duration {
        self.timers.lock().unwrap().get(name).map(|e| e.0).unwrap_or(Duration::ZERO)
    }

    /// Number of samples of a timer.
    pub fn samples(&self, name: &str) -> u64 {
        self.timers.lock().unwrap().get(name).map(|e| e.1).unwrap_or(0)
    }

    /// Render a report table.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.lock().unwrap();
        let timers = self.timers.lock().unwrap();
        if !counters.is_empty() {
            out.push_str("counters:\n");
            for (k, v) in counters.iter() {
                out.push_str(&format!("  {k:<40} {v}\n"));
            }
        }
        if !timers.is_empty() {
            out.push_str("timers:\n");
            for (k, (total, n)) in timers.iter() {
                let avg = if *n > 0 { *total / *n as u32 } else { Duration::ZERO };
                out.push_str(&format!(
                    "  {k:<40} total {:>10.3?}  n {n:>6}  avg {avg:>10.3?}\n",
                    total
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.add("writes", 3);
        m.add("writes", 4);
        assert_eq!(m.get("writes"), 7);
        assert_eq!(m.get("nonexistent"), 0);
    }

    #[test]
    fn timers_accumulate_and_count() {
        let m = Metrics::new();
        let out = m.time("op", || {
            std::thread::sleep(Duration::from_millis(2));
            42
        });
        assert_eq!(out, 42);
        m.record("op", Duration::from_millis(5));
        assert_eq!(m.samples("op"), 2);
        assert!(m.total("op") >= Duration::from_millis(7));
        let rep = m.report();
        assert!(rep.contains("op"));
    }

    #[test]
    fn trace_event_round_trips() {
        let ev = TraceEvent {
            rank: 3,
            kind: "op".into(),
            name: "write_at_all".into(),
            offset: -128,
            bytes: 4096,
            micros: 0,
        };
        assert_eq!(TraceEvent::parse(&ev.to_json()), Some(ev));
        let ph = TraceEvent {
            rank: 0,
            kind: "phase".into(),
            name: "storage".into(),
            offset: 0,
            bytes: 0,
            micros: 1234,
        };
        assert_eq!(TraceEvent::parse(&ph.to_json()), Some(ph));
        assert_eq!(TraceEvent::parse("not json"), None);
        assert_eq!(TraceEvent::parse("{\"rank\":1}"), None, "missing fields must not parse");
    }

    #[test]
    fn disabled_stats_skip_timers_but_count() {
        let s = FileStats::disabled();
        assert!(!s.enabled());
        assert!(s.start().is_none(), "timers off must never read the clock");
        s.record(Phase::Storage, s.start());
        assert_eq!(s.phase_samples[Phase::Storage as usize].load(Ordering::Relaxed), 0);
        s.add(Counter::WriteOps, 2);
        assert_eq!(s.value(Counter::WriteOps), 2, "counters stay on with timers off");
    }

    #[test]
    fn enabled_stats_record_phase_spans() {
        let s = FileStats::from_info(&Info::from([(keys::STATS, "true")]), 0);
        assert!(s.enabled());
        s.record(Phase::Exchange, s.start());
        s.record_span(Phase::Exchange, Duration::from_micros(50));
        assert_eq!(s.phase_samples[Phase::Exchange as usize].load(Ordering::Relaxed), 2);
        assert!(
            s.phase_nanos[Phase::Exchange as usize].load(Ordering::Relaxed) >= 50_000,
            "recorded span must include the explicit 50µs"
        );
    }

    #[test]
    fn reduced_folds_min_max_sum() {
        let mut r = Reduced::of(5);
        r.fold(2);
        r.fold(9);
        assert_eq!(r, Reduced { min: 2, max: 9, sum: 16 });
    }

    #[test]
    fn report_render_skips_zero_rows() {
        let mut report = StatsReport { ranks: 2, ..Default::default() };
        let wire = vec![0u64; Counter::ALL.len() + EXTRA_COUNTERS.len() + 2 * Phase::ALL.len()];
        report.fold_wire(&wire, true);
        let mut wire2 = wire;
        wire2[Counter::WriteOps as usize] = 7;
        report.fold_wire(&wire2, false);
        let text = report.render();
        assert!(text.contains("write_ops"));
        assert!(!text.contains("read_ops"), "zero counters must not clutter the table");
        assert_eq!(report.counter("write_ops"), Reduced { min: 0, max: 7, sum: 7 });
    }
}
