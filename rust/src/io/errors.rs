//! I/O error classes (§7.2.8 — the MPI-2.2 chapter-13 error classes).
//!
//! ROMIO 1.2.5.1 shipped without user-defined error handlers; we provide
//! the full class set plus a Rust-idiomatic `Result` surface. Each variant
//! corresponds to one `MPI_ERR_*` class so test assertions can match on
//! class rather than message text.

use std::fmt;

/// MPI-IO error classes.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ErrorClass {
    /// `MPI_ERR_FILE` — invalid file handle.
    File,
    /// `MPI_ERR_NOT_SAME` — collective argument mismatch across ranks.
    NotSame,
    /// `MPI_ERR_AMODE` — invalid access-mode combination.
    Amode,
    /// `MPI_ERR_UNSUPPORTED_DATAREP` — unknown data representation.
    UnsupportedDatarep,
    /// `MPI_ERR_UNSUPPORTED_OPERATION` — op not allowed in this mode.
    UnsupportedOperation,
    /// `MPI_ERR_NO_SUCH_FILE` — file does not exist.
    NoSuchFile,
    /// `MPI_ERR_FILE_EXISTS` — file already exists (EXCL).
    FileExists,
    /// `MPI_ERR_BAD_FILE` — invalid file name.
    BadFile,
    /// `MPI_ERR_ACCESS` — permission denied.
    Access,
    /// `MPI_ERR_NO_SPACE` — not enough space.
    NoSpace,
    /// `MPI_ERR_QUOTA` — quota exceeded.
    Quota,
    /// `MPI_ERR_READ_ONLY` — write on a read-only file/system.
    ReadOnly,
    /// `MPI_ERR_FILE_IN_USE` — delete/resize while open elsewhere.
    FileInUse,
    /// `MPI_ERR_DUP_DATAREP` — datarep name already registered.
    DupDatarep,
    /// `MPI_ERR_CONVERSION` — datarep conversion failed.
    Conversion,
    /// `MPI_ERR_IO` — other I/O error.
    Io,
    /// `MPI_ERR_REQUEST` — invalid request handle (nonblocking ops).
    Request,
    /// `MPI_ERR_ARG` — invalid argument (count/offset/datatype).
    Arg,
    /// `JPIO_ERR_DEGRADED` — jpio extension (no MPI equivalent): the
    /// operation *succeeded* by reconstructing data around a failed
    /// stripe server (replica/parity redundancy). Never returned as an
    /// `Err`; surfaced through the advisory path
    /// ([`StorageFile::take_advisories`](crate::storage::StorageFile::take_advisories)
    /// / [`File::take_advisories`](crate::io::File::take_advisories)).
    Degraded,
}

impl ErrorClass {
    /// The MPI constant name of this class.
    pub const fn mpi_name(self) -> &'static str {
        match self {
            ErrorClass::File => "MPI_ERR_FILE",
            ErrorClass::NotSame => "MPI_ERR_NOT_SAME",
            ErrorClass::Amode => "MPI_ERR_AMODE",
            ErrorClass::UnsupportedDatarep => "MPI_ERR_UNSUPPORTED_DATAREP",
            ErrorClass::UnsupportedOperation => "MPI_ERR_UNSUPPORTED_OPERATION",
            ErrorClass::NoSuchFile => "MPI_ERR_NO_SUCH_FILE",
            ErrorClass::FileExists => "MPI_ERR_FILE_EXISTS",
            ErrorClass::BadFile => "MPI_ERR_BAD_FILE",
            ErrorClass::Access => "MPI_ERR_ACCESS",
            ErrorClass::NoSpace => "MPI_ERR_NO_SPACE",
            ErrorClass::Quota => "MPI_ERR_QUOTA",
            ErrorClass::ReadOnly => "MPI_ERR_READ_ONLY",
            ErrorClass::FileInUse => "MPI_ERR_FILE_IN_USE",
            ErrorClass::DupDatarep => "MPI_ERR_DUP_DATAREP",
            ErrorClass::Conversion => "MPI_ERR_CONVERSION",
            ErrorClass::Io => "MPI_ERR_IO",
            ErrorClass::Request => "MPI_ERR_REQUEST",
            ErrorClass::Arg => "MPI_ERR_ARG",
            ErrorClass::Degraded => "JPIO_ERR_DEGRADED",
        }
    }
}

/// An MPJ-IO error: a class plus context.
#[derive(Debug)]
pub struct IoError {
    /// The MPI error class.
    pub class: ErrorClass,
    /// Human-readable context.
    pub message: String,
    /// Underlying OS error, when one exists.
    pub source: Option<std::io::Error>,
}

impl IoError {
    /// Construct an error of `class` with a message.
    pub fn new(class: ErrorClass, message: impl Into<String>) -> IoError {
        IoError { class, message: message.into(), source: None }
    }

    /// Wrap an OS error, mapping its kind onto an MPI class.
    pub fn from_os(err: std::io::Error, context: impl Into<String>) -> IoError {
        use std::io::ErrorKind::*;
        let class = match err.kind() {
            NotFound => ErrorClass::NoSuchFile,
            PermissionDenied => ErrorClass::Access,
            AlreadyExists => ErrorClass::FileExists,
            InvalidInput => ErrorClass::Arg,
            WriteZero | UnexpectedEof => ErrorClass::Io,
            _ => match err.raw_os_error() {
                Some(libc::ENOSPC) => ErrorClass::NoSpace,
                Some(libc::EDQUOT) => ErrorClass::Quota,
                Some(libc::EROFS) => ErrorClass::ReadOnly,
                _ => ErrorClass::Io,
            },
        };
        IoError { class, message: context.into(), source: Some(err) }
    }
}

impl fmt::Display for IoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.class.mpi_name(), self.message)?;
        if let Some(src) = &self.source {
            write!(f, " ({src})")?;
        }
        Ok(())
    }
}

impl std::error::Error for IoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_ref().map(|e| e as _)
    }
}

/// Result alias for the io layer.
pub type Result<T> = std::result::Result<T, IoError>;

/// Shorthand constructors used across the io layer.
macro_rules! err_ctor {
    ($fn_name:ident, $class:ident) => {
        /// Construct an error of the corresponding class.
        pub fn $fn_name(msg: impl Into<String>) -> IoError {
            IoError::new(ErrorClass::$class, msg)
        }
    };
}

err_ctor!(err_file, File);
err_ctor!(err_not_same, NotSame);
err_ctor!(err_amode, Amode);
err_ctor!(err_unsupported_datarep, UnsupportedDatarep);
err_ctor!(err_unsupported_op, UnsupportedOperation);
err_ctor!(err_no_such_file, NoSuchFile);
err_ctor!(err_file_exists, FileExists);
err_ctor!(err_bad_file, BadFile);
err_ctor!(err_access, Access);
err_ctor!(err_read_only, ReadOnly);
err_ctor!(err_file_in_use, FileInUse);
err_ctor!(err_dup_datarep, DupDatarep);
err_ctor!(err_conversion, Conversion);
err_ctor!(err_io, Io);
err_ctor!(err_request, Request);
err_ctor!(err_arg, Arg);
err_ctor!(err_degraded, Degraded);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_have_mpi_names() {
        assert_eq!(ErrorClass::NoSuchFile.mpi_name(), "MPI_ERR_NO_SUCH_FILE");
        assert_eq!(ErrorClass::Amode.mpi_name(), "MPI_ERR_AMODE");
    }

    #[test]
    fn os_error_mapping() {
        let e = IoError::from_os(std::io::Error::from(std::io::ErrorKind::NotFound), "open");
        assert_eq!(e.class, ErrorClass::NoSuchFile);
        let e = IoError::from_os(std::io::Error::from_raw_os_error(libc::ENOSPC), "write");
        assert_eq!(e.class, ErrorClass::NoSpace);
        let e = IoError::from_os(
            std::io::Error::from(std::io::ErrorKind::PermissionDenied),
            "open",
        );
        assert_eq!(e.class, ErrorClass::Access);
    }

    #[test]
    fn display_includes_class_and_message() {
        let e = err_amode("RDONLY|WRONLY is invalid");
        let s = e.to_string();
        assert!(s.contains("MPI_ERR_AMODE"), "{s}");
        assert!(s.contains("RDONLY"), "{s}");
    }
}
