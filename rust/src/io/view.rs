//! File views (§3.5.2 / §7.2.3): `disp` + `etype` + `filetype` + datarep.
//!
//! "The setView routine changes the process's view of the data in the
//! file." A view tiles the file from byte `disp` with instances of
//! `filetype` (whose holes belong to other processes); the data visible to
//! this process is the sequence of `etype` elements inside the filetype
//! payload. Offsets in every data-access routine are expressed in etype
//! units relative to the current view — the machinery that lets N ranks
//! interleave a shared file without overlapping.
//!
//! This module flattens `(disp, etype, filetype)` into absolute byte runs
//! for the access engine, with a small cache so repeated same-shape
//! accesses (the steady state of every bench) skip re-flattening.

use std::sync::Mutex;

use crate::comm::datatype::{Datatype, Prim, Segment};
use crate::io::datarep::DataRep;
use crate::io::errors::{err_arg, Result};

/// A process's view of the file.
#[derive(Debug)]
pub struct FileView {
    /// Absolute byte displacement of the view start.
    pub disp: i64,
    /// Elementary datatype: the unit of offsets and counts.
    pub etype: Datatype,
    /// File tiling type (payload positions belong to this process).
    pub filetype: Datatype,
    /// Data representation for file bytes.
    pub datarep: DataRep,
    /// Flattened filetype segments (one instance).
    segments: Vec<Segment>,
    /// Filetype extent (instance-to-instance stride in the file).
    extent: i64,
    /// Payload bytes per filetype instance.
    payload_per_instance: usize,
    /// Etypes per filetype instance.
    etypes_per_instance: usize,
    /// Run cache: (etype_offset, payload_bytes) → absolute runs.
    cache: Mutex<Option<RunCacheEntry>>,
}

#[derive(Debug, Clone)]
struct RunCacheEntry {
    etype_offset: i64,
    payload_bytes: usize,
    runs: Vec<(u64, usize)>,
}

impl Clone for FileView {
    fn clone(&self) -> Self {
        FileView {
            disp: self.disp,
            etype: self.etype.clone(),
            filetype: self.filetype.clone(),
            datarep: self.datarep.clone(),
            segments: self.segments.clone(),
            extent: self.extent,
            payload_per_instance: self.payload_per_instance,
            etypes_per_instance: self.etypes_per_instance,
            cache: Mutex::new(None),
        }
    }
}

impl Default for FileView {
    /// The default view: `disp = 0`, `etype = filetype = BYTE`, native
    /// representation (what `open` installs).
    fn default() -> Self {
        FileView::new(0, Datatype::BYTE, Datatype::BYTE, DataRep::Native).unwrap()
    }
}

impl FileView {
    /// Validate and build a view.
    pub fn new(
        disp: i64,
        etype: Datatype,
        filetype: Datatype,
        datarep: DataRep,
    ) -> Result<FileView> {
        if disp < 0 {
            return Err(err_arg(format!("setView: negative displacement {disp}")));
        }
        let esz = etype.size();
        if esz == 0 {
            return Err(err_arg("setView: zero-size etype"));
        }
        if filetype.size() % esz != 0 {
            return Err(err_arg(format!(
                "setView: filetype size {} is not a multiple of etype size {esz}",
                filetype.size()
            )));
        }
        // The filetype must be "derived from etype": every run holds the
        // etype's primitive (needed for datarep conversion and the MPI
        // type-matching rules, §7.2.6.5).
        let eprim = etype.base_prim();
        if !etype.is_homogeneous() {
            return Err(err_arg("setView: heterogeneous etype is unsupported"));
        }
        let segments = filetype.segments();
        if segments.iter().any(|s| s.prim != eprim) {
            return Err(err_arg(format!(
                "setView: filetype primitives do not match etype {}",
                eprim.name()
            )));
        }
        let extent = filetype.extent();
        Ok(FileView {
            disp,
            payload_per_instance: filetype.size(),
            etypes_per_instance: filetype.size() / esz,
            segments,
            extent,
            etype,
            filetype,
            datarep,
            cache: Mutex::new(None),
        })
    }

    /// Etype size in bytes.
    pub fn etype_size(&self) -> usize {
        self.etype.size()
    }

    /// The element primitive of the view.
    pub fn prim(&self) -> Prim {
        self.etype.base_prim()
    }

    /// The single contiguous run of this access, when the filetype tiles
    /// the file gap-free — the allocation-free hot path for flat views.
    pub fn contiguous_run(&self, etype_offset: i64, payload_bytes: usize) -> Option<(u64, usize)> {
        if etype_offset >= 0
            && self.filetype.is_contiguous()
            && self.payload_per_instance as i64 == self.extent
        {
            let start = self.disp + etype_offset * self.etype.size() as i64;
            Some((start as u64, payload_bytes))
        } else {
            None
        }
    }

    /// Absolute byte runs covering `payload_bytes` of view payload
    /// starting at `etype_offset` etypes into the view. Adjacent runs are
    /// coalesced; results are cached for the repeat-access fast path.
    pub fn runs(&self, etype_offset: i64, payload_bytes: usize) -> Result<Vec<(u64, usize)>> {
        if etype_offset < 0 {
            return Err(err_arg(format!("negative view offset {etype_offset}")));
        }
        if payload_bytes == 0 {
            return Ok(Vec::new());
        }
        {
            let cache = self.cache.lock().unwrap();
            if let Some(e) = cache.as_ref() {
                if e.etype_offset == etype_offset && e.payload_bytes == payload_bytes {
                    return Ok(e.runs.clone());
                }
            }
        }
        let runs = self.compute_runs(etype_offset, payload_bytes);
        *self.cache.lock().unwrap() = Some(RunCacheEntry {
            etype_offset,
            payload_bytes,
            runs: runs.clone(),
        });
        Ok(runs)
    }

    fn compute_runs(&self, etype_offset: i64, payload_bytes: usize) -> Vec<(u64, usize)> {
        let esz = self.etype.size();
        // Fast path: a gap-free filetype tiles the file contiguously, so
        // the whole access is one run. (Without this, the default BYTE
        // view would walk its type map once per *byte*.)
        if self.filetype.is_contiguous() && self.payload_per_instance as i64 == self.extent {
            let start = self.disp + etype_offset * esz as i64;
            return vec![(start as u64, payload_bytes)];
        }
        let mut instance = (etype_offset as usize) / self.etypes_per_instance;
        let mut skip = ((etype_offset as usize) % self.etypes_per_instance) * esz;
        let mut remaining = payload_bytes;
        let mut runs: Vec<(u64, usize)> = Vec::new();
        while remaining > 0 {
            let base = self.disp + instance as i64 * self.extent;
            for seg in &self.segments {
                if remaining == 0 {
                    break;
                }
                let seg_len = seg.len();
                if skip >= seg_len {
                    skip -= seg_len;
                    continue;
                }
                let take = (seg_len - skip).min(remaining);
                let abs = (base + seg.offset) as u64 + skip as u64;
                if let Some(last) = runs.last_mut() {
                    if last.0 + last.1 as u64 == abs {
                        last.1 += take;
                        skip = 0;
                        remaining -= take;
                        continue;
                    }
                }
                runs.push((abs, take));
                skip = 0;
                remaining -= take;
            }
            instance += 1;
        }
        runs
    }

    /// Convert a view-relative etype offset to the absolute byte position
    /// (`MPI_FILE_GET_BYTE_OFFSET`, §7.2.4.3).
    pub fn byte_offset(&self, etype_offset: i64) -> Result<i64> {
        if etype_offset < 0 {
            return Err(err_arg(format!("negative view offset {etype_offset}")));
        }
        let esz = self.etype.size();
        let instance = (etype_offset as usize) / self.etypes_per_instance;
        let mut skip = ((etype_offset as usize) % self.etypes_per_instance) * esz;
        let base = self.disp + instance as i64 * self.extent;
        for seg in &self.segments {
            if skip < seg.len() {
                return Ok(base + seg.offset + skip as i64);
            }
            skip -= seg.len();
        }
        // etype_offset landed exactly on an instance boundary.
        Ok(base + self.extent)
    }

    /// The (prim, count) element runs describing `payload_bytes` of packed
    /// payload — input to datarep conversion. Homogeneity is enforced at
    /// construction, so this is a single run.
    pub fn payload_elems(&self, payload_bytes: usize) -> Vec<(Prim, usize)> {
        let p = self.prim();
        vec![(p, payload_bytes / p.size())]
    }

    /// Number of etypes covered by `bytes` of payload (rounded down).
    pub fn bytes_to_etypes(&self, bytes: usize) -> i64 {
        (bytes / self.etype.size()) as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::datatype::ArrayOrder;
    use crate::testing::{forall, Config};

    #[test]
    fn default_view_is_flat_bytes() {
        let v = FileView::default();
        assert_eq!(v.runs(0, 100).unwrap(), vec![(0, 100)]);
        assert_eq!(v.runs(25, 10).unwrap(), vec![(25, 10)]);
        assert_eq!(v.byte_offset(42).unwrap(), 42);
    }

    #[test]
    fn displacement_shifts_everything() {
        let v =
            FileView::new(1000, Datatype::INT, Datatype::INT, DataRep::Native).unwrap();
        assert_eq!(v.runs(0, 8).unwrap(), vec![(1000, 8)]);
        assert_eq!(v.runs(3, 4).unwrap(), vec![(1012, 4)]);
        assert_eq!(v.byte_offset(3).unwrap(), 1012);
    }

    #[test]
    fn strided_vector_view_interleaves() {
        // The canonical 2-rank interleave: each rank sees alternate blocks
        // of 2 ints (stride 4 ints). Rank 1's view starts at disp 8.
        let ft = Datatype::vector(1, 2, 4, &Datatype::INT).unwrap();
        let ft = Datatype::resized(&ft, 0, 16).unwrap(); // extent = 4 ints
        let v0 = FileView::new(0, Datatype::INT, ft.clone(), DataRep::Native).unwrap();
        let v1 = FileView::new(8, Datatype::INT, ft, DataRep::Native).unwrap();
        assert_eq!(v0.runs(0, 16).unwrap(), vec![(0, 8), (16, 8)]);
        assert_eq!(v1.runs(0, 16).unwrap(), vec![(8, 8), (24, 8)]);
        // Offsets are etype-relative: etype 2 of rank 0 = second block.
        assert_eq!(v0.byte_offset(2).unwrap(), 16);
        assert_eq!(v0.runs(2, 8).unwrap(), vec![(16, 8)]);
    }

    #[test]
    fn subarray_view_covers_only_the_block() {
        // 4x4 ints, rank owns the 2x2 block at (1,1).
        let ft = Datatype::subarray(&[4, 4], &[2, 2], &[1, 1], ArrayOrder::C, &Datatype::INT)
            .unwrap();
        let v = FileView::new(0, Datatype::INT, ft, DataRep::Native).unwrap();
        let runs = v.runs(0, 16).unwrap();
        assert_eq!(runs, vec![((4 + 1) * 4, 8), ((8 + 1) * 4, 8)]);
        // Reading across instances: a second instance starts at extent 64.
        let runs2 = v.runs(4, 16).unwrap();
        assert_eq!(runs2, vec![(64 + 20, 8), (64 + 36, 8)]);
    }

    #[test]
    fn partial_etype_offsets_inside_instances() {
        let ft = Datatype::vector(2, 2, 3, &Datatype::INT).unwrap(); // XX.XX (extent 20)
        let v = FileView::new(0, Datatype::INT, ft, DataRep::Native).unwrap();
        // 4 etypes per instance; offset 1 = second int of first block.
        assert_eq!(v.runs(1, 12).unwrap(), vec![(4, 4), (12, 8)]);
        assert_eq!(v.byte_offset(1).unwrap(), 4);
        assert_eq!(v.byte_offset(2).unwrap(), 12);
        assert_eq!(v.byte_offset(4).unwrap(), 20); // next instance
    }

    #[test]
    fn validation_rejects_bad_views() {
        // filetype not a multiple of etype.
        let three_bytes = Datatype::contiguous(3, &Datatype::BYTE).unwrap();
        assert!(FileView::new(0, Datatype::INT, three_bytes, DataRep::Native).is_err());
        // mismatched primitives.
        assert!(FileView::new(0, Datatype::INT, Datatype::FLOAT, DataRep::Native).is_err());
        // negative disp.
        assert!(FileView::new(-1, Datatype::BYTE, Datatype::BYTE, DataRep::Native).is_err());
    }

    #[test]
    fn runs_cache_hit_returns_same_result() {
        let ft = Datatype::vector(4, 1, 2, &Datatype::INT).unwrap();
        let v = FileView::new(0, Datatype::INT, ft, DataRep::Native).unwrap();
        let a = v.runs(0, 16).unwrap();
        let b = v.runs(0, 16).unwrap(); // cached
        assert_eq!(a, b);
        let c = v.runs(1, 16).unwrap(); // different key
        assert_ne!(a, c);
    }

    #[test]
    fn prop_runs_total_equals_payload_and_are_disjoint_sorted() {
        forall(
            Config::default().cases(150),
            |r| {
                let count = r.range(1, 5);
                let blocklen = r.range(1, 4);
                let stride = r.range_i64(blocklen as i64, 8);
                let disp = r.range(0, 64) as i64 * 4;
                let off = r.range(0, 10) as i64;
                let etypes = r.range(1, 40);
                (count, blocklen, stride, disp, off, etypes)
            },
            |&(count, blocklen, stride, disp, off, etypes)| {
                let ft = Datatype::vector(count, blocklen, stride, &Datatype::INT).unwrap();
                let v = FileView::new(disp, Datatype::INT, ft, DataRep::Native).unwrap();
                let bytes = etypes * 4;
                let runs = v.runs(off, bytes).unwrap();
                let total: usize = runs.iter().map(|&(_, l)| l).sum();
                let sorted = runs.windows(2).all(|w| w[0].0 + w[0].1 as u64 <= w[1].0);
                let past_disp = runs.iter().all(|&(o, _)| o >= disp as u64);
                total == bytes && sorted && past_disp
            },
        );
    }

    #[test]
    fn prop_byte_offset_matches_first_run() {
        forall(
            Config::default().cases(150),
            |r| {
                let count = r.range(1, 4);
                let blocklen = r.range(1, 3);
                let stride = r.range_i64(blocklen as i64, 6);
                let off = r.range(0, 12) as i64;
                (count, blocklen, stride, off)
            },
            |&(count, blocklen, stride, off)| {
                let ft = Datatype::vector(count, blocklen, stride, &Datatype::INT).unwrap();
                let v = FileView::new(16, Datatype::INT, ft, DataRep::Native).unwrap();
                let bo = v.byte_offset(off).unwrap();
                let runs = v.runs(off, 4).unwrap();
                runs[0].0 == bo as u64
            },
        );
    }
}
