//! Collective data access (`*_ALL`, §7.2.4) with two-phase collective
//! buffering — ROMIO's flagship optimization ("an optimized implementation
//! of collective I/O, an important optimization in parallel I/O", §2.2.1) —
//! plus the MPI-3.1 nonblocking collectives `iread_all`/`iwrite_all`.
//!
//! ## Two-phase algorithm
//!
//! 1. Every rank compiles its request into an [`IoPlan`] (view-flattened
//!    absolute byte runs + payload map) and the ranks agree on the global
//!    byte range.
//! 2. The range is split into *aggregator domains* (`cb_nodes` hint;
//!    default: every rank aggregates). `cb_config_list` pins the
//!    aggregator role of each domain to an explicit rank.
//! 3. **Exchange phase** (communication): each rank clips its plan to
//!    each domain ([`IoPlan::clip`]) and ships the pieces to that
//!    domain's aggregator.
//! 4. **I/O phase** (storage): aggregators merge the pieces into large,
//!    mostly-contiguous transfers (data sieving on reads) and hit the
//!    file once, instead of N ranks issuing interleaved small I/O.
//!
//! The *execution* of both phases lives in the [`AccessOp`] core
//! ([`crate::io::op`]) and the [`IoScheduler`](crate::io::schedule) —
//! this module owns the pure machinery (file-domain assignment,
//! aggregator placement, exchange message codecs, and the
//! thread-agnostic phase drivers [`exchange_write`]/[`collective_read`])
//! plus the thin public wrappers that name their matrix cell.
//!
//! The exchange alltoall picks its schedule from the
//! `jpio_alltoall_algorithm` hint ([`AlltoallAlgorithm`]): `linear` for
//! small worlds, `pairwise` or `bruck` past the `auto` rank threshold,
//! with the rank-to-self payload always *moved*, never serialized. On
//! plan-executing backends the I/O phase hands the exchanged pieces
//! straight to [`StorageFile::write_pieces`](crate::storage::StorageFile)
//! — no payload-sized staging copy; the `staging_copy_bytes` counter
//! records what the staged fallback still copies.
//!
//! *Which thread* runs each phase depends on the routine:
//!
//! * blocking `*_ALL`: both phases on the caller;
//! * split collectives: when the world has a progress lane
//!   ([`Comm::progress_lane`]), `BEGIN` registers the op and *both*
//!   phases run on the lane; without one, exchange on the caller at
//!   `BEGIN` and storage-only I/O phase on the request engine
//!   (§7.2.9.1 double buffering);
//! * MPI-3.1 nonblocking collectives (`iread_(at_)all` /
//!   `iwrite_(at_)all`): when the world has a progress lane, *both*
//!   phases — including the reply exchange a collective read needs —
//!   run on the rank's progress thread, so the call returns after
//!   registering the operation and the whole collective overlaps
//!   computation (DESIGN.md §2). With `jpio_progress_threads > 1`
//!   independent collectives pipeline round-robin across lanes while a
//!   per-file sequencer keeps their storage phases in issue order.
//!   Without a lane (sub-communicators, forked inheritors, or
//!   `jpio_progress_threads = 0`) they fall back to the split
//!   collectives' no-lane contract: exchange on the caller, I/O on the
//!   engine.
//!
//! ## Stripe-aligned file domains
//!
//! On striped storage ([`crate::storage::striped`]) the aggregator
//! domains are not contiguous byte ranges but *stripe-cyclic* sets:
//! stripe unit `i` belongs to aggregator `i % cb_nodes`, so domain
//! boundaries always coincide with stripe boundaries and — when
//! `cb_nodes` equals the striping factor — each aggregator's I/O lands on
//! exactly one server. This is the file-domain alignment of Thakur,
//! Gropp & Lusk ("Optimizing Noncontiguous Accesses in MPI-IO") in its
//! Lustre/PVFS group-cyclic form: aggregators stop contending for each
//! other's servers, and aggregate bandwidth scales with the stripe count.
//! Under parity redundancy (`jpio_stripe_redundancy = parity`) the
//! rotation permutes the unit→server mapping, so the assignment follows
//! the unit's *data server* instead of the raw unit cycle — domains
//! stay server-disjoint on redundant files too.
//! Disable with the `jpio_cb_stripe_align = false` hint (the ablation
//! bench measures the difference). The ROMIO-style `cb_config_list` hint
//! ([`parse_cb_config_list`]) additionally pins *which rank* serves each
//! stripe server's domain; absent the hint, domain `i` falls back to the
//! stripe-cyclic default of rank `i`.
//!
//! **Degraded-aware placement** (elastic membership, DESIGN.md §1c):
//! when the striped backend reports dead servers
//! ([`StorageFile::server_health`](crate::storage::StorageFile)), units
//! whose home server is dead are remapped to the next healthy server's
//! aggregator domain. A dead server's units can only be served by
//! reconstruction from the survivors, so pinning their traffic to the
//! dead server's dedicated aggregator (or `cb_config_list` slot) would
//! concentrate the reconstruction fan-in on one rank while its "own"
//! server contributes nothing; shifting those units onto the healthy
//! cycle spreads the reconstruction-heavy rows across ranks that are
//! already talking to the surviving servers. Every remapped piece counts
//! one `degraded_domain_avoidances`. Any remapping keeps correctness:
//! domains partition the byte range whichever aggregator serves them.

use crate::comm::datatype::{Datatype, IoBuf, IoBufMut, Offset};
use crate::comm::{AlltoallAlgorithm, Comm, ReduceOp, Status};
use crate::io::engine::Request;
use crate::io::errors::Result;
use crate::io::file::File;
use crate::io::hints::keys;
use crate::io::op::{AccessOp, Coordination, Positioning, Synchronism, TransferCtx};
use crate::io::plan::IoPlan;
use crate::io::schedule::IoScheduler;
use crate::io::stats::{Counter, FileStats, Phase};
use crate::storage::layout::{Redundancy, StripeMap};

/// Serialize pieces + payload bytes into one exchange message.
pub(crate) fn encode_write_msg(pieces: &[(u64, usize, usize)], payload: &[u8]) -> Vec<u8> {
    let total: usize = pieces.iter().map(|p| p.1).sum();
    let mut msg = Vec::with_capacity(4 + pieces.len() * 16 + total);
    msg.extend_from_slice(&(pieces.len() as u32).to_le_bytes());
    for &(off, len, _) in pieces {
        msg.extend_from_slice(&off.to_le_bytes());
        msg.extend_from_slice(&(len as u64).to_le_bytes());
    }
    for &(_, len, pos) in pieces {
        msg.extend_from_slice(&payload[pos..pos + len]);
    }
    msg
}

/// Decode an exchange message's run list; returns `(runs, payload_pos)`.
pub(crate) fn decode_runs(msg: &[u8]) -> (Vec<(u64, usize)>, usize) {
    let n = u32::from_le_bytes(msg[..4].try_into().unwrap()) as usize;
    let mut runs = Vec::with_capacity(n);
    let mut pos = 4;
    for _ in 0..n {
        let off = u64::from_le_bytes(msg[pos..pos + 8].try_into().unwrap());
        let len = u64::from_le_bytes(msg[pos + 8..pos + 16].try_into().unwrap()) as usize;
        runs.push((off, len));
        pos += 16;
    }
    (runs, pos)
}

/// Aggregator file-domain assignment for one collective operation.
pub(crate) enum FileDomains {
    /// Contiguous near-even byte ranges (the classic ROMIO default).
    Contiguous(Vec<(u64, u64)>),
    /// Stripe-cyclic: stripe unit `i` belongs to aggregator
    /// [`cyclic_aggregator`] of `i` (the plain `i % naggr` cycle, or the
    /// unit's data server modulo `naggr` under parity redundancy — see
    /// the module docs). Domains are unions of stripe units, so the
    /// global byte range needs no explicit bounds here. `dead[s]` marks
    /// stripe server `s` as known-dead (from the backend's health
    /// vector); units homed there are remapped to the next healthy
    /// server's aggregator. Empty = all healthy.
    StripeCyclic { map: StripeMap, naggr: usize, dead: Vec<bool> },
}

/// Aggregator owning the stripe unit at logical offset `off`, plus
/// whether the assignment was steered away from a dead server. Plain and
/// replica layouts use the documented unit cycle (`unit i → aggregator
/// i % naggr`, which with `naggr == factor` is exactly the unit's
/// server). Parity rotation permutes the unit→server mapping, so there
/// the unit's *data server* modulo `naggr` keeps each aggregator's
/// domain on a disjoint server subset — the whole point of alignment.
/// When the unit's home server is marked dead the cycle index advances
/// to the next healthy server (degraded-aware placement, module docs);
/// with every server dead the plain cycle stands.
fn cyclic_aggregator(map: &StripeMap, naggr: usize, dead: &[bool], off: u64) -> (usize, bool) {
    let factor = map.layout.factor;
    // `cycle` drives the aggregator assignment; `server` is where the
    // unit's data physically lives (they coincide under parity).
    let (cycle, server) = match map.redundancy {
        Redundancy::Parity => {
            let s = map.locate(off).0;
            (s as u64, s)
        }
        _ => {
            let u = map.layout.stripe_of(off);
            (u, (u % factor as u64) as usize)
        }
    };
    let is_dead = |s: usize| dead.get(s).copied().unwrap_or(false);
    if is_dead(server) {
        for step in 1..factor as u64 {
            if !is_dead(((server as u64 + step) % factor as u64) as usize) {
                return (((cycle + step) % naggr as u64) as usize, true);
            }
        }
    }
    ((cycle % naggr as u64) as usize, false)
}

impl FileDomains {
    /// Pick the domain shape: stripe-cyclic when the file sits on striped
    /// storage and alignment is enabled, contiguous otherwise.
    fn choose(ctx: &TransferCtx, lo: u64, hi: u64, naggr: usize, stripe_align: bool) -> FileDomains {
        if stripe_align {
            if let Some(map) = ctx.storage.stripe_map() {
                // Known-dead servers (elastic membership) bias the
                // assignment; a backend without health tracking — or a
                // fully healthy one — yields the empty dead set.
                let dead: Vec<bool> = ctx
                    .storage
                    .server_health()
                    .map(|h| h.iter().map(|&ok| !ok).collect())
                    .unwrap_or_default();
                return FileDomains::StripeCyclic { map, naggr, dead };
            }
        }
        FileDomains::Contiguous(split_domains(lo, hi, naggr))
    }

    /// This rank's plan pieces destined for file domain `a`:
    /// `(file_off, len, payload_pos)` clipped to the domain. Pieces whose
    /// home server is dead count one `degraded_domain_avoidances` each
    /// into `stats` as they are steered to a healthy domain.
    fn pieces_for(
        &self,
        plan: &IoPlan,
        a: usize,
        stats: Option<&FileStats>,
    ) -> Vec<(u64, usize, usize)> {
        match self {
            FileDomains::Contiguous(domains) => plan.clip(domains[a]),
            FileDomains::StripeCyclic { map, naggr, dead } => {
                let mut out = Vec::new();
                let mut avoided = 0u64;
                for (i, &(off, len)) in plan.runs.iter().enumerate() {
                    // The walk splits at unit boundaries; the assignment
                    // comes from the redundancy-aware mapping.
                    map.layout.for_each_piece(off, len, |_, cur, piece_len| {
                        let (agg, remapped) = cyclic_aggregator(map, *naggr, dead, cur);
                        if agg == a {
                            out.push((cur, piece_len, plan.positions[i] + (cur - off) as usize));
                            avoided += remapped as u64;
                        }
                    });
                }
                if avoided > 0 {
                    if let Some(stats) = stats {
                        stats.add(Counter::DegradedDomainAvoidances, avoided);
                    }
                }
                out
            }
        }
    }
}

/// Work an aggregator owes the I/O phase of a collective write; executed
/// by `IoScheduler::write_phase` / `IoScheduler::write_phase_async`.
pub(crate) struct WriteIoWork {
    /// Raw inbound exchange messages in rank order. Run *headers* are
    /// decoded up front by the I/O phase; payload bytes stay in place
    /// until their staging round is built, so the decode of round `n+1`
    /// can overlap the storage write of round `n` (the double-buffer
    /// pipeline in `IoScheduler::write_phase`).
    pub inbound: Vec<Vec<u8>>,
    /// Staging-buffer (round) size for the aggregator pipeline.
    pub cb_buffer: usize,
}

impl WriteIoWork {
    /// No aggregator work (non-aggregators, degenerate collectives).
    pub(crate) fn empty() -> WriteIoWork {
        WriteIoWork { inbound: Vec::new(), cb_buffer: 1 }
    }
}

/// Collective-buffering parameters snapshotted from the Info hints.
pub(crate) struct CbParams {
    /// `cb_nodes`: number of aggregators (`None` = every rank).
    pub nodes: Option<usize>,
    /// `cb_buffer_size`: aggregator staging-buffer bytes.
    pub buffer: Option<usize>,
    /// `jpio_staging_buffer_size`: round size of the aggregator
    /// double-buffer pipeline; defaults to `cb_buffer_size`.
    pub staging: Option<usize>,
    /// `romio_cb_read`: collective buffering on/off.
    pub enabled: bool,
    /// `jpio_cb_stripe_align`: stripe-aligned file domains on/off.
    pub stripe_align: bool,
    /// Parsed `cb_config_list`: explicit aggregator-rank placement per
    /// file domain; `None` falls back to rank `i` aggregating domain `i`.
    pub config_list: Option<Vec<usize>>,
    /// `jpio_alltoall_algorithm`: exchange algorithm for the two-phase
    /// alltoalls (auto/linear/pairwise/bruck).
    pub alltoall_algo: AlltoallAlgorithm,
}

impl CbParams {
    /// Aggregator staging bytes for the phase pipelines
    /// (`jpio_staging_buffer_size`, defaulting to `cb_buffer_size`).
    pub(crate) fn staging_bytes(&self) -> usize {
        self.staging.or(self.buffer).unwrap_or(16 << 20).max(4096)
    }
}

/// Parse a ROMIO-style `cb_config_list` hint into an aggregator rank
/// list. ROMIO's grammar names hosts; in a single-machine world ranks
/// stand in for hosts, so entries are `rank` or `rank:count` (the rank
/// serves `count` consecutive file domains), with `*` expanding to all
/// ranks. Returns `None` — fall back to the default placement — when the
/// spec is empty or malformed, per the MPI rule that unrecognized hint
/// values are ignored.
pub(crate) fn parse_cb_config_list(spec: &str, n: usize) -> Option<Vec<usize>> {
    let mut out = Vec::new();
    for tok in spec.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        if tok == "*" {
            out.extend(0..n);
            continue;
        }
        let (rank_s, count_s) = match tok.split_once(':') {
            Some((r, c)) => (r, c),
            None => (tok, "1"),
        };
        let rank: usize = rank_s.trim().parse().ok()?;
        let count: usize = count_s.trim().parse().ok()?;
        if rank >= n || count == 0 {
            return None;
        }
        out.resize(out.len() + count, rank);
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// The rank owning each file domain of a collective: `aggr[j]` is the
/// rank that aggregates domain `j`. Without `cb_config_list` this is the
/// identity on the first `cb_nodes` ranks (the stripe-cyclic default);
/// with it, the parsed list is tiled across the domains, pinning e.g.
/// stripe server `j`'s traffic to the listed rank.
pub(crate) fn aggregator_ranks(cb: &CbParams, n: usize) -> Vec<usize> {
    match &cb.config_list {
        Some(list) if !list.is_empty() => {
            let naggr = cb.nodes.unwrap_or(list.len()).clamp(1, n.max(list.len()));
            (0..naggr).map(|j| list[j % list.len()]).collect()
        }
        _ => {
            let naggr = cb.nodes.unwrap_or(n).clamp(1, n);
            (0..naggr).collect()
        }
    }
}

/// The shared first half of every two-phase collective: agree on the
/// global byte range and clip this rank's plan into per-aggregator-rank
/// piece lists (`result[rank]` = sorted pieces destined for `rank`; a
/// rank pinned to several domains receives them concatenated). `None`
/// when the collective's global byte range is empty.
pub(crate) fn route_to_aggregators(
    comm: &dyn Comm,
    ctx: &TransferCtx,
    cb: &CbParams,
    plan: &IoPlan,
) -> Option<Vec<Vec<(u64, usize, usize)>>> {
    let n = comm.size();
    let (my_min, my_max) = match plan.bounds() {
        Some((lo, hi)) => (lo as i64, hi as i64),
        None => (i64::MAX, 0),
    };
    let gmin = comm.allreduce_i64(ReduceOp::Min, my_min);
    let gmax = comm.allreduce_i64(ReduceOp::Max, my_max);
    if gmin >= gmax {
        return None;
    }
    let owners = aggregator_ranks(cb, n);
    let domains = FileDomains::choose(ctx, gmin as u64, gmax as u64, owners.len(), cb.stripe_align);
    let mut per_rank: Vec<Vec<(u64, usize, usize)>> = vec![Vec::new(); n];
    for (j, &rank) in owners.iter().enumerate() {
        per_rank[rank].extend(domains.pieces_for(plan, j, Some(&*ctx.stats)));
    }
    for pieces in &mut per_rank {
        pieces.sort_unstable_by_key(|&(off, _, _)| off);
    }
    Some(per_rank)
}

/// Split `[lo, hi)` into `n` near-even contiguous domains.
fn split_domains(lo: u64, hi: u64, n: usize) -> Vec<(u64, u64)> {
    let total = hi - lo;
    let base = total / n as u64;
    let rem = (total % n as u64) as usize;
    let mut out = Vec::with_capacity(n);
    let mut cur = lo;
    for i in 0..n {
        let len = base + (i < rem) as u64;
        out.push((cur, cur + len));
        cur += len;
    }
    out
}

/// Sort + merge overlapping/adjacent intervals.
pub(crate) fn merge_intervals(iv: &mut Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    iv.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::with_capacity(iv.len());
    for &(s, e) in iv.iter() {
        if let Some(last) = out.last_mut() {
            if s <= last.1 {
                last.1 = last.1.max(e);
                continue;
            }
        }
        out.push((s, e));
    }
    out
}

// ----------------------------------------------------------------------
// Thread-agnostic phase drivers
// ----------------------------------------------------------------------
//
// Both drivers take the communicator endpoint explicitly, so the same
// code runs on the application thread (blocking and split collectives,
// lane-less fallbacks) and on the rank's progress thread (the MPI-3.1
// nonblocking collectives' off-caller path). Plans are compiled by the
// caller — through the handle's plan cache — before the hand-off.

/// Exchange phase of a collective write: route this rank's plan pieces
/// to their aggregators and collect, still encoded, the messages this
/// rank owes the I/O phase as an aggregator. On degenerate collectives
/// (buffering disabled or a single rank) the payload is written
/// independently here and the returned work is empty. Returns the work
/// plus this rank's payload byte count.
pub(crate) fn exchange_write(
    comm: &dyn Comm,
    ctx: &TransferCtx,
    cb: &CbParams,
    plan: &IoPlan,
    payload: &[u8],
) -> Result<(WriteIoWork, usize)> {
    let n = comm.size();
    if !cb.enabled || n == 1 {
        // Degenerate: independent write, collective completion only.
        IoScheduler::write(ctx, plan, payload)?;
        return Ok((WriteIoWork::empty(), payload.len()));
    }
    let per_rank = match route_to_aggregators(comm, ctx, cb, plan) {
        Some(p) => p,
        None => return Ok((WriteIoWork::empty(), payload.len())),
    };
    let msgs: Vec<Vec<u8>> =
        per_rank.iter().map(|pieces| encode_write_msg(pieces, payload)).collect();
    let t0 = ctx.stats.start();
    // `alltoall_owned` moves the messages into the exchange, so the
    // rank-to-self slot changes hands without a serialize/copy cycle.
    let inbound = comm.alltoall_owned(msgs, cb.alltoall_algo);
    ctx.stats.record(Phase::Exchange, t0);
    Ok((WriteIoWork { inbound, cb_buffer: cb.staging_bytes() }, payload.len()))
}

/// Full collective read: request exchange, aggregator pipelined sieved
/// reads (reply slicing of round `n` overlapped with the storage read of
/// round `n+1`), reply exchange, local reassembly. Returns the
/// EOF-clamped bytes read into `payload`.
pub(crate) fn collective_read(
    comm: &dyn Comm,
    ctx: &TransferCtx,
    cb: &CbParams,
    plan: &IoPlan,
    payload: &mut [u8],
) -> Result<usize> {
    let n = comm.size();
    if !cb.enabled || n == 1 {
        let got = IoScheduler::read(ctx, plan, payload)?;
        if cb.enabled {
            comm.barrier();
        }
        return Ok(got);
    }
    // Request phase: ship (off,len) lists to the owning aggregators.
    let my_pieces = match route_to_aggregators(comm, ctx, cb, plan) {
        Some(p) => p,
        None => return Ok(0),
    };
    let mut reqs = Vec::with_capacity(n);
    for pieces in &my_pieces {
        let mut msg = Vec::with_capacity(4 + pieces.len() * 16);
        msg.extend_from_slice(&(pieces.len() as u32).to_le_bytes());
        for &(off, len, _) in pieces.iter() {
            msg.extend_from_slice(&off.to_le_bytes());
            msg.extend_from_slice(&(len as u64).to_le_bytes());
        }
        reqs.push(msg);
    }
    let t0 = ctx.stats.start();
    let inbound = comm.alltoall_owned(reqs, cb.alltoall_algo);
    ctx.stats.record(Phase::Exchange, t0);

    // Aggregator I/O phase: merge all requested intervals, then read
    // them through the pipelined scheduler.
    let eof = ctx.storage.size()?;
    let mut per_src_runs: Vec<Vec<(u64, usize)>> = Vec::with_capacity(n);
    let mut intervals: Vec<(u64, u64)> = Vec::new();
    for msg in &inbound {
        let (rs, _) = decode_runs(msg);
        for &(off, len) in &rs {
            intervals.push((off, off + len as u64));
        }
        per_src_runs.push(rs);
    }
    let merged = merge_intervals(&mut intervals);
    let merged_runs: Vec<(u64, usize)> =
        merged.iter().map(|&(s, e)| (s, (e - s) as usize)).collect();
    let total: usize = merged_runs.iter().map(|r| r.1).sum();
    let mut agg_buf = vec![0u8; total];
    let locate = |off: u64| -> Option<usize> {
        // Position of `off` within the packed agg_buf.
        let mut base = 0usize;
        for &(s, e) in &merged {
            if off >= s && off < e {
                return Some(base + (off - s) as usize);
            }
            base += (e - s) as usize;
        }
        None
    };
    // Reply layout: each source's reply is its runs concatenated in
    // request order. Every requested run lies inside exactly one merged
    // interval — and rounds never split an interval — so each run can be
    // sliced into its reply the moment its round's bytes land, while the
    // next round is still being read from storage.
    let mut reply_len = vec![0usize; n];
    let mut scatter: Vec<(usize, usize, usize, usize)> = Vec::new(); // (agg pos, len, src, cursor)
    for (src, rs) in per_src_runs.iter().enumerate() {
        for &(off, len) in rs {
            let p = locate(off).expect("requested run must be inside merged intervals");
            scatter.push((p, len, src, reply_len[src]));
            reply_len[src] += len;
        }
    }
    scatter.sort_unstable_by_key(|&(p, ..)| p);
    let mut replies: Vec<Vec<u8>> = reply_len.iter().map(|&l| vec![0u8; l]).collect();
    let mut si = 0usize;
    IoScheduler::read_phase_pipelined(
        ctx,
        &merged_runs,
        cb.staging_bytes(),
        &mut agg_buf,
        |base, round: &[u8]| {
            while si < scatter.len() {
                let (p, len, src, cursor) = scatter[si];
                if p >= base + round.len() {
                    break;
                }
                let s = p - base;
                replies[src][cursor..cursor + len].copy_from_slice(&round[s..s + len]);
                si += 1;
            }
        },
    )?;
    debug_assert_eq!(si, scatter.len(), "every requested run must be sliced into a reply");
    let t0 = ctx.stats.start();
    let mut answers = comm.alltoall_owned(replies, cb.alltoall_algo);
    ctx.stats.record(Phase::Exchange, t0);

    // Reassemble my payload from the per-aggregator answers; compute
    // the EOF-clamped byte count.
    let mut got = 0usize;
    for (a, pieces) in my_pieces.iter().enumerate() {
        let ans = std::mem::take(&mut answers[a]);
        let mut cursor = 0usize;
        for &(off, len, pos) in pieces {
            payload[pos..pos + len].copy_from_slice(&ans[cursor..cursor + len]);
            cursor += len;
            let visible = (eof.saturating_sub(off) as usize).min(len);
            got += visible;
        }
    }
    // Datarep decode on the assembled payload.
    if plan.needs_convert() {
        plan.datarep.decode(&mut payload[..got], &plan.decode_elems(got));
    }
    Ok(got)
}

impl File<'_> {
    pub(crate) fn cb_params(&self) -> CbParams {
        self.cb_params_with(None)
    }

    /// [`CbParams`] with an optional per-operation hint overlay: the
    /// overlay's keys shadow the file's Info for this one snapshot, so a
    /// single operation can switch e.g. the exchange algorithm or the
    /// staging-round size without mutating the handle (the per-op hints
    /// of [`File::submit_write_with`]/[`File::submit_read_with`]).
    pub(crate) fn cb_params_with(&self, overlay: Option<&Info>) -> CbParams {
        let merged;
        let guard = self.info.lock().unwrap();
        let info: &Info = match overlay {
            Some(over) => {
                let mut m = guard.clone();
                m.merge(over);
                merged = m;
                &merged
            }
            None => &*guard,
        };
        CbParams {
            nodes: info.get_usize(keys::CB_NODES),
            buffer: info.get_usize(keys::CB_BUFFER_SIZE),
            staging: info.get_usize(keys::STAGING_BUFFER_SIZE),
            enabled: info.get_flag(keys::COLLECTIVE_BUFFERING).unwrap_or(true),
            stripe_align: info.get_flag(keys::CB_STRIPE_ALIGN).unwrap_or(true),
            config_list: info
                .get(keys::CB_CONFIG_LIST)
                .and_then(|spec| parse_cb_config_list(spec, self.comm.size())),
            alltoall_algo: AlltoallAlgorithm::parse(info.get(keys::ALLTOALL_ALGORITHM)),
        }
    }

    /// `MPI_FILE_WRITE_AT_ALL`: collective write at explicit offsets.
    pub fn write_at_all(
        &self,
        offset: Offset,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::write(
            Positioning::Explicit(offset),
            Coordination::Collective,
            Synchronism::Blocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.status()
    }

    /// `MPI_FILE_READ_AT_ALL`: collective read at explicit offsets.
    pub fn read_at_all(
        &self,
        offset: Offset,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::read(
            Positioning::Explicit(offset),
            Coordination::Collective,
            Synchronism::Blocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_read(&op, buf)
    }

    /// `MPI_FILE_WRITE_ALL`: collective write at the individual pointer.
    pub fn write_all(
        &self,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::write(
            Positioning::Individual,
            Coordination::Collective,
            Synchronism::Blocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.status()
    }

    /// `MPI_FILE_READ_ALL`: collective read at the individual pointer.
    pub fn read_all(
        &self,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::read(
            Positioning::Individual,
            Coordination::Collective,
            Synchronism::Blocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_read(&op, buf)
    }

    // ------------------------------------------------------------------
    // MPI-3.1 nonblocking collectives
    // ------------------------------------------------------------------

    /// `MPI_FILE_IWRITE_AT_ALL` (MPI-3.1): nonblocking collective write
    /// at an explicit offset. On worlds with a progress lane (the thread
    /// and process transports) the call returns after registering the
    /// operation, and *both* phases — aggregator exchange and storage
    /// I/O — run on the rank's progress thread, fully overlapping
    /// computation. Without a lane (sub-communicators, or
    /// `jpio_progress_threads = 0`) the exchange runs in this call and
    /// only the I/O phase overlaps, like the split collectives.
    /// Completion ([`Request::wait`]) is local — no barrier.
    pub fn iwrite_at_all(
        &self,
        offset: Offset,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Request<()>> {
        let op = AccessOp::write(
            Positioning::Explicit(offset),
            Coordination::Collective,
            Synchronism::Nonblocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.request()
    }

    /// `MPI_FILE_IREAD_AT_ALL` (MPI-3.1): nonblocking collective read at
    /// an explicit offset. On worlds with a progress lane the request
    /// exchange, aggregation, reply exchange, and the scatter into `buf`
    /// all run on the rank's progress thread — the call returns before
    /// any byte moves. Without a lane the exchange and aggregation
    /// complete in this call (the split-read contract) and only the
    /// local scatter/decode runs on the engine.
    pub fn iread_at_all<T>(
        &self,
        offset: Offset,
        buf: Vec<T>,
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Request<Vec<T>>>
    where
        T: Send + 'static,
        [T]: IoBufMut,
    {
        let op = AccessOp::read(
            Positioning::Explicit(offset),
            Coordination::Collective,
            Synchronism::Nonblocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_read_owned(&op, buf)
    }

    /// `MPI_FILE_IWRITE_ALL` (MPI-3.1): nonblocking collective write at
    /// the individual pointer. The pointer advances immediately by the
    /// full request size (the same MPI semantics as [`File::iwrite`]).
    pub fn iwrite_all(
        &self,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Request<()>> {
        let op = AccessOp::write(
            Positioning::Individual,
            Coordination::Collective,
            Synchronism::Nonblocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.request()
    }

    /// `MPI_FILE_IREAD_ALL` (MPI-3.1): nonblocking collective read at the
    /// individual pointer.
    pub fn iread_all<T>(
        &self,
        buf: Vec<T>,
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Request<Vec<T>>>
    where
        T: Send + 'static,
        [T]: IoBufMut,
    {
        let op = AccessOp::read(
            Positioning::Individual,
            Coordination::Collective,
            Synchronism::Nonblocking,
            buf_offset,
            count,
            datatype,
        );
        self.submit_read_owned(&op, buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::threads;
    use crate::comm::Comm;
    use crate::io::file::amode;
    use crate::io::hints::Info;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-coll-{}-{name}", std::process::id())
    }

    #[test]
    fn split_domains_cover_exactly() {
        let d = split_domains(10, 107, 4);
        assert_eq!(d.len(), 4);
        assert_eq!(d[0].0, 10);
        assert_eq!(d[3].1, 107);
        for w in d.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn merge_intervals_handles_overlap_and_adjacency() {
        let mut iv = vec![(10, 20), (0, 5), (5, 8), (15, 30), (40, 41)];
        assert_eq!(merge_intervals(&mut iv), vec![(0, 8), (10, 30), (40, 41)]);
    }

    #[test]
    fn stripe_cyclic_domains_partition_at_unit_boundaries() {
        use crate::storage::layout::StripeLayout;
        let map = StripeMap::new(StripeLayout::new(10, 2).unwrap(), Redundancy::None).unwrap();
        let d = FileDomains::StripeCyclic { map, naggr: 2, dead: Vec::new() };
        // One run [5, 45): stripes 0..4 → aggregator 0 gets stripes 0 and
        // 2, aggregator 1 gets stripes 1 and 3.
        let mut plan = IoPlan::from_runs(vec![(5u64, 40usize)], false);
        plan.positions = vec![100]; // pretend the payload starts at 100
        let a0 = d.pieces_for(&plan, 0, None);
        let a1 = d.pieces_for(&plan, 1, None);
        assert_eq!(a0, vec![(5, 5, 100), (20, 10, 115), (40, 5, 135)]);
        assert_eq!(a1, vec![(10, 10, 105), (30, 10, 125)]);
        // Together the pieces cover the run exactly.
        let total: usize = a0.iter().chain(&a1).map(|p| p.1).sum();
        assert_eq!(total, 40);
        for &(off, len, _) in a0.iter().chain(&a1) {
            assert_eq!(off / 10, (off + len as u64 - 1) / 10, "piece crosses a boundary");
        }
    }

    #[test]
    fn stripe_cyclic_domains_follow_parity_data_servers() {
        use crate::storage::layout::StripeLayout;
        // Under parity the rotation permutes the unit→server mapping;
        // with naggr == factor each aggregator's pieces must still land
        // on exactly one server — its own.
        let map = StripeMap::new(StripeLayout::new(10, 4).unwrap(), Redundancy::Parity).unwrap();
        let d = FileDomains::StripeCyclic { map, naggr: 4, dead: Vec::new() };
        let plan = IoPlan::from_runs(vec![(5u64, 110usize)], false);
        let mut total = 0usize;
        for a in 0..4 {
            for &(off, len, _) in &d.pieces_for(&plan, a, None) {
                assert_eq!(map.locate(off).0, a, "piece at {off} not on aggregator {a}'s server");
                total += len;
            }
        }
        // Together the pieces cover the run exactly once.
        assert_eq!(total, 110);
    }

    #[test]
    fn dead_server_units_steer_to_next_healthy_domain() {
        use crate::io::stats::FileStats;
        use crate::storage::layout::StripeLayout;
        // Parity, factor 4, naggr == factor, server 1 dead: every unit
        // homed on server 1 must leave domain 1 for domain 2 (the next
        // healthy server's aggregator), the partition must stay exact,
        // and each steered piece must count one avoidance.
        let map = StripeMap::new(StripeLayout::new(10, 4).unwrap(), Redundancy::Parity).unwrap();
        let dead = vec![false, true, false, false];
        let d = FileDomains::StripeCyclic { map, naggr: 4, dead };
        let plan = IoPlan::from_runs(vec![(0u64, 120usize)], false);
        let stats = FileStats::disabled();
        let mut total = 0usize;
        let mut displaced = 0u64;
        for a in 0..4 {
            for &(off, len, _) in &d.pieces_for(&plan, a, Some(&stats)) {
                let server = map.locate(off).0;
                assert_ne!(a, 1, "dead server 1's domain must receive nothing");
                if server == 1 {
                    assert_eq!(a, 2, "server 1's units must land on server 2's domain");
                    displaced += 1;
                }
                total += len;
            }
        }
        assert_eq!(total, 120, "steering must not change the partition's coverage");
        assert!(displaced > 0, "the 120-byte run must include server-1 units");
        assert_eq!(
            stats.value(Counter::DegradedDomainAvoidances),
            displaced,
            "one avoidance per steered piece"
        );
        // All-dead degenerates to the plain cycle (nothing to steer to).
        let all_dead = FileDomains::StripeCyclic { map, naggr: 4, dead: vec![true; 4] };
        let healthy = FileDomains::StripeCyclic { map, naggr: 4, dead: Vec::new() };
        for a in 0..4 {
            assert_eq!(all_dead.pieces_for(&plan, a, None), healthy.pieces_for(&plan, a, None));
        }
    }

    #[test]
    fn cb_config_list_parses_romio_style() {
        assert_eq!(parse_cb_config_list("0,2,5", 8), Some(vec![0, 2, 5]));
        assert_eq!(parse_cb_config_list("1:3", 4), Some(vec![1, 1, 1]));
        assert_eq!(parse_cb_config_list("3, 1:2 ,0", 4), Some(vec![3, 1, 1, 0]));
        assert_eq!(parse_cb_config_list("*", 3), Some(vec![0, 1, 2]));
        // Out-of-range rank, zero count, garbage → ignored hint.
        assert_eq!(parse_cb_config_list("7", 4), None);
        assert_eq!(parse_cb_config_list("1:0", 4), None);
        assert_eq!(parse_cb_config_list("host1:2", 4), None);
        assert_eq!(parse_cb_config_list("", 4), None);
    }

    #[test]
    fn aggregator_ranks_pin_and_fall_back() {
        let base = CbParams {
            nodes: None,
            buffer: None,
            staging: None,
            enabled: true,
            stripe_align: true,
            config_list: None,
            alltoall_algo: AlltoallAlgorithm::Auto,
        };
        // Default: stripe-cyclic identity placement.
        assert_eq!(aggregator_ranks(&base, 4), vec![0, 1, 2, 3]);
        let two = CbParams { nodes: Some(2), ..base };
        assert_eq!(aggregator_ranks(&two, 4), vec![0, 1]);
        // Pinned: domain j → list[j % len], tiled across cb_nodes domains.
        let pinned = CbParams { config_list: Some(vec![3, 1]), nodes: None, ..two };
        assert_eq!(aggregator_ranks(&pinned, 4), vec![3, 1]);
        let pinned4 = CbParams { config_list: Some(vec![3, 1]), nodes: Some(4), ..pinned };
        assert_eq!(aggregator_ranks(&pinned4, 4), vec![3, 1, 3, 1]);
    }

    #[test]
    fn collective_on_striped_storage_aligned_and_not() {
        use crate::storage::striped::StripedBackend;
        for align in ["true", "false"] {
            let path = tmp(&format!("striped-{align}"));
            threads::run(4, |c| {
                let backend: std::sync::Arc<dyn crate::storage::Backend> =
                    std::sync::Arc::new(StripedBackend::local(4, 64));
                let info = Info::from([(keys::CB_STRIPE_ALIGN, align), (keys::CB_NODES, "4")]);
                let f = File::open_with_backend(
                    c,
                    &path,
                    amode::RDWR | amode::CREATE,
                    info,
                    backend,
                )
                .unwrap();
                let n = c.size();
                let r = c.rank();
                // Interleaved strided pattern: rank r owns every n-th int.
                let ft = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
                let ft = Datatype::resized(&ft, 0, (n * 4) as i64).unwrap();
                f.set_view((r * 4) as i64, &Datatype::INT, &ft, "native", &Info::null())
                    .unwrap();
                let k = 300; // spans many 64-byte stripe units
                let mine: Vec<i32> = (0..k).map(|i| (i * n + r) as i32).collect();
                f.write_at_all(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
                c.barrier();
                let mut back = vec![0i32; k];
                let st = f.read_at_all(0, back.as_mut_slice(), 0, k, &Datatype::INT).unwrap();
                assert_eq!(st.bytes, k * 4);
                assert_eq!(back, mine);
                // Flat logical contents check through the striped file.
                f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null())
                    .unwrap();
                let total = k * n;
                let mut all = vec![0i32; total];
                f.read_at(0, all.as_mut_slice(), 0, total, &Datatype::INT).unwrap();
                let want: Vec<i32> = (0..total as i32).collect();
                assert_eq!(all, want);
                f.close().unwrap();
            });
            let backend = StripedBackend::local(4, 64);
            crate::storage::Backend::delete(&backend, &path).unwrap();
            let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
        }
    }

    #[test]
    fn cb_config_list_pins_aggregators_and_stays_correct() {
        // Pin every file domain to rank 2 ("2:4"), then to a reversed
        // rank list on striped storage; the data path must stay correct
        // either way (placement changes who does the I/O, not what lands).
        use crate::storage::striped::StripedBackend;
        for (list, striped) in [("2:4", false), ("3,2,1,0", true)] {
            let path = tmp(&format!("cbcfg-{}", if striped { "striped" } else { "flat" }));
            threads::run(4, |c| {
                let info = Info::from([(keys::CB_CONFIG_LIST, list), (keys::CB_NODES, "4")]);
                let backend: std::sync::Arc<dyn crate::storage::Backend> = if striped {
                    std::sync::Arc::new(StripedBackend::local(4, 64))
                } else {
                    std::sync::Arc::new(crate::storage::local::LocalBackend::instant())
                };
                let f = File::open_with_backend(
                    c,
                    &path,
                    amode::RDWR | amode::CREATE,
                    info,
                    backend,
                )
                .unwrap();
                let n = c.size();
                let r = c.rank();
                let ft = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
                let ft = Datatype::resized(&ft, 0, (n * 4) as i64).unwrap();
                f.set_view((r * 4) as i64, &Datatype::INT, &ft, "native", &Info::null())
                    .unwrap();
                let k = 256;
                let mine: Vec<i32> = (0..k).map(|i| (i * n + r) as i32).collect();
                f.write_at_all(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
                c.barrier();
                let mut back = vec![0i32; k];
                let st = f.read_at_all(0, back.as_mut_slice(), 0, k, &Datatype::INT).unwrap();
                assert_eq!(st.bytes, k * 4);
                assert_eq!(back, mine);
                f.close().unwrap();
            });
            if striped {
                let backend = StripedBackend::local(4, 64);
                let _ = crate::storage::Backend::delete(&backend, &path);
                let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
            } else {
                File::delete(&path, &Info::null()).unwrap();
            }
        }
    }

    #[test]
    fn collective_write_read_interleaved_blocks() {
        let path = tmp("blocks");
        threads::run(4, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let n = c.size();
            let r = c.rank();
            // Rank r writes ints [r*256, (r+1)*256) at its block.
            f.set_view((r * 1024) as i64, &Datatype::INT, &Datatype::INT, "native", &Info::null())
                .unwrap();
            let mine: Vec<i32> = (0..256).map(|i| (r * 256 + i) as i32).collect();
            let st = f.write_all(mine.as_slice(), 0, 256, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, 1024);
            f.sync().unwrap();
            c.barrier();
            f.close().unwrap();

            let f2 = File::open(c, &path, amode::RDONLY, Info::null()).unwrap();
            let mut all = vec![0i32; 256 * n];
            let st = f2.read_at_all(0, all.as_mut_slice(), 0, 256 * n, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, 1024 * n);
            let want: Vec<i32> = (0..(256 * n) as i32).collect();
            assert_eq!(all, want);
            f2.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn collective_strided_interleave_two_phase() {
        // The classic two-phase win: rank r owns every n-th int. One
        // collective write must produce the full interleaved file.
        let path = tmp("strided");
        threads::run(4, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let n = c.size();
            let r = c.rank();
            let ft = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
            let ft = Datatype::resized(&ft, 0, (n * 4) as i64).unwrap();
            f.set_view((r * 4) as i64, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
            let k = 512;
            let mine: Vec<i32> = (0..k).map(|i| (i * n + r) as i32).collect();
            f.write_at_all(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
            c.barrier();
            // Read back collectively through the same strided view.
            let mut back = vec![0i32; k];
            let st = f.read_at_all(0, back.as_mut_slice(), 0, k, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, k * 4);
            assert_eq!(back, mine);
            f.close().unwrap();
        });
        // Flat check.
        let raw = std::fs::read(&path).unwrap();
        let ints: Vec<i32> =
            raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
        let want: Vec<i32> = (0..ints.len() as i32).collect();
        assert_eq!(ints, want);
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn cb_nodes_one_aggregator_still_correct() {
        let path = tmp("onenode");
        threads::run(3, |c| {
            let info = Info::from([(keys::CB_NODES, "1"), (keys::CB_BUFFER_SIZE, "4096")]);
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, info).unwrap();
            let r = c.rank();
            let data = vec![r as i32; 100];
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            f.write_at_all((r * 100) as i64, data.as_slice(), 0, 100, &Datatype::INT).unwrap();
            c.barrier();
            let mut all = vec![0i32; 300];
            f.read_at_all(0, all.as_mut_slice(), 0, 300, &Datatype::INT).unwrap();
            for (i, v) in all.iter().enumerate() {
                assert_eq!(*v, (i / 100) as i32);
            }
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn collective_buffering_disabled_fallback() {
        let path = tmp("nocb");
        threads::run(2, |c| {
            let info = Info::from([(keys::COLLECTIVE_BUFFERING, "false")]);
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, info).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let r = c.rank();
            let data = vec![(r + 1) as i32; 64];
            f.write_at_all((r * 64) as i64, data.as_slice(), 0, 64, &Datatype::INT).unwrap();
            c.barrier();
            let mut back = vec![0i32; 128];
            f.read_at_all(0, back.as_mut_slice(), 0, 128, &Datatype::INT).unwrap();
            assert!(back[..64].iter().all(|&v| v == 1));
            assert!(back[64..].iter().all(|&v| v == 2));
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn collective_read_shorter_than_eof_clamps() {
        let path = tmp("eofclamp");
        threads::run(2, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            if c.rank() == 0 {
                f.write_at(0, vec![5i32; 10].as_slice(), 0, 10, &Datatype::INT).unwrap();
            }
            c.barrier();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let mut buf = vec![0i32; 20];
            let st = f.read_at_all(0, buf.as_mut_slice(), 0, 20, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, 40);
            assert_eq!(st.count(&Datatype::INT), Some(10));
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn nonblocking_collective_roundtrip_threaded() {
        // iwrite_all / iread_all through the strided interleave: the
        // engine-scheduled I/O phase must produce the same file as the
        // blocking two-phase path, and the individual pointer advances
        // immediately.
        let path = tmp("nbcoll");
        threads::run(4, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let n = c.size();
            let r = c.rank();
            let ft = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
            let ft = Datatype::resized(&ft, 0, (n * 4) as i64).unwrap();
            f.set_view((r * 4) as i64, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
            let k = 256;
            let mine: Vec<i32> = (0..k).map(|i| (i * n + r) as i32).collect();
            let req = f.iwrite_all(mine.as_slice(), 0, k, &Datatype::INT).unwrap();
            assert_eq!(f.get_position().unwrap(), k as i64, "pointer advances at call");
            let (st, ()) = req.wait().unwrap();
            assert_eq!(st.bytes, k * 4);
            c.barrier();
            f.seek(0, crate::io::file::seek::SET).unwrap();
            let req = f.iread_all(vec![0i32; k], 0, k, &Datatype::INT).unwrap();
            let (st, back) = req.wait().unwrap();
            assert_eq!(st.bytes, k * 4);
            assert_eq!(back, mine);
            f.close().unwrap();
        });
        let raw = std::fs::read(&path).unwrap();
        let ints: Vec<i32> =
            raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
        let want: Vec<i32> = (0..ints.len() as i32).collect();
        assert_eq!(ints, want);
        File::delete(&path, &Info::null()).unwrap();
    }
}
