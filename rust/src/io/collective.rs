//! Collective data access (`*_ALL`, §7.2.4) with two-phase collective
//! buffering — ROMIO's flagship optimization ("an optimized implementation
//! of collective I/O, an important optimization in parallel I/O", §2.2.1).
//!
//! ## Two-phase algorithm
//!
//! 1. Every rank flattens its request through its view into absolute byte
//!    runs and the ranks agree on the global byte range.
//! 2. The range is split into contiguous *aggregator domains* (`cb_nodes`
//!    hint; default: every rank aggregates).
//! 3. **Exchange phase** (communication): each rank ships the pieces of
//!    its request that fall into each domain to that domain's aggregator.
//! 4. **I/O phase** (storage): aggregators merge the pieces into large,
//!    mostly-contiguous transfers (data sieving on reads) and hit the
//!    file once, instead of N ranks issuing interleaved small I/O.
//!
//! The I/O phase touches only storage, which is what lets the split
//! collectives ([`crate::io::split`]) run it on the request engine while
//! the application computes (§7.2.9.1 double buffering).
//!
//! ## Stripe-aligned file domains
//!
//! On striped storage ([`crate::storage::striped`]) the aggregator
//! domains are not contiguous byte ranges but *stripe-cyclic* sets:
//! stripe unit `i` belongs to aggregator `i % cb_nodes`, so domain
//! boundaries always coincide with stripe boundaries and — when
//! `cb_nodes` equals the striping factor — each aggregator's I/O lands on
//! exactly one server. This is the file-domain alignment of Thakur,
//! Gropp & Lusk ("Optimizing Noncontiguous Accesses in MPI-IO") in its
//! Lustre/PVFS group-cyclic form: aggregators stop contending for each
//! other's servers, and aggregate bandwidth scales with the stripe count.
//! Disable with the `jpio_cb_stripe_align = false` hint (the ablation
//! bench measures the difference).

use crate::comm::datatype::{Datatype, IoBuf, IoBufMut, Offset};
use crate::comm::{Comm, ReduceOp, Status};
use crate::io::access::{pack_payload, read_payload, unpack_payload, write_payload, TransferCtx};
use crate::io::errors::Result;
use crate::io::file::File;
use crate::io::hints::keys;
use crate::storage::layout::StripeLayout;
use crate::strategy::{AccessStrategy, ViewBufStrategy};

/// One rank's pieces destined for a single aggregator.
fn slice_runs_for_domain(
    runs: &[(u64, usize)],
    payload_positions: &[usize],
    domain: (u64, u64),
) -> Vec<(u64, usize, usize)> {
    // Returns (file_off, len, payload_pos) clipped to the domain.
    let mut out = Vec::new();
    for (i, &(off, len)) in runs.iter().enumerate() {
        let end = off + len as u64;
        let s = off.max(domain.0);
        let e = end.min(domain.1);
        if s < e {
            let head = (s - off) as usize;
            out.push((s, (e - s) as usize, payload_positions[i] + head));
        }
    }
    out
}

/// Serialize pieces + payload bytes into one exchange message.
fn encode_write_msg(pieces: &[(u64, usize, usize)], payload: &[u8]) -> Vec<u8> {
    let total: usize = pieces.iter().map(|p| p.1).sum();
    let mut msg = Vec::with_capacity(4 + pieces.len() * 16 + total);
    msg.extend_from_slice(&(pieces.len() as u32).to_le_bytes());
    for &(off, len, _) in pieces {
        msg.extend_from_slice(&off.to_le_bytes());
        msg.extend_from_slice(&(len as u64).to_le_bytes());
    }
    for &(_, len, pos) in pieces {
        msg.extend_from_slice(&payload[pos..pos + len]);
    }
    msg
}

fn decode_runs(msg: &[u8]) -> (Vec<(u64, usize)>, usize) {
    let n = u32::from_le_bytes(msg[..4].try_into().unwrap()) as usize;
    let mut runs = Vec::with_capacity(n);
    let mut pos = 4;
    for _ in 0..n {
        let off = u64::from_le_bytes(msg[pos..pos + 8].try_into().unwrap());
        let len = u64::from_le_bytes(msg[pos + 8..pos + 16].try_into().unwrap()) as usize;
        runs.push((off, len));
        pos += 16;
    }
    (runs, pos)
}

/// Aggregator file-domain assignment for one collective operation.
pub(crate) enum FileDomains {
    /// Contiguous near-even byte ranges (the classic ROMIO default).
    Contiguous(Vec<(u64, u64)>),
    /// Stripe-cyclic: stripe unit `i` belongs to aggregator `i % naggr`
    /// (see the module docs). Domains are unions of stripe units, so the
    /// global byte range needs no explicit bounds here.
    StripeCyclic { unit: u64, naggr: usize },
}

impl FileDomains {
    /// Pick the domain shape: stripe-cyclic when the file sits on striped
    /// storage and alignment is enabled, contiguous otherwise.
    fn choose(ctx: &TransferCtx, lo: u64, hi: u64, naggr: usize, stripe_align: bool) -> FileDomains {
        if stripe_align {
            if let Some(layout) = ctx.storage.stripe_layout() {
                return FileDomains::StripeCyclic { unit: layout.unit, naggr };
            }
        }
        FileDomains::Contiguous(split_domains(lo, hi, naggr))
    }

    /// This rank's request pieces destined for aggregator `a`:
    /// `(file_off, len, payload_pos)` clipped to the aggregator's domain.
    fn pieces_for(
        &self,
        runs: &[(u64, usize)],
        positions: &[usize],
        a: usize,
    ) -> Vec<(u64, usize, usize)> {
        match self {
            FileDomains::Contiguous(domains) => slice_runs_for_domain(runs, positions, domains[a]),
            FileDomains::StripeCyclic { unit, naggr } => {
                // Reuse the layout walk with the aggregator count as the
                // "factor": the piece's server index *is* its aggregator.
                let cyclic = StripeLayout { unit: *unit, factor: *naggr };
                let mut out = Vec::new();
                for (i, &(off, len)) in runs.iter().enumerate() {
                    cyclic.for_each_piece(off, len, |aggr, cur, piece_len| {
                        if aggr == a {
                            out.push((cur, piece_len, positions[i] + (cur - off) as usize));
                        }
                    });
                }
                out
            }
        }
    }
}

/// Work an aggregator owes the I/O phase of a collective write.
pub(crate) struct WriteIoWork {
    /// Per-source (in rank order) decoded runs + their bytes, already
    /// flattened to (off, len, bytes) writes in arrival order.
    pub writes: Vec<(u64, Vec<u8>)>,
    /// Staging-buffer size for the aggregator strategy.
    pub cb_buffer: usize,
}

impl WriteIoWork {
    /// Execute the I/O phase (storage only — engine-safe).
    pub(crate) fn execute(self, ctx: &TransferCtx) -> Result<()> {
        let strat = ViewBufStrategy::with_stage(self.cb_buffer);
        let _guard = if ctx.atomic { Some(ctx.storage.lock_exclusive()?) } else { None };
        // Coalesce strictly-adjacent pieces into single large transfers —
        // the whole point of aggregation. (Overlapping pieces are never
        // merged: sorted order preserves the deterministic rank-order
        // overwrite semantics.)
        let mut pending: Option<(u64, Vec<u8>)> = None;
        for (off, bytes) in self.writes {
            match &mut pending {
                Some((poff, pbuf))
                    if *poff + pbuf.len() as u64 == off
                        && pbuf.len() + bytes.len() <= self.cb_buffer =>
                {
                    pbuf.extend_from_slice(&bytes);
                }
                Some((poff, pbuf)) => {
                    strat.write(ctx.storage.as_ref(), &[(*poff, pbuf.len())], pbuf)?;
                    pending = Some((off, bytes));
                }
                None => pending = Some((off, bytes)),
            }
        }
        if let Some((poff, pbuf)) = pending {
            strat.write(ctx.storage.as_ref(), &[(poff, pbuf.len())], &pbuf)?;
        }
        Ok(())
    }
}

/// Collective-buffering parameters snapshotted from the Info hints.
pub(crate) struct CbParams {
    /// `cb_nodes`: number of aggregators (`None` = every rank).
    pub nodes: Option<usize>,
    /// `cb_buffer_size`: aggregator staging-buffer bytes.
    pub buffer: Option<usize>,
    /// `romio_cb_read`: collective buffering on/off.
    pub enabled: bool,
    /// `jpio_cb_stripe_align`: stripe-aligned file domains on/off.
    pub stripe_align: bool,
}

/// Outcome of the exchange phase of a collective write: the I/O work this
/// rank must perform as an aggregator (empty for non-aggregators).
pub(crate) fn exchange_write(
    comm: &dyn Comm,
    ctx: &TransferCtx,
    cb: &CbParams,
    etype_off: i64,
    payload: &[u8],
) -> Result<(WriteIoWork, usize)> {
    let n = comm.size();
    let runs = ctx.view.runs(etype_off, payload.len())?;
    if !cb.enabled || n == 1 {
        // Degenerate: independent write, collective completion only.
        write_payload(ctx, etype_off, payload)?;
        return Ok((WriteIoWork { writes: Vec::new(), cb_buffer: 1 }, payload.len()));
    }
    // Payload position of each run.
    let mut positions = Vec::with_capacity(runs.len());
    let mut acc = 0usize;
    for &(_, len) in &runs {
        positions.push(acc);
        acc += len;
    }
    // Global byte range.
    let my_min = runs.first().map(|&(o, _)| o as i64).unwrap_or(i64::MAX);
    let my_max = runs.last().map(|&(o, l)| (o + l as u64) as i64).unwrap_or(0);
    let gmin = comm.allreduce_i64(ReduceOp::Min, my_min);
    let gmax = comm.allreduce_i64(ReduceOp::Max, my_max);
    if gmin >= gmax {
        return Ok((WriteIoWork { writes: Vec::new(), cb_buffer: 1 }, payload.len()));
    }
    let naggr = cb.nodes.unwrap_or(n).clamp(1, n);
    let domains = FileDomains::choose(ctx, gmin as u64, gmax as u64, naggr, cb.stripe_align);
    // Build one message per rank (non-aggregators get empty messages).
    let mut msgs = vec![Vec::new(); n];
    for (a, msg) in msgs.iter_mut().enumerate().take(naggr) {
        let pieces = domains.pieces_for(&runs, &positions, a);
        *msg = encode_write_msg(&pieces, payload);
    }
    for m in msgs.iter_mut().skip(naggr) {
        m.extend_from_slice(&0u32.to_le_bytes());
    }
    let inbound = comm.alltoall(&msgs);
    // Decode in rank order (deterministic overlap resolution).
    let mut writes = Vec::new();
    for msg in &inbound {
        if msg.len() < 4 {
            continue;
        }
        let (rs, mut pos) = decode_runs(msg);
        for (off, len) in rs {
            writes.push((off, msg[pos..pos + len].to_vec()));
            pos += len;
        }
    }
    writes.sort_by_key(|&(off, _)| off);
    Ok((
        WriteIoWork { writes, cb_buffer: cb.buffer.unwrap_or(16 << 20).max(4096) },
        payload.len(),
    ))
}

/// Full collective read: exchange requests, aggregator sieved reads,
/// reply exchange, local reassembly. Returns bytes read into `payload`.
pub(crate) fn collective_read(
    comm: &dyn Comm,
    ctx: &TransferCtx,
    cb: &CbParams,
    etype_off: i64,
    payload: &mut [u8],
) -> Result<usize> {
    let n = comm.size();
    if !cb.enabled || n == 1 {
        let got = read_payload(ctx, etype_off, payload)?;
        if cb.enabled {
            comm.barrier();
        }
        return Ok(got);
    }
    let runs = ctx.view.runs(etype_off, payload.len())?;
    let mut positions = Vec::with_capacity(runs.len());
    let mut acc = 0usize;
    for &(_, len) in &runs {
        positions.push(acc);
        acc += len;
    }
    let my_min = runs.first().map(|&(o, _)| o as i64).unwrap_or(i64::MAX);
    let my_max = runs.last().map(|&(o, l)| (o + l as u64) as i64).unwrap_or(0);
    let gmin = comm.allreduce_i64(ReduceOp::Min, my_min);
    let gmax = comm.allreduce_i64(ReduceOp::Max, my_max);
    if gmin >= gmax {
        return Ok(0);
    }
    let naggr = cb.nodes.unwrap_or(n).clamp(1, n);
    let domains = FileDomains::choose(ctx, gmin as u64, gmax as u64, naggr, cb.stripe_align);
    // Request phase: ship (off,len) lists to aggregators.
    let mut reqs = vec![Vec::new(); n];
    let mut my_pieces: Vec<Vec<(u64, usize, usize)>> = vec![Vec::new(); n];
    for (a, (req, mine)) in reqs.iter_mut().zip(my_pieces.iter_mut()).enumerate().take(naggr) {
        let pieces = domains.pieces_for(&runs, &positions, a);
        let mut msg = Vec::with_capacity(4 + pieces.len() * 16);
        msg.extend_from_slice(&(pieces.len() as u32).to_le_bytes());
        for &(off, len, _) in &pieces {
            msg.extend_from_slice(&off.to_le_bytes());
            msg.extend_from_slice(&(len as u64).to_le_bytes());
        }
        *req = msg;
        *mine = pieces;
    }
    for m in reqs.iter_mut().skip(naggr) {
        m.extend_from_slice(&0u32.to_le_bytes());
    }
    let inbound = comm.alltoall(&reqs);

    // Aggregator I/O phase: merge all requested intervals, sieved read.
    let eof = ctx.storage.size()?;
    let mut per_src_runs: Vec<Vec<(u64, usize)>> = Vec::with_capacity(n);
    let mut intervals: Vec<(u64, u64)> = Vec::new();
    for msg in &inbound {
        let (rs, _) = decode_runs(msg);
        for &(off, len) in &rs {
            intervals.push((off, off + len as u64));
        }
        per_src_runs.push(rs);
    }
    let merged = merge_intervals(&mut intervals);
    let strat = ViewBufStrategy::with_stage(cb.buffer.unwrap_or(16 << 20).max(4096));
    let merged_runs: Vec<(u64, usize)> =
        merged.iter().map(|&(s, e)| (s, (e - s) as usize)).collect();
    let total: usize = merged_runs.iter().map(|r| r.1).sum();
    let mut agg_buf = vec![0u8; total];
    if total > 0 {
        let _guard = if ctx.atomic { Some(ctx.storage.lock_exclusive()?) } else { None };
        strat.read(ctx.storage.as_ref(), &merged_runs, &mut agg_buf)?;
    }
    // Reply phase: slice the aggregated buffer per source request.
    let locate = |off: u64| -> Option<usize> {
        // Position of `off` within agg_buf.
        let mut base = 0usize;
        for &(s, e) in &merged {
            if off >= s && off < e {
                return Some(base + (off - s) as usize);
            }
            base += (e - s) as usize;
        }
        None
    };
    let mut replies = vec![Vec::new(); n];
    for (src, rs) in per_src_runs.iter().enumerate() {
        let bytes: usize = rs.iter().map(|r| r.1).sum();
        let mut reply = Vec::with_capacity(bytes);
        for &(off, len) in rs {
            let p = locate(off).expect("requested run must be inside merged intervals");
            reply.extend_from_slice(&agg_buf[p..p + len]);
        }
        replies[src] = reply;
    }
    let mut answers = comm.alltoall(&replies);

    // Reassemble my payload from the per-aggregator answers; compute the
    // EOF-clamped byte count.
    let mut got = 0usize;
    for (a, pieces) in my_pieces.iter().enumerate() {
        let ans = std::mem::take(&mut answers[a]);
        let mut cursor = 0usize;
        for &(off, len, pos) in pieces {
            payload[pos..pos + len].copy_from_slice(&ans[cursor..cursor + len]);
            cursor += len;
            let visible = (eof.saturating_sub(off) as usize).min(len);
            got += visible;
        }
    }
    // Datarep decode on the assembled payload.
    if !ctx.view.datarep.is_identity() {
        let elems = ctx.view.payload_elems(got);
        ctx.view.datarep.decode(&mut payload[..got], &elems);
    }
    Ok(got)
}

/// Split `[lo, hi)` into `n` near-even contiguous domains.
fn split_domains(lo: u64, hi: u64, n: usize) -> Vec<(u64, u64)> {
    let total = hi - lo;
    let base = total / n as u64;
    let rem = (total % n as u64) as usize;
    let mut out = Vec::with_capacity(n);
    let mut cur = lo;
    for i in 0..n {
        let len = base + (i < rem) as u64;
        out.push((cur, cur + len));
        cur += len;
    }
    out
}

/// Sort + merge overlapping/adjacent intervals.
fn merge_intervals(iv: &mut Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    iv.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::with_capacity(iv.len());
    for &(s, e) in iv.iter() {
        if let Some(last) = out.last_mut() {
            if s <= last.1 {
                last.1 = last.1.max(e);
                continue;
            }
        }
        out.push((s, e));
    }
    out
}

impl File<'_> {
    pub(crate) fn cb_params(&self) -> CbParams {
        let info = self.info.lock().unwrap();
        CbParams {
            nodes: info.get_usize(keys::CB_NODES),
            buffer: info.get_usize(keys::CB_BUFFER_SIZE),
            enabled: info.get_flag(keys::COLLECTIVE_BUFFERING).unwrap_or(true),
            stripe_align: info.get_flag(keys::CB_STRIPE_ALIGN).unwrap_or(true),
        }
    }

    /// `MPI_FILE_WRITE_AT_ALL`: collective write at explicit offsets.
    pub fn write_at_all(
        &self,
        offset: Offset,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        self.check_open()?;
        self.check_writable()?;
        let ctx = self.transfer_ctx();
        let payload = pack_payload(buf, buf_offset, count, datatype, &ctx.view)?;
        let cb = self.cb_params();
        let (work, bytes) = exchange_write(self.comm, &ctx, &cb, offset, &payload)?;
        work.execute(&ctx)?;
        self.comm.barrier();
        Ok(Status::of_bytes(bytes))
    }

    /// `MPI_FILE_READ_AT_ALL`: collective read at explicit offsets.
    pub fn read_at_all(
        &self,
        offset: Offset,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        self.check_open()?;
        self.check_readable()?;
        let ctx = self.transfer_ctx();
        let mut payload = vec![0u8; count * datatype.size()];
        let cb = self.cb_params();
        let got = collective_read(self.comm, &ctx, &cb, offset, &mut payload)?;
        unpack_payload(buf, buf_offset, count, datatype, &payload, got)?;
        Ok(Status::of_bytes(got))
    }

    /// `MPI_FILE_WRITE_ALL`: collective write at the individual pointer.
    pub fn write_all(
        &self,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let off = *self.indiv_ptr.lock().unwrap();
        let st = self.write_at_all(off, buf, buf_offset, count, datatype)?;
        let view = self.view_snapshot();
        *self.indiv_ptr.lock().unwrap() = off + view.bytes_to_etypes(st.bytes);
        Ok(st)
    }

    /// `MPI_FILE_READ_ALL`: collective read at the individual pointer.
    pub fn read_all(
        &self,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let off = *self.indiv_ptr.lock().unwrap();
        let st = self.read_at_all(off, buf, buf_offset, count, datatype)?;
        let view = self.view_snapshot();
        *self.indiv_ptr.lock().unwrap() = off + view.bytes_to_etypes(st.bytes);
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::threads;
    use crate::comm::Comm;
    use crate::io::file::amode;
    use crate::io::hints::Info;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-coll-{}-{name}", std::process::id())
    }

    #[test]
    fn split_domains_cover_exactly() {
        let d = split_domains(10, 107, 4);
        assert_eq!(d.len(), 4);
        assert_eq!(d[0].0, 10);
        assert_eq!(d[3].1, 107);
        for w in d.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn merge_intervals_handles_overlap_and_adjacency() {
        let mut iv = vec![(10, 20), (0, 5), (5, 8), (15, 30), (40, 41)];
        assert_eq!(merge_intervals(&mut iv), vec![(0, 8), (10, 30), (40, 41)]);
    }

    #[test]
    fn stripe_cyclic_domains_partition_at_unit_boundaries() {
        let d = FileDomains::StripeCyclic { unit: 10, naggr: 2 };
        // One run [5, 45): stripes 0..4 → aggregator 0 gets stripes 0 and
        // 2, aggregator 1 gets stripes 1 and 3.
        let runs = [(5u64, 40usize)];
        let positions = [100usize];
        let a0 = d.pieces_for(&runs, &positions, 0);
        let a1 = d.pieces_for(&runs, &positions, 1);
        assert_eq!(a0, vec![(5, 5, 100), (20, 10, 115), (40, 5, 135)]);
        assert_eq!(a1, vec![(10, 10, 105), (30, 10, 125)]);
        // Together the pieces cover the run exactly.
        let total: usize = a0.iter().chain(&a1).map(|p| p.1).sum();
        assert_eq!(total, 40);
        for &(off, len, _) in a0.iter().chain(&a1) {
            assert_eq!(off / 10, (off + len as u64 - 1) / 10, "piece crosses a boundary");
        }
    }

    #[test]
    fn collective_on_striped_storage_aligned_and_not() {
        use crate::storage::striped::StripedBackend;
        for align in ["true", "false"] {
            let path = tmp(&format!("striped-{align}"));
            threads::run(4, |c| {
                let backend: std::sync::Arc<dyn crate::storage::Backend> =
                    std::sync::Arc::new(StripedBackend::local(4, 64));
                let info = Info::from([(keys::CB_STRIPE_ALIGN, align), (keys::CB_NODES, "4")]);
                let f = File::open_with_backend(
                    c,
                    &path,
                    amode::RDWR | amode::CREATE,
                    info,
                    backend,
                )
                .unwrap();
                let n = c.size();
                let r = c.rank();
                // Interleaved strided pattern: rank r owns every n-th int.
                let ft = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
                let ft = Datatype::resized(&ft, 0, (n * 4) as i64).unwrap();
                f.set_view((r * 4) as i64, &Datatype::INT, &ft, "native", &Info::null())
                    .unwrap();
                let k = 300; // spans many 64-byte stripe units
                let mine: Vec<i32> = (0..k).map(|i| (i * n + r) as i32).collect();
                f.write_at_all(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
                c.barrier();
                let mut back = vec![0i32; k];
                let st = f.read_at_all(0, back.as_mut_slice(), 0, k, &Datatype::INT).unwrap();
                assert_eq!(st.bytes, k * 4);
                assert_eq!(back, mine);
                // Flat logical contents check through the striped file.
                f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null())
                    .unwrap();
                let total = k * n;
                let mut all = vec![0i32; total];
                f.read_at(0, all.as_mut_slice(), 0, total, &Datatype::INT).unwrap();
                let want: Vec<i32> = (0..total as i32).collect();
                assert_eq!(all, want);
                f.close().unwrap();
            });
            let backend = StripedBackend::local(4, 64);
            crate::storage::Backend::delete(&backend, &path).unwrap();
            let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
        }
    }

    #[test]
    fn collective_write_read_interleaved_blocks() {
        let path = tmp("blocks");
        threads::run(4, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let n = c.size();
            let r = c.rank();
            // Rank r writes ints [r*256, (r+1)*256) at its block.
            f.set_view((r * 1024) as i64, &Datatype::INT, &Datatype::INT, "native", &Info::null())
                .unwrap();
            let mine: Vec<i32> = (0..256).map(|i| (r * 256 + i) as i32).collect();
            let st = f.write_all(mine.as_slice(), 0, 256, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, 1024);
            f.sync().unwrap();
            c.barrier();
            f.close().unwrap();

            let f2 = File::open(c, &path, amode::RDONLY, Info::null()).unwrap();
            let mut all = vec![0i32; 256 * n];
            let st = f2.read_at_all(0, all.as_mut_slice(), 0, 256 * n, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, 1024 * n);
            let want: Vec<i32> = (0..(256 * n) as i32).collect();
            assert_eq!(all, want);
            f2.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn collective_strided_interleave_two_phase() {
        // The classic two-phase win: rank r owns every n-th int. One
        // collective write must produce the full interleaved file.
        let path = tmp("strided");
        threads::run(4, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let n = c.size();
            let r = c.rank();
            let ft = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
            let ft = Datatype::resized(&ft, 0, (n * 4) as i64).unwrap();
            f.set_view((r * 4) as i64, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
            let k = 512;
            let mine: Vec<i32> = (0..k).map(|i| (i * n + r) as i32).collect();
            f.write_at_all(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
            c.barrier();
            // Read back collectively through the same strided view.
            let mut back = vec![0i32; k];
            let st = f.read_at_all(0, back.as_mut_slice(), 0, k, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, k * 4);
            assert_eq!(back, mine);
            f.close().unwrap();
        });
        // Flat check.
        let raw = std::fs::read(&path).unwrap();
        let ints: Vec<i32> =
            raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
        let want: Vec<i32> = (0..ints.len() as i32).collect();
        assert_eq!(ints, want);
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn cb_nodes_one_aggregator_still_correct() {
        let path = tmp("onenode");
        threads::run(3, |c| {
            let info = Info::from([(keys::CB_NODES, "1"), (keys::CB_BUFFER_SIZE, "4096")]);
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, info).unwrap();
            let r = c.rank();
            let data = vec![r as i32; 100];
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            f.write_at_all((r * 100) as i64, data.as_slice(), 0, 100, &Datatype::INT).unwrap();
            c.barrier();
            let mut all = vec![0i32; 300];
            f.read_at_all(0, all.as_mut_slice(), 0, 300, &Datatype::INT).unwrap();
            for (i, v) in all.iter().enumerate() {
                assert_eq!(*v, (i / 100) as i32);
            }
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn collective_buffering_disabled_fallback() {
        let path = tmp("nocb");
        threads::run(2, |c| {
            let info = Info::from([(keys::COLLECTIVE_BUFFERING, "false")]);
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, info).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let r = c.rank();
            let data = vec![(r + 1) as i32; 64];
            f.write_at_all((r * 64) as i64, data.as_slice(), 0, 64, &Datatype::INT).unwrap();
            c.barrier();
            let mut back = vec![0i32; 128];
            f.read_at_all(0, back.as_mut_slice(), 0, 128, &Datatype::INT).unwrap();
            assert!(back[..64].iter().all(|&v| v == 1));
            assert!(back[64..].iter().all(|&v| v == 2));
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn collective_read_shorter_than_eof_clamps() {
        let path = tmp("eofclamp");
        threads::run(2, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            if c.rank() == 0 {
                f.write_at(0, vec![5i32; 10].as_slice(), 0, 10, &Datatype::INT).unwrap();
            }
            c.barrier();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let mut buf = vec![0i32; 20];
            let st = f.read_at_all(0, buf.as_mut_slice(), 0, 20, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, 40);
            assert_eq!(st.count(&Datatype::INT), Some(10));
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }
}
