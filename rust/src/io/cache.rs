//! Coherent client-side page cache with write-behind.
//!
//! ViPIOS puts a data-administration layer between clients and disks;
//! jpio's analogue is a per-`File` [`PageCache`] the scheduler consults
//! before touching [`StorageFile`]. Its reason to exist is the
//! "millions of tiny requests" workload: Thakur's noncontiguous-access
//! lesson is that small strided requests only approach bandwidth when
//! coalesced into large aligned transfers, so cached writes accumulate
//! in dirty pages (**write-behind**) and flush as stripe-aligned
//! coalesced runs — pages are sized to the backend's
//! [`preferred_flush_alignment`](StorageFile::preferred_flush_alignment)
//! (one data row on striped storage), so a full-page flush never pays a
//! parity read-modify-write.
//!
//! The cache is off by default (`jpio_cache = enable` turns it on); with
//! it off every access path is byte-identical to the uncached library.
//! When on:
//!
//! * **Reads** are served from resident pages (`cache_hit_bytes`); a
//!   miss fetches the whole page — the plan-level read-modify-write
//!   pre-read — plus `jpio_prefetch` pages ahead (`cache_miss_bytes`).
//!   Pre-reads go through the same `Arc<dyn StorageFile>` as every
//!   other access, so `JPIO_ERR_DEGRADED` advisories queue on the
//!   backend and drain through `File::take_advisories` untouched.
//! * **Writes** copy into pages and mark byte-exact dirty extents.
//!   Past the high-water mark (half the `jpio_cache_size` budget) a
//!   background flush drains on the cache's progress lane; with
//!   `jpio_write_behind = disable` every write flushes before
//!   returning (write-through).
//! * **Flushes** coalesce dirty extents: a fetched (or multi-extent,
//!   RMW-fetched) page contributes one covering run, adjacent runs
//!   across pages merge, and multi-run flushes dispatch as one
//!   [`write_plan`](StorageFile::write_plan) so the striped fan-out
//!   sees the large transfer (`write_behind_flush_bytes`, `rmw_cycles`).
//!   While the storage write is in flight its pages stay pinned: they
//!   cannot be evicted, and a fetch of one waits for the write to land
//!   — the flushed bytes exist only in the page buffer until then, so
//!   evicting or re-fetching would resurrect pre-flush storage bytes.
//!
//! **Coherence points** (MPI §7.2.6.1: a process sees another process's
//! writes after writer-sync → barrier → reader-sync): `sync`, `close`,
//! size changes, collective two-phase execution, and enabling atomic
//! mode all flush — and, where another agent may have written,
//! invalidate. Cross-process coherence rides a
//! `<path>.jpio-cache-lease` sidecar (the shared-pointer sidecar
//! machinery): a sync that flushed data bumps the lease generation —
//! an atomic read-modify-write under the sidecar's `flock` — and a
//! sync that observes a foreign generation drops every resident page.
//! The foreign check always runs against the generation observed
//! *before* this handle's own bump, so a handle that both writes and
//! reads (two ranks exchanging regions) never masks another writer's
//! publication with its own.
//! Atomic-mode operations bypass the cache entirely — they serialize
//! under the whole-file lock, which resident pages cannot see.

use std::collections::BTreeMap;
use std::os::unix::fs::FileExt;
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::comm::progress::ProgressEngine;
use crate::io::errors::{IoError, Result};
use crate::io::hints::{keys, Info};
use crate::io::plan::IoPlan;
use crate::io::stats::{Counter, FileStats};
use crate::storage::StorageFile;

/// Default page-cache byte budget (`jpio_cache_size`): 8 MiB.
const DEFAULT_BUDGET: usize = 8 << 20;

/// Fallback page size when the backend states no flush-alignment
/// preference (single-device backends): 64 KiB.
const DEFAULT_PAGE: u64 = 64 << 10;

/// One cached page: the buffer, whether its clean bytes were fetched
/// from storage, and the byte-exact dirty extents awaiting flush.
struct Page {
    buf: Vec<u8>,
    /// Whole-page contents loaded from storage (clean bytes are real
    /// file bytes; past-EOF bytes are zeros from the short read).
    fetched: bool,
    /// Sorted, merged dirty `[start, end)` extents within the page.
    dirty: Vec<(usize, usize)>,
    /// LRU stamp (monotonic access clock).
    stamp: u64,
    /// Snapshotted into an in-flight flush whose storage write has not
    /// landed yet. The snapshotted bytes live only in `buf` (the dirty
    /// extents were cleared when the snapshot was taken), so the page
    /// must not be evicted and a fetch must not merge storage contents
    /// over it until the write completes.
    flushing: bool,
}

impl Page {
    fn new(page_size: usize) -> Page {
        Page {
            buf: vec![0u8; page_size],
            fetched: false,
            dirty: Vec::new(),
            stamp: 0,
            flushing: false,
        }
    }

    /// Mark `[s, e)` dirty; returns the newly-dirtied byte count.
    fn mark_dirty(&mut self, s: usize, e: usize) -> usize {
        let before: usize = self.dirty.iter().map(|&(a, b)| b - a).sum();
        self.dirty.push((s, e));
        self.dirty.sort_unstable();
        let mut merged: Vec<(usize, usize)> = Vec::with_capacity(self.dirty.len());
        for &(a, b) in &self.dirty {
            if let Some(last) = merged.last_mut() {
                if a <= last.1 {
                    last.1 = last.1.max(b);
                    continue;
                }
            }
            merged.push((a, b));
        }
        self.dirty = merged;
        let after: usize = self.dirty.iter().map(|&(a, b)| b - a).sum();
        after - before
    }

    /// Whether `[s, e)` is fully resident (fetched, or covered by one
    /// dirty extent — extents are merged, so a cover is a single one).
    fn covers(&self, s: usize, e: usize) -> bool {
        self.fetched || self.dirty.iter().any(|&(a, b)| a <= s && e <= b)
    }

    fn dirty_bytes(&self) -> usize {
        self.dirty.iter().map(|&(a, b)| b - a).sum()
    }
}

/// The page table and everything that must stay consistent with it.
struct CacheState {
    /// Pages keyed by page index (`file_off / page_size`).
    pages: BTreeMap<u64, Page>,
    /// Total dirty bytes across all pages (high-water trigger).
    dirty_bytes: u64,
    /// The file size this cache believes in: storage EOF as last
    /// observed, advanced by cached writes — the short-read boundary
    /// for cached reads.
    logical_size: u64,
    /// Monotonic LRU clock.
    clock: u64,
    /// Bumped when a flush's storage write completes. A fetch that read
    /// storage outside the lock re-reads when the epoch moved under it:
    /// the bytes it holds may predate the flush that just landed.
    flush_epoch: u64,
    /// Last lease generation this handle observed (see
    /// [`PageCache::sync_point`]).
    lease_seen: u64,
    /// A direct write may have moved the storage EOF behind the cache's
    /// back (atomic-mode and aggregator writes, size changes): the next
    /// access re-observes `logical_size` from storage.
    size_stale: bool,
}

/// A per-`File` page cache with write-behind; see the module docs. One
/// lives on the handle when `jpio_cache = enable`; a clone of its `Arc`
/// travels in every [`TransferCtx`](crate::io::op::TransferCtx).
pub(crate) struct PageCache {
    storage: Arc<dyn StorageFile>,
    stats: Arc<FileStats>,
    page_size: u64,
    /// Page-count budget (`jpio_cache_size` rounded up to pages).
    max_pages: usize,
    /// Dirty-byte level that queues a background flush.
    high_water: u64,
    /// Pages to fetch ahead of a read miss (`jpio_prefetch`).
    prefetch: usize,
    /// `false` = write-through (`jpio_write_behind = disable`).
    write_behind: bool,
    rank: usize,
    /// Cross-process coherence sidecar (`<path>.jpio-cache-lease`).
    lease_path: String,
    state: Mutex<CacheState>,
    /// Signalled (with `state`) when an in-flight flush lands and
    /// unpins its pages; fetches of pinned pages wait here.
    flush_done: Condvar,
    /// Serializes flushes: dirty extents are snapshotted and marked
    /// clean under `state`, but the storage write runs outside it, so
    /// overlapping flushes must not reorder.
    flush_gate: Mutex<()>,
    /// A background flush is queued but has not started.
    flush_queued: AtomicBool,
    /// A background flush failed; surfaced at the next write or sync
    /// (write-behind semantics — like the OS page cache's deferred EIO).
    flush_err: Mutex<Option<IoError>>,
    /// Lazily-spawned flush lane (`jpio-cache-flush-<rank>`); respawned
    /// after a fork, where the inherited worker thread does not exist.
    lane: Mutex<Option<Arc<ProgressEngine>>>,
}

fn read_lease(path: &str) -> u64 {
    std::fs::read(path)
        .ok()
        .and_then(|b| b.get(..8).map(|b| u64::from_le_bytes(b.try_into().unwrap())))
        .unwrap_or(0)
}

impl PageCache {
    /// Build the handle's cache from the open-time hints; `None` unless
    /// `jpio_cache = enable` (the default-off path stays byte-identical
    /// to the uncached library).
    pub(crate) fn from_info(
        info: &Info,
        path: &str,
        storage: Arc<dyn StorageFile>,
        stats: Arc<FileStats>,
        rank: usize,
    ) -> Option<Arc<PageCache>> {
        if !info.get_flag(keys::CACHE).unwrap_or(false) {
            return None;
        }
        let page_size =
            storage.preferred_flush_alignment().unwrap_or(DEFAULT_PAGE).clamp(512, 8 << 20);
        let budget = info.get_usize(keys::CACHE_SIZE).unwrap_or(DEFAULT_BUDGET) as u64;
        let max_pages = budget.div_ceil(page_size).max(2) as usize;
        let lease_path = format!("{path}.jpio-cache-lease");
        let logical_size = storage.size().unwrap_or(0);
        let lease_seen = read_lease(&lease_path);
        Some(Arc::new(PageCache {
            storage,
            stats,
            page_size,
            max_pages,
            high_water: (max_pages as u64 * page_size) / 2,
            prefetch: info.get_usize(keys::PREFETCH).unwrap_or(0),
            write_behind: info.get_flag(keys::WRITE_BEHIND).unwrap_or(true),
            rank,
            lease_path,
            state: Mutex::new(CacheState {
                pages: BTreeMap::new(),
                dirty_bytes: 0,
                logical_size,
                clock: 0,
                flush_epoch: 0,
                lease_seen,
                size_stale: false,
            }),
            flush_done: Condvar::new(),
            flush_gate: Mutex::new(()),
            flush_queued: AtomicBool::new(false),
            flush_err: Mutex::new(None),
            lane: Mutex::new(None),
        }))
    }

    // ------------------------------------------------------------------
    // The access path (independent reads and writes)
    // ------------------------------------------------------------------

    /// Serve a compiled read plan from the cache, fetching missing
    /// pages. Returns bytes read, short at the cached EOF with the same
    /// stop-at-first-short-run semantics as
    /// [`read_plan`](StorageFile::read_plan).
    pub(crate) fn read_plan(&self, plan: &IoPlan, payload: &mut [u8]) -> Result<usize> {
        let logical_size = {
            let mut st = self.state.lock().unwrap();
            self.refresh_size(&mut st);
            st.logical_size
        };
        let mut got = 0usize;
        for (off, len, pos) in plan.segments() {
            let avail = (logical_size.saturating_sub(off) as usize).min(len);
            if avail > 0 {
                self.copy_out(off, &mut payload[pos..pos + avail])?;
                got += avail;
            }
            if avail < len {
                break;
            }
        }
        self.enforce_budget()?;
        Ok(got)
    }

    /// Absorb a compiled write plan into dirty pages (write-behind).
    /// Flushes inline in write-through mode; queues a background flush
    /// on the cache's progress lane past the high-water mark. A stored
    /// background-flush error surfaces here before any new data is
    /// absorbed.
    pub(crate) fn write_plan(
        this: &Arc<PageCache>,
        plan: &IoPlan,
        payload: &[u8],
    ) -> Result<usize> {
        if let Some(e) = this.flush_err.lock().unwrap().take() {
            return Err(e);
        }
        {
            let mut st = this.state.lock().unwrap();
            this.refresh_size(&mut st);
            for (off, len, pos) in plan.segments() {
                this.copy_in(&mut st, off, &payload[pos..pos + len]);
            }
        }
        if this.write_behind {
            Self::maybe_background_flush(this);
        } else {
            this.flush()?;
        }
        this.enforce_budget()?;
        Ok(plan.bytes)
    }

    /// Copy `[off, off + out.len())` out of the cache, fetching (and
    /// prefetching) pages on miss. The page table is locked per page,
    /// never across a storage round-trip — one page miss must not
    /// block hits on other pages; a page evicted between the fetch and
    /// the copy is simply fetched again.
    fn copy_out(&self, off: u64, out: &mut [u8]) -> Result<()> {
        let ps = self.page_size;
        let end = off + out.len() as u64;
        let mut cur = off;
        while cur < end {
            let idx = cur / ps;
            let in_page = (cur - idx * ps) as usize;
            let n = (((idx + 1) * ps).min(end) - cur) as usize;
            let mut counted = false;
            loop {
                {
                    let mut st = self.state.lock().unwrap();
                    let resident = st
                        .pages
                        .get(&idx)
                        .map(|p| p.covers(in_page, in_page + n))
                        .unwrap_or(false);
                    if resident {
                        if !counted {
                            self.stats.add(Counter::CacheHitBytes, n as u64);
                        }
                        st.clock += 1;
                        let clock = st.clock;
                        let page = st.pages.get_mut(&idx).expect("resident page");
                        page.stamp = clock;
                        let s = (cur - off) as usize;
                        out[s..s + n].copy_from_slice(&page.buf[in_page..in_page + n]);
                        break;
                    }
                }
                if !counted {
                    self.stats.add(Counter::CacheMissBytes, n as u64);
                    counted = true;
                }
                self.fetch(idx)?;
                self.prefetch_after(idx)?;
            }
            cur += n as u64;
        }
        Ok(())
    }

    /// Hint-driven read-ahead after a miss on page `idx`: the next
    /// `jpio_prefetch` pages inside the cached EOF become hits for
    /// sequential re-reads.
    fn prefetch_after(&self, idx: u64) -> Result<()> {
        for k in 1..=self.prefetch as u64 {
            let ahead = idx + k;
            let (past_eof, resident) = {
                let st = self.state.lock().unwrap();
                (
                    ahead * self.page_size >= st.logical_size,
                    st.pages.get(&ahead).map(|p| p.fetched).unwrap_or(false),
                )
            };
            if past_eof {
                break;
            }
            if !resident {
                self.fetch(ahead)?;
            }
        }
        Ok(())
    }

    /// Copy `data` into the pages covering `[off, off + data.len())`,
    /// marking dirty extents (write-allocate, no pre-read: the flush
    /// path fetches only when gap-filling actually needs file bytes).
    fn copy_in(&self, st: &mut CacheState, off: u64, data: &[u8]) {
        let ps = self.page_size;
        let end = off + data.len() as u64;
        let mut cur = off;
        while cur < end {
            let idx = cur / ps;
            let in_page = (cur - idx * ps) as usize;
            let n = (((idx + 1) * ps).min(end) - cur) as usize;
            st.clock += 1;
            let clock = st.clock;
            let page = st.pages.entry(idx).or_insert_with(|| Page::new(ps as usize));
            page.stamp = clock;
            let s = (cur - off) as usize;
            page.buf[in_page..in_page + n].copy_from_slice(&data[s..s + n]);
            st.dirty_bytes += page.mark_dirty(in_page, in_page + n) as u64;
            cur += n as u64;
        }
        st.logical_size = st.logical_size.max(end);
    }

    /// Re-observe the storage EOF when a direct write may have moved it
    /// behind the cache's back (see [`PageCache::flush_and_invalidate`]).
    fn refresh_size(&self, st: &mut CacheState) {
        if st.size_stale {
            st.logical_size = self.storage.size().unwrap_or(st.logical_size);
            st.size_stale = false;
        }
    }

    /// Fetch page `idx` from storage — the plan-level read-modify-write
    /// pre-read. Dirty bytes are preserved; only clean bytes take the
    /// storage contents. The storage round-trip runs *outside* the
    /// state lock, so a miss never blocks hits on other pages; the
    /// merge re-locks and re-reads if a flush landed in between
    /// (`flush_epoch`), and waits out a flush that holds the page
    /// pinned — in both cases the bytes read may predate the flush, and
    /// merging them would resurrect pre-flush storage contents over the
    /// only copy of the flushed data. The pre-read runs on the same
    /// storage handle as every other access, so degraded-mode
    /// advisories queue on the backend for `File::take_advisories` —
    /// nothing here drains or converts them.
    fn fetch(&self, idx: u64) -> Result<()> {
        let ps = self.page_size as usize;
        loop {
            let epoch = {
                let mut st = self.state.lock().unwrap();
                while st.pages.get(&idx).map(|p| p.flushing).unwrap_or(false) {
                    st = self.flush_done.wait(st).unwrap();
                }
                if st.pages.get(&idx).map(|p| p.fetched).unwrap_or(false) {
                    return Ok(());
                }
                st.flush_epoch
            };
            let mut from_store = vec![0u8; ps];
            // Short at EOF only; the tail stays zeros, like a file hole.
            self.storage.read_at(idx * self.page_size, &mut from_store)?;
            let mut st = self.state.lock().unwrap();
            if st.flush_epoch != epoch
                || st.pages.get(&idx).map(|p| p.flushing).unwrap_or(false)
            {
                continue;
            }
            let page = st.pages.entry(idx).or_insert_with(|| Page::new(ps));
            if page.fetched {
                return Ok(());
            }
            if !page.dirty.is_empty() {
                self.stats.add(Counter::RmwCycles, 1);
            }
            let mut at = 0usize;
            for &(s, e) in &page.dirty {
                page.buf[at..s].copy_from_slice(&from_store[at..s]);
                at = e;
            }
            page.buf[at..].copy_from_slice(&from_store[at..]);
            page.fetched = true;
            return Ok(());
        }
    }

    // ------------------------------------------------------------------
    // Flushing
    // ------------------------------------------------------------------

    /// Flush every dirty extent to storage as coalesced runs; returns
    /// the bytes written. Extents are snapshotted and marked clean under
    /// the page-table lock, then written outside it (concurrent writes
    /// re-dirty their pages and flush next time); `flush_gate`
    /// serializes overlapping flushes so writes never reorder. On a
    /// failed flush the snapshotted bytes are lost and the error is the
    /// caller's (or, from the background lane, stored for the next
    /// write/sync) — deferred-error write-behind semantics.
    pub(crate) fn flush(&self) -> Result<usize> {
        let _gate = self.flush_gate.lock().unwrap();
        // Gap-filling RMW, outside the state lock: a multi-extent
        // unfetched page flushes as one covering run, which needs real
        // file bytes between the extents. If the pre-read fails (a
        // truly dead region), degrade to extent-only writes rather than
        // losing the dirty data or inventing gap bytes.
        let need_fill: Vec<u64> = {
            let st = self.state.lock().unwrap();
            st.pages
                .iter()
                .filter(|(_, p)| p.dirty.len() > 1 && !p.fetched)
                .map(|(&i, _)| i)
                .collect()
        };
        for idx in need_fill {
            let _ = self.fetch(idx);
        }
        let (runs, payload, pinned) = {
            let mut st = self.state.lock().unwrap();
            let st = &mut *st;
            let mut runs: Vec<(u64, usize)> = Vec::new();
            let mut payload: Vec<u8> = Vec::new();
            let mut pinned: Vec<u64> = Vec::new();
            let dirty_pages: Vec<u64> = st
                .pages
                .iter()
                .filter(|(_, p)| !p.dirty.is_empty())
                .map(|(&i, _)| i)
                .collect();
            for idx in dirty_pages {
                let base = idx * self.page_size;
                let page = st.pages.get_mut(&idx).expect("dirty page resident");
                let spans: Vec<(usize, usize)> = if page.fetched {
                    vec![(page.dirty[0].0, page.dirty[page.dirty.len() - 1].1)]
                } else {
                    page.dirty.clone()
                };
                for (s, e) in spans {
                    let abs = base + s as u64;
                    if let Some(last) = runs.last_mut() {
                        if last.0 + last.1 as u64 == abs {
                            last.1 += e - s;
                            payload.extend_from_slice(&page.buf[s..e]);
                            continue;
                        }
                    }
                    runs.push((abs, e - s));
                    payload.extend_from_slice(&page.buf[s..e]);
                }
                st.dirty_bytes -= page.dirty_bytes() as u64;
                page.dirty.clear();
                // The snapshot lives only in `payload` and `page.buf`
                // now: pin the page until the storage write lands, or
                // budget eviction plus a re-fetch would cache pre-flush
                // storage bytes — a read-your-own-writes violation.
                page.flushing = true;
                pinned.push(idx);
            }
            (runs, payload, pinned)
        };
        if runs.is_empty() {
            return Ok(0);
        }
        let wrote = if runs.len() > 1 {
            self.storage.write_plan(&runs, &payload).map(|_| ())
        } else {
            self.storage.write_at(runs[0].0, &payload).map(|_| ())
        };
        {
            let mut st = self.state.lock().unwrap();
            // Unpin even on failure — the snapshot is lost either way
            // (deferred-error write-behind semantics), and a page
            // pinned forever would wedge eviction. The epoch bump makes
            // any fetch that overlapped the write re-read storage: its
            // buffered bytes may predate what this flush landed.
            for idx in &pinned {
                if let Some(page) = st.pages.get_mut(idx) {
                    page.flushing = false;
                }
            }
            st.flush_epoch += 1;
            self.flush_done.notify_all();
        }
        wrote?;
        self.stats.add(Counter::WriteBehindFlushBytes, payload.len() as u64);
        Ok(payload.len())
    }

    /// Queue a flush on the cache's progress lane once the dirty level
    /// crosses the high-water mark (at most one queued at a time). In a
    /// forked child without a usable lane the flush runs inline.
    fn maybe_background_flush(this: &Arc<PageCache>) {
        if this.state.lock().unwrap().dirty_bytes < this.high_water {
            return;
        }
        if this.flush_queued.swap(true, Ordering::SeqCst) {
            return;
        }
        let me = this.clone();
        this.lane().submit_or_run(move || {
            me.flush_queued.store(false, Ordering::SeqCst);
            if let Err(e) = me.flush() {
                *me.flush_err.lock().unwrap() = Some(e);
            }
        });
    }

    /// The flush lane, spawned on first use (and respawned after a fork
    /// made the inherited worker unusable).
    fn lane(&self) -> Arc<ProgressEngine> {
        let mut lane = self.lane.lock().unwrap();
        match lane.as_ref() {
            Some(engine) if engine.usable() => engine.clone(),
            _ => {
                let engine =
                    Arc::new(ProgressEngine::spawn(format!("jpio-cache-flush-{}", self.rank)));
                *lane = Some(engine.clone());
                engine
            }
        }
    }

    /// Wait out any in-flight background flush.
    fn quiesce(&self) {
        let lane = self.lane.lock().unwrap().clone();
        if let Some(engine) = lane {
            engine.quiesce();
        }
    }

    /// Evict least-recently-used clean pages down to the budget,
    /// flushing first when only dirty pages remain.
    fn enforce_budget(&self) -> Result<()> {
        if self.evict_clean() {
            return Ok(());
        }
        self.flush()?;
        self.evict_clean();
        Ok(())
    }

    /// Evict clean LRU pages; `true` when the budget holds afterwards.
    /// Pages pinned by an in-flight flush are not candidates: they are
    /// clean only because their dirty extents were snapshotted, and the
    /// snapshot has not reached storage yet.
    fn evict_clean(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.pages.len() > self.max_pages {
            let victim = st
                .pages
                .iter()
                .filter(|(_, p)| p.dirty.is_empty() && !p.flushing)
                .min_by_key(|(_, p)| p.stamp)
                .map(|(&i, _)| i);
            match victim {
                Some(i) => {
                    st.pages.remove(&i);
                }
                None => return false,
            }
        }
        true
    }

    // ------------------------------------------------------------------
    // Coherence points
    // ------------------------------------------------------------------

    /// Flush and drop every resident page, and mark the cached EOF
    /// stale — the next access re-observes it from storage, *after* the
    /// operation this call fences has moved it. The coherence point for
    /// paths that hand the file to agents the cache cannot see:
    /// collective two-phase execution, atomic-mode operations, and size
    /// changes.
    pub(crate) fn flush_and_invalidate(&self) -> Result<()> {
        self.flush()?;
        let mut st = self.state.lock().unwrap();
        st.pages.clear();
        st.dirty_bytes = 0;
        st.size_stale = true;
        Ok(())
    }

    /// Run `f` with the lease sidecar open and exclusively flocked —
    /// the same cross-process serialization idiom as the striped
    /// metadata sidecar.
    fn with_locked_lease<T>(&self, f: impl FnOnce(&std::fs::File) -> Result<T>) -> Result<T> {
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&self.lease_path)
            .map_err(|e| IoError::from_os(e, "cache lease"))?;
        let fd = file.as_raw_fd();
        if unsafe { libc::flock(fd, libc::LOCK_EX) } != 0 {
            return Err(IoError::from_os(std::io::Error::last_os_error(), "flock cache lease"));
        }
        let out = f(&file);
        unsafe { libc::flock(fd, libc::LOCK_UN) };
        out
    }

    /// Bump the lease generation: an atomic read-modify-write under the
    /// sidecar's flock (concurrent publishers each land their own bump
    /// — no lost update), written in place through the locked fd (no
    /// truncate window for an unlocked [`read_lease`] to observe as
    /// generation 0). Returns the published generation plus whether the
    /// locked read saw a generation beyond `observed` — another handle
    /// published between the caller's unlocked observation and this
    /// bump, which the caller must treat as foreign.
    fn bump_lease(&self, observed: u64) -> Result<(u64, bool)> {
        self.with_locked_lease(|file| {
            let mut buf = [0u8; 8];
            let cur = match file.read_exact_at(&mut buf, 0) {
                Ok(()) => u64::from_le_bytes(buf),
                Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => 0,
                Err(e) => return Err(IoError::from_os(e, "cache lease read")),
            };
            let next = cur.wrapping_add(1);
            file.write_all_at(&next.to_le_bytes(), 0)
                .map_err(|e| IoError::from_os(e, "cache lease write"))?;
            Ok((next, cur != observed))
        })
    }

    /// The `sync`/`close` coherence point: drain the flush lane, flush,
    /// surface any stored background-flush error, and run the lease
    /// protocol — a sync that published data bumps the
    /// `<path>.jpio-cache-lease` generation under its flock; a sync
    /// that observes a generation another handle bumped invalidates
    /// every resident page (MPI §7.2.6.1 writer-sync / reader-sync
    /// visibility). The foreign check runs against the generation read
    /// *before* this handle's own bump — and re-checked inside the
    /// bump's critical section — so a handle that both writes and reads
    /// (two ranks exchanging regions: each writes, syncs, barriers,
    /// syncs, reads the other's region) still drops its stale pages at
    /// the same sync that publishes its own writes.
    pub(crate) fn sync_point(&self) -> Result<()> {
        self.quiesce();
        if let Some(e) = self.flush_err.lock().unwrap().take() {
            return Err(e);
        }
        let observed = read_lease(&self.lease_path);
        let flushed = self.flush()?;
        let published = if flushed > 0 { Some(self.bump_lease(observed)?) } else { None };
        let mut st = self.state.lock().unwrap();
        let mut foreign = observed != st.lease_seen;
        st.lease_seen = match published {
            Some((gen, raced)) => {
                foreign |= raced;
                gen
            }
            None => observed,
        };
        if foreign {
            st.pages.clear();
            st.dirty_bytes = 0;
            st.logical_size = self.storage.size().unwrap_or(st.logical_size);
            st.size_stale = false;
        }
        Ok(())
    }

    /// The cached EOF (storage size advanced by unflushed writes).
    pub(crate) fn logical_size(&self) -> u64 {
        let mut st = self.state.lock().unwrap();
        self.refresh_size(&mut st);
        st.logical_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::local::LocalBackend;
    use crate::storage::Backend;

    fn cache_at(path: &str, extra: &[(&str, &str)]) -> (Arc<PageCache>, Arc<dyn StorageFile>) {
        let mut info = Info::from([(keys::CACHE, "enable")]);
        for &(k, v) in extra {
            info.set(k, v);
        }
        let storage = LocalBackend::instant().open(path, crate::storage::OpenOptions::rw_create())
            .unwrap();
        let cache = PageCache::from_info(
            &info,
            path,
            storage.clone(),
            crate::io::stats::FileStats::disabled(),
            0,
        )
        .unwrap();
        (cache, storage)
    }

    fn cleanup(path: &str) {
        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_file(format!("{path}.jpio-cache-lease"));
    }

    #[test]
    fn disabled_hint_builds_no_cache() {
        let path = format!("/tmp/jpio-cache-off-{}", std::process::id());
        let storage =
            LocalBackend::instant().open(&path, crate::storage::OpenOptions::rw_create()).unwrap();
        assert!(PageCache::from_info(
            &Info::null(),
            &path,
            storage.clone(),
            crate::io::stats::FileStats::disabled(),
            0
        )
        .is_none());
        assert!(PageCache::from_info(
            &Info::from([(keys::CACHE, "disable")]),
            &path,
            storage,
            crate::io::stats::FileStats::disabled(),
            0
        )
        .is_none());
        cleanup(&path);
    }

    #[test]
    fn write_behind_coalesces_strided_extents_into_one_run() {
        let path = format!("/tmp/jpio-cache-coalesce-{}", std::process::id());
        let (cache, storage) = cache_at(&path, &[]);
        // 16 strided 64-byte writes inside one page: nothing on storage
        // until the flush, which lands them (plus the fetched gap bytes)
        // as one covering run.
        storage.write_at(0, &[0xEEu8; 2048]).unwrap();
        cache.flush_and_invalidate().unwrap();
        for i in 0..16u64 {
            let plan = IoPlan::from_runs(vec![(i * 128, 64)], false);
            PageCache::write_plan(&cache, &plan, &[i as u8; 64]).unwrap();
        }
        assert_eq!(cache.state.lock().unwrap().dirty_bytes, 16 * 64);
        let flushed = cache.flush().unwrap();
        // One covering span [0, 15*128+64): dirty bytes plus RMW-fetched
        // gap bytes written back unchanged.
        assert_eq!(flushed, 15 * 128 + 64);
        let mut back = vec![0u8; 2048];
        storage.read_at(0, &mut back).unwrap();
        for i in 0..16usize {
            assert_eq!(&back[i * 128..i * 128 + 64], &[i as u8; 64]);
            if i < 15 {
                assert_eq!(&back[i * 128 + 64..(i + 1) * 128], &[0xEEu8; 64], "gap bytes");
            }
        }
        cleanup(&path);
    }

    #[test]
    fn read_hits_after_miss_and_respects_eof() {
        let path = format!("/tmp/jpio-cache-read-{}", std::process::id());
        let (cache, storage) = cache_at(&path, &[]);
        let data: Vec<u8> = (0..200u8).collect();
        storage.write_at(0, &data).unwrap();
        cache.flush_and_invalidate().unwrap(); // observe the new EOF
        let stats = cache.stats.clone();
        let plan = IoPlan::from_runs(vec![(10, 50)], false);
        let mut buf = vec![0u8; 50];
        assert_eq!(cache.read_plan(&plan, &mut buf).unwrap(), 50);
        assert_eq!(buf, data[10..60]);
        let miss0 = stats.value(Counter::CacheMissBytes);
        assert!(miss0 >= 50, "first read must miss");
        assert_eq!(cache.read_plan(&plan, &mut buf).unwrap(), 50);
        assert_eq!(stats.value(Counter::CacheMissBytes), miss0, "repeat read must not miss");
        assert_eq!(stats.value(Counter::CacheHitBytes), 50);
        // Reads past EOF are short, stopping at the first short run.
        let plan = IoPlan::from_runs(vec![(150, 50), (300, 10)], false);
        let mut buf = vec![0u8; 60];
        assert_eq!(cache.read_plan(&plan, &mut buf).unwrap(), 50);
        cleanup(&path);
    }

    #[test]
    fn cached_writes_are_read_back_before_any_flush() {
        let path = format!("/tmp/jpio-cache-rwb-{}", std::process::id());
        let (cache, storage) = cache_at(&path, &[]);
        let plan = IoPlan::from_runs(vec![(100, 8), (300, 8)], false);
        let payload: Vec<u8> = (0..16).collect();
        PageCache::write_plan(&cache, &plan, &payload).unwrap();
        assert_eq!(storage.size().unwrap(), 0, "write-behind: storage untouched");
        assert_eq!(cache.logical_size(), 308);
        let mut back = vec![0u8; 16];
        assert_eq!(cache.read_plan(&plan, &mut back).unwrap(), 16);
        assert_eq!(back, payload);
        cache.sync_point().unwrap();
        assert_eq!(storage.size().unwrap(), 308);
        cleanup(&path);
    }

    #[test]
    fn budget_evicts_clean_pages_and_flushes_dirty_ones() {
        let path = format!("/tmp/jpio-cache-budget-{}", std::process::id());
        // Budget of exactly 2 pages (the floor) at the 64 KiB default.
        let (cache, storage) = cache_at(&path, &[(keys::CACHE_SIZE, "1")]);
        assert_eq!(cache.max_pages, 2);
        let ps = cache.page_size;
        for i in 0..6u64 {
            let plan = IoPlan::from_runs(vec![(i * ps, 16)], false);
            PageCache::write_plan(&cache, &plan, &[i as u8; 16]).unwrap();
        }
        assert!(cache.state.lock().unwrap().pages.len() <= 2, "budget must hold");
        // Every evicted page was flushed first: the data survives.
        cache.sync_point().unwrap();
        let mut back = vec![0u8; 16];
        for i in 0..6u64 {
            storage.read_at(i * ps, &mut back).unwrap();
            assert_eq!(back, [i as u8; 16], "page {i} lost by eviction");
        }
        cleanup(&path);
    }

    #[test]
    fn lease_sync_invalidates_the_other_handles_pages() {
        let path = format!("/tmp/jpio-cache-lease-{}", std::process::id());
        let (writer, storage) = cache_at(&path, &[]);
        let (reader, _) = cache_at(&path, &[]);
        storage.write_at(0, &[1u8; 64]).unwrap();
        writer.flush_and_invalidate().unwrap();
        reader.flush_and_invalidate().unwrap();
        // Reader caches the old bytes.
        let plan = IoPlan::from_runs(vec![(0, 64)], false);
        let mut buf = vec![0u8; 64];
        reader.read_plan(&plan, &mut buf).unwrap();
        assert_eq!(buf, [1u8; 64]);
        // Writer overwrites through its cache and syncs (bumps lease).
        PageCache::write_plan(&writer, &plan, &[2u8; 64]).unwrap();
        writer.sync_point().unwrap();
        // Without a sync the reader still serves its resident page…
        reader.read_plan(&plan, &mut buf).unwrap();
        assert_eq!(buf, [1u8; 64]);
        // …and its own sync observes the bumped lease and refetches.
        reader.sync_point().unwrap();
        reader.read_plan(&plan, &mut buf).unwrap();
        assert_eq!(buf, [2u8; 64]);
        cleanup(&path);
    }

    /// Storage double whose writes announce themselves and then block
    /// on a test-held mutex — a deterministic "flush in flight" window.
    struct BlockingWrites {
        inner: Arc<dyn StorageFile>,
        entered: Mutex<std::sync::mpsc::Sender<()>>,
        release: Arc<Mutex<()>>,
    }

    impl StorageFile for BlockingWrites {
        fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
            self.inner.read_at(offset, buf)
        }
        fn write_at(&self, offset: u64, buf: &[u8]) -> Result<usize> {
            let _ = self.entered.lock().unwrap().send(());
            let _hold = self.release.lock().unwrap();
            self.inner.write_at(offset, buf)
        }
        fn size(&self) -> Result<u64> {
            self.inner.size()
        }
        fn set_size(&self, size: u64) -> Result<()> {
            self.inner.set_size(size)
        }
        fn preallocate(&self, size: u64) -> Result<()> {
            self.inner.preallocate(size)
        }
        fn sync(&self) -> Result<()> {
            self.inner.sync()
        }
        fn map(
            &self,
            offset: u64,
            len: usize,
            writable: bool,
        ) -> Result<Box<dyn crate::storage::MappedRegion>> {
            self.inner.map(offset, len, writable)
        }
        fn lock_exclusive(&self) -> Result<crate::storage::FileLockGuard> {
            self.inner.lock_exclusive()
        }
        fn backend_name(&self) -> &'static str {
            "blocking-test"
        }
    }

    #[test]
    fn in_flight_flush_pins_pages_against_eviction_and_stale_refetch() {
        let path = format!("/tmp/jpio-cache-pin-{}", std::process::id());
        let inner = LocalBackend::instant()
            .open(&path, crate::storage::OpenOptions::rw_create())
            .unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        let release = Arc::new(Mutex::new(()));
        let storage: Arc<dyn StorageFile> =
            Arc::new(BlockingWrites { inner, entered: Mutex::new(tx), release: release.clone() });
        let info = Info::from([(keys::CACHE, "enable"), (keys::CACHE_SIZE, "1")]);
        let cache = PageCache::from_info(
            &info,
            &path,
            storage,
            crate::io::stats::FileStats::disabled(),
            0,
        )
        .unwrap();
        let plan = IoPlan::from_runs(vec![(0, 64)], false);
        PageCache::write_plan(&cache, &plan, &[9u8; 64]).unwrap();
        // Hold the flush's storage write in flight.
        let held = release.lock().unwrap();
        let flusher = {
            let c = cache.clone();
            std::thread::spawn(move || c.flush().unwrap())
        };
        rx.recv().unwrap();
        {
            let mut st = cache.state.lock().unwrap();
            let page = &st.pages[&0];
            assert!(page.flushing && page.dirty.is_empty(), "snapshotted, write in flight");
            // Budget pressure during the write window (max_pages == 2):
            // the steady state for the write-behind workload.
            for i in 1..=4u64 {
                st.pages.entry(i).or_insert_with(|| Page::new(cache.page_size as usize));
            }
        }
        assert!(cache.evict_clean(), "budget must be enforceable around the pin");
        assert!(
            cache.state.lock().unwrap().pages.contains_key(&0),
            "page with an in-flight flush must not be evicted"
        );
        // A read of the snapshotted (now clean) extent must wait for the
        // write to land, not merge pre-flush storage bytes over it.
        let reader = {
            let c = cache.clone();
            std::thread::spawn(move || {
                let plan = IoPlan::from_runs(vec![(0, 64)], false);
                let mut buf = vec![0u8; 64];
                assert_eq!(c.read_plan(&plan, &mut buf).unwrap(), 64);
                buf
            })
        };
        drop(held);
        assert_eq!(flusher.join().unwrap(), 64);
        assert_eq!(reader.join().unwrap(), [9u8; 64], "read-your-own-writes across a flush");
        assert!(!cache.state.lock().unwrap().pages[&0].flushing, "unpinned after landing");
        cleanup(&path);
    }

    #[test]
    fn exchange_writers_invalidate_despite_their_own_bump() {
        let path = format!("/tmp/jpio-cache-exchange-{}", std::process::id());
        let (a, storage) = cache_at(&path, &[]);
        let (b, _) = cache_at(&path, &[]);
        storage.write_at(0, &[0xAAu8; 128]).unwrap();
        a.flush_and_invalidate().unwrap();
        b.flush_and_invalidate().unwrap();
        let r0 = IoPlan::from_runs(vec![(0, 64)], false);
        let r1 = IoPlan::from_runs(vec![(64, 64)], false);
        let mut buf = vec![0u8; 64];
        // Both handles cache both regions.
        for handle in [&a, &b] {
            handle.read_plan(&r0, &mut buf).unwrap();
            handle.read_plan(&r1, &mut buf).unwrap();
        }
        // The §7.2.6.1 exchange: A writes region 0, B writes region 1,
        // each syncs (writer-sync), each syncs again after the
        // "barrier" (reader-sync), then reads the region the other
        // wrote. Each handle's first sync both flushes and observes —
        // publishing must not absorb the foreign generation it read.
        PageCache::write_plan(&a, &r0, &[0x0Au8; 64]).unwrap();
        PageCache::write_plan(&b, &r1, &[0x0Bu8; 64]).unwrap();
        a.sync_point().unwrap();
        b.sync_point().unwrap();
        a.sync_point().unwrap();
        b.sync_point().unwrap();
        a.read_plan(&r1, &mut buf).unwrap();
        assert_eq!(buf, [0x0Bu8; 64], "A must see B's region after sync/barrier/sync");
        b.read_plan(&r0, &mut buf).unwrap();
        assert_eq!(buf, [0x0Au8; 64], "B must see A's region after sync/barrier/sync");
        cleanup(&path);
    }

    #[test]
    fn concurrent_lease_bumps_never_lose_updates() {
        let path = format!("/tmp/jpio-cache-lease-rmw-{}", std::process::id());
        let threads: Vec<_> = (0..2u64)
            .map(|h| {
                let (cache, _) = cache_at(&path, &[]);
                std::thread::spawn(move || {
                    for i in 0..8u64 {
                        let off = (h * 8 + i) * 64;
                        let plan = IoPlan::from_runs(vec![(off, 64)], false);
                        PageCache::write_plan(&cache, &plan, &[h as u8; 64]).unwrap();
                        cache.sync_point().unwrap();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // 16 publishing syncs → exactly 16 bumps: the flocked RMW loses
        // none to a concurrent read-then-write of the sidecar.
        assert_eq!(read_lease(&format!("{path}.jpio-cache-lease")), 16);
        cleanup(&path);
    }

    #[test]
    fn write_through_hint_flushes_every_write() {
        let path = format!("/tmp/jpio-cache-wt-{}", std::process::id());
        let (cache, storage) = cache_at(&path, &[(keys::WRITE_BEHIND, "disable")]);
        let plan = IoPlan::from_runs(vec![(0, 32)], false);
        PageCache::write_plan(&cache, &plan, &[7u8; 32]).unwrap();
        let mut back = vec![0u8; 32];
        assert_eq!(storage.read_at(0, &mut back).unwrap(), 32, "write-through must land");
        assert_eq!(back, [7u8; 32]);
        assert_eq!(cache.state.lock().unwrap().dirty_bytes, 0);
        cleanup(&path);
    }
}
