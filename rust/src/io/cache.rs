//! Coherent client-side page cache with write-behind.
//!
//! ViPIOS puts a data-administration layer between clients and disks;
//! jpio's analogue is a per-`File` [`PageCache`] the scheduler consults
//! before touching [`StorageFile`]. Its reason to exist is the
//! "millions of tiny requests" workload: Thakur's noncontiguous-access
//! lesson is that small strided requests only approach bandwidth when
//! coalesced into large aligned transfers, so cached writes accumulate
//! in dirty pages (**write-behind**) and flush as stripe-aligned
//! coalesced runs — pages are sized to the backend's
//! [`preferred_flush_alignment`](StorageFile::preferred_flush_alignment)
//! (one data row on striped storage), so a full-page flush never pays a
//! parity read-modify-write.
//!
//! The cache is off by default (`jpio_cache = enable` turns it on); with
//! it off every access path is byte-identical to the uncached library.
//! When on:
//!
//! * **Reads** are served from resident pages (`cache_hit_bytes`); a
//!   miss fetches the whole page — the plan-level read-modify-write
//!   pre-read — plus `jpio_prefetch` pages ahead (`cache_miss_bytes`).
//!   Pre-reads go through the same `Arc<dyn StorageFile>` as every
//!   other access, so `JPIO_ERR_DEGRADED` advisories queue on the
//!   backend and drain through `File::take_advisories` untouched.
//! * **Writes** copy into pages and mark byte-exact dirty extents.
//!   Past the high-water mark (half the `jpio_cache_size` budget) a
//!   background flush drains on the cache's progress lane; with
//!   `jpio_write_behind = disable` every write flushes before
//!   returning (write-through).
//! * **Flushes** coalesce dirty extents: a fetched (or multi-extent,
//!   RMW-fetched) page contributes one covering run, adjacent runs
//!   across pages merge, and multi-run flushes dispatch as one
//!   [`write_plan`](StorageFile::write_plan) so the striped fan-out
//!   sees the large transfer (`write_behind_flush_bytes`, `rmw_cycles`).
//!
//! **Coherence points** (MPI §7.2.6.1: a process sees another process's
//! writes after writer-sync → barrier → reader-sync): `sync`, `close`,
//! size changes, collective two-phase execution, and enabling atomic
//! mode all flush — and, where another agent may have written,
//! invalidate. Cross-process coherence rides a
//! `<path>.jpio-cache-lease` sidecar (the shared-pointer sidecar
//! machinery): a sync that flushed data bumps the lease generation, and
//! a sync that observes a foreign generation drops every resident page.
//! Atomic-mode operations bypass the cache entirely — they serialize
//! under the whole-file lock, which resident pages cannot see.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::comm::progress::ProgressEngine;
use crate::io::errors::{IoError, Result};
use crate::io::hints::{keys, Info};
use crate::io::plan::IoPlan;
use crate::io::stats::{Counter, FileStats};
use crate::storage::StorageFile;

/// Default page-cache byte budget (`jpio_cache_size`): 8 MiB.
const DEFAULT_BUDGET: usize = 8 << 20;

/// Fallback page size when the backend states no flush-alignment
/// preference (single-device backends): 64 KiB.
const DEFAULT_PAGE: u64 = 64 << 10;

/// One cached page: the buffer, whether its clean bytes were fetched
/// from storage, and the byte-exact dirty extents awaiting flush.
struct Page {
    buf: Vec<u8>,
    /// Whole-page contents loaded from storage (clean bytes are real
    /// file bytes; past-EOF bytes are zeros from the short read).
    fetched: bool,
    /// Sorted, merged dirty `[start, end)` extents within the page.
    dirty: Vec<(usize, usize)>,
    /// LRU stamp (monotonic access clock).
    stamp: u64,
}

impl Page {
    fn new(page_size: usize) -> Page {
        Page { buf: vec![0u8; page_size], fetched: false, dirty: Vec::new(), stamp: 0 }
    }

    /// Mark `[s, e)` dirty; returns the newly-dirtied byte count.
    fn mark_dirty(&mut self, s: usize, e: usize) -> usize {
        let before: usize = self.dirty.iter().map(|&(a, b)| b - a).sum();
        self.dirty.push((s, e));
        self.dirty.sort_unstable();
        let mut merged: Vec<(usize, usize)> = Vec::with_capacity(self.dirty.len());
        for &(a, b) in &self.dirty {
            if let Some(last) = merged.last_mut() {
                if a <= last.1 {
                    last.1 = last.1.max(b);
                    continue;
                }
            }
            merged.push((a, b));
        }
        self.dirty = merged;
        let after: usize = self.dirty.iter().map(|&(a, b)| b - a).sum();
        after - before
    }

    /// Whether `[s, e)` is fully resident (fetched, or covered by one
    /// dirty extent — extents are merged, so a cover is a single one).
    fn covers(&self, s: usize, e: usize) -> bool {
        self.fetched || self.dirty.iter().any(|&(a, b)| a <= s && e <= b)
    }

    fn dirty_bytes(&self) -> usize {
        self.dirty.iter().map(|&(a, b)| b - a).sum()
    }
}

/// The page table and everything that must stay consistent with it.
struct CacheState {
    /// Pages keyed by page index (`file_off / page_size`).
    pages: BTreeMap<u64, Page>,
    /// Total dirty bytes across all pages (high-water trigger).
    dirty_bytes: u64,
    /// The file size this cache believes in: storage EOF as last
    /// observed, advanced by cached writes — the short-read boundary
    /// for cached reads.
    logical_size: u64,
    /// Monotonic LRU clock.
    clock: u64,
    /// Last lease generation this handle observed (see
    /// [`PageCache::sync_point`]).
    lease_seen: u64,
    /// A direct write may have moved the storage EOF behind the cache's
    /// back (atomic-mode and aggregator writes, size changes): the next
    /// access re-observes `logical_size` from storage.
    size_stale: bool,
}

/// A per-`File` page cache with write-behind; see the module docs. One
/// lives on the handle when `jpio_cache = enable`; a clone of its `Arc`
/// travels in every [`TransferCtx`](crate::io::op::TransferCtx).
pub(crate) struct PageCache {
    storage: Arc<dyn StorageFile>,
    stats: Arc<FileStats>,
    page_size: u64,
    /// Page-count budget (`jpio_cache_size` rounded up to pages).
    max_pages: usize,
    /// Dirty-byte level that queues a background flush.
    high_water: u64,
    /// Pages to fetch ahead of a read miss (`jpio_prefetch`).
    prefetch: usize,
    /// `false` = write-through (`jpio_write_behind = disable`).
    write_behind: bool,
    rank: usize,
    /// Cross-process coherence sidecar (`<path>.jpio-cache-lease`).
    lease_path: String,
    state: Mutex<CacheState>,
    /// Serializes flushes: dirty extents are snapshotted and marked
    /// clean under `state`, but the storage write runs outside it, so
    /// overlapping flushes must not reorder.
    flush_gate: Mutex<()>,
    /// A background flush is queued but has not started.
    flush_queued: AtomicBool,
    /// A background flush failed; surfaced at the next write or sync
    /// (write-behind semantics — like the OS page cache's deferred EIO).
    flush_err: Mutex<Option<IoError>>,
    /// Lazily-spawned flush lane (`jpio-cache-flush-<rank>`); respawned
    /// after a fork, where the inherited worker thread does not exist.
    lane: Mutex<Option<Arc<ProgressEngine>>>,
}

fn read_lease(path: &str) -> u64 {
    std::fs::read(path)
        .ok()
        .and_then(|b| b.get(..8).map(|b| u64::from_le_bytes(b.try_into().unwrap())))
        .unwrap_or(0)
}

impl PageCache {
    /// Build the handle's cache from the open-time hints; `None` unless
    /// `jpio_cache = enable` (the default-off path stays byte-identical
    /// to the uncached library).
    pub(crate) fn from_info(
        info: &Info,
        path: &str,
        storage: Arc<dyn StorageFile>,
        stats: Arc<FileStats>,
        rank: usize,
    ) -> Option<Arc<PageCache>> {
        if !info.get_flag(keys::CACHE).unwrap_or(false) {
            return None;
        }
        let page_size =
            storage.preferred_flush_alignment().unwrap_or(DEFAULT_PAGE).clamp(512, 8 << 20);
        let budget = info.get_usize(keys::CACHE_SIZE).unwrap_or(DEFAULT_BUDGET) as u64;
        let max_pages = budget.div_ceil(page_size).max(2) as usize;
        let lease_path = format!("{path}.jpio-cache-lease");
        let logical_size = storage.size().unwrap_or(0);
        let lease_seen = read_lease(&lease_path);
        Some(Arc::new(PageCache {
            storage,
            stats,
            page_size,
            max_pages,
            high_water: (max_pages as u64 * page_size) / 2,
            prefetch: info.get_usize(keys::PREFETCH).unwrap_or(0),
            write_behind: info.get_flag(keys::WRITE_BEHIND).unwrap_or(true),
            rank,
            lease_path,
            state: Mutex::new(CacheState {
                pages: BTreeMap::new(),
                dirty_bytes: 0,
                logical_size,
                clock: 0,
                lease_seen,
                size_stale: false,
            }),
            flush_gate: Mutex::new(()),
            flush_queued: AtomicBool::new(false),
            flush_err: Mutex::new(None),
            lane: Mutex::new(None),
        }))
    }

    // ------------------------------------------------------------------
    // The access path (independent reads and writes)
    // ------------------------------------------------------------------

    /// Serve a compiled read plan from the cache, fetching missing
    /// pages. Returns bytes read, short at the cached EOF with the same
    /// stop-at-first-short-run semantics as
    /// [`read_plan`](StorageFile::read_plan).
    pub(crate) fn read_plan(&self, plan: &IoPlan, payload: &mut [u8]) -> Result<usize> {
        let mut st = self.state.lock().unwrap();
        self.refresh_size(&mut st);
        let mut got = 0usize;
        for (off, len, pos) in plan.segments() {
            let avail = (st.logical_size.saturating_sub(off) as usize).min(len);
            if avail > 0 {
                self.copy_out(&mut st, off, &mut payload[pos..pos + avail])?;
                got += avail;
            }
            if avail < len {
                break;
            }
        }
        drop(st);
        self.enforce_budget()?;
        Ok(got)
    }

    /// Absorb a compiled write plan into dirty pages (write-behind).
    /// Flushes inline in write-through mode; queues a background flush
    /// on the cache's progress lane past the high-water mark. A stored
    /// background-flush error surfaces here before any new data is
    /// absorbed.
    pub(crate) fn write_plan(
        this: &Arc<PageCache>,
        plan: &IoPlan,
        payload: &[u8],
    ) -> Result<usize> {
        if let Some(e) = this.flush_err.lock().unwrap().take() {
            return Err(e);
        }
        {
            let mut st = this.state.lock().unwrap();
            this.refresh_size(&mut st);
            for (off, len, pos) in plan.segments() {
                this.copy_in(&mut st, off, &payload[pos..pos + len]);
            }
        }
        if this.write_behind {
            Self::maybe_background_flush(this);
        } else {
            this.flush()?;
        }
        this.enforce_budget()?;
        Ok(plan.bytes)
    }

    /// Copy `[off, off + out.len())` out of the cache, fetching (and
    /// prefetching) pages on miss.
    fn copy_out(&self, st: &mut CacheState, off: u64, out: &mut [u8]) -> Result<()> {
        let ps = self.page_size;
        let end = off + out.len() as u64;
        let mut cur = off;
        while cur < end {
            let idx = cur / ps;
            let in_page = (cur - idx * ps) as usize;
            let n = (((idx + 1) * ps).min(end) - cur) as usize;
            let resident =
                st.pages.get(&idx).map(|p| p.covers(in_page, in_page + n)).unwrap_or(false);
            if resident {
                self.stats.add(Counter::CacheHitBytes, n as u64);
            } else {
                self.stats.add(Counter::CacheMissBytes, n as u64);
                self.fetch(st, idx)?;
                // Hint-driven read-ahead: the next `prefetch` pages
                // inside the cached EOF become hits for sequential
                // re-reads.
                for k in 1..=self.prefetch as u64 {
                    let ahead = idx + k;
                    if ahead * ps >= st.logical_size {
                        break;
                    }
                    if !st.pages.get(&ahead).map(|p| p.fetched).unwrap_or(false) {
                        self.fetch(st, ahead)?;
                    }
                }
            }
            st.clock += 1;
            let clock = st.clock;
            let page = st.pages.get_mut(&idx).expect("page resident after fetch");
            page.stamp = clock;
            let s = (cur - off) as usize;
            out[s..s + n].copy_from_slice(&page.buf[in_page..in_page + n]);
            cur += n as u64;
        }
        Ok(())
    }

    /// Copy `data` into the pages covering `[off, off + data.len())`,
    /// marking dirty extents (write-allocate, no pre-read: the flush
    /// path fetches only when gap-filling actually needs file bytes).
    fn copy_in(&self, st: &mut CacheState, off: u64, data: &[u8]) {
        let ps = self.page_size;
        let end = off + data.len() as u64;
        let mut cur = off;
        while cur < end {
            let idx = cur / ps;
            let in_page = (cur - idx * ps) as usize;
            let n = (((idx + 1) * ps).min(end) - cur) as usize;
            st.clock += 1;
            let clock = st.clock;
            let page = st.pages.entry(idx).or_insert_with(|| Page::new(ps as usize));
            page.stamp = clock;
            let s = (cur - off) as usize;
            page.buf[in_page..in_page + n].copy_from_slice(&data[s..s + n]);
            st.dirty_bytes += page.mark_dirty(in_page, in_page + n) as u64;
            cur += n as u64;
        }
        st.logical_size = st.logical_size.max(end);
    }

    /// Re-observe the storage EOF when a direct write may have moved it
    /// behind the cache's back (see [`PageCache::flush_and_invalidate`]).
    fn refresh_size(&self, st: &mut CacheState) {
        if st.size_stale {
            st.logical_size = self.storage.size().unwrap_or(st.logical_size);
            st.size_stale = false;
        }
    }

    /// Fetch page `idx` from storage — the plan-level read-modify-write
    /// pre-read. Dirty bytes are preserved; only clean bytes take the
    /// storage contents. The pre-read runs on the same storage handle as
    /// every other access, so degraded-mode advisories queue on the
    /// backend for `File::take_advisories` — nothing here drains or
    /// converts them.
    fn fetch(&self, st: &mut CacheState, idx: u64) -> Result<()> {
        let ps = self.page_size as usize;
        let page = st.pages.entry(idx).or_insert_with(|| Page::new(ps));
        if page.fetched {
            return Ok(());
        }
        if !page.dirty.is_empty() {
            self.stats.add(Counter::RmwCycles, 1);
        }
        let mut from_store = vec![0u8; ps];
        // Short at EOF only; the tail stays zeros, like a file hole.
        self.storage.read_at(idx * self.page_size, &mut from_store)?;
        let mut at = 0usize;
        for &(s, e) in &page.dirty {
            page.buf[at..s].copy_from_slice(&from_store[at..s]);
            at = e;
        }
        page.buf[at..].copy_from_slice(&from_store[at..]);
        page.fetched = true;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Flushing
    // ------------------------------------------------------------------

    /// Flush every dirty extent to storage as coalesced runs; returns
    /// the bytes written. Extents are snapshotted and marked clean under
    /// the page-table lock, then written outside it (concurrent writes
    /// re-dirty their pages and flush next time); `flush_gate`
    /// serializes overlapping flushes so writes never reorder. On a
    /// failed flush the snapshotted bytes are lost and the error is the
    /// caller's (or, from the background lane, stored for the next
    /// write/sync) — deferred-error write-behind semantics.
    pub(crate) fn flush(&self) -> Result<usize> {
        let _gate = self.flush_gate.lock().unwrap();
        let (runs, payload) = {
            let mut st = self.state.lock().unwrap();
            let st = &mut *st;
            let mut runs: Vec<(u64, usize)> = Vec::new();
            let mut payload: Vec<u8> = Vec::new();
            let dirty_pages: Vec<u64> = st
                .pages
                .iter()
                .filter(|(_, p)| !p.dirty.is_empty())
                .map(|(&i, _)| i)
                .collect();
            for idx in dirty_pages {
                // Gap-filling RMW: a multi-extent page flushes as one
                // covering run, which needs real file bytes between the
                // extents. If the pre-read fails (a truly dead region),
                // degrade to extent-only writes rather than losing the
                // dirty data or inventing gap bytes.
                let needs_fill = {
                    let p = &st.pages[&idx];
                    p.dirty.len() > 1 && !p.fetched
                };
                let whole = !needs_fill || self.fetch(st, idx).is_ok();
                let base = idx * self.page_size;
                let page = st.pages.get_mut(&idx).expect("dirty page resident");
                let spans: Vec<(usize, usize)> = if whole && page.fetched {
                    vec![(page.dirty[0].0, page.dirty[page.dirty.len() - 1].1)]
                } else {
                    page.dirty.clone()
                };
                for (s, e) in spans {
                    let abs = base + s as u64;
                    if let Some(last) = runs.last_mut() {
                        if last.0 + last.1 as u64 == abs {
                            last.1 += e - s;
                            payload.extend_from_slice(&page.buf[s..e]);
                            continue;
                        }
                    }
                    runs.push((abs, e - s));
                    payload.extend_from_slice(&page.buf[s..e]);
                }
                st.dirty_bytes -= page.dirty_bytes() as u64;
                page.dirty.clear();
            }
            (runs, payload)
        };
        if runs.is_empty() {
            return Ok(0);
        }
        if runs.len() > 1 {
            self.storage.write_plan(&runs, &payload)?;
        } else {
            self.storage.write_at(runs[0].0, &payload)?;
        }
        self.stats.add(Counter::WriteBehindFlushBytes, payload.len() as u64);
        Ok(payload.len())
    }

    /// Queue a flush on the cache's progress lane once the dirty level
    /// crosses the high-water mark (at most one queued at a time). In a
    /// forked child without a usable lane the flush runs inline.
    fn maybe_background_flush(this: &Arc<PageCache>) {
        if this.state.lock().unwrap().dirty_bytes < this.high_water {
            return;
        }
        if this.flush_queued.swap(true, Ordering::SeqCst) {
            return;
        }
        let me = this.clone();
        this.lane().submit_or_run(move || {
            me.flush_queued.store(false, Ordering::SeqCst);
            if let Err(e) = me.flush() {
                *me.flush_err.lock().unwrap() = Some(e);
            }
        });
    }

    /// The flush lane, spawned on first use (and respawned after a fork
    /// made the inherited worker unusable).
    fn lane(&self) -> Arc<ProgressEngine> {
        let mut lane = self.lane.lock().unwrap();
        match lane.as_ref() {
            Some(engine) if engine.usable() => engine.clone(),
            _ => {
                let engine =
                    Arc::new(ProgressEngine::spawn(format!("jpio-cache-flush-{}", self.rank)));
                *lane = Some(engine.clone());
                engine
            }
        }
    }

    /// Wait out any in-flight background flush.
    fn quiesce(&self) {
        let lane = self.lane.lock().unwrap().clone();
        if let Some(engine) = lane {
            engine.quiesce();
        }
    }

    /// Evict least-recently-used clean pages down to the budget,
    /// flushing first when only dirty pages remain.
    fn enforce_budget(&self) -> Result<()> {
        if self.evict_clean() {
            return Ok(());
        }
        self.flush()?;
        self.evict_clean();
        Ok(())
    }

    /// Evict clean LRU pages; `true` when the budget holds afterwards.
    fn evict_clean(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.pages.len() > self.max_pages {
            let victim = st
                .pages
                .iter()
                .filter(|(_, p)| p.dirty.is_empty())
                .min_by_key(|(_, p)| p.stamp)
                .map(|(&i, _)| i);
            match victim {
                Some(i) => {
                    st.pages.remove(&i);
                }
                None => return false,
            }
        }
        true
    }

    // ------------------------------------------------------------------
    // Coherence points
    // ------------------------------------------------------------------

    /// Flush and drop every resident page, and mark the cached EOF
    /// stale — the next access re-observes it from storage, *after* the
    /// operation this call fences has moved it. The coherence point for
    /// paths that hand the file to agents the cache cannot see:
    /// collective two-phase execution, atomic-mode operations, and size
    /// changes.
    pub(crate) fn flush_and_invalidate(&self) -> Result<()> {
        self.flush()?;
        let mut st = self.state.lock().unwrap();
        st.pages.clear();
        st.dirty_bytes = 0;
        st.size_stale = true;
        Ok(())
    }

    /// The `sync`/`close` coherence point: drain the flush lane, flush,
    /// surface any stored background-flush error, and run the lease
    /// protocol — a sync that published data bumps the
    /// `<path>.jpio-cache-lease` generation; a sync that observes a
    /// generation another handle bumped invalidates every resident page
    /// (MPI §7.2.6.1 writer-sync / reader-sync visibility).
    pub(crate) fn sync_point(&self) -> Result<()> {
        self.quiesce();
        if let Some(e) = self.flush_err.lock().unwrap().take() {
            return Err(e);
        }
        let flushed = self.flush()?;
        let mut st = self.state.lock().unwrap();
        if flushed > 0 {
            let gen = read_lease(&self.lease_path).wrapping_add(1);
            std::fs::write(&self.lease_path, gen.to_le_bytes())
                .map_err(|e| IoError::from_os(e, "cache lease write"))?;
            st.lease_seen = gen;
        }
        let gen = read_lease(&self.lease_path);
        if gen != st.lease_seen {
            st.pages.clear();
            st.dirty_bytes = 0;
            st.logical_size = self.storage.size().unwrap_or(st.logical_size);
            st.size_stale = false;
            st.lease_seen = gen;
        }
        Ok(())
    }

    /// The cached EOF (storage size advanced by unflushed writes).
    pub(crate) fn logical_size(&self) -> u64 {
        let mut st = self.state.lock().unwrap();
        self.refresh_size(&mut st);
        st.logical_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::local::LocalBackend;
    use crate::storage::Backend;

    fn cache_at(path: &str, extra: &[(&str, &str)]) -> (Arc<PageCache>, Arc<dyn StorageFile>) {
        let mut info = Info::from([(keys::CACHE, "enable")]);
        for &(k, v) in extra {
            info.set(k, v);
        }
        let storage = LocalBackend::instant().open(path, crate::storage::OpenOptions::rw_create())
            .unwrap();
        let cache = PageCache::from_info(
            &info,
            path,
            storage.clone(),
            crate::io::stats::FileStats::disabled(),
            0,
        )
        .unwrap();
        (cache, storage)
    }

    fn cleanup(path: &str) {
        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_file(format!("{path}.jpio-cache-lease"));
    }

    #[test]
    fn disabled_hint_builds_no_cache() {
        let path = format!("/tmp/jpio-cache-off-{}", std::process::id());
        let storage =
            LocalBackend::instant().open(&path, crate::storage::OpenOptions::rw_create()).unwrap();
        assert!(PageCache::from_info(
            &Info::null(),
            &path,
            storage.clone(),
            crate::io::stats::FileStats::disabled(),
            0
        )
        .is_none());
        assert!(PageCache::from_info(
            &Info::from([(keys::CACHE, "disable")]),
            &path,
            storage,
            crate::io::stats::FileStats::disabled(),
            0
        )
        .is_none());
        cleanup(&path);
    }

    #[test]
    fn write_behind_coalesces_strided_extents_into_one_run() {
        let path = format!("/tmp/jpio-cache-coalesce-{}", std::process::id());
        let (cache, storage) = cache_at(&path, &[]);
        // 16 strided 64-byte writes inside one page: nothing on storage
        // until the flush, which lands them (plus the fetched gap bytes)
        // as one covering run.
        storage.write_at(0, &[0xEEu8; 2048]).unwrap();
        cache.flush_and_invalidate().unwrap();
        for i in 0..16u64 {
            let plan = IoPlan::from_runs(vec![(i * 128, 64)], false);
            PageCache::write_plan(&cache, &plan, &[i as u8; 64]).unwrap();
        }
        assert_eq!(cache.state.lock().unwrap().dirty_bytes, 16 * 64);
        let flushed = cache.flush().unwrap();
        // One covering span [0, 15*128+64): dirty bytes plus RMW-fetched
        // gap bytes written back unchanged.
        assert_eq!(flushed, 15 * 128 + 64);
        let mut back = vec![0u8; 2048];
        storage.read_at(0, &mut back).unwrap();
        for i in 0..16usize {
            assert_eq!(&back[i * 128..i * 128 + 64], &[i as u8; 64]);
            if i < 15 {
                assert_eq!(&back[i * 128 + 64..(i + 1) * 128], &[0xEEu8; 64], "gap bytes");
            }
        }
        cleanup(&path);
    }

    #[test]
    fn read_hits_after_miss_and_respects_eof() {
        let path = format!("/tmp/jpio-cache-read-{}", std::process::id());
        let (cache, storage) = cache_at(&path, &[]);
        let data: Vec<u8> = (0..200u8).collect();
        storage.write_at(0, &data).unwrap();
        cache.flush_and_invalidate().unwrap(); // observe the new EOF
        let stats = cache.stats.clone();
        let plan = IoPlan::from_runs(vec![(10, 50)], false);
        let mut buf = vec![0u8; 50];
        assert_eq!(cache.read_plan(&plan, &mut buf).unwrap(), 50);
        assert_eq!(buf, data[10..60]);
        let miss0 = stats.value(Counter::CacheMissBytes);
        assert!(miss0 >= 50, "first read must miss");
        assert_eq!(cache.read_plan(&plan, &mut buf).unwrap(), 50);
        assert_eq!(stats.value(Counter::CacheMissBytes), miss0, "repeat read must not miss");
        assert_eq!(stats.value(Counter::CacheHitBytes), 50);
        // Reads past EOF are short, stopping at the first short run.
        let plan = IoPlan::from_runs(vec![(150, 50), (300, 10)], false);
        let mut buf = vec![0u8; 60];
        assert_eq!(cache.read_plan(&plan, &mut buf).unwrap(), 50);
        cleanup(&path);
    }

    #[test]
    fn cached_writes_are_read_back_before_any_flush() {
        let path = format!("/tmp/jpio-cache-rwb-{}", std::process::id());
        let (cache, storage) = cache_at(&path, &[]);
        let plan = IoPlan::from_runs(vec![(100, 8), (300, 8)], false);
        let payload: Vec<u8> = (0..16).collect();
        PageCache::write_plan(&cache, &plan, &payload).unwrap();
        assert_eq!(storage.size().unwrap(), 0, "write-behind: storage untouched");
        assert_eq!(cache.logical_size(), 308);
        let mut back = vec![0u8; 16];
        assert_eq!(cache.read_plan(&plan, &mut back).unwrap(), 16);
        assert_eq!(back, payload);
        cache.sync_point().unwrap();
        assert_eq!(storage.size().unwrap(), 308);
        cleanup(&path);
    }

    #[test]
    fn budget_evicts_clean_pages_and_flushes_dirty_ones() {
        let path = format!("/tmp/jpio-cache-budget-{}", std::process::id());
        // Budget of exactly 2 pages (the floor) at the 64 KiB default.
        let (cache, storage) = cache_at(&path, &[(keys::CACHE_SIZE, "1")]);
        assert_eq!(cache.max_pages, 2);
        let ps = cache.page_size;
        for i in 0..6u64 {
            let plan = IoPlan::from_runs(vec![(i * ps, 16)], false);
            PageCache::write_plan(&cache, &plan, &[i as u8; 16]).unwrap();
        }
        assert!(cache.state.lock().unwrap().pages.len() <= 2, "budget must hold");
        // Every evicted page was flushed first: the data survives.
        cache.sync_point().unwrap();
        let mut back = vec![0u8; 16];
        for i in 0..6u64 {
            storage.read_at(i * ps, &mut back).unwrap();
            assert_eq!(back, [i as u8; 16], "page {i} lost by eviction");
        }
        cleanup(&path);
    }

    #[test]
    fn lease_sync_invalidates_the_other_handles_pages() {
        let path = format!("/tmp/jpio-cache-lease-{}", std::process::id());
        let (writer, storage) = cache_at(&path, &[]);
        let (reader, _) = cache_at(&path, &[]);
        storage.write_at(0, &[1u8; 64]).unwrap();
        writer.flush_and_invalidate().unwrap();
        reader.flush_and_invalidate().unwrap();
        // Reader caches the old bytes.
        let plan = IoPlan::from_runs(vec![(0, 64)], false);
        let mut buf = vec![0u8; 64];
        reader.read_plan(&plan, &mut buf).unwrap();
        assert_eq!(buf, [1u8; 64]);
        // Writer overwrites through its cache and syncs (bumps lease).
        PageCache::write_plan(&writer, &plan, &[2u8; 64]).unwrap();
        writer.sync_point().unwrap();
        // Without a sync the reader still serves its resident page…
        reader.read_plan(&plan, &mut buf).unwrap();
        assert_eq!(buf, [1u8; 64]);
        // …and its own sync observes the bumped lease and refetches.
        reader.sync_point().unwrap();
        reader.read_plan(&plan, &mut buf).unwrap();
        assert_eq!(buf, [2u8; 64]);
        cleanup(&path);
    }

    #[test]
    fn write_through_hint_flushes_every_write() {
        let path = format!("/tmp/jpio-cache-wt-{}", std::process::id());
        let (cache, storage) = cache_at(&path, &[(keys::WRITE_BEHIND, "disable")]);
        let plan = IoPlan::from_runs(vec![(0, 32)], false);
        PageCache::write_plan(&cache, &plan, &[7u8; 32]).unwrap();
        let mut back = vec![0u8; 32];
        assert_eq!(storage.read_at(0, &mut back).unwrap(), 32, "write-through must land");
        assert_eq!(back, [7u8; 32]);
        assert_eq!(cache.state.lock().unwrap().dirty_bytes, 0);
        cleanup(&path);
    }
}
