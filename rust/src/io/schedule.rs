//! The `IoScheduler` — the single executor every compiled [`IoPlan`]
//! runs on — and its [`PlanCache`].
//!
//! Compilation ([`crate::io::plan`]) decides *what* bytes move;
//! scheduling decides *how and when*, in one of three modes (the
//! ViPIOS decoupling of request preparation from an asynchronous
//! execution engine):
//!
//! * **synchronous** ([`IoScheduler::write`] / [`IoScheduler::read`]) —
//!   the blocking routines of every access family;
//! * **engine** ([`IoScheduler::write_async`] /
//!   [`IoScheduler::read_async`]) — nonblocking routines; the plan is
//!   compiled on the caller and executed on the request-engine worker
//!   pool ([`crate::io::engine`]);
//! * **phase-by-phase** ([`IoScheduler::write_phase`],
//!   [`IoScheduler::write_phase_async`],
//!   [`IoScheduler::read_phase_pipelined`]) — two-phase collectives: the
//!   exchange phase ran wherever the communicator endpoint lives (the
//!   caller for blocking/split collectives, the rank's progress thread
//!   for the off-caller nonblocking collectives), and the storage-only
//!   I/O phase runs here. Both phase executors pipeline their work in
//!   staging-buffer-sized **rounds** with one helper thread at depth 1 —
//!   the aggregator double buffer: exchange decode (write) or reply
//!   slicing (read) of round *n+1* overlaps the storage I/O of round
//!   *n*.
//!
//! Since every access cell funnels through the [`AccessOp`] core
//! ([`crate::io::op`]), the scheduler is the one place plan reuse can
//! live: [`PlanCache`] memoizes compiled plans keyed by *(view identity,
//! direction, atomicity, etype offset, payload length)* — the steady
//! state of every bench repeats the same access shape, and a hit skips
//! the whole view flatten/coalesce pass, not just the view's run cache.
//!
//! Execution routes through the access strategy's plan entry points, or
//! hands whole multi-run plans straight to storage backends that dispatch
//! vectored plans themselves
//! ([`crate::storage::StorageFile::prefers_plan_execution`] — the striped
//! backend's per-server concurrent fan-out).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::comm::Status;
use crate::io::cache::PageCache;
use crate::io::collective::{decode_runs, WriteIoWork};
use crate::io::engine::{self, Request};
use crate::io::errors::Result;
use crate::io::op::{Direction, TransferCtx};
use crate::io::plan::IoPlan;
use crate::io::stats::{Counter, Phase, PlanCacheStats};
use crate::io::view::FileView;
use crate::strategy::{AccessStrategy, ViewBufStrategy};

/// Capacity of the per-file plan cache. Small on purpose: the cache
/// exists for the repeat-same-shape steady state, not as a general
/// memoizer, and entries pin their `Arc<FileView>` alive.
const PLAN_CACHE_CAP: usize = 16;

struct PlanCacheEntry {
    /// The view the plan was compiled against. Holding the `Arc` keeps
    /// the pointer alive, so identity comparison (`Arc::ptr_eq`) can
    /// never alias a reallocated view.
    view: Arc<FileView>,
    direction: Direction,
    atomic: bool,
    etype_off: i64,
    len: usize,
    plan: Arc<IoPlan>,
}

/// Memoizes compiled [`IoPlan`]s per file handle, keyed by
/// *(view identity, direction, atomicity, etype offset, payload len)*.
/// A `set_view` installs a new `Arc<FileView>`, so stale entries can
/// never match again and simply age out of the small LRU. Gap-free
/// (contiguous) views bypass the cache entirely: their plans compile in
/// O(1), and caching them would evict the noncontiguous flattens the
/// cache exists to keep.
pub(crate) struct PlanCache {
    entries: Mutex<Vec<PlanCacheEntry>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new()
    }
}

impl PlanCache {
    /// An empty cache (one per open file handle).
    pub(crate) fn new() -> PlanCache {
        PlanCache {
            entries: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Return the cached plan for the key, or compile and insert it.
    pub(crate) fn lookup(
        &self,
        view: &Arc<FileView>,
        direction: Direction,
        atomic: bool,
        etype_off: i64,
        len: usize,
    ) -> Result<Arc<IoPlan>> {
        // Gap-free views compile to a single run in O(1) — IoPlan's own
        // fast path. Caching them would only churn the LRU slots the
        // expensive noncontiguous flattens need, so they bypass the
        // cache (and its counters).
        if view.contiguous_run(etype_off, len).is_some() {
            return Ok(Arc::new(IoPlan::compile(view, atomic, etype_off, len)?));
        }
        let probe = |entries: &mut Vec<PlanCacheEntry>| -> Option<Arc<IoPlan>> {
            let i = entries.iter().position(|e| {
                Arc::ptr_eq(&e.view, view)
                    && e.direction == direction
                    && e.atomic == atomic
                    && e.etype_off == etype_off
                    && e.len == len
            })?;
            let e = entries.remove(i);
            let plan = e.plan.clone();
            entries.insert(0, e);
            Some(plan)
        };
        if let Some(plan) = probe(&mut self.entries.lock().unwrap()) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(plan);
        }
        // Compile outside the lock; the compile walk can be expensive.
        let plan = Arc::new(IoPlan::compile(view, atomic, etype_off, len)?);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut entries = self.entries.lock().unwrap();
        // Re-probe: a concurrent first access of the same shape may have
        // inserted while we compiled — serve its entry rather than
        // stuffing the small LRU with duplicates.
        if let Some(existing) = probe(&mut entries) {
            return Ok(existing);
        }
        entries.insert(
            0,
            PlanCacheEntry {
                view: view.clone(),
                direction,
                atomic,
                etype_off,
                len,
                plan: plan.clone(),
            },
        );
        entries.truncate(PLAN_CACHE_CAP);
        Ok(plan)
    }

    /// Hit/miss counters.
    pub(crate) fn stats(&self) -> PlanCacheStats {
        PlanCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

/// Executes compiled plans; see the module docs for the three modes.
pub(crate) struct IoScheduler;

impl IoScheduler {
    /// Synchronous write of a packed (already datarep-encoded) payload.
    /// Timed as the `storage` phase.
    pub(crate) fn write(ctx: &TransferCtx, plan: &IoPlan, payload: &[u8]) -> Result<Status> {
        let t0 = ctx.stats.start();
        if let Some(cache) = &ctx.cache {
            if plan.atomic {
                // Atomic-mode coherence point: serialize under the
                // whole-file lock below, which resident pages can't see.
                cache.flush_and_invalidate()?;
            } else {
                let n = PageCache::write_plan(cache, plan, payload)?;
                ctx.stats.record(Phase::Storage, t0);
                return Ok(Status::of_bytes(n));
            }
        }
        let _guard = if plan.atomic { Some(ctx.storage.lock_exclusive()?) } else { None };
        let n = if ctx.storage.prefers_plan_execution() && plan.runs.len() > 1 {
            ctx.storage.write_plan(&plan.runs, payload)?
        } else {
            ctx.strategy.write_plan(ctx.storage.as_ref(), plan, payload)?
        };
        ctx.stats.record(Phase::Storage, t0);
        Ok(Status::of_bytes(n))
    }

    /// Synchronous read into a packed payload buffer; returns bytes read
    /// (short at EOF) after datarep decode. Timed as the `storage` phase.
    pub(crate) fn read(ctx: &TransferCtx, plan: &IoPlan, payload: &mut [u8]) -> Result<usize> {
        let t0 = ctx.stats.start();
        if let Some(cache) = &ctx.cache {
            if plan.atomic {
                cache.flush_and_invalidate()?;
            } else {
                let got = cache.read_plan(plan, payload)?;
                if plan.needs_convert() {
                    plan.datarep.decode(&mut payload[..got], &plan.decode_elems(got));
                }
                ctx.stats.record(Phase::Storage, t0);
                return Ok(got);
            }
        }
        let got = {
            let _guard = if plan.atomic { Some(ctx.storage.lock_exclusive()?) } else { None };
            if ctx.storage.prefers_plan_execution() && plan.runs.len() > 1 {
                ctx.storage.read_plan(&plan.runs, payload)?
            } else {
                ctx.strategy.read_plan(ctx.storage.as_ref(), plan, payload)?
            }
        };
        if plan.needs_convert() {
            plan.datarep.decode(&mut payload[..got], &plan.decode_elems(got));
        }
        ctx.stats.record(Phase::Storage, t0);
        Ok(got)
    }

    /// Engine-scheduled write: the caller keeps computing while the plan
    /// executes on the worker pool.
    pub(crate) fn write_async(
        ctx: TransferCtx,
        plan: Arc<IoPlan>,
        payload: Vec<u8>,
    ) -> Request<()> {
        engine::submit(move || (Self::write(&ctx, &plan, &payload), ()))
    }

    /// Engine-scheduled read returning the packed payload.
    pub(crate) fn read_async(
        ctx: TransferCtx,
        plan: Arc<IoPlan>,
        payload_len: usize,
    ) -> Request<Vec<u8>> {
        engine::submit(move || {
            let mut payload = vec![0u8; payload_len];
            match Self::read(&ctx, &plan, &mut payload) {
                Ok(got) => (Ok(Status::of_bytes(got)), payload),
                Err(e) => (Err(e), payload),
            }
        })
    }

    /// The storage-only I/O phase of a two-phase collective write:
    /// decode the exchanged messages into staging **rounds** of
    /// strictly-adjacent pieces (up to `cb_buffer` bytes each) and hit
    /// the file once per round. Rounds are pipelined at depth 1 to a
    /// scoped writer thread, so decoding (gathering payload bytes out of
    /// the raw exchange messages) of round *n+1* overlaps the storage
    /// write of round *n* — the aggregator double buffer; spent staging
    /// buffers ping-pong back for reuse. Touches no communicator state,
    /// so it is safe on the engine and on progress threads. Timed as the
    /// `storage` phase.
    pub(crate) fn write_phase(ctx: &TransferCtx, work: WriteIoWork) -> Result<()> {
        let t0 = ctx.stats.start();
        Self::write_phase_inner(ctx, work)?;
        ctx.stats.record(Phase::Storage, t0);
        Ok(())
    }

    fn write_phase_inner(ctx: &TransferCtx, work: WriteIoWork) -> Result<()> {
        // Header pass: run lists only; payload bytes stay in the raw
        // messages until their round is staged. Message order is rank
        // order, and the stable sort keeps it on equal offsets — the
        // deterministic overwrite semantics. (Overlapping pieces are
        // never merged; the single writer stores rounds in order.)
        let mut pieces: Vec<(u64, usize, usize, usize)> = Vec::new(); // (off, len, msg, pos)
        for (m, msg) in work.inbound.iter().enumerate() {
            if msg.len() < 4 {
                continue;
            }
            let (rs, mut pos) = decode_runs(msg);
            for (off, len) in rs {
                pieces.push((off, len, m, pos));
                pos += len;
            }
        }
        pieces.sort_by_key(|&(off, ..)| off);
        if pieces.is_empty() {
            return Ok(());
        }
        // Two-phase coherence point: the aggregator writes bytes other
        // ranks own, so this rank's resident pages go stale here — and
        // its own dirty pages must land first to keep write order.
        if let Some(cache) = &ctx.cache {
            cache.flush_and_invalidate()?;
        }
        let cb_buffer = work.cb_buffer;
        let strat = ViewBufStrategy::with_stage(cb_buffer);
        let _guard = if ctx.atomic { Some(ctx.storage.lock_exclusive()?) } else { None };
        // Zero-copy fast path: backends that execute whole plans
        // themselves (the striped per-server fan-out) take the exchange
        // pieces in place — no payload-sized staging copy, no rounds.
        // Overlapping pieces stay on the staged path below, whose
        // ordered single writer carries the rank-order overwrite
        // semantics.
        let overlaps = pieces.windows(2).any(|w| w[0].0 + w[0].1 as u64 > w[1].0);
        if !overlaps && ctx.storage.prefers_plan_execution() {
            let refs: Vec<(u64, &[u8])> = pieces
                .iter()
                .map(|&(off, len, m, pos)| (off, &work.inbound[m][pos..pos + len]))
                .collect();
            ctx.storage.write_pieces(&refs)?;
            return Ok(());
        }
        // Every staged byte below is one copy out of the raw exchange
        // messages — the quantity the zero-copy path eliminates.
        ctx.stats.add(
            Counter::StagingCopyBytes,
            pieces.iter().map(|&(_, len, ..)| len as u64).sum(),
        );
        // Count rounds from the headers alone. The common case — a
        // contiguous collective whose pieces coalesce into one round —
        // stages and writes inline: there is nothing to pipeline, so it
        // skips the writer thread and both channels entirely.
        let mut nrounds = 0usize;
        let mut probe: Option<(u64, usize)> = None; // (start, staged len)
        for &(off, len, ..) in &pieces {
            match &mut probe {
                Some((poff, plen)) if *poff + *plen as u64 == off && *plen + len <= cb_buffer => {
                    *plen += len;
                }
                _ => {
                    nrounds += 1;
                    probe = Some((off, len));
                }
            }
        }
        if nrounds == 1 {
            let (start, total) = probe.expect("pieces is non-empty");
            let mut buf = Vec::with_capacity(total);
            for &(_, len, m, pos) in &pieces {
                buf.extend_from_slice(&work.inbound[m][pos..pos + len]);
            }
            strat.write(ctx.storage.as_ref(), &[(start, buf.len())], &buf)?;
            return Ok(());
        }
        let storage = &ctx.storage;
        std::thread::scope(|s| -> Result<()> {
            // Depth-1 pipeline: one round queued while one is written.
            let (tx, rx) = mpsc::sync_channel::<(u64, Vec<u8>)>(1);
            let (back_tx, back_rx) = mpsc::channel::<Vec<u8>>();
            let writer = s.spawn(move || -> Result<()> {
                while let Ok((off, buf)) = rx.recv() {
                    strat.write(storage.as_ref(), &[(off, buf.len())], &buf)?;
                    let _ = back_tx.send(buf);
                }
                Ok(())
            });
            let mut cur: Option<(u64, Vec<u8>)> = None;
            'stage: for &(off, len, m, pos) in &pieces {
                let bytes = &work.inbound[m][pos..pos + len];
                let merges = match &cur {
                    Some((coff, cbuf)) => {
                        *coff + cbuf.len() as u64 == off && cbuf.len() + len <= cb_buffer
                    }
                    None => false,
                };
                if merges {
                    cur.as_mut().unwrap().1.extend_from_slice(bytes);
                    continue;
                }
                if let Some(round) = cur.take() {
                    if tx.send(round).is_err() {
                        // Writer failed early; its error surfaces at join.
                        break 'stage;
                    }
                }
                let mut buf = back_rx.try_recv().unwrap_or_default();
                buf.clear();
                buf.extend_from_slice(bytes);
                cur = Some((off, buf));
            }
            if let Some(round) = cur.take() {
                let _ = tx.send(round);
            }
            drop(tx);
            writer.join().expect("aggregator writer thread panicked")
        })
    }

    /// [`IoScheduler::write_phase`] on the request engine — the split
    /// collectives' and `iwrite_all`'s overlap path. `bytes` is the
    /// payload size reported on completion.
    pub(crate) fn write_phase_async(
        ctx: TransferCtx,
        work: WriteIoWork,
        bytes: usize,
    ) -> Request<()> {
        engine::submit(move || match Self::write_phase(&ctx, work) {
            Ok(()) => (Ok(Status::of_bytes(bytes)), ()),
            Err(e) => (Err(e), ()),
        })
    }

    /// Pipelined aggregator read: the merged request intervals are split
    /// into **rounds** of whole runs totalling at most `stage` bytes,
    /// and the storage read of round *n+1* (on a scoped helper thread,
    /// depth 1) overlaps `consume(base, bytes)` of round *n* — reply
    /// slicing, in the collective read. `base` is the round's starting
    /// position within the packed `buf`; rounds arrive in order and
    /// cover `buf` exactly. Returns total bytes read (short at EOF).
    ///
    /// `runs` are already merged sorted intervals (an aggregator-side
    /// plan in all but name) — no recompilation needed. Backends with
    /// their own vectored fan-out ([`crate::storage::StorageFile::prefers_plan_execution`] —
    /// the striped per-server pool) take the whole plan in one shot
    /// instead: chunking it into rounds would serialize their internal
    /// concurrency.
    pub(crate) fn read_phase_pipelined<F>(
        ctx: &TransferCtx,
        runs: &[(u64, usize)],
        stage: usize,
        buf: &mut [u8],
        consume: F,
    ) -> Result<usize>
    where
        F: FnMut(usize, &[u8]),
    {
        let t0 = ctx.stats.start();
        let got = Self::read_phase_pipelined_inner(ctx, runs, stage, buf, consume)?;
        ctx.stats.record(Phase::Storage, t0);
        Ok(got)
    }

    fn read_phase_pipelined_inner<F>(
        ctx: &TransferCtx,
        runs: &[(u64, usize)],
        stage: usize,
        buf: &mut [u8],
        mut consume: F,
    ) -> Result<usize>
    where
        F: FnMut(usize, &[u8]),
    {
        if runs.is_empty() {
            return Ok(0);
        }
        // Two-phase coherence point: the aggregator reads bytes for
        // other ranks, so this rank's dirty pages must be visible on
        // storage before the pre-read.
        if let Some(cache) = &ctx.cache {
            cache.flush_and_invalidate()?;
        }
        let _guard = if ctx.atomic { Some(ctx.storage.lock_exclusive()?) } else { None };
        if ctx.storage.prefers_plan_execution() && runs.len() > 1 {
            let got = ctx.storage.read_plan(runs, buf)?;
            consume(0, &buf[..]);
            return Ok(got);
        }
        // Round boundaries: whole runs greedily grouped under `stage`
        // bytes (a run larger than the stage is its own round — the
        // strategy streams it in stage-sized chunks internally).
        let mut rounds: Vec<(usize, usize, usize)> = Vec::new(); // (first run, count, bytes)
        let mut first = 0usize;
        let mut bytes = 0usize;
        for (i, &(_, len)) in runs.iter().enumerate() {
            if i > first && bytes + len > stage {
                rounds.push((first, i - first, bytes));
                first = i;
                bytes = 0;
            }
            bytes += len;
        }
        rounds.push((first, runs.len() - first, bytes));
        let strat = ViewBufStrategy::with_stage(stage);
        if rounds.len() == 1 {
            let got = strat.read(ctx.storage.as_ref(), runs, buf)?;
            consume(0, &buf[..]);
            return Ok(got);
        }
        let storage = &ctx.storage;
        let strat = &strat;
        std::thread::scope(|s| -> Result<usize> {
            let mut total = 0usize;
            let mut rest: &mut [u8] = buf;
            let mut base = 0usize;
            let mut prev = None;
            for &(first, count, bytes) in &rounds {
                let (slice, tail) = std::mem::take(&mut rest).split_at_mut(bytes);
                rest = tail;
                let round_runs = &runs[first..first + count];
                let handle = s.spawn(move || {
                    let res = strat.read(storage.as_ref(), round_runs, &mut *slice);
                    (res, slice)
                });
                if let Some((h, pbase)) = prev.replace((handle, base)) {
                    let (res, done): (Result<usize>, &mut [u8]) =
                        h.join().expect("aggregator reader thread panicked");
                    total += res?;
                    consume(pbase, &done[..]);
                }
                base += bytes;
            }
            if let Some((h, pbase)) = prev {
                let (res, done): (Result<usize>, &mut [u8]) =
                    h.join().expect("aggregator reader thread panicked");
                total += res?;
                consume(pbase, &done[..]);
            }
            Ok(total)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::view::FileView;
    use crate::storage::local::LocalBackend;
    use crate::storage::{Backend, OpenOptions};
    use crate::strategy;
    use std::sync::Arc;

    fn ctx(path: &str) -> TransferCtx {
        let b = LocalBackend::instant();
        TransferCtx {
            storage: b.open(path, OpenOptions::rw_create()).unwrap(),
            strategy: Arc::from(strategy::by_name("view_buffer").unwrap()),
            view: Arc::new(FileView::default()),
            atomic: false,
            stats: crate::io::stats::FileStats::disabled(),
            cache: None,
        }
    }

    #[test]
    fn sync_plan_roundtrip() {
        let path = format!("/tmp/jpio-sched-sync-{}", std::process::id());
        let c = ctx(&path);
        let plan = IoPlan::from_runs(vec![(3, 4), (20, 4)], false);
        let st = IoScheduler::write(&c, &plan, b"abcdwxyz").unwrap();
        assert_eq!(st.bytes, 8);
        let mut back = [0u8; 8];
        assert_eq!(IoScheduler::read(&c, &plan, &mut back).unwrap(), 8);
        assert_eq!(&back, b"abcdwxyz");
        LocalBackend::instant().delete(&path).unwrap();
    }

    #[test]
    fn async_plan_roundtrip() {
        let path = format!("/tmp/jpio-sched-async-{}", std::process::id());
        let c = ctx(&path);
        let plan = Arc::new(IoPlan::from_runs(vec![(0, 6)], false));
        let req = IoScheduler::write_async(ctx(&path), plan.clone(), b"hello!".to_vec());
        let (st, ()) = req.wait().unwrap();
        assert_eq!(st.bytes, 6);
        let (st, payload) = IoScheduler::read_async(c, plan, 6).wait().unwrap();
        assert_eq!(st.bytes, 6);
        assert_eq!(&payload, b"hello!");
        LocalBackend::instant().delete(&path).unwrap();
    }

    /// A strided (noncontiguous) view — the kind of plan the cache keeps.
    fn strided_view() -> Arc<FileView> {
        use crate::comm::datatype::Datatype;
        use crate::io::datarep::DataRep;
        let ft = Datatype::vector(1, 2, 4, &Datatype::INT).unwrap();
        let ft = Datatype::resized(&ft, 0, 16).unwrap();
        Arc::new(FileView::new(0, Datatype::INT, ft, DataRep::Native).unwrap())
    }

    #[test]
    fn plan_cache_hits_on_repeat_shapes_and_respects_identity() {
        let cache = PlanCache::new();
        let v1 = strided_view();
        let p1 = cache.lookup(&v1, Direction::Read, false, 0, 64).unwrap();
        assert_eq!(cache.stats(), PlanCacheStats { hits: 0, misses: 1 });
        let p2 = cache.lookup(&v1, Direction::Read, false, 0, 64).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "same key must reuse the compiled plan");
        assert_eq!(cache.stats(), PlanCacheStats { hits: 1, misses: 1 });
        // Different direction, offset, len, atomicity: distinct keys.
        cache.lookup(&v1, Direction::Write, false, 0, 64).unwrap();
        cache.lookup(&v1, Direction::Read, false, 8, 64).unwrap();
        cache.lookup(&v1, Direction::Read, false, 0, 32).unwrap();
        cache.lookup(&v1, Direction::Read, true, 0, 64).unwrap();
        assert_eq!(cache.stats(), PlanCacheStats { hits: 1, misses: 5 });
        // A new view Arc (set_view) never matches the old identity.
        let v2 = strided_view();
        cache.lookup(&v2, Direction::Read, false, 0, 64).unwrap();
        assert_eq!(cache.stats(), PlanCacheStats { hits: 1, misses: 6 });
    }

    #[test]
    fn plan_cache_bypasses_contiguous_views() {
        // Gap-free views compile O(1); they must not occupy LRU slots or
        // touch the counters.
        let cache = PlanCache::new();
        let flat = Arc::new(FileView::default());
        let p = cache.lookup(&flat, Direction::Read, false, 3, 64).unwrap();
        assert_eq!(p.runs, vec![(3, 64)]);
        cache.lookup(&flat, Direction::Read, false, 3, 64).unwrap();
        let s = cache.stats();
        assert_eq!(s, PlanCacheStats::default(), "contiguous plans must bypass the cache");
    }

    #[test]
    fn plan_cache_evicts_beyond_capacity() {
        let cache = PlanCache::new();
        let v = strided_view();
        for i in 0..(PLAN_CACHE_CAP + 4) {
            cache.lookup(&v, Direction::Read, false, i as i64, 8).unwrap();
        }
        // The oldest keys were evicted: looking one up again is a miss.
        let misses_before = cache.stats().misses;
        cache.lookup(&v, Direction::Read, false, 0, 8).unwrap();
        assert_eq!(cache.stats().misses, misses_before + 1);
        // The most recent key is still cached.
        let hits_before = cache.stats().hits;
        cache.lookup(&v, Direction::Read, false, (PLAN_CACHE_CAP + 3) as i64, 8).unwrap();
        assert_eq!(cache.stats().hits, hits_before + 1);
    }

    #[test]
    fn degraded_plan_execution_on_striped_parity() {
        // A multi-run plan on striped parity storage with one dead
        // child: the whole-plan dispatch (prefers_plan_execution) must
        // still round-trip, reporting Degraded advisories instead of
        // errors — the scheduler sees a plain Ok.
        use crate::io::errors::ErrorClass;
        use crate::storage::faults::{FaultBackend, FaultPlan};
        use crate::storage::layout::Redundancy;
        use crate::storage::striped::StripedBackend;
        let plan_faults = FaultPlan::new(vec![]);
        let children: Vec<Arc<dyn Backend>> = (0..4)
            .map(|i| {
                if i == 2 {
                    Arc::new(FaultBackend::new(LocalBackend::instant(), plan_faults.clone()))
                        as Arc<dyn Backend>
                } else {
                    Arc::new(LocalBackend::instant()) as Arc<dyn Backend>
                }
            })
            .collect();
        let b = StripedBackend::with_redundancy(children, 8, Redundancy::Parity).unwrap();
        let path = format!("/tmp/jpio-sched-degraded-{}", std::process::id());
        let c = TransferCtx {
            storage: b.open(&path, OpenOptions::rw_create()).unwrap(),
            strategy: Arc::from(strategy::by_name("view_buffer").unwrap()),
            view: Arc::new(FileView::default()),
            atomic: false,
            stats: crate::io::stats::FileStats::disabled(),
            cache: None,
        };
        let plan = IoPlan::from_runs(vec![(3, 20), (40, 9), (70, 12)], false);
        let payload: Vec<u8> = (0..41u8).collect();
        let st = IoScheduler::write(&c, &plan, &payload).unwrap();
        assert_eq!(st.bytes, 41);
        assert!(c.storage.take_advisories().is_empty(), "healthy write must not degrade");
        // Kill child 2 and read the plan back: reconstruction under the
        // scheduler, correct bytes, Degraded advisory.
        plan_faults.inject_kill(ErrorClass::Io);
        let mut back = vec![0u8; 41];
        assert_eq!(IoScheduler::read(&c, &plan, &mut back).unwrap(), 41);
        assert_eq!(back, payload);
        let advisories = c.storage.take_advisories();
        assert!(!advisories.is_empty(), "degraded read must be advised");
        assert!(advisories.iter().all(|a| a.class == ErrorClass::Degraded));
        b.delete(&path).unwrap();
    }

    #[test]
    fn write_phase_coalesces_adjacent_pieces() {
        use crate::io::collective::encode_write_msg;
        let path = format!("/tmp/jpio-sched-phase-{}", std::process::id());
        let c = ctx(&path);
        // Two exchange messages, as the aggregator receives them: rank 0
        // owns [0,4) and [16,20), rank 1 owns the adjacent [4,8).
        let p0: Vec<u8> = [[1u8; 4], [3u8; 4]].concat();
        let m0 = encode_write_msg(&[(0, 4, 0), (16, 4, 4)], &p0);
        let m1 = encode_write_msg(&[(4, 4, 0)], &[2u8; 4]);
        let work = WriteIoWork { inbound: vec![m0, m1], cb_buffer: 4096 };
        IoScheduler::write_phase(&c, work).unwrap();
        let mut back = [0u8; 20];
        c.storage.read_at(0, &mut back).unwrap();
        assert_eq!(&back[..4], &[1u8; 4]);
        assert_eq!(&back[4..8], &[2u8; 4]);
        assert_eq!(&back[16..20], &[3u8; 4]);
        LocalBackend::instant().delete(&path).unwrap();
    }

    #[test]
    fn write_phase_rank_order_wins_on_overlap() {
        use crate::io::collective::encode_write_msg;
        let path = format!("/tmp/jpio-sched-overlap-{}", std::process::id());
        let c = ctx(&path);
        // Ranks 0 and 1 both write [0,8): the higher rank's bytes must
        // land last (deterministic rank-order overwrite), across any
        // round boundary (cb_buffer = 4 forces one round per piece).
        let m0 = encode_write_msg(&[(0, 8, 0)], &[7u8; 8]);
        let m1 = encode_write_msg(&[(0, 8, 0)], &[9u8; 8]);
        let work = WriteIoWork { inbound: vec![m0, m1], cb_buffer: 4 };
        IoScheduler::write_phase(&c, work).unwrap();
        let mut back = [0u8; 8];
        c.storage.read_at(0, &mut back).unwrap();
        assert_eq!(back, [9u8; 8]);
        LocalBackend::instant().delete(&path).unwrap();
    }

    #[test]
    fn write_phase_zero_copy_on_plan_backends() {
        use crate::io::collective::encode_write_msg;
        use crate::storage::striped::StripedBackend;
        let b = StripedBackend::local(4, 8);
        let path = format!("/tmp/jpio-sched-zc-{}", std::process::id());
        let c = TransferCtx {
            storage: b.open(&path, OpenOptions::rw_create()).unwrap(),
            strategy: Arc::from(strategy::by_name("view_buffer").unwrap()),
            view: Arc::new(FileView::default()),
            atomic: false,
            stats: crate::io::stats::FileStats::disabled(),
            cache: None,
        };
        // Disjoint pieces spanning stripe boundaries, from two ranks:
        // the plan-execution backend must take them in place.
        let p0: Vec<u8> = (1..=20u8).collect();
        let m0 = encode_write_msg(&[(0, 12, 0), (30, 8, 12)], &p0);
        let m1 = encode_write_msg(&[(12, 10, 0)], &[0xABu8; 10]);
        let work = WriteIoWork { inbound: vec![m0, m1], cb_buffer: 4096 };
        IoScheduler::write_phase(&c, work).unwrap();
        assert_eq!(
            c.stats.value(Counter::StagingCopyBytes),
            0,
            "zero-copy dispatch must not stage any payload bytes"
        );
        let mut back = vec![0u8; 38];
        assert_eq!(c.storage.read_at(0, &mut back).unwrap(), 38);
        assert_eq!(&back[..12], &p0[..12]);
        assert_eq!(&back[12..22], &[0xABu8; 10]);
        assert!(back[22..30].iter().all(|&v| v == 0), "gap must stay zeros");
        assert_eq!(&back[30..38], &p0[12..20]);
        // Overlapping pieces fall back to the staged single writer
        // (rank-order overwrite) and count every copied byte.
        let m0 = encode_write_msg(&[(0, 8, 0)], &[7u8; 8]);
        let m1 = encode_write_msg(&[(0, 8, 0)], &[9u8; 8]);
        let work = WriteIoWork { inbound: vec![m0, m1], cb_buffer: 4096 };
        IoScheduler::write_phase(&c, work).unwrap();
        assert_eq!(c.stats.value(Counter::StagingCopyBytes), 16);
        let mut over = [0u8; 8];
        c.storage.read_at(0, &mut over).unwrap();
        assert_eq!(over, [9u8; 8]);
        b.delete(&path).unwrap();
    }

    #[test]
    fn read_phase_pipelined_rounds_cover_buf_in_order() {
        let path = format!("/tmp/jpio-sched-rounds-{}", std::process::id());
        let c = ctx(&path);
        let data: Vec<u8> = (0..200u8).collect();
        c.storage.write_at(0, &data).unwrap();
        // Five disjoint runs, stage = 40 bytes → multiple rounds; the
        // consumer must see ordered, exactly-covering rounds.
        let runs = [(0u64, 30usize), (40, 30), (80, 30), (120, 30), (160, 30)];
        let mut buf = vec![0u8; 150];
        let mut seen = Vec::new();
        let got = IoScheduler::read_phase_pipelined(&c, &runs, 40, &mut buf, |base, round| {
            seen.push((base, round.len()));
        })
        .unwrap();
        assert_eq!(got, 150);
        let covered: usize = seen.iter().map(|&(_, l)| l).sum();
        assert_eq!(covered, 150, "rounds must cover the buffer exactly");
        for w in seen.windows(2) {
            assert_eq!(w[0].0 + w[0].1, w[1].0, "rounds must arrive in order");
        }
        assert!(seen.len() >= 3, "stage=40 over 150 bytes must split into rounds");
        // The packed bytes match the runs.
        let mut want = Vec::new();
        for &(off, len) in &runs {
            want.extend_from_slice(&data[off as usize..off as usize + len]);
        }
        assert_eq!(buf, want);
        LocalBackend::instant().delete(&path).unwrap();
    }
}
