//! The Info object (`mpj.Info`, §7.2.2.8) — implementation hints.
//!
//! "We will prove implementation of Info class to apply info hints for
//! different file systems" (§5 future work) — implemented here. Hints
//! follow the ROMIO naming convention where one exists (`cb_buffer_size`,
//! `cb_nodes`, `ind_rd_buffer_size`, ...) plus jpio-specific keys for
//! backend/strategy selection.

use std::collections::BTreeMap;

/// Key/value hints attached to a file at open or via `setInfo`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Info {
    map: BTreeMap<String, String>,
}

/// Hint keys understood by this implementation.
pub mod keys {
    /// Access strategy: `view_buffer` (default) | `mapped` | `bulk` | `per_item`.
    pub const ACCESS_STYLE: &str = "access_style";
    /// Collective buffering (two-phase I/O): `true` (default) | `false`.
    pub const COLLECTIVE_BUFFERING: &str = "romio_cb_read";
    /// Collective buffer size per aggregator, bytes (ROMIO `cb_buffer_size`).
    pub const CB_BUFFER_SIZE: &str = "cb_buffer_size";
    /// Number of aggregator ranks (ROMIO `cb_nodes`).
    pub const CB_NODES: &str = "cb_nodes";
    /// Explicit aggregator placement (ROMIO `cb_config_list`): entries
    /// `rank` or `rank:count`, comma-separated, `*` = all ranks; entry
    /// `j` of the expansion aggregates file domain `j`, which on striped
    /// storage with `cb_nodes = striping_factor` pins stripe server `j`'s
    /// traffic to that rank. Malformed lists are ignored (MPI hint
    /// semantics) and placement falls back to the stripe-cyclic default.
    pub const CB_CONFIG_LIST: &str = "cb_config_list";
    /// Independent-read data-sieving buffer, bytes.
    pub const IND_RD_BUFFER_SIZE: &str = "ind_rd_buffer_size";
    /// Independent-write staging buffer, bytes.
    pub const IND_WR_BUFFER_SIZE: &str = "ind_wr_buffer_size";
    /// Data sieving for independent reads: `enable` (default) | `disable`.
    pub const DATA_SIEVING: &str = "romio_ds_read";
    /// Storage backend: `local` (default) | `nfs` | `san` | `striped`.
    pub const BACKEND: &str = "jpio_backend";
    /// Backend performance profile: `instant` (default) | `barq` | `rcms`.
    pub const BACKEND_PROFILE: &str = "jpio_backend_profile";
    /// Number of stripe servers for the `striped` backend (ROMIO
    /// `striping_factor`); default 4.
    pub const STRIPING_FACTOR: &str = "striping_factor";
    /// Stripe unit in bytes for the `striped` backend (ROMIO
    /// `striping_unit`); default 64 KiB.
    pub const STRIPING_UNIT: &str = "striping_unit";
    /// Child backend each stripe server runs on when `jpio_backend =
    /// striped`: `local` (default) | `nfs` | `san`. The
    /// `jpio_backend_profile` hint applies to every child.
    pub const STRIPE_CHILD_BACKEND: &str = "jpio_stripe_backend";
    /// Redundancy mode for the `striped` backend: `none` (default) |
    /// `replica:<k>` (k total copies of every stripe unit, tolerating
    /// k-1 lost servers) | `parity` (RAID-5-style rotating parity,
    /// tolerating one lost server). Survivable failures surface as
    /// `Degraded` advisories instead of errors. Malformed values are
    /// ignored; well-formed values the striping factor cannot host
    /// (e.g. `replica:9` over 4 servers) are an error.
    pub const STRIPE_REDUNDANCY: &str = "jpio_stripe_redundancy";
    /// Align collective (two-phase) file domains to stripe boundaries on
    /// striped storage, giving each aggregator a disjoint server subset:
    /// `true` (default) | `false`. Ignored on unstriped backends.
    pub const CB_STRIPE_ALIGN: &str = "jpio_cb_stripe_align";
    /// Per-world progress threads (lanes) driving the MPI-3.1
    /// nonblocking and split collectives entirely off the caller: `1`
    /// (default; one progress thread per rank, spawned lazily) | `0`
    /// (disable — nonblocking collectives run their exchange on the
    /// calling thread) | `k > 1` (k lanes per rank; successive collective
    /// operations round-robin across lanes, each in its own disjoint tag
    /// band, so independent operations pipeline while per-op ordering is
    /// preserved by the engine's operation sequencer). Values above the
    /// lane cap ([`crate::comm::progress::MAX_LANES`]) are clamped.
    /// Collective: every rank of a file must agree, like all
    /// collective-buffering hints — lane assignment is derived from the
    /// collective issue order, which MPI already requires to match.
    pub const PROGRESS_THREADS: &str = "jpio_progress_threads";
    /// All-to-all algorithm for the two-phase exchange:
    /// `auto` (default; rank-count/message-size threshold) | `linear` |
    /// `pairwise` | `bruck`. See
    /// [`crate::comm::AlltoallAlgorithm`] for the selection table.
    /// Collective: every rank must agree (the algorithms are matched
    /// schedules). Malformed values behave as `auto`.
    pub const ALLTOALL_ALGORITHM: &str = "jpio_alltoall_algorithm";
    /// Staging-buffer (round) size in bytes for the aggregator
    /// double-buffer pipeline — the unit at which exchange decode of one
    /// round overlaps storage I/O of the previous round in the two-phase
    /// I/O phases. Defaults to `cb_buffer_size`.
    pub const STAGING_BUFFER_SIZE: &str = "jpio_staging_buffer_size";
    /// Darshan-style instrumentation (`crate::io::stats`): `false`
    /// (default; always-on atomic counters only) | `true` (additionally
    /// record the per-phase wall-clock timers and reduce the per-rank
    /// records collectively at close). Collective: every rank of a file
    /// must agree, like all collective-buffering hints — the close-time
    /// reduction is a collective operation.
    pub const STATS: &str = "jpio_stats";
    /// JSONL trace-event stream path (requires `jpio_stats = true`):
    /// every op and phase span of rank `r` appends one event to
    /// `<path>.<r>` (one file per rank, so ranks never interleave
    /// writes). Schema: [`crate::io::stats::TraceEvent`]. An unopenable
    /// path disables tracing rather than failing the open (MPI hint
    /// semantics).
    pub const STATS_TRACE: &str = "jpio_stats_trace";
    /// Client-side page cache with write-behind
    /// ([`crate::io::cache`]): `disable` (default; every access goes
    /// straight to storage, byte-identical to the uncached path) |
    /// `enable`. Independent data access is absorbed by per-File pages;
    /// `sync`, `close`, size changes, collective phases, and enabling
    /// atomic mode are the coherence points that flush and invalidate.
    /// Cross-process coherence rides a `<path>.jpio-cache-lease`
    /// sidecar (the shared-pointer sidecar machinery): `sync` bumps the
    /// lease generation and readers invalidate on change.
    pub const CACHE: &str = "jpio_cache";
    /// Page-cache byte budget per File (requires `jpio_cache = enable`);
    /// default 8 MiB. Rounded up to one page; when the budget fills,
    /// dirty pages flush and clean pages evict, least recently used
    /// first.
    pub const CACHE_SIZE: &str = "jpio_cache_size";
    /// Pages to read ahead past a cache miss: `0` (default) | `k`.
    /// Sequential re-reads within the prefetched window become hits.
    /// Requires `jpio_cache = enable`.
    pub const PREFETCH: &str = "jpio_prefetch";
    /// Elastic-membership rebuild for the `striped` backend: `start`
    /// (detect a blank/replaced stripe server at open and re-materialize
    /// its objects from the surviving redundancy in the background, on
    /// the process-wide maintenance lane). The rebuild persists a
    /// `<name>.jpio-rebuild` cursor sidecar and resumes across opens;
    /// any other value is ignored (MPI hint semantics). See DESIGN.md
    /// §1c.
    pub const REBUILD: &str = "jpio_rebuild";
    /// Rebuild/restripe throttle for the `striped` backend: bytes
    /// re-materialized or migrated per locked batch (default 64 stripe
    /// units). Smaller batches yield the stripe-consistency lock to
    /// foreground writes more often; larger batches finish maintenance
    /// sooner.
    pub const REBUILD_THROTTLE: &str = "jpio_rebuild_throttle";
    /// Write-behind for the page cache: `enable` (default; small writes
    /// accumulate in dirty pages and coalesce into stripe-aligned
    /// flushes, drained on the progress lane past the high-water mark) |
    /// `disable` (every cached write flushes before returning —
    /// write-through). Requires `jpio_cache = enable`.
    pub const WRITE_BEHIND: &str = "jpio_write_behind";
}

impl Info {
    /// Empty info (`MPJ.INFO_NULL`).
    pub fn null() -> Info {
        Info::default()
    }

    /// Set a hint (`MPI_Info_set`).
    pub fn set(&mut self, key: impl Into<String>, value: impl Into<String>) -> &mut Self {
        self.map.insert(key.into(), value.into());
        self
    }

    /// Builder-style set.
    pub fn with(mut self, key: impl Into<String>, value: impl Into<String>) -> Self {
        self.set(key, value);
        self
    }

    /// Get a hint (`MPI_Info_get`).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    /// Delete a hint (`MPI_Info_delete`); returns whether it existed.
    pub fn delete(&mut self, key: &str) -> bool {
        self.map.remove(key).is_some()
    }

    /// Number of hints (`MPI_Info_get_nkeys`).
    pub fn nkeys(&self) -> usize {
        self.map.len()
    }

    /// The nth key, in sorted order (`MPI_Info_get_nthkey`).
    pub fn nthkey(&self, n: usize) -> Option<&str> {
        self.map.keys().nth(n).map(|s| s.as_str())
    }

    /// Iterate hints.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.map.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    /// Merge `other` into `self`, later values winning (`setInfo` semantics:
    /// "hints may be set at open and amended later").
    pub fn merge(&mut self, other: &Info) {
        for (k, v) in other.iter() {
            self.map.insert(k.to_string(), v.to_string());
        }
    }

    /// Typed getter: usize.
    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    /// Typed getter: boolean-ish (`true/enable/1` vs `false/disable/0`).
    pub fn get_flag(&self, key: &str) -> Option<bool> {
        match self.get(key)? {
            "true" | "enable" | "1" | "yes" => Some(true),
            "false" | "disable" | "0" | "no" => Some(false),
            _ => None,
        }
    }
}

impl<const N: usize> From<[(&str, &str); N]> for Info {
    fn from(pairs: [(&str, &str); N]) -> Info {
        let mut i = Info::default();
        for (k, v) in pairs {
            i.set(k, v);
        }
        i
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_delete() {
        let mut i = Info::null();
        i.set(keys::CB_NODES, "4");
        assert_eq!(i.get(keys::CB_NODES), Some("4"));
        assert_eq!(i.get_usize(keys::CB_NODES), Some(4));
        assert!(i.delete(keys::CB_NODES));
        assert!(!i.delete(keys::CB_NODES));
        assert_eq!(i.nkeys(), 0);
    }

    #[test]
    fn flags_parse_romio_style() {
        let i = Info::from([("romio_ds_read", "disable"), ("x", "enable")]);
        assert_eq!(i.get_flag("romio_ds_read"), Some(false));
        assert_eq!(i.get_flag("x"), Some(true));
        assert_eq!(i.get_flag("missing"), None);
    }

    #[test]
    fn nthkey_is_sorted() {
        let i = Info::from([("b", "2"), ("a", "1")]);
        assert_eq!(i.nthkey(0), Some("a"));
        assert_eq!(i.nthkey(1), Some("b"));
        assert_eq!(i.nthkey(2), None);
    }

    #[test]
    fn merge_overwrites() {
        let mut a = Info::from([("k", "old"), ("only_a", "1")]);
        let b = Info::from([("k", "new")]);
        a.merge(&b);
        assert_eq!(a.get("k"), Some("new"));
        assert_eq!(a.get("only_a"), Some("1"));
    }
}
