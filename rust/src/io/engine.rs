//! Nonblocking request engine.
//!
//! `MPI_FILE_IREAD`/`IWRITE`, the asynchronous half of the split
//! collectives, and the lane-less fallbacks of the MPI-3.1
//! `iread_all`/`iwrite_all` run on a small shared worker pool (the same
//! design ROMIO uses for its nonblocking file I/O: the "async"
//! operations are real threads doing blocking positioned I/O; the
//! nonblocking *collectives* normally run whole on the per-world
//! progress threads instead — [`crate::comm::progress`]). The engine
//! knows nothing about plans —
//! compiled [`crate::io::plan::IoPlan`]s reach it through the
//! [`crate::io::schedule::IoScheduler`]'s engine mode (typed reads add a
//! memory-side unpack around the scheduled plan). The offline
//! environment has no tokio; this pool is the substitution documented in
//! DESIGN.md §2.
//!
//! Ownership model: Rust cannot express MPI's "don't touch the buffer
//! until wait" rule for borrowed buffers, so nonblocking operations *take
//! ownership* of their buffer and [`Request::wait`] returns it. This is
//! the one deliberate deviation from the Java binding's signatures (noted
//! in README §API differences).

use std::sync::mpsc;
use std::sync::Mutex;

use once_cell::sync::Lazy;

use crate::comm::Status;
use crate::io::errors::{err_request, IoError, Result};
use crate::io::stats::{FileStats, Phase};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Pool {
    tx: mpsc::Sender<Job>,
    /// Process that spawned the workers. A forked child (the process-
    /// based communicator) inherits the initialized statics but *not* the
    /// worker threads; submitting there would hang forever, so callers
    /// fall back to inline execution on a pid mismatch.
    pid: u32,
}

static POOL: Lazy<Mutex<Pool>> = Lazy::new(|| {
    let (tx, rx) = mpsc::channel::<Job>();
    let rx = std::sync::Arc::new(Mutex::new(rx));
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16);
    for i in 0..workers {
        let rx = rx.clone();
        std::thread::Builder::new()
            .name(format!("jpio-io-{i}"))
            .spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => job(),
                    Err(_) => break,
                }
            })
            .expect("spawn io worker");
    }
    Mutex::new(Pool { tx, pid: std::process::id() })
});

// ----------------------------------------------------------------------
// Stripe fan-out pool
// ----------------------------------------------------------------------
//
// The striped storage backend issues its per-server I/O concurrently. It
// cannot share `POOL`: a split collective's I/O phase already runs *on* a
// `POOL` worker, and if that job then waited for nested per-server jobs in
// the same pool, enough concurrent collectives would occupy every worker
// with waiters and deadlock. Per-server jobs therefore run on their own
// pool, whose workers never submit back into it (a nested striped backend
// falls back to inline execution, detected by the worker thread name).

static STRIPE_POOL: Lazy<Mutex<Pool>> = Lazy::new(|| {
    let (tx, rx) = mpsc::channel::<Job>();
    let rx = std::sync::Arc::new(Mutex::new(rx));
    let workers = std::thread::available_parallelism()
        .map(|n| n.get() * 2)
        .unwrap_or(8)
        .clamp(8, 32);
    for i in 0..workers {
        let rx = rx.clone();
        std::thread::Builder::new()
            .name(format!("jpio-stripe-{i}"))
            .spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => job(),
                    Err(_) => break,
                }
            })
            .expect("spawn stripe worker");
    }
    Mutex::new(Pool { tx, pid: std::process::id() })
});

/// Clone a pool's job sender if its worker threads exist in this
/// process; `None` means "run the work inline". The lock is held only
/// long enough to read the pid and clone the sender, and acquisition is
/// a bounded `try_lock` spin so a mutex left permanently locked by a
/// pre-fork thread can never hang a forked child.
fn pool_sender(pool: &Lazy<Mutex<Pool>>) -> Option<mpsc::Sender<Job>> {
    for _ in 0..64 {
        match pool.try_lock() {
            Ok(p) => {
                return if p.pid == std::process::id() { Some(p.tx.clone()) } else { None };
            }
            Err(std::sync::TryLockError::WouldBlock) => std::thread::yield_now(),
            Err(std::sync::TryLockError::Poisoned(_)) => return None,
        }
    }
    None
}

/// Run independent storage jobs concurrently on the dedicated stripe
/// worker pool, returning their results in submission order. Falls back
/// to inline sequential execution for a single job, when already on a
/// stripe worker (so a striped backend nested inside another striped
/// backend cannot deadlock the pool against itself), or in a forked child
/// that inherited a pool without its worker threads.
pub fn fanout<T, F>(jobs: Vec<F>) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let on_stripe_worker = std::thread::current()
        .name()
        .map(|n| n.starts_with("jpio-stripe-"))
        .unwrap_or(false);
    if jobs.len() <= 1 || on_stripe_worker {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let sender = match pool_sender(&STRIPE_POOL) {
        Some(sender) => sender,
        None => return jobs.into_iter().map(|j| j()).collect(),
    };
    let mut rxs = Vec::with_capacity(jobs.len());
    for job in jobs {
        let (tx, rx) = mpsc::channel();
        let boxed: Job = Box::new(move || {
            let _ = tx.send(job());
        });
        sender.send(boxed).expect("stripe pool alive");
        rxs.push(rx);
    }
    rxs.into_iter().map(|rx| rx.recv().expect("stripe worker died mid-job")).collect()
}

/// Submit a job producing `(Status, payload)`; returns the request handle.
pub fn submit<T, F>(f: F) -> Request<T>
where
    T: Send + 'static,
    F: FnOnce() -> (Result<Status>, T) + Send + 'static,
{
    if let Some(sender) = pool_sender(&POOL) {
        let (tx, rx) = mpsc::channel();
        let job: Job = Box::new(move || {
            let out = f();
            let _ = tx.send(out); // receiver may have been dropped (cancelled)
        });
        sender.send(job).expect("io pool alive");
        return Request { rx: Some(rx), done: None, failed: None, stats: None };
    }
    // Forked child without worker threads (or a pool mutex orphaned by
    // fork): complete synchronously.
    let done = f();
    Request { rx: None, done: Some(done), failed: None, stats: None }
}

// ----------------------------------------------------------------------
// Per-op ordering across progress lanes
// ----------------------------------------------------------------------

/// Total order over the operations a file hands to its progress lanes.
///
/// With `jpio_progress_threads > 1`, successive collective operations
/// round-robin across lanes and their *exchange* phases pipeline freely
/// (disjoint tag bands). Their *storage* phases, however, must still
/// apply in issue order — two operations touching the same bytes used to
/// be serialized by the single lane's FIFO, and requests must keep that
/// deterministic outcome. Each lane-bound operation therefore draws an
/// [`OpTicket`] at submit time (on the caller, in issue order); the lane
/// job calls [`OpTicket::wait_turn`] before its storage phase and the
/// ticket releases on drop, so ticket `k+1`'s storage starts only after
/// ticket `k` finished — while both exchanges ran concurrently.
///
/// Deadlock-free by construction: tickets are issued round-robin in
/// increasing order, each lane executes its tickets FIFO, so a ticket
/// only ever waits on strictly smaller tickets that are either already
/// running on another lane or ahead of it in its own lane's queue.
pub(crate) struct OpSequencer {
    next: std::sync::atomic::AtomicU64,
    done: Mutex<u64>,
    cv: std::sync::Condvar,
}

impl OpSequencer {
    /// A fresh sequencer (one per file handle).
    pub(crate) fn new() -> OpSequencer {
        OpSequencer {
            next: std::sync::atomic::AtomicU64::new(0),
            done: Mutex::new(0),
            cv: std::sync::Condvar::new(),
        }
    }

    /// Draw the next ticket. Must be called on the submitting thread, in
    /// operation issue order.
    pub(crate) fn issue(self: &std::sync::Arc<Self>) -> OpTicket {
        let ticket = self.next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        OpTicket { seq: self.clone(), ticket, waited: false }
    }
}

/// One operation's place in its file's cross-lane order — see
/// [`OpSequencer`]. Dropping the ticket (normally, on error, or during a
/// panic unwind of the lane job) releases the turn to the next
/// operation, so a failed exchange can never wedge the sequence.
pub(crate) struct OpTicket {
    seq: std::sync::Arc<OpSequencer>,
    ticket: u64,
    waited: bool,
}

impl OpTicket {
    /// Block until every earlier ticket has been released.
    pub(crate) fn wait_turn(&mut self) {
        if self.waited {
            return;
        }
        let mut done = self.seq.done.lock().unwrap();
        while *done != self.ticket {
            done = self.seq.cv.wait(done).unwrap();
        }
        self.waited = true;
    }
}

impl Drop for OpTicket {
    fn drop(&mut self) {
        // Waiting first keeps releases in ticket order, which is what
        // lets `wait_turn` track a single low-water mark.
        self.wait_turn();
        *self.seq.done.lock().unwrap() += 1;
        self.seq.cv.notify_all();
    }
}

/// A nonblocking operation handle (`mpj.Request`).
///
/// `T` is the buffer type carried through the operation (`Vec<i32>` for a
/// typed read, `()` for writes that copied their data).
pub struct Request<T> {
    rx: Option<mpsc::Receiver<(Result<Status>, T)>>,
    done: Option<(Result<Status>, T)>,
    /// The completion channel disconnected without a result: the worker
    /// or progress thread died mid-operation. Always `Some(Err(..))`
    /// when set; [`Request::test`] reports it and [`Request::wait`]
    /// returns it (the buffer is lost with the thread).
    failed: Option<Result<Status>>,
    /// Instrumentation record of the issuing file handle, when attached
    /// ([`Request::instrument`]): [`Request::wait`] records its blocking
    /// span as the `wait` phase.
    stats: Option<std::sync::Arc<FileStats>>,
}

fn completer_died() -> IoError {
    IoError::new(
        crate::io::errors::ErrorClass::Request,
        "the completing thread died without finishing the request",
    )
}

impl<T> Request<T> {
    /// An already-completed request (used for zero-byte operations).
    pub fn ready(status: Status, value: T) -> Request<T> {
        Request { rx: None, done: Some((Ok(status), value)), failed: None, stats: None }
    }

    /// A request completed externally: whoever holds the paired sender —
    /// the per-world progress thread, for the off-caller nonblocking
    /// collectives — delivers `(status, buffer)` when the operation
    /// finishes. Dropping the sender without sending surfaces as a
    /// request error at `test`/`wait` (the completing thread died).
    pub(crate) fn pending() -> (Request<T>, mpsc::Sender<(Result<Status>, T)>) {
        let (tx, rx) = mpsc::channel();
        (Request { rx: Some(rx), done: None, failed: None, stats: None }, tx)
    }

    /// Attach the issuing handle's instrumentation record so
    /// [`Request::wait`] reports how long the caller blocked (Darshan's
    /// request wait-time). Recording is gated inside [`FileStats`], so
    /// this is free when the `jpio_stats` hint is off.
    pub(crate) fn instrument(mut self, stats: &std::sync::Arc<FileStats>) -> Request<T> {
        self.stats = Some(stats.clone());
        self
    }

    /// Block until completion (`MPI_Wait`); returns the status and the
    /// buffer.
    pub fn wait(mut self) -> Result<(Status, T)> {
        let t0 = self.stats.as_ref().and_then(|s| s.start());
        let (status, value) = self.take_result()?;
        if let Some(stats) = &self.stats {
            stats.record(Phase::Wait, t0);
        }
        Ok((status?, value))
    }

    /// Non-blocking completion test (`MPI_Test`): `Some` if complete.
    /// A dead completer (worker/progress thread died mid-job) reports a
    /// `Request`-class error here rather than aborting the application —
    /// the sanctioned test-then-wait pattern sees the same error twice.
    pub fn test(&mut self) -> Option<&Result<Status>> {
        if self.done.is_none() && self.failed.is_none() {
            let rx = self.rx.as_ref()?;
            match rx.try_recv() {
                Ok(out) => {
                    self.done = Some(out);
                    self.rx = None;
                }
                Err(mpsc::TryRecvError::Empty) => return None,
                Err(mpsc::TryRecvError::Disconnected) => {
                    self.failed = Some(Err(completer_died()));
                    self.rx = None;
                }
            }
        }
        if let Some(res) = &self.failed {
            return Some(res);
        }
        self.done.as_ref().map(|(s, _)| s)
    }

    fn take_result(&mut self) -> Result<(Result<Status>, T)> {
        if let Some(done) = self.done.take() {
            return Ok(done);
        }
        if self.failed.take().is_some() {
            return Err(completer_died());
        }
        let rx = self.rx.take().ok_or_else(|| err_request("request already waited"))?;
        rx.recv().map_err(|_| completer_died())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_and_wait() {
        let req = submit(|| (Ok(Status::of_bytes(128)), vec![1, 2, 3]));
        let (st, buf) = req.wait().unwrap();
        assert_eq!(st.bytes, 128);
        assert_eq!(buf, vec![1, 2, 3]);
    }

    #[test]
    fn test_polls_until_done() {
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let mut req = submit(move || {
            gate_rx.recv().unwrap();
            (Ok(Status::of_bytes(4)), ())
        });
        // Not complete while the job is gated (can't assert strictly —
        // scheduling — but overwhelmingly it isn't yet).
        let _ = req.test();
        gate_tx.send(()).unwrap();
        // Poll until completion.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            if let Some(res) = req.test() {
                assert_eq!(res.as_ref().unwrap().bytes, 4);
                break;
            }
            assert!(std::time::Instant::now() < deadline, "request never completed");
            std::thread::yield_now();
        }
        let (st, ()) = req.wait().unwrap();
        assert_eq!(st.bytes, 4);
    }

    #[test]
    fn ready_requests_complete_immediately() {
        let mut r = Request::ready(Status::of_bytes(0), 7u8);
        assert!(r.test().is_some());
        let (st, v) = r.wait().unwrap();
        assert_eq!((st.bytes, v), (0, 7));
    }

    #[test]
    fn many_parallel_requests() {
        let reqs: Vec<_> = (0..64)
            .map(|i| submit(move || (Ok(Status::of_bytes(i)), i)))
            .collect();
        for (i, r) in reqs.into_iter().enumerate() {
            let (st, v) = r.wait().unwrap();
            assert_eq!(st.bytes, i);
            assert_eq!(v, i);
        }
    }

    #[test]
    fn fanout_preserves_order_and_runs_concurrently() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let peak = Arc::new(AtomicUsize::new(0));
        let live = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<_> = (0..6usize)
            .map(|i| {
                let peak = peak.clone();
                let live = live.clone();
                move || {
                    let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    live.fetch_sub(1, Ordering::SeqCst);
                    i * 10
                }
            })
            .collect();
        let out = fanout(jobs);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50]);
        assert!(peak.load(Ordering::SeqCst) >= 2, "jobs never overlapped");
    }

    #[test]
    fn fanout_single_job_runs_inline() {
        let out = fanout(vec![|| 41 + 1]);
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn op_tickets_serialize_guarded_sections_in_issue_order() {
        use std::sync::{Arc, Mutex};
        let seq = Arc::new(OpSequencer::new());
        let log = Arc::new(Mutex::new(Vec::new()));
        let mut t0 = seq.issue();
        let mut t1 = seq.issue();
        let t2 = seq.issue(); // released by drop alone, no explicit wait
        let h = {
            let log = log.clone();
            std::thread::spawn(move || {
                t1.wait_turn(); // must block until t0 is released
                log.lock().unwrap().push(1);
                drop(t1);
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        t0.wait_turn(); // front of the line: returns immediately
        log.lock().unwrap().push(0);
        drop(t0);
        h.join().unwrap();
        drop(t2);
        assert_eq!(*log.lock().unwrap(), vec![0, 1]);
    }

    #[test]
    fn errors_propagate() {
        let req: Request<()> =
            submit(|| (Err(crate::io::errors::err_io("disk on fire")), ()));
        let err = req.wait().unwrap_err();
        assert_eq!(err.class, crate::io::errors::ErrorClass::Io);
    }
}
