//! Nonblocking request engine.
//!
//! `MPI_FILE_IREAD`/`IWRITE` and the asynchronous half of the split
//! collectives run on a small shared worker pool (the same design ROMIO
//! uses for its nonblocking file I/O: the "async" operations are real
//! threads doing blocking positioned I/O). The offline environment has no
//! tokio; this pool is the substitution documented in DESIGN.md §2.
//!
//! Ownership model: Rust cannot express MPI's "don't touch the buffer
//! until wait" rule for borrowed buffers, so nonblocking operations *take
//! ownership* of their buffer and [`Request::wait`] returns it. This is
//! the one deliberate deviation from the Java binding's signatures (noted
//! in README §API differences).

use std::sync::mpsc;
use std::sync::Mutex;

use once_cell::sync::Lazy;

use crate::comm::Status;
use crate::io::errors::{err_request, IoError, Result};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Pool {
    tx: mpsc::Sender<Job>,
}

static POOL: Lazy<Mutex<Pool>> = Lazy::new(|| {
    let (tx, rx) = mpsc::channel::<Job>();
    let rx = std::sync::Arc::new(Mutex::new(rx));
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16);
    for i in 0..workers {
        let rx = rx.clone();
        std::thread::Builder::new()
            .name(format!("jpio-io-{i}"))
            .spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => job(),
                    Err(_) => break,
                }
            })
            .expect("spawn io worker");
    }
    Mutex::new(Pool { tx })
});

/// Submit a job producing `(Status, payload)`; returns the request handle.
pub fn submit<T, F>(f: F) -> Request<T>
where
    T: Send + 'static,
    F: FnOnce() -> (Result<Status>, T) + Send + 'static,
{
    let (tx, rx) = mpsc::channel();
    let job: Job = Box::new(move || {
        let out = f();
        let _ = tx.send(out); // receiver may have been dropped (cancelled)
    });
    POOL.lock().unwrap().tx.send(job).expect("io pool alive");
    Request { rx: Some(rx), done: None }
}

/// A nonblocking operation handle (`mpj.Request`).
///
/// `T` is the buffer type carried through the operation (`Vec<i32>` for a
/// typed read, `()` for writes that copied their data).
pub struct Request<T> {
    rx: Option<mpsc::Receiver<(Result<Status>, T)>>,
    done: Option<(Result<Status>, T)>,
}

impl<T> Request<T> {
    /// An already-completed request (used for zero-byte operations).
    pub fn ready(status: Status, value: T) -> Request<T> {
        Request { rx: None, done: Some((Ok(status), value)) }
    }

    /// Block until completion (`MPI_Wait`); returns the status and the
    /// buffer.
    pub fn wait(mut self) -> Result<(Status, T)> {
        let (status, value) = self.take_result()?;
        Ok((status?, value))
    }

    /// Non-blocking completion test (`MPI_Test`): `Some` if complete.
    pub fn test(&mut self) -> Option<&Result<Status>> {
        if self.done.is_none() {
            let rx = self.rx.as_ref()?;
            match rx.try_recv() {
                Ok(out) => {
                    self.done = Some(out);
                    self.rx = None;
                }
                Err(mpsc::TryRecvError::Empty) => return None,
                // Workers always send before exiting; a disconnect means
                // the worker thread died mid-job.
                Err(mpsc::TryRecvError::Disconnected) => {
                    panic!("jpio io worker died without completing a request")
                }
            }
        }
        self.done.as_ref().map(|(s, _)| s)
    }

    fn take_result(&mut self) -> Result<(Result<Status>, T)> {
        if let Some(done) = self.done.take() {
            return Ok(done);
        }
        let rx = self.rx.take().ok_or_else(|| err_request("request already waited"))?;
        rx.recv().map_err(|_| {
            IoError::new(
                crate::io::errors::ErrorClass::Request,
                "io worker died without completing the request",
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_and_wait() {
        let req = submit(|| (Ok(Status::of_bytes(128)), vec![1, 2, 3]));
        let (st, buf) = req.wait().unwrap();
        assert_eq!(st.bytes, 128);
        assert_eq!(buf, vec![1, 2, 3]);
    }

    #[test]
    fn test_polls_until_done() {
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let mut req = submit(move || {
            gate_rx.recv().unwrap();
            (Ok(Status::of_bytes(4)), ())
        });
        // Not complete while the job is gated (can't assert strictly —
        // scheduling — but overwhelmingly it isn't yet).
        let _ = req.test();
        gate_tx.send(()).unwrap();
        // Poll until completion.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            if let Some(res) = req.test() {
                assert_eq!(res.as_ref().unwrap().bytes, 4);
                break;
            }
            assert!(std::time::Instant::now() < deadline, "request never completed");
            std::thread::yield_now();
        }
        let (st, ()) = req.wait().unwrap();
        assert_eq!(st.bytes, 4);
    }

    #[test]
    fn ready_requests_complete_immediately() {
        let mut r = Request::ready(Status::of_bytes(0), 7u8);
        assert!(r.test().is_some());
        let (st, v) = r.wait().unwrap();
        assert_eq!((st.bytes, v), (0, 7));
    }

    #[test]
    fn many_parallel_requests() {
        let reqs: Vec<_> = (0..64)
            .map(|i| submit(move || (Ok(Status::of_bytes(i)), i)))
            .collect();
        for (i, r) in reqs.into_iter().enumerate() {
            let (st, v) = r.wait().unwrap();
            assert_eq!(st.bytes, i);
            assert_eq!(v, i);
        }
    }

    #[test]
    fn errors_propagate() {
        let req: Request<()> =
            submit(|| (Err(crate::io::errors::err_io("disk on fire")), ()));
        let err = req.wait().unwrap_err();
        assert_eq!(err.class, crate::io::errors::ErrorClass::Io);
    }
}
