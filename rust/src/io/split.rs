//! Split collective data access (§7.2.4.5): `*_BEGIN` / `*_END` pairs.
//!
//! MPI's rules, all enforced here: at most one split collective may be
//! active per file handle; the `END` call must match the pending `BEGIN`;
//! the buffer must not be touched in between (expressed in Rust by moving
//! ownership through the request, like the nonblocking ops).
//!
//! For writes, the communication (exchange) phase runs in `BEGIN` and the
//! storage phase is handed to the [`IoScheduler`]'s engine mode — so
//! computation between `BEGIN` and `END` genuinely overlaps the file I/O,
//! which is the whole point of the double-buffering pattern in §7.2.9.1.
//! Reads complete their aggregation in `BEGIN` (the reply exchange needs
//! the communicator, which cannot leave the calling thread) and hand the
//! payload to `END`. The MPI-3.1 nonblocking collectives
//! ([`File::iwrite_all`]/[`File::iread_all`]) follow exactly the same
//! phase split, with a [`crate::io::engine::Request`] in place of the
//! `END` call.

use crate::comm::datatype::{Datatype, IoBuf, IoBufMut, Offset};
use crate::comm::Status;
use crate::io::access::{pack_payload, unpack_payload};
use crate::io::collective::{collective_read, exchange_write};
use crate::io::engine::Request;
use crate::io::errors::{err_io, err_request, Result};
use crate::io::file::{File, SplitPending};
use crate::io::plan::IoPlan;
use crate::io::schedule::IoScheduler;

macro_rules! check_no_pending {
    ($self:ident) => {{
        let pending = $self.split.lock().unwrap();
        if pending.is_some() {
            return Err(err_request(
                "a split collective is already active on this file handle",
            ));
        }
        drop(pending);
    }};
}

impl File<'_> {
    fn stash(&self, p: SplitPending) {
        *self.split.lock().unwrap() = Some(p);
    }

    fn take_pending(&self, want: &'static str) -> Result<SplitPending> {
        let mut slot = self.split.lock().unwrap();
        match slot.take() {
            None => Err(err_request(format!("{want}: no split collective is active"))),
            Some(p) => {
                let kind = match &p {
                    SplitPending::Read { kind, .. } | SplitPending::Write { kind, .. } => kind,
                };
                if *kind != want {
                    let msg = format!("{want} does not match pending {kind}");
                    *slot = Some(p);
                    return Err(err_request(msg));
                }
                Ok(p)
            }
        }
    }

    fn begin_write(
        &self,
        kind: &'static str,
        offset: Offset,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<()> {
        self.check_open()?;
        self.check_writable()?;
        check_no_pending!(self);
        let ctx = self.transfer_ctx();
        let payload = pack_payload(buf, buf_offset, count, datatype, &ctx.view)?.into_owned();
        let cb = self.cb_params();
        // Exchange phase: synchronous (uses the communicator).
        let (work, bytes) = exchange_write(self.comm, &ctx, &cb, offset, &payload)?;
        // I/O phase: scheduled on the engine.
        let req = IoScheduler::write_phase_async(ctx, work, bytes);
        self.stash(SplitPending::Write { kind, req });
        Ok(())
    }

    fn end_write(&self, kind: &'static str) -> Result<Status> {
        match self.take_pending(kind)? {
            SplitPending::Write { req, .. } => {
                let (st, ()) = req.wait()?;
                // Collective completion.
                self.comm.barrier();
                Ok(st)
            }
            SplitPending::Read { .. } => unreachable!("kind checked in take_pending"),
        }
    }

    fn begin_read(
        &self,
        kind: &'static str,
        offset: Offset,
        payload_len: usize,
    ) -> Result<()> {
        self.check_open()?;
        self.check_readable()?;
        check_no_pending!(self);
        let ctx = self.transfer_ctx();
        let cb = self.cb_params();
        let mut payload = vec![0u8; payload_len];
        let got = collective_read(self.comm, &ctx, &cb, offset, &mut payload)?;
        payload.truncate(payload_len);
        let req = Request::ready(Status::of_bytes(got), payload);
        self.stash(SplitPending::Read { kind, req });
        Ok(())
    }

    fn end_read(
        &self,
        kind: &'static str,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        match self.take_pending(kind)? {
            SplitPending::Read { req, .. } => {
                let (st, payload) = req.wait()?;
                if payload.len() < count * datatype.size() {
                    return Err(err_io("split read payload shorter than END request"));
                }
                unpack_payload(buf, buf_offset, count, datatype, &payload, st.bytes)?;
                Ok(st)
            }
            SplitPending::Write { .. } => unreachable!("kind checked in take_pending"),
        }
    }

    // ------------------------------------------------------------------
    // Explicit offsets (§7.2.4.5)
    // ------------------------------------------------------------------

    /// `MPI_FILE_READ_AT_ALL_BEGIN`.
    pub fn read_at_all_begin(
        &self,
        offset: Offset,
        count: usize,
        datatype: &Datatype,
    ) -> Result<()> {
        self.begin_read("readAtAllEnd", offset, count * datatype.size())
    }

    /// `MPI_FILE_READ_AT_ALL_END`.
    pub fn read_at_all_end(
        &self,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        self.end_read("readAtAllEnd", buf, buf_offset, count, datatype)
    }

    /// `MPI_FILE_WRITE_AT_ALL_BEGIN`.
    pub fn write_at_all_begin(
        &self,
        offset: Offset,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<()> {
        self.begin_write("writeAtAllEnd", offset, buf, buf_offset, count, datatype)
    }

    /// `MPI_FILE_WRITE_AT_ALL_END`.
    pub fn write_at_all_end(&self) -> Result<Status> {
        self.end_write("writeAtAllEnd")
    }

    // ------------------------------------------------------------------
    // Individual file pointers (§7.2.4.5)
    // ------------------------------------------------------------------

    /// `MPI_FILE_READ_ALL_BEGIN`.
    pub fn read_all_begin(&self, count: usize, datatype: &Datatype) -> Result<()> {
        let view = self.view_snapshot();
        let mut ptr = self.indiv_ptr.lock().unwrap();
        let off = *ptr;
        *ptr = off + view.bytes_to_etypes(count * datatype.size());
        drop(ptr);
        self.begin_read("readAllEnd", off, count * datatype.size())
    }

    /// `MPI_FILE_READ_ALL_END`.
    pub fn read_all_end(
        &self,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        self.end_read("readAllEnd", buf, buf_offset, count, datatype)
    }

    /// `MPI_FILE_WRITE_ALL_BEGIN`.
    pub fn write_all_begin(
        &self,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<()> {
        let view = self.view_snapshot();
        let mut ptr = self.indiv_ptr.lock().unwrap();
        let off = *ptr;
        *ptr = off + view.bytes_to_etypes(count * datatype.size());
        drop(ptr);
        self.begin_write("writeAllEnd", off, buf, buf_offset, count, datatype)
    }

    /// `MPI_FILE_WRITE_ALL_END`.
    pub fn write_all_end(&self) -> Result<Status> {
        self.end_write("writeAllEnd")
    }

    // ------------------------------------------------------------------
    // Shared file pointer, ordered (§7.2.4.5)
    // ------------------------------------------------------------------

    /// `MPI_FILE_READ_ORDERED_BEGIN`.
    pub fn read_ordered_begin(&self, count: usize, datatype: &Datatype) -> Result<()> {
        self.check_open()?;
        self.check_readable()?;
        check_no_pending!(self);
        let view = self.view_snapshot();
        let my = view.bytes_to_etypes(count * datatype.size());
        let off = self.ordered_offsets(my)?;
        let ctx = self.transfer_ctx();
        let len = count * datatype.size();
        let plan = IoPlan::compile(&ctx.view, ctx.atomic, off, len)?;
        let req = IoScheduler::read_async(ctx, plan, len);
        self.stash(SplitPending::Read { kind: "readOrderedEnd", req });
        Ok(())
    }

    /// `MPI_FILE_READ_ORDERED_END`.
    pub fn read_ordered_end(
        &self,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let st = self.end_read("readOrderedEnd", buf, buf_offset, count, datatype)?;
        self.comm.barrier();
        Ok(st)
    }

    /// `MPI_FILE_WRITE_ORDERED_BEGIN`.
    pub fn write_ordered_begin(
        &self,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<()> {
        self.check_open()?;
        self.check_writable()?;
        check_no_pending!(self);
        let view = self.view_snapshot();
        let my = view.bytes_to_etypes(count * datatype.size());
        let off = self.ordered_offsets(my)?;
        let ctx = self.transfer_ctx();
        let payload = pack_payload(buf, buf_offset, count, datatype, &ctx.view)?.into_owned();
        let plan = IoPlan::compile(&ctx.view, ctx.atomic, off, payload.len())?;
        let req = IoScheduler::write_async(ctx, plan, payload);
        self.stash(SplitPending::Write { kind: "writeOrderedEnd", req });
        Ok(())
    }

    /// `MPI_FILE_WRITE_ORDERED_END`.
    pub fn write_ordered_end(&self) -> Result<Status> {
        let st = self.end_write("writeOrderedEnd")?;
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::threads;
    use crate::comm::Comm;
    use crate::io::errors::ErrorClass;
    use crate::io::file::amode;
    use crate::io::hints::Info;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-split-{}-{name}", std::process::id())
    }

    #[test]
    fn split_write_then_read_roundtrip() {
        let path = tmp("rt");
        threads::run(4, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let r = c.rank() as i64;
            let mine: Vec<i32> = (0..128).map(|i| (r * 128 + i) as i32).collect();
            f.write_at_all_begin(r * 128, mine.as_slice(), 0, 128, &Datatype::INT).unwrap();
            // ... overlapped computation would happen here ...
            let st = f.write_at_all_end().unwrap();
            assert_eq!(st.bytes, 512);
            c.barrier();
            f.read_at_all_begin(0, 512, &Datatype::INT).unwrap();
            let mut all = vec![0i32; 512];
            let st = f.read_at_all_end(all.as_mut_slice(), 0, 512, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, 2048);
            let want: Vec<i32> = (0..512).collect();
            assert_eq!(all, want);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn individual_pointer_split_ops_advance_pointer() {
        let path = tmp("indiv");
        threads::run(2, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            // Both ranks write the same 64 ints collectively (overlap —
            // same data, so deterministic).
            let data: Vec<i32> = (0..64).collect();
            f.write_all_begin(data.as_slice(), 0, 64, &Datatype::INT).unwrap();
            f.write_all_end().unwrap();
            assert_eq!(f.get_position().unwrap(), 64);
            f.seek(0, crate::io::file::seek::SET).unwrap();
            f.read_all_begin(64, &Datatype::INT).unwrap();
            let mut back = vec![0i32; 64];
            f.read_all_end(back.as_mut_slice(), 0, 64, &Datatype::INT).unwrap();
            assert_eq!(back, data);
            assert_eq!(f.get_position().unwrap(), 64);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn ordered_split_ops_are_rank_ordered() {
        let path = tmp("ordered");
        threads::run(3, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let mine = vec![c.rank() as i32; 10];
            f.write_ordered_begin(mine.as_slice(), 0, 10, &Datatype::INT).unwrap();
            f.write_ordered_end().unwrap();
            c.barrier();
            f.seek_shared(0, crate::io::file::seek::SET).unwrap();
            f.read_ordered_begin(10, &Datatype::INT).unwrap();
            let mut back = vec![-1i32; 10];
            f.read_ordered_end(back.as_mut_slice(), 0, 10, &Datatype::INT).unwrap();
            assert_eq!(back, mine);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn double_begin_is_rejected() {
        let path = tmp("dbl");
        threads::run(1, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let d = vec![1i32; 4];
            f.write_at_all_begin(0, d.as_slice(), 0, 4, &Datatype::INT).unwrap();
            let err =
                f.write_at_all_begin(16, d.as_slice(), 0, 4, &Datatype::INT).unwrap_err();
            assert_eq!(err.class, ErrorClass::Request);
            f.write_at_all_end().unwrap();
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn mismatched_end_is_rejected_and_state_preserved() {
        let path = tmp("mismatch");
        threads::run(1, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let d = vec![1i32; 4];
            f.write_at_all_begin(0, d.as_slice(), 0, 4, &Datatype::INT).unwrap();
            let mut buf = vec![0i32; 4];
            let err = f
                .read_at_all_end(buf.as_mut_slice(), 0, 4, &Datatype::INT)
                .unwrap_err();
            assert_eq!(err.class, ErrorClass::Request);
            // The pending write survives the bad end call.
            f.write_at_all_end().unwrap();
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn end_without_begin_is_rejected() {
        let path = tmp("nobegin");
        threads::run(1, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            assert_eq!(f.write_at_all_end().unwrap_err().class, ErrorClass::Request);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }
}
