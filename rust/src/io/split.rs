//! Split collective data access (§7.2.4.5): `*_BEGIN` / `*_END` pairs.
//!
//! MPI's rules, all enforced by the [`AccessOp`] core
//! ([`crate::io::op`]): at most one split collective may be active per
//! file handle; the `END` call must match the pending `BEGIN` (the
//! matching tag is *derived* from the op's matrix cell); the buffer must
//! not be touched in between (expressed in Rust by binding the read
//! buffer only at `END`, like the nonblocking ops' ownership transfer).
//!
//! On worlds with a progress lane ([`crate::comm::progress`]), `BEGIN`
//! only registers the operation: *both* phases — the exchange and the
//! storage I/O, reply exchange included for reads — run on the rank's
//! progress thread, so all the computation between `BEGIN` and `END`
//! overlaps the whole collective. Without a lane
//! (`jpio_progress_threads = 0`, or endpoints that cannot host one) the
//! write exchange runs in `BEGIN` and the storage phase lands on the
//! request engine — the double-buffering pattern of §7.2.9.1 — while
//! reads complete their aggregation in `BEGIN` (the reply exchange
//! needs a communicator endpoint, and the lane-less split collectives
//! keep theirs on the calling thread) and hand the payload to `END`.
//! The MPI-3.1 nonblocking collectives
//! ([`File::iwrite_all`]/[`File::iread_all`]) return a
//! [`crate::io::engine::Request`] in place of the `END` call under the
//! same lane contract.
//!
//! Every routine here is a thin wrapper naming its matrix cell; `BEGIN`
//! reads and `END` writes carry no buffer, so they pass an empty slice
//! to the core (the core never touches it for those phases).

use crate::comm::datatype::{Datatype, IoBuf, IoBufMut, Offset};
use crate::comm::Status;
use crate::io::errors::Result;
use crate::io::file::File;
use crate::io::op::{AccessOp, Coordination, Positioning, SplitPhase, Synchronism};

impl File<'_> {
    // ------------------------------------------------------------------
    // Explicit offsets (§7.2.4.5)
    // ------------------------------------------------------------------

    /// `MPI_FILE_READ_AT_ALL_BEGIN`.
    pub fn read_at_all_begin(
        &self,
        offset: Offset,
        count: usize,
        datatype: &Datatype,
    ) -> Result<()> {
        let op = AccessOp::read(
            Positioning::Explicit(offset),
            Coordination::Collective,
            Synchronism::Split(SplitPhase::Begin),
            0,
            count,
            datatype,
        );
        self.submit_read(&op, [0u8; 0].as_mut_slice()).map(|_| ())
    }

    /// `MPI_FILE_READ_AT_ALL_END`.
    pub fn read_at_all_end(
        &self,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::read(
            Positioning::Explicit(0),
            Coordination::Collective,
            Synchronism::Split(SplitPhase::End),
            buf_offset,
            count,
            datatype,
        );
        self.submit_read(&op, buf)
    }

    /// `MPI_FILE_WRITE_AT_ALL_BEGIN`.
    pub fn write_at_all_begin(
        &self,
        offset: Offset,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<()> {
        let op = AccessOp::write(
            Positioning::Explicit(offset),
            Coordination::Collective,
            Synchronism::Split(SplitPhase::Begin),
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.begun()
    }

    /// `MPI_FILE_WRITE_AT_ALL_END`.
    pub fn write_at_all_end(&self) -> Result<Status> {
        let op = AccessOp::write(
            Positioning::Explicit(0),
            Coordination::Collective,
            Synchronism::Split(SplitPhase::End),
            0,
            0,
            &Datatype::BYTE,
        );
        self.submit_write(&op, [0u8; 0].as_slice())?.status()
    }

    // ------------------------------------------------------------------
    // Individual file pointers (§7.2.4.5)
    // ------------------------------------------------------------------

    /// `MPI_FILE_READ_ALL_BEGIN`. The individual pointer advances
    /// immediately by the full request size.
    pub fn read_all_begin(&self, count: usize, datatype: &Datatype) -> Result<()> {
        let op = AccessOp::read(
            Positioning::Individual,
            Coordination::Collective,
            Synchronism::Split(SplitPhase::Begin),
            0,
            count,
            datatype,
        );
        self.submit_read(&op, [0u8; 0].as_mut_slice()).map(|_| ())
    }

    /// `MPI_FILE_READ_ALL_END`.
    pub fn read_all_end(
        &self,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::read(
            Positioning::Individual,
            Coordination::Collective,
            Synchronism::Split(SplitPhase::End),
            buf_offset,
            count,
            datatype,
        );
        self.submit_read(&op, buf)
    }

    /// `MPI_FILE_WRITE_ALL_BEGIN`. The individual pointer advances
    /// immediately by the full request size.
    pub fn write_all_begin(
        &self,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<()> {
        let op = AccessOp::write(
            Positioning::Individual,
            Coordination::Collective,
            Synchronism::Split(SplitPhase::Begin),
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.begun()
    }

    /// `MPI_FILE_WRITE_ALL_END`.
    pub fn write_all_end(&self) -> Result<Status> {
        let op = AccessOp::write(
            Positioning::Individual,
            Coordination::Collective,
            Synchronism::Split(SplitPhase::End),
            0,
            0,
            &Datatype::BYTE,
        );
        self.submit_write(&op, [0u8; 0].as_slice())?.status()
    }

    // ------------------------------------------------------------------
    // Shared file pointer, ordered (§7.2.4.5)
    // ------------------------------------------------------------------

    /// `MPI_FILE_READ_ORDERED_BEGIN`.
    pub fn read_ordered_begin(&self, count: usize, datatype: &Datatype) -> Result<()> {
        let op = AccessOp::read(
            Positioning::Shared,
            Coordination::Ordered,
            Synchronism::Split(SplitPhase::Begin),
            0,
            count,
            datatype,
        );
        self.submit_read(&op, [0u8; 0].as_mut_slice()).map(|_| ())
    }

    /// `MPI_FILE_READ_ORDERED_END`.
    pub fn read_ordered_end(
        &self,
        buf: &mut (impl IoBufMut + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<Status> {
        let op = AccessOp::read(
            Positioning::Shared,
            Coordination::Ordered,
            Synchronism::Split(SplitPhase::End),
            buf_offset,
            count,
            datatype,
        );
        self.submit_read(&op, buf)
    }

    /// `MPI_FILE_WRITE_ORDERED_BEGIN`.
    pub fn write_ordered_begin(
        &self,
        buf: &(impl IoBuf + ?Sized),
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> Result<()> {
        let op = AccessOp::write(
            Positioning::Shared,
            Coordination::Ordered,
            Synchronism::Split(SplitPhase::Begin),
            buf_offset,
            count,
            datatype,
        );
        self.submit_write(&op, buf)?.begun()
    }

    /// `MPI_FILE_WRITE_ORDERED_END`.
    pub fn write_ordered_end(&self) -> Result<Status> {
        let op = AccessOp::write(
            Positioning::Shared,
            Coordination::Ordered,
            Synchronism::Split(SplitPhase::End),
            0,
            0,
            &Datatype::BYTE,
        );
        self.submit_write(&op, [0u8; 0].as_slice())?.status()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::threads;
    use crate::comm::Comm;
    use crate::io::errors::ErrorClass;
    use crate::io::file::amode;
    use crate::io::hints::Info;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-split-{}-{name}", std::process::id())
    }

    #[test]
    fn split_write_then_read_roundtrip() {
        let path = tmp("rt");
        threads::run(4, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let r = c.rank() as i64;
            let mine: Vec<i32> = (0..128).map(|i| (r * 128 + i) as i32).collect();
            f.write_at_all_begin(r * 128, mine.as_slice(), 0, 128, &Datatype::INT).unwrap();
            // ... overlapped computation would happen here ...
            let st = f.write_at_all_end().unwrap();
            assert_eq!(st.bytes, 512);
            c.barrier();
            f.read_at_all_begin(0, 512, &Datatype::INT).unwrap();
            let mut all = vec![0i32; 512];
            let st = f.read_at_all_end(all.as_mut_slice(), 0, 512, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, 2048);
            let want: Vec<i32> = (0..512).collect();
            assert_eq!(all, want);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn individual_pointer_split_ops_advance_pointer() {
        let path = tmp("indiv");
        threads::run(2, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            // Both ranks write the same 64 ints collectively (overlap —
            // same data, so deterministic).
            let data: Vec<i32> = (0..64).collect();
            f.write_all_begin(data.as_slice(), 0, 64, &Datatype::INT).unwrap();
            f.write_all_end().unwrap();
            assert_eq!(f.get_position().unwrap(), 64);
            f.seek(0, crate::io::file::seek::SET).unwrap();
            f.read_all_begin(64, &Datatype::INT).unwrap();
            let mut back = vec![0i32; 64];
            f.read_all_end(back.as_mut_slice(), 0, 64, &Datatype::INT).unwrap();
            assert_eq!(back, data);
            assert_eq!(f.get_position().unwrap(), 64);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn ordered_split_ops_are_rank_ordered() {
        let path = tmp("ordered");
        threads::run(3, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let mine = vec![c.rank() as i32; 10];
            f.write_ordered_begin(mine.as_slice(), 0, 10, &Datatype::INT).unwrap();
            f.write_ordered_end().unwrap();
            c.barrier();
            f.seek_shared(0, crate::io::file::seek::SET).unwrap();
            f.read_ordered_begin(10, &Datatype::INT).unwrap();
            let mut back = vec![-1i32; 10];
            f.read_ordered_end(back.as_mut_slice(), 0, 10, &Datatype::INT).unwrap();
            assert_eq!(back, mine);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn double_begin_is_rejected() {
        let path = tmp("dbl");
        threads::run(1, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let d = vec![1i32; 4];
            f.write_at_all_begin(0, d.as_slice(), 0, 4, &Datatype::INT).unwrap();
            let err =
                f.write_at_all_begin(16, d.as_slice(), 0, 4, &Datatype::INT).unwrap_err();
            assert_eq!(err.class, ErrorClass::Request);
            f.write_at_all_end().unwrap();
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn mismatched_end_is_rejected_and_state_preserved() {
        let path = tmp("mismatch");
        threads::run(1, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let d = vec![1i32; 4];
            f.write_at_all_begin(0, d.as_slice(), 0, 4, &Datatype::INT).unwrap();
            let mut buf = vec![0i32; 4];
            let err = f
                .read_at_all_end(buf.as_mut_slice(), 0, 4, &Datatype::INT)
                .unwrap_err();
            assert_eq!(err.class, ErrorClass::Request);
            // The pending write survives the bad end call.
            f.write_at_all_end().unwrap();
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn end_without_begin_is_rejected() {
        let path = tmp("nobegin");
        threads::run(1, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            assert_eq!(f.write_at_all_end().unwrap_err().class, ErrorClass::Request);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }
}
