//! The orthogonal `AccessOp` descriptor core — one entry point for the
//! whole data-access matrix.
//!
//! MPI defines data access along three orthogonal axes (§7.2.4):
//! *positioning* (explicit offset / individual pointer / shared pointer),
//! *coordination* (independent / collective / ordered), and *synchronism*
//! (blocking / nonblocking / split). The 34 transfer routines of the
//! 52+4-routine matrix are the legal cells of that cube, crossed with the
//! transfer direction. Instead of hand-rolling each cell, every public
//! routine constructs an [`AccessOp`] describing its cell and delegates to
//! the single core pair [`File::submit_read`] / [`File::submit_write`]
//! (plus [`File::submit_read_owned`], the owned-buffer front the
//! nonblocking reads need under Rust's ownership rules).
//!
//! The core owns, in order:
//!
//! 1. **validation** — open/permission checks and the amode×op legality
//!    rules ([`AccessOp::validate`]: `MODE_APPEND` rejects explicit
//!    offsets, `MODE_SEQUENTIAL` rejects everything but shared-pointer
//!    access);
//! 2. **memory-side checks and payload pack/unpack**
//!    ([`check_mem_args`], [`pack_payload`], [`unpack_payload`]);
//! 3. **pointer resolution and update** — individual pointer (advance by
//!    the actual transfer for blocking ops, immediately by the full
//!    request for nonblocking/split, per MPI), shared-pointer sidecar
//!    fetch-and-add, ordered prefix-sum offsets;
//! 4. **plan compilation** through the scheduler's plan cache
//!    ([`crate::io::schedule::PlanCache`]);
//! 5. **dispatch** — synchronous, request-engine, progress-lane
//!    (the MPI-3.1 nonblocking collectives run both two-phase halves on
//!    the rank's [`progress`](crate::comm::progress) thread), or
//!    phase-by-phase two-phase collective execution on the
//!    [`IoScheduler`](crate::io::schedule::IoScheduler).
//!
//! No access family keeps a private copy of this pipeline: `access.rs`,
//! `shared.rs`, `collective.rs` and `split.rs` only build descriptors.
//! The routine matrix itself ([`access_cells`]) is *derived* from the op
//! dimensions, so the table printed by `jpio routines` cannot drift from
//! the implementation (`jpio routines --check` additionally dispatches
//! every cell through its public wrapper).

use std::borrow::Cow;
use std::sync::Arc;

use crate::comm::datatype::{Datatype, IoBuf, IoBufMut, Offset};
use crate::comm::progress::ProgressLane;
use crate::comm::Status;
use crate::io::cache::PageCache;
use crate::io::collective::{self, CbParams, WriteIoWork};
use crate::io::engine::{self, Request};
use crate::io::errors::{err_arg, err_io, err_request, err_unsupported_op, Result};
use crate::io::file::{amode, File, SplitPending};
use crate::io::hints::{keys, Info};
use crate::io::plan::IoPlan;
use crate::io::schedule::IoScheduler;
use crate::io::stats::{FileStats, Phase};
use crate::io::view::FileView;
use crate::storage::StorageFile;
use crate::strategy::AccessStrategy;

// ----------------------------------------------------------------------
// The descriptor
// ----------------------------------------------------------------------

/// Transfer direction.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Direction {
    /// File → memory.
    Read,
    /// Memory → file.
    Write,
}

/// Positioning axis: where the access starts.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Positioning {
    /// Explicit etype offset (`*_at` routines).
    Explicit(Offset),
    /// The per-handle individual file pointer.
    Individual,
    /// The per-file shared pointer (flocked sidecar).
    Shared,
}

impl Positioning {
    /// The offset-free kind of this positioning (the matrix dimension).
    pub fn kind(self) -> PositioningKind {
        match self {
            Positioning::Explicit(_) => PositioningKind::Explicit,
            Positioning::Individual => PositioningKind::Individual,
            Positioning::Shared => PositioningKind::Shared,
        }
    }
}

/// [`Positioning`] without its offset payload — the matrix dimension.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PositioningKind {
    /// Explicit etype offset.
    Explicit,
    /// Individual file pointer.
    Individual,
    /// Shared file pointer.
    Shared,
}

/// Coordination axis: which ranks take part.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Coordination {
    /// This rank alone.
    Independent,
    /// All ranks, two-phase collective buffering (`*_all`).
    Collective,
    /// All ranks in rank order at the shared pointer (`*_ordered`).
    Ordered,
}

/// The half of a split collective an op describes.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SplitPhase {
    /// `*_begin`: start the collective; the handle stashes the pending op.
    Begin,
    /// `*_end`: complete the pending op (binds the read buffer).
    End,
}

/// Synchronism axis: when the call returns.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Synchronism {
    /// Complete before returning.
    Blocking,
    /// Return a [`Request`]; complete on the engine.
    Nonblocking,
    /// Split collective `*_begin` / `*_end` pair.
    Split(SplitPhase),
}

/// One fully-described data access: a cell of the routine matrix plus the
/// buffer spec `(buf_offset, count, datatype)`. The buffer itself is
/// passed alongside (Rust ownership: blocking ops borrow, nonblocking
/// reads own).
#[derive(Clone, Debug)]
pub struct AccessOp {
    /// Transfer direction.
    pub direction: Direction,
    /// Positioning axis (with the explicit offset when applicable).
    pub positioning: Positioning,
    /// Coordination axis.
    pub coordination: Coordination,
    /// Synchronism axis.
    pub synchronism: Synchronism,
    /// Element offset into the user buffer.
    pub buf_offset: usize,
    /// Number of `datatype` items to transfer.
    pub count: usize,
    /// Memory datatype of the transfer.
    pub datatype: Datatype,
}

impl AccessOp {
    /// Build a descriptor.
    pub fn new(
        direction: Direction,
        positioning: Positioning,
        coordination: Coordination,
        synchronism: Synchronism,
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> AccessOp {
        AccessOp {
            direction,
            positioning,
            coordination,
            synchronism,
            buf_offset,
            count,
            datatype: datatype.clone(),
        }
    }

    /// A read descriptor.
    pub fn read(
        positioning: Positioning,
        coordination: Coordination,
        synchronism: Synchronism,
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> AccessOp {
        AccessOp::new(
            Direction::Read,
            positioning,
            coordination,
            synchronism,
            buf_offset,
            count,
            datatype,
        )
    }

    /// A write descriptor.
    pub fn write(
        positioning: Positioning,
        coordination: Coordination,
        synchronism: Synchronism,
        buf_offset: usize,
        count: usize,
        datatype: &Datatype,
    ) -> AccessOp {
        AccessOp::new(
            Direction::Write,
            positioning,
            coordination,
            synchronism,
            buf_offset,
            count,
            datatype,
        )
    }

    /// Packed payload bytes this op moves.
    pub fn payload_len(&self) -> usize {
        self.count * self.datatype.size()
    }

    /// The matrix cell this op describes (positioning stripped of its
    /// offset) — the classification key the instrumentation records.
    pub fn cell(&self) -> AccessCell {
        AccessCell {
            direction: self.direction,
            positioning: self.positioning.kind(),
            coordination: self.coordination,
            synchronism: self.synchronism,
        }
    }

    /// Validate the op against the file's access mode: the cell must be a
    /// legal point of the matrix, `MODE_APPEND` rejects explicit-offset
    /// access, and `MODE_SEQUENTIAL` rejects explicit-offset and
    /// individual-pointer (mixed-positioning) access — only shared-pointer
    /// access is sequential. The mode rules raise
    /// `MPI_ERR_UNSUPPORTED_OPERATION` (§7.2.2.1).
    pub fn validate(&self, mode: u32) -> Result<()> {
        let kind = self.positioning.kind();
        if !cell_is_legal(kind, self.coordination, self.synchronism) {
            return Err(err_arg(format!(
                "no routine exists for access cell {:?}/{:?}/{:?}",
                kind, self.coordination, self.synchronism
            )));
        }
        if mode & amode::APPEND != 0 && kind == PositioningKind::Explicit {
            return Err(err_unsupported_op("explicit-offset access in MODE_APPEND"));
        }
        if mode & amode::SEQUENTIAL != 0 && kind != PositioningKind::Shared {
            return Err(err_unsupported_op(
                "MODE_SEQUENTIAL permits only shared-pointer data access",
            ));
        }
        Ok(())
    }

    /// The pending-operation tag of this op's split `*_end` routine —
    /// derived from the cell so BEGIN/END matching cannot drift.
    pub(crate) fn end_kind(&self) -> &'static str {
        match (self.direction, self.positioning.kind(), self.coordination) {
            (Direction::Read, PositioningKind::Explicit, Coordination::Collective) => {
                "readAtAllEnd"
            }
            (Direction::Read, PositioningKind::Individual, Coordination::Collective) => {
                "readAllEnd"
            }
            (Direction::Read, PositioningKind::Shared, Coordination::Ordered) => "readOrderedEnd",
            (Direction::Write, PositioningKind::Explicit, Coordination::Collective) => {
                "writeAtAllEnd"
            }
            (Direction::Write, PositioningKind::Individual, Coordination::Collective) => {
                "writeAllEnd"
            }
            (Direction::Write, PositioningKind::Shared, Coordination::Ordered) => {
                "writeOrderedEnd"
            }
            _ => "invalidSplitEnd",
        }
    }
}

/// Whether a (positioning, coordination, synchronism) triple is a routine
/// of the MPI data-access matrix:
///
/// * independent access has no split form;
/// * the shared pointer has no plain collective (`*_ALL`) form — its
///   collective form *is* the ordered access;
/// * ordered access exists only on the shared pointer and has no
///   nonblocking form.
pub fn cell_is_legal(pos: PositioningKind, coord: Coordination, sync: Synchronism) -> bool {
    match coord {
        Coordination::Independent => !matches!(sync, Synchronism::Split(_)),
        Coordination::Collective => pos != PositioningKind::Shared,
        Coordination::Ordered => {
            pos == PositioningKind::Shared && !matches!(sync, Synchronism::Nonblocking)
        }
    }
}

// ----------------------------------------------------------------------
// The derived routine matrix
// ----------------------------------------------------------------------

/// One legal transfer cell of the data-access matrix (direction ×
/// positioning × coordination × synchronism, split phases as separate
/// routines). [`access_cells`] enumerates all 34.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct AccessCell {
    /// Transfer direction.
    pub direction: Direction,
    /// Positioning dimension.
    pub positioning: PositioningKind,
    /// Coordination dimension.
    pub coordination: Coordination,
    /// Synchronism dimension.
    pub synchronism: Synchronism,
}

impl AccessCell {
    /// The routine's method stem, e.g. `read_at_all_begin` — also the
    /// op-cell label in `jpio_stats_trace` events.
    pub fn stem(&self) -> String {
        let mut s = String::new();
        if matches!(self.synchronism, Synchronism::Nonblocking) {
            s.push('i');
        }
        s.push_str(match self.direction {
            Direction::Read => "read",
            Direction::Write => "write",
        });
        if self.positioning == PositioningKind::Explicit {
            s.push_str("_at");
        }
        match self.coordination {
            Coordination::Collective => s.push_str("_all"),
            Coordination::Ordered => s.push_str("_ordered"),
            Coordination::Independent => {
                if self.positioning == PositioningKind::Shared {
                    s.push_str("_shared");
                }
            }
        }
        match self.synchronism {
            Synchronism::Split(SplitPhase::Begin) => s.push_str("_begin"),
            Synchronism::Split(SplitPhase::End) => s.push_str("_end"),
            _ => {}
        }
        s
    }

    /// The MPI routine name, e.g. `MPI_FILE_READ_AT_ALL_BEGIN`.
    pub fn mpi_name(&self) -> String {
        format!("MPI_FILE_{}", self.stem().to_uppercase())
    }

    /// The jpio binding name, e.g. `File::read_at_all_begin`.
    pub fn method_name(&self) -> String {
        format!("File::{}", self.stem())
    }
}

/// Every legal transfer cell, enumerated from the op dimensions — the
/// derived half of [`crate::io::routine_matrix`]. 34 cells: 2 directions
/// × (6 independent + 8 collective + 3 ordered) synchronism/positioning
/// combinations.
pub fn access_cells() -> Vec<AccessCell> {
    let mut out = Vec::new();
    for &direction in &[Direction::Read, Direction::Write] {
        for &positioning in &[
            PositioningKind::Explicit,
            PositioningKind::Individual,
            PositioningKind::Shared,
        ] {
            for &coordination in &[
                Coordination::Independent,
                Coordination::Collective,
                Coordination::Ordered,
            ] {
                for &synchronism in &[
                    Synchronism::Blocking,
                    Synchronism::Nonblocking,
                    Synchronism::Split(SplitPhase::Begin),
                    Synchronism::Split(SplitPhase::End),
                ] {
                    if cell_is_legal(positioning, coordination, synchronism) {
                        out.push(AccessCell { direction, positioning, coordination, synchronism });
                    }
                }
            }
        }
    }
    out
}

// ----------------------------------------------------------------------
// Submission outcome
// ----------------------------------------------------------------------

/// What a write submission produced; which variant is fixed by the op's
/// synchronism, so the typed accessors never fail on descriptors built by
/// the public wrappers.
pub enum Submission {
    /// Completed synchronously (blocking routines, split `*_end`).
    Done(Status),
    /// Queued on the request engine (nonblocking routines).
    Queued(Request<()>),
    /// A split `*_begin` was stashed on the handle; complete at `*_end`.
    Begun,
}

impl Submission {
    /// The completion status of a synchronous submission.
    pub fn status(self) -> Result<Status> {
        match self {
            Submission::Done(st) => Ok(st),
            _ => Err(err_request("submission did not complete synchronously")),
        }
    }

    /// The request handle of a nonblocking submission.
    pub fn request(self) -> Result<Request<()>> {
        match self {
            Submission::Queued(req) => Ok(req),
            _ => Err(err_request("submission was not queued on the engine")),
        }
    }

    /// Confirm a split `*_begin` was stashed.
    pub fn begun(self) -> Result<()> {
        match self {
            Submission::Begun => Ok(()),
            _ => Err(err_request("submission was not a split begin")),
        }
    }
}

// ----------------------------------------------------------------------
// Transfer context + memory-side helpers
// ----------------------------------------------------------------------

/// Everything a transfer needs, snapshotted from the file handle so the
/// nonblocking engine can run it without borrowing the `File`.
pub(crate) struct TransferCtx {
    pub storage: Arc<dyn StorageFile>,
    pub strategy: Arc<dyn AccessStrategy>,
    pub view: Arc<FileView>,
    pub atomic: bool,
    /// The handle's instrumentation record: travels with the snapshot so
    /// the scheduler, phase drivers, and progress-lane jobs record into
    /// it without borrowing the `File`.
    pub stats: Arc<FileStats>,
    /// The handle's page cache (`jpio_cache = enable`), `None` on the
    /// default uncached path. The scheduler routes independent
    /// non-atomic plans through it and flushes it at the two-phase and
    /// atomic coherence points.
    pub cache: Option<Arc<PageCache>>,
}

/// Validate the memory-side arguments of `(buf, buf_offset, count,
/// datatype)`.
pub(crate) fn check_mem_args(
    buf: &(impl IoBuf + ?Sized),
    buf_offset: usize,
    count: usize,
    datatype: &Datatype,
) -> Result<()> {
    let psz = buf.prim().size();
    if datatype.size() % psz != 0 || datatype.base_prim().size() != psz {
        return Err(err_arg(format!(
            "datatype {datatype} does not match buffer element size {psz}"
        )));
    }
    let need_bytes = if count == 0 {
        0
    } else {
        (count as i64 - 1) * datatype.extent() + datatype.true_lb() + datatype.true_extent()
    };
    let have = buf.elems().saturating_sub(buf_offset) * psz;
    if need_bytes > have as i64 {
        return Err(err_arg(format!(
            "buffer too small: need {need_bytes} bytes at element offset {buf_offset}, have {have}"
        )));
    }
    Ok(())
}

/// Validate the memory-side arguments and return the packed payload for a
/// write (borrowed when possible).
pub(crate) fn pack_payload<'b>(
    buf: &'b (impl IoBuf + ?Sized),
    buf_offset: usize,
    count: usize,
    datatype: &Datatype,
    view: &FileView,
) -> Result<Cow<'b, [u8]>> {
    let bytes = buf.as_bytes();
    let psz = buf.prim().size();
    let base = buf_offset * psz;
    let payload_len = count * datatype.size();
    check_mem_args(buf, buf_offset, count, datatype)?;
    if datatype.is_contiguous() && view.datarep.is_identity() {
        return Ok(Cow::Borrowed(&bytes[base..base + payload_len]));
    }
    // Gather the memory runs into a packed buffer.
    let mut payload = Vec::with_capacity(payload_len);
    for run in datatype.byte_runs(count) {
        let s = base + run.offset as usize;
        payload.extend_from_slice(&bytes[s..s + run.len()]);
    }
    // Representation conversion (memory → file).
    if !view.datarep.is_identity() {
        let elems = view.payload_elems(payload.len());
        view.datarep.encode(&mut payload, &elems);
    }
    Ok(Cow::Owned(payload))
}

/// Scatter a packed payload (already datarep-decoded) into the memory runs
/// of `(buf, buf_offset, count, datatype)`. `got` bytes are valid.
pub(crate) fn unpack_payload(
    buf: &mut (impl IoBufMut + ?Sized),
    buf_offset: usize,
    count: usize,
    datatype: &Datatype,
    payload: &[u8],
    got: usize,
) -> Result<()> {
    check_mem_args(buf, buf_offset, count, datatype)?;
    let psz = buf.prim().size();
    let base = buf_offset * psz;
    let bytes = buf.as_bytes_mut();
    if datatype.is_contiguous() {
        let n = (count * datatype.size()).min(got);
        bytes[base..base + n].copy_from_slice(&payload[..n]);
        return Ok(());
    }
    let mut pos = 0;
    for run in datatype.byte_runs(count) {
        if pos >= got {
            break;
        }
        let n = run.len().min(got - pos);
        let d = base + run.offset as usize;
        bytes[d..d + n].copy_from_slice(&payload[pos..pos + n]);
        pos += n;
    }
    Ok(())
}

// ----------------------------------------------------------------------
// The core
// ----------------------------------------------------------------------

impl File<'_> {
    pub(crate) fn transfer_ctx(&self) -> TransferCtx {
        TransferCtx {
            storage: self.storage.clone(),
            strategy: self.strategy_snapshot(),
            view: self.view_snapshot(),
            atomic: self.get_atomicity(),
            stats: self.stats.clone(),
            cache: self.cache.clone(),
        }
    }

    /// Compile (or reuse from the scheduler's plan cache) the plan of an
    /// access of `len` payload bytes at etype offset `off`. Every
    /// plan-compiling path funnels through here, so this is also the
    /// single point recording the run-shape counters (contiguous vs
    /// strided, run count, bytes moved).
    fn plan_for(
        &self,
        ctx: &TransferCtx,
        direction: Direction,
        off: Offset,
        len: usize,
    ) -> Result<Arc<IoPlan>> {
        let plan = self.plan_cache.lookup(&ctx.view, direction, ctx.atomic, off, len)?;
        ctx.stats.note_plan(&plan);
        Ok(plan)
    }

    /// The validation prologue every submission runs: handle state,
    /// direction permissions, amode×op legality, split-pending exclusion.
    /// Timed as the `validate` phase.
    fn prologue(&self, op: &AccessOp) -> Result<TransferCtx> {
        let t0 = self.stats.start();
        self.check_open()?;
        match op.direction {
            Direction::Read => self.check_readable()?,
            Direction::Write => self.check_writable()?,
        }
        op.validate(self.amode)?;
        if matches!(op.synchronism, Synchronism::Split(SplitPhase::Begin))
            && self.split.lock().unwrap().is_some()
        {
            return Err(err_request(
                "a split collective is already active on this file handle",
            ));
        }
        let ctx = self.transfer_ctx();
        // Coherence point: collective (and ordered) execution hands the
        // transfer to aggregators and peer ranks the cache cannot see,
        // so resident pages must flush and drop before the exchange.
        if !matches!(op.coordination, Coordination::Independent) {
            if let Some(cache) = &ctx.cache {
                cache.flush_and_invalidate()?;
            }
        }
        self.stats.record(Phase::Validate, t0);
        Ok(ctx)
    }

    /// Apply the per-submission overlays to a fresh [`TransferCtx`]:
    ///
    /// * a scoped **view** replacing the installed one (dataset subarray
    ///   access) — rejected off `Positioning::Explicit`, whose offsets
    ///   alone are insensitive to the installed view's etype scaling;
    /// * a `jpio_cache = disable` **hint** dropping the page cache from
    ///   this submission's path. The cache first flushes and invalidates
    ///   so a bypassed read still observes write-behind data and a
    ///   bypassed write cannot be shadowed by stale resident pages.
    fn apply_overlay(
        &self,
        ctx: &mut TransferCtx,
        op: &AccessOp,
        overlay: Option<Arc<FileView>>,
        hints: Option<&Info>,
    ) -> Result<()> {
        if let Some(view) = overlay {
            if !matches!(op.positioning, Positioning::Explicit(_)) {
                return Err(err_arg(
                    "per-op view overlays require explicit-offset positioning",
                ));
            }
            ctx.view = view;
        }
        if hints.and_then(|h| h.get_flag(keys::CACHE)) == Some(false) {
            if let Some(cache) = ctx.cache.take() {
                cache.flush_and_invalidate()?;
            }
        }
        Ok(())
    }

    /// Resolve the op's starting etype offset and update the pointer it
    /// names. Returns `(offset, advance_by_actual)`: blocking
    /// individual-pointer ops advance by the *actual* transfer size after
    /// completion (via [`File::commit_indiv_ptr`]); nonblocking and split
    /// BEGIN ops advance immediately by the full request (MPI semantics —
    /// the pointer update is not deferred to completion). The shared
    /// pointer is reserved here by sidecar fetch-and-add (independent) or
    /// the ordered prefix-sum pass (ordered). Timed as the `resolve`
    /// phase (the shared-pointer sidecar and ordered prefix-sum variants
    /// are where the time goes).
    fn resolve_offset(&self, op: &AccessOp, view: &FileView) -> Result<(Offset, bool)> {
        let t0 = self.stats.start();
        let resolved = self.resolve_offset_inner(op, view);
        self.stats.record(Phase::Resolve, t0);
        if let Ok((off, _)) = resolved {
            self.stats.note_op(op, off, !view.datarep.is_identity());
        }
        resolved
    }

    fn resolve_offset_inner(&self, op: &AccessOp, view: &FileView) -> Result<(Offset, bool)> {
        let req_etypes = view.bytes_to_etypes(op.payload_len());
        match (op.positioning, op.coordination) {
            (Positioning::Explicit(off), _) => Ok((off, false)),
            (Positioning::Individual, _) => {
                // Take the lock briefly and release it before any
                // collective exchange: holding it across the exchange
                // would stall every other thread's pointer op for the
                // whole collective.
                let mut ptr = self.indiv_ptr.lock().unwrap();
                let off = *ptr;
                if matches!(op.synchronism, Synchronism::Blocking) {
                    Ok((off, true))
                } else {
                    *ptr = off + req_etypes;
                    Ok((off, false))
                }
            }
            (Positioning::Shared, Coordination::Ordered) => {
                Ok((self.ordered_offsets(req_etypes)?, false))
            }
            (Positioning::Shared, _) => Ok((self.sfp_fetch_add(req_etypes)?, false)),
        }
    }

    /// Commit a deferred individual-pointer update (blocking ops): the
    /// pointer lands at `off` + the etypes actually transferred.
    fn commit_indiv_ptr(&self, advance: bool, off: Offset, view: &FileView, actual_bytes: usize) {
        if advance {
            *self.indiv_ptr.lock().unwrap() = off + view.bytes_to_etypes(actual_bytes);
        }
    }

    fn stash(&self, p: SplitPending) {
        *self.split.lock().unwrap() = Some(p);
    }

    fn take_pending(&self, want: &'static str) -> Result<SplitPending> {
        let mut slot = self.split.lock().unwrap();
        match slot.take() {
            None => Err(err_request(format!("{want}: no split collective is active"))),
            Some(p) => {
                let kind = match &p {
                    SplitPending::Read { kind, .. } | SplitPending::Write { kind, .. } => kind,
                };
                if *kind != want {
                    let msg = format!("{want} does not match pending {kind}");
                    *slot = Some(p);
                    return Err(err_request(msg));
                }
                Ok(p)
            }
        }
    }

    // ------------------------------------------------------------------
    // submit_write: every write cell
    // ------------------------------------------------------------------

    /// The single write entry point: every write routine of the matrix —
    /// blocking, nonblocking, collective, ordered, and split — constructs
    /// an [`AccessOp`] and lands here. Split `*_end` ops ignore `buf`
    /// (the data was bound at BEGIN; pass an empty slice).
    pub fn submit_write(&self, op: &AccessOp, buf: &(impl IoBuf + ?Sized)) -> Result<Submission> {
        self.submit_write_with(op, buf, None)
    }

    /// [`File::submit_write`] with a per-operation hint overlay: the
    /// overlay's keys shadow the handle's Info for this one submission
    /// (intended for A/B-ing `jpio_alltoall_algorithm` and
    /// `jpio_staging_buffer_size` without reopening the file; any
    /// collective-buffering hint works, and `jpio_cache = disable`
    /// bypasses the page cache for this one submission — see
    /// [`keys::CACHE`]). Like the hints they override, overlays on
    /// collective cells must match across ranks.
    pub fn submit_write_with(
        &self,
        op: &AccessOp,
        buf: &(impl IoBuf + ?Sized),
        hints: Option<&Info>,
    ) -> Result<Submission> {
        self.submit_write_overlay(op, buf, None, hints)
    }

    /// [`File::submit_write_with`] plus a per-op *view* overlay: `overlay`
    /// replaces the handle's installed file view for this one submission
    /// only, without the collective `set_view` (pointer reset, sfp
    /// rewrite) or its cross-handle visibility. The dataset layer compiles
    /// every subarray request into such a scoped view; only
    /// `Positioning::Explicit` ops may carry one (the file pointers are
    /// etype-indexed against the *installed* view, so a scoped view would
    /// silently rescale them).
    pub(crate) fn submit_write_overlay(
        &self,
        op: &AccessOp,
        buf: &(impl IoBuf + ?Sized),
        overlay: Option<Arc<FileView>>,
        hints: Option<&Info>,
    ) -> Result<Submission> {
        if let Synchronism::Split(SplitPhase::End) = op.synchronism {
            // END binds no buffer or offset, but still runs the
            // validation prologue: illegal End cells are MPI_ERR_ARG
            // like every other cell, not a confusing pending-mismatch.
            self.prologue(op)?;
            return self.end_write(op).map(Submission::Done);
        }
        let mut ctx = self.prologue(op)?;
        self.apply_overlay(&mut ctx, op, overlay, hints)?;
        let payload = pack_payload(buf, op.buf_offset, op.count, &op.datatype, &ctx.view)?;
        let (off, advance) = self.resolve_offset(op, &ctx.view)?;
        match (op.coordination, op.synchronism) {
            (Coordination::Independent, Synchronism::Blocking)
            | (Coordination::Ordered, Synchronism::Blocking) => {
                let plan = self.plan_for(&ctx, Direction::Write, off, payload.len())?;
                let st = IoScheduler::write(&ctx, &plan, &payload)?;
                self.commit_indiv_ptr(advance, off, &ctx.view, st.bytes);
                if op.coordination == Coordination::Ordered {
                    // Ordered collective completion.
                    self.comm.barrier();
                }
                Ok(Submission::Done(st))
            }
            (Coordination::Independent, Synchronism::Nonblocking) => {
                let plan = self.plan_for(&ctx, Direction::Write, off, payload.len())?;
                Ok(Submission::Queued(
                    IoScheduler::write_async(ctx, plan, payload.into_owned())
                        .instrument(&self.stats),
                ))
            }
            (Coordination::Ordered, Synchronism::Split(SplitPhase::Begin)) => {
                // Ordered BEGIN: offset already reserved in rank order;
                // the independent transfer overlaps on the engine.
                let plan = self.plan_for(&ctx, Direction::Write, off, payload.len())?;
                let req = IoScheduler::write_async(ctx, plan, payload.into_owned())
                    .instrument(&self.stats);
                self.stash(SplitPending::Write { kind: op.end_kind(), req });
                Ok(Submission::Begun)
            }
            (Coordination::Collective, Synchronism::Blocking) => {
                let cb = self.cb_params_with(hints);
                let (work, bytes) = self.exchange_write(&ctx, &cb, off, &payload)?;
                IoScheduler::write_phase(&ctx, work)?;
                self.comm.barrier();
                self.commit_indiv_ptr(advance, off, &ctx.view, bytes);
                Ok(Submission::Done(Status::of_bytes(bytes)))
            }
            (Coordination::Collective, Synchronism::Nonblocking) => {
                let cb = self.cb_params_with(hints);
                if !cb.enabled || self.comm.size() == 1 {
                    // No aggregation: the whole operation runs on the
                    // engine, like an independent nonblocking write.
                    let plan = self.plan_for(&ctx, Direction::Write, off, payload.len())?;
                    return Ok(Submission::Queued(
                        IoScheduler::write_async(ctx, plan, payload.into_owned())
                            .instrument(&self.stats),
                    ));
                }
                if let Some(ProgressLane { engine, comm }) = self.progress_lane() {
                    // Truly asynchronous: exchange *and* I/O phases run
                    // on the rank's progress thread; this call returns
                    // after registering the op, before any byte moves.
                    // The ticket keeps storage phases in issue order
                    // across lanes while exchanges pipeline freely.
                    let plan = self.plan_for(&ctx, Direction::Write, off, payload.len())?;
                    let payload = payload.into_owned();
                    let mut ticket = self.lane_order.issue();
                    let (req, tx) = Request::pending();
                    let req = req.instrument(&self.stats);
                    let q0 = self.stats.start();
                    // A failed submit (fork race) drops `tx`, surfacing
                    // a request error at wait instead of hanging.
                    engine.submit(move || {
                        // Queue latency: submit → job start on the lane.
                        ctx.stats.record(Phase::Queue, q0);
                        let res =
                            collective::exchange_write(comm.as_ref(), &ctx, &cb, &plan, &payload)
                                .and_then(|(work, bytes)| {
                                    ticket.wait_turn();
                                    IoScheduler::write_phase(&ctx, work)?;
                                    Ok(Status::of_bytes(bytes))
                                });
                        drop(ticket); // release the turn before completion
                        let _ = tx.send((res, ()));
                    });
                    return Ok(Submission::Queued(req));
                }
                // No progress lane (sub-communicator, disabled by hint):
                // exchange phase on the caller, I/O phase overlaps on
                // the engine — the split collectives' lane-less contract.
                let (work, bytes) = self.exchange_write(&ctx, &cb, off, &payload)?;
                Ok(Submission::Queued(
                    IoScheduler::write_phase_async(ctx, work, bytes).instrument(&self.stats),
                ))
            }
            (Coordination::Collective, Synchronism::Split(SplitPhase::Begin)) => {
                let cb = self.cb_params_with(hints);
                if cb.enabled && self.comm.size() > 1 {
                    if let Some(ProgressLane { engine, comm }) = self.progress_lane() {
                        // BEGIN is truly immediate: both two-phase halves
                        // run on the progress lane, like the MPI-3.1
                        // nonblocking collectives; END waits for the
                        // stashed request and adds the collective
                        // completion barrier.
                        let plan = self.plan_for(&ctx, Direction::Write, off, payload.len())?;
                        let payload = payload.into_owned();
                        let mut ticket = self.lane_order.issue();
                        let (req, tx) = Request::pending();
                        let req = req.instrument(&self.stats);
                        let q0 = self.stats.start();
                        engine.submit(move || {
                            ctx.stats.record(Phase::Queue, q0);
                            let res = collective::exchange_write(
                                comm.as_ref(),
                                &ctx,
                                &cb,
                                &plan,
                                &payload,
                            )
                            .and_then(|(work, bytes)| {
                                ticket.wait_turn();
                                IoScheduler::write_phase(&ctx, work)?;
                                Ok(Status::of_bytes(bytes))
                            });
                            drop(ticket);
                            let _ = tx.send((res, ()));
                        });
                        self.stash(SplitPending::Write { kind: op.end_kind(), req });
                        return Ok(Submission::Begun);
                    }
                }
                // Lane-less fallback: exchange on the caller, I/O phase
                // overlaps on the engine (§7.2.9.1 double buffering).
                let (work, bytes) = self.exchange_write(&ctx, &cb, off, &payload)?;
                let req =
                    IoScheduler::write_phase_async(ctx, work, bytes).instrument(&self.stats);
                self.stash(SplitPending::Write { kind: op.end_kind(), req });
                Ok(Submission::Begun)
            }
            _ => Err(err_arg("illegal write cell")), // unreachable after validate
        }
    }

    fn end_write(&self, op: &AccessOp) -> Result<Status> {
        match self.take_pending(op.end_kind())? {
            SplitPending::Write { req, .. } => {
                let (st, ()) = req.wait()?;
                // Collective completion.
                self.comm.barrier();
                Ok(st)
            }
            SplitPending::Read { .. } => unreachable!("kind checked in take_pending"),
        }
    }

    // ------------------------------------------------------------------
    // submit_read: blocking + split read cells
    // ------------------------------------------------------------------

    /// The single read entry point for borrowed buffers: blocking reads
    /// of every family, split `*_begin` (which ignores `buf` — the
    /// buffer binds at END; pass an empty slice) and split `*_end`.
    /// Nonblocking reads own their buffer and enter through
    /// [`File::submit_read_owned`], which shares every pipeline stage.
    pub fn submit_read(
        &self,
        op: &AccessOp,
        buf: &mut (impl IoBufMut + ?Sized),
    ) -> Result<Status> {
        self.submit_read_with(op, buf, None)
    }

    /// [`File::submit_read`] with a per-operation hint overlay — see
    /// [`File::submit_write_with`].
    pub fn submit_read_with(
        &self,
        op: &AccessOp,
        buf: &mut (impl IoBufMut + ?Sized),
        hints: Option<&Info>,
    ) -> Result<Status> {
        self.submit_read_overlay(op, buf, None, hints)
    }

    /// [`File::submit_read_with`] plus a per-op view overlay — see
    /// [`File::submit_write_overlay`].
    pub(crate) fn submit_read_overlay(
        &self,
        op: &AccessOp,
        buf: &mut (impl IoBufMut + ?Sized),
        overlay: Option<Arc<FileView>>,
        hints: Option<&Info>,
    ) -> Result<Status> {
        match op.synchronism {
            Synchronism::Split(SplitPhase::End) => {
                self.prologue(op)?;
                return self.end_read(op, buf);
            }
            Synchronism::Nonblocking => {
                return Err(err_arg(
                    "nonblocking reads own their buffer: use File::submit_read_owned",
                ))
            }
            _ => {}
        }
        let mut ctx = self.prologue(op)?;
        self.apply_overlay(&mut ctx, op, overlay, hints)?;
        let payload_len = op.payload_len();
        if let Synchronism::Split(SplitPhase::Begin) = op.synchronism {
            let (off, _) = self.resolve_offset(op, &ctx.view)?;
            self.begin_read(op, ctx, off, payload_len, hints)?;
            return Ok(Status::of_bytes(0));
        }
        // Blocking. Memory-side arguments are pre-checked for
        // noncollective cells only: a blocking collective *read* can
        // reach the exchange even with bad arguments (its peers would
        // block in the alltoall otherwise) — the check runs in
        // unpack_payload after the exchange, surfacing the error
        // locally. (Writes cannot defer it: the exchange ships the
        // packed payload, so packing — and its validation — must come
        // first, as it always has.)
        if op.coordination != Coordination::Collective {
            check_mem_args(buf, op.buf_offset, op.count, &op.datatype)?;
        }
        let (off, advance) = self.resolve_offset(op, &ctx.view)?;
        let got = if op.coordination == Coordination::Collective {
            let cb = self.cb_params_with(hints);
            let mut payload = vec![0u8; payload_len];
            let got = self.collective_read(&ctx, &cb, off, &mut payload)?;
            unpack_payload(buf, op.buf_offset, op.count, &op.datatype, &payload, got)?;
            got
        } else if op.datatype.is_contiguous() && ctx.view.datarep.is_identity() {
            // Fast path: contiguous memory type + identity representation
            // → the storage strategy fills the user buffer directly.
            let base = op.buf_offset * buf.prim().size();
            let plan = self.plan_for(&ctx, Direction::Read, off, payload_len)?;
            IoScheduler::read(&ctx, &plan, &mut buf.as_bytes_mut()[base..base + payload_len])?
        } else {
            let plan = self.plan_for(&ctx, Direction::Read, off, payload_len)?;
            let mut payload = vec![0u8; payload_len];
            let got = IoScheduler::read(&ctx, &plan, &mut payload)?;
            unpack_payload(buf, op.buf_offset, op.count, &op.datatype, &payload, got)?;
            got
        };
        self.commit_indiv_ptr(advance, off, &ctx.view, got);
        if op.coordination == Coordination::Ordered {
            self.comm.barrier();
        }
        Ok(Status::of_bytes(got))
    }

    /// The owned-buffer front of [`File::submit_read`]: nonblocking reads
    /// take ownership of the buffer ([`Request::wait`] returns it filled)
    /// and run the same validation / pointer / plan / dispatch stages.
    pub fn submit_read_owned<T>(&self, op: &AccessOp, buf: Vec<T>) -> Result<Request<Vec<T>>>
    where
        T: Send + 'static,
        [T]: IoBufMut,
    {
        self.submit_read_owned_with(op, buf, None)
    }

    /// [`File::submit_read_owned`] with a per-operation hint overlay —
    /// see [`File::submit_write_with`].
    pub fn submit_read_owned_with<T>(
        &self,
        op: &AccessOp,
        buf: Vec<T>,
        hints: Option<&Info>,
    ) -> Result<Request<Vec<T>>>
    where
        T: Send + 'static,
        [T]: IoBufMut,
    {
        self.submit_read_owned_overlay(op, buf, None, hints)
    }

    /// [`File::submit_read_owned_with`] plus a per-op view overlay — see
    /// [`File::submit_write_overlay`].
    pub(crate) fn submit_read_owned_overlay<T>(
        &self,
        op: &AccessOp,
        buf: Vec<T>,
        overlay: Option<Arc<FileView>>,
        hints: Option<&Info>,
    ) -> Result<Request<Vec<T>>>
    where
        T: Send + 'static,
        [T]: IoBufMut,
    {
        if !matches!(op.synchronism, Synchronism::Nonblocking) {
            return Err(err_arg("submit_read_owned handles only nonblocking reads"));
        }
        let mut ctx = self.prologue(op)?;
        self.apply_overlay(&mut ctx, op, overlay, hints)?;
        check_mem_args(buf.as_slice(), op.buf_offset, op.count, &op.datatype)?;
        let payload_len = op.payload_len();
        let (buf_offset, count, dt) = (op.buf_offset, op.count, op.datatype.clone());
        if op.coordination == Coordination::Collective {
            let cb = self.cb_params_with(hints);
            if cb.enabled && self.comm.size() > 1 {
                let (off, _) = self.resolve_offset(op, &ctx.view)?;
                if let Some(ProgressLane { engine, comm }) = self.progress_lane() {
                    // Truly asynchronous read: request exchange,
                    // aggregation, reply exchange, and the scatter into
                    // `buf` all run on the rank's progress thread; this
                    // call returns before any byte moves. The ticket
                    // holds the whole read behind earlier operations'
                    // storage phases (a read's request exchange, storage
                    // and reply exchange interleave inside
                    // `collective_read`, so the gate sits in front).
                    let plan = self.plan_for(&ctx, Direction::Read, off, payload_len)?;
                    let mut ticket = self.lane_order.issue();
                    let (req, tx) = Request::pending();
                    let req = req.instrument(&self.stats);
                    let q0 = self.stats.start();
                    engine.submit(move || {
                        // Queue latency: submit → job start on the lane.
                        ctx.stats.record(Phase::Queue, q0);
                        ticket.wait_turn();
                        let mut buf = buf;
                        let mut payload = vec![0u8; payload_len];
                        let res = collective::collective_read(
                            comm.as_ref(),
                            &ctx,
                            &cb,
                            &plan,
                            &mut payload,
                        )
                        .and_then(|got| {
                            unpack_payload(
                                buf.as_mut_slice(),
                                buf_offset,
                                count,
                                &dt,
                                &payload,
                                got,
                            )?;
                            Ok(Status::of_bytes(got))
                        });
                        let _ = tx.send((res, buf));
                    });
                    return Ok(req);
                }
                // No progress lane: the exchange *and* aggregation
                // complete in this call (the reply exchange needs a
                // communicator endpoint); only the local scatter/decode
                // runs on the engine.
                let mut payload = vec![0u8; payload_len];
                let got = self.collective_read(&ctx, &cb, off, &mut payload)?;
                return Ok(engine::submit(move || {
                    let mut buf = buf;
                    let res =
                        unpack_payload(buf.as_mut_slice(), buf_offset, count, &dt, &payload, got)
                            .map(|()| Status::of_bytes(got));
                    (res, buf)
                })
                .instrument(&self.stats));
            }
            // Degenerate collective: fall through to the engine path.
        }
        let (off, _) = self.resolve_offset(op, &ctx.view)?;
        // Compile on the caller (argument errors surface here); execute
        // on the engine.
        let plan = self.plan_for(&ctx, Direction::Read, off, payload_len)?;
        Ok(engine::submit(move || {
            let mut buf = buf;
            let mut payload = vec![0u8; payload_len];
            let res = IoScheduler::read(&ctx, &plan, &mut payload).and_then(|got| {
                unpack_payload(buf.as_mut_slice(), buf_offset, count, &dt, &payload, got)?;
                Ok(Status::of_bytes(got))
            });
            (res, buf)
        })
        .instrument(&self.stats))
    }

    /// Start a split read. Collective reads route through the progress
    /// lane when the transport has one — BEGIN returns before any byte
    /// moves, and the whole two-phase read (request exchange,
    /// aggregation, reply exchange) runs on the lane; END binds the
    /// buffer and unpacks. Without a lane the aggregation completes here
    /// (the reply exchange needs a communicator endpoint) and a ready
    /// payload is stashed. Ordered reads overlap on the engine.
    fn begin_read(
        &self,
        op: &AccessOp,
        ctx: TransferCtx,
        off: Offset,
        payload_len: usize,
        hints: Option<&Info>,
    ) -> Result<()> {
        let req = match op.coordination {
            Coordination::Collective => {
                let cb = self.cb_params_with(hints);
                if cb.enabled && self.comm.size() > 1 {
                    if let Some(ProgressLane { engine, comm }) = self.progress_lane() {
                        let plan = self.plan_for(&ctx, Direction::Read, off, payload_len)?;
                        let mut ticket = self.lane_order.issue();
                        let (req, tx) = Request::pending();
                        let req = req.instrument(&self.stats);
                        let q0 = self.stats.start();
                        engine.submit(move || {
                            ctx.stats.record(Phase::Queue, q0);
                            ticket.wait_turn();
                            let mut payload = vec![0u8; payload_len];
                            let res = collective::collective_read(
                                comm.as_ref(),
                                &ctx,
                                &cb,
                                &plan,
                                &mut payload,
                            )
                            .map(Status::of_bytes);
                            drop(ticket);
                            let _ = tx.send((res, payload));
                        });
                        self.stash(SplitPending::Read { kind: op.end_kind(), req });
                        return Ok(());
                    }
                }
                let mut payload = vec![0u8; payload_len];
                let got = self.collective_read(&ctx, &cb, off, &mut payload)?;
                Request::ready(Status::of_bytes(got), payload)
            }
            Coordination::Ordered => {
                let plan = self.plan_for(&ctx, Direction::Read, off, payload_len)?;
                IoScheduler::read_async(ctx, plan, payload_len).instrument(&self.stats)
            }
            Coordination::Independent => {
                return Err(err_arg("independent access has no split form"))
            }
        };
        self.stash(SplitPending::Read { kind: op.end_kind(), req });
        Ok(())
    }

    fn end_read(&self, op: &AccessOp, buf: &mut (impl IoBufMut + ?Sized)) -> Result<Status> {
        match self.take_pending(op.end_kind())? {
            SplitPending::Read { req, .. } => {
                let (st, payload) = req.wait()?;
                if payload.len() < op.payload_len() {
                    return Err(err_io("split read payload shorter than END request"));
                }
                unpack_payload(buf, op.buf_offset, op.count, &op.datatype, &payload, st.bytes)?;
                if op.coordination == Coordination::Ordered {
                    self.comm.barrier();
                }
                Ok(st)
            }
            SplitPending::Write { .. } => unreachable!("kind checked in take_pending"),
        }
    }

    // ------------------------------------------------------------------
    // Two-phase collective plumbing (the thread-agnostic phase drivers
    // live in collective.rs; these wrappers bind the handle's
    // communicator and plan cache for the on-caller paths)
    // ------------------------------------------------------------------

    /// The progress lane for the *next* lane-bound collective, unless
    /// the collective `jpio_progress_threads` hint disables it or the
    /// engine is unusable (a forked child that inherited the world — a
    /// whole-world condition, so every rank answers alike and the
    /// fallback stays collectively consistent).
    ///
    /// With `jpio_progress_threads = k > 1` (clamped to
    /// [`MAX_LANES`](crate::comm::progress::MAX_LANES)) the handle
    /// round-robins lane-bound collectives across `k` lanes. The cursor
    /// follows the collective issue order, which MPI already requires to
    /// be identical on every rank, so matched collectives always share a
    /// lane; exchanges then pipeline across lanes while the
    /// [`OpSequencer`](engine::OpSequencer) keeps storage phases in
    /// issue order.
    pub(crate) fn progress_lane(&self) -> Option<ProgressLane> {
        let nlanes = self
            .info
            .lock()
            .unwrap()
            .get_usize(keys::PROGRESS_THREADS)
            .unwrap_or(1)
            .min(crate::comm::progress::MAX_LANES);
        if nlanes == 0 {
            return None;
        }
        let lane = if nlanes == 1 {
            0
        } else {
            self.lane_seq.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % nlanes
        };
        self.progress_lane_for(lane)
    }

    /// A specific progress lane, bypassing the round-robin cursor (the
    /// stats queries go through lane 0 so they never perturb the
    /// assignment the data path depends on).
    pub(crate) fn progress_lane_for(&self, lane: usize) -> Option<ProgressLane> {
        if self.info.lock().unwrap().get_usize(keys::PROGRESS_THREADS) == Some(0) {
            return None;
        }
        let lane = self.comm.progress_lane_at(lane)?;
        if !lane.engine.usable() {
            return None;
        }
        Some(lane)
    }

    /// [`collective::exchange_write`] on the calling thread — the
    /// blocking and split collectives' exchange half.
    fn exchange_write(
        &self,
        ctx: &TransferCtx,
        cb: &CbParams,
        etype_off: Offset,
        payload: &[u8],
    ) -> Result<(WriteIoWork, usize)> {
        let plan = self.plan_for(ctx, Direction::Write, etype_off, payload.len())?;
        collective::exchange_write(self.comm, ctx, cb, &plan, payload)
    }

    /// [`collective::collective_read`] on the calling thread — the
    /// blocking, split, and lane-less nonblocking collective reads.
    fn collective_read(
        &self,
        ctx: &TransferCtx,
        cb: &CbParams,
        etype_off: Offset,
        payload: &mut [u8],
    ) -> Result<usize> {
        let plan = self.plan_for(ctx, Direction::Read, etype_off, payload.len())?;
        collective::collective_read(self.comm, ctx, cb, &plan, payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::threads;
    use crate::io::errors::ErrorClass;
    use crate::io::hints::Info;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-op-{}-{name}", std::process::id())
    }

    #[test]
    fn matrix_has_34_unique_cells() {
        let cells = access_cells();
        assert_eq!(cells.len(), 34);
        let mut mpi: Vec<String> = cells.iter().map(|c| c.mpi_name()).collect();
        mpi.sort();
        mpi.dedup();
        assert_eq!(mpi.len(), 34);
        let mut methods: Vec<String> = cells.iter().map(|c| c.method_name()).collect();
        methods.sort();
        methods.dedup();
        assert_eq!(methods.len(), 34);
    }

    #[test]
    fn derived_names_match_the_spec() {
        let cells = access_cells();
        let has = |mpi: &str, method: &str| {
            cells.iter().any(|c| c.mpi_name() == mpi && c.method_name() == method)
        };
        assert!(has("MPI_FILE_READ_AT", "File::read_at"));
        assert!(has("MPI_FILE_IWRITE_AT_ALL", "File::iwrite_at_all"));
        assert!(has("MPI_FILE_READ_AT_ALL_BEGIN", "File::read_at_all_begin"));
        assert!(has("MPI_FILE_WRITE_ORDERED_END", "File::write_ordered_end"));
        assert!(has("MPI_FILE_IREAD_SHARED", "File::iread_shared"));
        assert!(has("MPI_FILE_WRITE", "File::write"));
        // Illegal cells stay out: no nonblocking ordered, no shared
        // collective, no independent split.
        assert!(!cells.iter().any(|c| c.mpi_name().contains("IREAD_ORDERED")));
        assert!(!cells.iter().any(|c| c.mpi_name() == "MPI_FILE_READ_SHARED_ALL"));
        assert!(!cells.iter().any(|c| c.mpi_name() == "MPI_FILE_READ_BEGIN"));
    }

    #[test]
    fn legality_rules() {
        use Coordination::*;
        use PositioningKind::*;
        use Synchronism::*;
        assert!(cell_is_legal(Explicit, Independent, Blocking));
        assert!(cell_is_legal(Shared, Ordered, Split(SplitPhase::Begin)));
        assert!(!cell_is_legal(Shared, Collective, Blocking));
        assert!(!cell_is_legal(Shared, Ordered, Nonblocking));
        assert!(!cell_is_legal(Explicit, Independent, Split(SplitPhase::End)));
        assert!(!cell_is_legal(Individual, Ordered, Blocking));
    }

    #[test]
    fn amode_legality_is_centralized() {
        let op = |pos| {
            AccessOp::write(
                pos,
                Coordination::Independent,
                Synchronism::Blocking,
                0,
                1,
                &Datatype::BYTE,
            )
        };
        // APPEND rejects explicit offsets, allows pointer access.
        let e = op(Positioning::Explicit(0)).validate(amode::WRONLY | amode::APPEND).unwrap_err();
        assert_eq!(e.class, ErrorClass::UnsupportedOperation);
        assert!(op(Positioning::Individual).validate(amode::WRONLY | amode::APPEND).is_ok());
        // SEQUENTIAL permits only shared-pointer access.
        let e = op(Positioning::Explicit(0))
            .validate(amode::WRONLY | amode::SEQUENTIAL)
            .unwrap_err();
        assert_eq!(e.class, ErrorClass::UnsupportedOperation);
        let e =
            op(Positioning::Individual).validate(amode::WRONLY | amode::SEQUENTIAL).unwrap_err();
        assert_eq!(e.class, ErrorClass::UnsupportedOperation);
        assert!(op(Positioning::Shared).validate(amode::WRONLY | amode::SEQUENTIAL).is_ok());
        // Illegal cells are MPI_ERR_ARG regardless of mode.
        let bad = AccessOp::read(
            Positioning::Shared,
            Coordination::Collective,
            Synchronism::Blocking,
            0,
            1,
            &Datatype::BYTE,
        );
        assert_eq!(bad.validate(amode::RDWR).unwrap_err().class, ErrorClass::Arg);
    }

    #[test]
    fn submit_matches_wrapper_for_explicit_blocking() {
        let path = tmp("core");
        threads::run(1, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let data: Vec<i32> = (0..16).collect();
            let op = AccessOp::write(
                Positioning::Explicit(0),
                Coordination::Independent,
                Synchronism::Blocking,
                0,
                16,
                &Datatype::INT,
            );
            let st = f.submit_write(&op, data.as_slice()).unwrap().status().unwrap();
            assert_eq!(st.bytes, 64);
            let mut back = vec![0i32; 16];
            let op = AccessOp::read(
                Positioning::Explicit(0),
                Coordination::Independent,
                Synchronism::Blocking,
                0,
                16,
                &Datatype::INT,
            );
            let st = f.submit_read(&op, back.as_mut_slice()).unwrap();
            assert_eq!(st.bytes, 64);
            assert_eq!(back, data);
            // The wrapper is the same path.
            let mut again = vec![0i32; 16];
            f.read_at(0, again.as_mut_slice(), 0, 16, &Datatype::INT).unwrap();
            assert_eq!(again, data);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn append_mode_rejects_explicit_access_and_appends_pointer_writes() {
        let path = tmp("append");
        std::fs::write(&path, vec![7u8; 16]).unwrap();
        threads::run(1, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::APPEND, Info::null()).unwrap();
            let mut b = vec![0u8; 4];
            let e = f.read_at(0, b.as_mut_slice(), 0, 4, &Datatype::BYTE).unwrap_err();
            assert_eq!(e.class, ErrorClass::UnsupportedOperation);
            let e = f.write_at(0, b.as_slice(), 0, 4, &Datatype::BYTE).unwrap_err();
            assert_eq!(e.class, ErrorClass::UnsupportedOperation);
            // Both file pointers start at EOF (§7.2.2.1), so pointer
            // writes append instead of overwriting the head.
            assert_eq!(f.get_position().unwrap(), 16);
            assert_eq!(f.get_position_shared().unwrap(), 16);
            f.write(vec![9u8; 4].as_slice(), 0, 4, &Datatype::BYTE).unwrap();
            assert_eq!(f.get_position().unwrap(), 20);
            f.close().unwrap();
        });
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(raw.len(), 20, "pointer write must land at EOF");
        assert!(raw[..16].iter().all(|&v| v == 7), "existing data must survive APPEND writes");
        assert!(raw[16..].iter().all(|&v| v == 9));
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn sequential_mode_rejects_mixed_positioning() {
        let path = tmp("seq");
        std::fs::write(&path, vec![9u8; 64]).unwrap();
        threads::run(1, |c| {
            let f =
                File::open(c, &path, amode::RDONLY | amode::SEQUENTIAL, Info::null()).unwrap();
            let mut b = vec![0u8; 8];
            let e = f.read_at(0, b.as_mut_slice(), 0, 8, &Datatype::BYTE).unwrap_err();
            assert_eq!(e.class, ErrorClass::UnsupportedOperation);
            let e = f.read(b.as_mut_slice(), 0, 8, &Datatype::BYTE).unwrap_err();
            assert_eq!(e.class, ErrorClass::UnsupportedOperation);
            // Shared-pointer access is the sequential mode's one path.
            let st = f.read_shared(b.as_mut_slice(), 0, 8, &Datatype::BYTE).unwrap();
            assert_eq!(st.bytes, 8);
            assert!(b.iter().all(|&v| v == 9));
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn per_op_cache_bypass_leaves_counters_untouched() {
        let path = tmp("cache-bypass");
        threads::run(1, |c| {
            let info = Info::from([("jpio_cache", "enable")]);
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, info).unwrap();
            let cache_traffic = |f: &File| {
                let report = f.stats();
                ["cache_hit_bytes", "cache_miss_bytes", "write_behind_flush_bytes", "rmw_cycles"]
                    .iter()
                    .map(|k| report.counter(k).sum)
                    .sum::<u64>()
            };
            let bypass = Info::from([("jpio_cache", "disable")]);
            let data: Vec<u8> = (0..128u32).map(|v| v as u8).collect();
            let wop = AccessOp::write(
                Positioning::Explicit(0),
                Coordination::Independent,
                Synchronism::Blocking,
                0,
                data.len(),
                &Datatype::BYTE,
            );
            f.submit_write_with(&wop, data.as_slice(), Some(&bypass)).unwrap();
            let mut back = vec![0u8; data.len()];
            let rop = AccessOp::read(
                Positioning::Explicit(0),
                Coordination::Independent,
                Synchronism::Blocking,
                0,
                data.len(),
                &Datatype::BYTE,
            );
            f.submit_read_with(&rop, back.as_mut_slice(), Some(&bypass)).unwrap();
            assert_eq!(back, data);
            assert_eq!(
                cache_traffic(&f),
                0,
                "jpio_cache=disable overlay must keep the submission off the page cache"
            );
            // Control: the same read without the overlay runs through the
            // cache, so the bypass above was a choice, not a dead cache.
            f.submit_read(&rop, back.as_mut_slice()).unwrap();
            assert!(cache_traffic(&f) > 0, "handle cache never engaged; bypass test is vacuous");
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
        let _ = std::fs::remove_file(format!("{path}.jpio-cache-lease"));
    }

    #[test]
    fn view_overlay_requires_explicit_positioning() {
        let path = tmp("overlay-pos");
        threads::run(1, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let overlay = Arc::new(FileView::default());
            let op = AccessOp::write(
                Positioning::Individual,
                Coordination::Independent,
                Synchronism::Blocking,
                0,
                4,
                &Datatype::BYTE,
            );
            let e = f
                .submit_write_overlay(&op, [0u8; 4].as_slice(), Some(overlay), None)
                .unwrap_err();
            assert_eq!(e.class, ErrorClass::Arg);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn submission_accessors_reject_mismatches() {
        assert!(Submission::Begun.status().is_err());
        assert!(Submission::Done(Status::of_bytes(1)).request().is_err());
        assert!(Submission::Done(Status::of_bytes(1)).begun().is_err());
        assert_eq!(Submission::Done(Status::of_bytes(9)).status().unwrap().bytes, 9);
        assert!(Submission::Begun.begun().is_ok());
    }
}
