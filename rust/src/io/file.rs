//! The `mpj.File` class (§3.5.1): file manipulation, views, consistency.
//!
//! "We note that the mpj.File class used in the method signatures is not
//! to be confused with java.io.File" — nor with `std::fs::File` here.
//! `File::open` is a collective over an intracommunicator; every rank
//! holds its own handle onto the same shared file. Data-access routines
//! live in the sibling modules (`access`, `collective`, `shared`,
//! `split`) as `impl File` blocks.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::comm::datatype::{Datatype, Offset};
use crate::comm::{Comm, Group};
use crate::io::datarep::DataRep;
use crate::io::errors::{
    err_amode, err_arg, err_file, err_not_same, err_read_only, Result,
};
use crate::io::hints::{keys, Info};
use crate::io::schedule::PlanCache;
use crate::io::stats::{Counter, FileStats, PlanCacheStats, StatsReport};
use crate::io::view::FileView;
use crate::storage::layout::Redundancy;
use crate::storage::local::LocalBackend;
use crate::storage::nfs::NfsBackend;
use crate::storage::san::SanBackend;
use crate::storage::striped::StripedBackend;
use crate::storage::{Backend, OpenOptions, StorageFile};
use crate::strategy::{self, AccessStrategy};

/// File access modes (`MPJ.MODE_*`, §7.2.2.1). Combine with `|`.
pub mod amode {
    /// Create the file if it does not exist.
    pub const CREATE: u32 = 0x001;
    /// Read-only access.
    pub const RDONLY: u32 = 0x002;
    /// Write-only access.
    pub const WRONLY: u32 = 0x004;
    /// Read/write access.
    pub const RDWR: u32 = 0x008;
    /// Delete the file when it is closed.
    pub const DELETE_ON_CLOSE: u32 = 0x010;
    /// The file is not opened concurrently elsewhere.
    pub const UNIQUE_OPEN: u32 = 0x020;
    /// Fail if the file exists.
    pub const EXCL: u32 = 0x040;
    /// All writes append. Explicit-offset data access raises
    /// `MPI_ERR_UNSUPPORTED_OPERATION`
    /// ([`AccessOp::validate`](crate::io::op::AccessOp::validate)).
    pub const APPEND: u32 = 0x080;
    /// The file will be accessed sequentially: only shared-pointer data
    /// access is permitted — explicit-offset and individual-pointer
    /// (mixed-positioning) access raises `MPI_ERR_UNSUPPORTED_OPERATION`
    /// ([`AccessOp::validate`](crate::io::op::AccessOp::validate)).
    pub const SEQUENTIAL: u32 = 0x100;
}

/// Seek update modes (`MPJ.SEEK_*`, §7.2.4.3).
pub mod seek {
    /// Set the pointer to `offset`.
    pub const SET: i32 = 0;
    /// Set the pointer to current + `offset`.
    pub const CUR: i32 = 1;
    /// Set the pointer to end-of-file + `offset`.
    pub const END: i32 = 2;
}

/// Split-collective state (at most one active per handle, §7.2.4.5).
pub(crate) enum SplitPending {
    /// A pending collective read; payload carried back at `*End`.
    Read { kind: &'static str, req: crate::io::engine::Request<Vec<u8>> },
    /// A pending collective write.
    Write { kind: &'static str, req: crate::io::engine::Request<()> },
}

/// An open parallel file (`mpj.File`).
pub struct File<'c> {
    pub(crate) comm: &'c dyn Comm,
    pub(crate) storage: Arc<dyn StorageFile>,
    pub(crate) backend: Arc<dyn Backend>,
    pub(crate) path: String,
    pub(crate) amode: u32,
    pub(crate) info: Mutex<Info>,
    pub(crate) view: Mutex<Arc<FileView>>,
    /// Individual file pointer, in etype units relative to the view.
    pub(crate) indiv_ptr: Mutex<i64>,
    pub(crate) atomic: AtomicBool,
    pub(crate) strategy: Mutex<Arc<dyn AccessStrategy>>,
    /// Sidecar path holding the shared file pointer.
    pub(crate) sfp_path: String,
    pub(crate) split: Mutex<Option<SplitPending>>,
    /// Compiled-plan cache shared by every access cell (see
    /// [`crate::io::schedule`]): repeated same-shape accesses reuse the
    /// compiled `IoPlan` instead of re-flattening the view.
    pub(crate) plan_cache: PlanCache,
    /// Darshan-style per-rank instrumentation record
    /// ([`crate::io::stats`]); counters always on, timers/tracing gated
    /// on the `jpio_stats` hint.
    pub(crate) stats: Arc<FileStats>,
    /// Client-side page cache ([`crate::io::cache`]), built when
    /// `jpio_cache = enable`; `None` keeps the access path byte-identical
    /// to the uncached library.
    pub(crate) cache: Option<Arc<crate::io::cache::PageCache>>,
    /// The collectively reduced stats report, filled at close when
    /// `jpio_stats` is set; [`File::stats`] serves it afterwards.
    pub(crate) reduced_stats: Mutex<Option<StatsReport>>,
    /// Round-robin lane cursor for `jpio_progress_threads > 1`: the k-th
    /// lane-bound collective on this handle runs on lane `k % nlanes`.
    /// MPI requires every rank to issue collectives in the same order, so
    /// the cursors agree across ranks and matched collectives always land
    /// on the same lane everywhere.
    pub(crate) lane_seq: AtomicUsize,
    /// Cross-lane storage-phase sequencer
    /// ([`OpSequencer`](crate::io::engine::OpSequencer)): exchanges of
    /// lane-bound collectives pipeline freely across lanes while their
    /// storage phases run in operation issue order.
    pub(crate) lane_order: Arc<crate::io::engine::OpSequencer>,
    pub(crate) closed: AtomicBool,
}

/// Resolve the backend named by the info hints.
///
/// `jpio_backend = striped` builds a [`StripedBackend`] from the ROMIO
/// striping hints: `striping_factor` servers (default 4) of
/// `striping_unit` bytes (default 64 KiB), each server running the
/// `jpio_stripe_backend` child kind (default `local`) at the
/// `jpio_backend_profile` cost profile, with `jpio_stripe_redundancy`
/// replica/parity stripes (default `none`).
pub fn backend_from_info(info: &Info) -> Result<Arc<dyn Backend>> {
    let profile = info.get(keys::BACKEND_PROFILE).unwrap_or("instant");
    let kind = info.get(keys::BACKEND).unwrap_or("local");
    if kind == "striped" {
        let factor = info.get_usize(keys::STRIPING_FACTOR).unwrap_or(4);
        let unit = info.get_usize(keys::STRIPING_UNIT).unwrap_or(64 << 10) as u64;
        let child_kind = info.get(keys::STRIPE_CHILD_BACKEND).unwrap_or("local");
        if child_kind == "striped" {
            return Err(err_arg("jpio_stripe_backend cannot itself be striped"));
        }
        // Malformed redundancy values are ignored (MPI hint semantics);
        // a well-formed mode the factor cannot host errors below.
        let redundancy = info
            .get(keys::STRIPE_REDUNDANCY)
            .and_then(Redundancy::parse)
            .unwrap_or(Redundancy::None);
        let child_info = Info::null()
            .with(keys::BACKEND, child_kind)
            .with(keys::BACKEND_PROFILE, profile);
        let mut children = Vec::with_capacity(factor);
        for _ in 0..factor {
            children.push(backend_from_info(&child_info)?);
        }
        return Ok(Arc::new(StripedBackend::with_redundancy(children, unit, redundancy)?));
    }
    match (kind, profile) {
        ("local", "instant") => Ok(Arc::new(LocalBackend::instant())),
        ("local", "barq") => Ok(Arc::new(LocalBackend::barq())),
        ("nfs", "instant") => Ok(Arc::new(NfsBackend::instant())),
        ("nfs", "barq") => Ok(Arc::new(NfsBackend::barq())),
        ("nfs", "rcms") => Ok(Arc::new(NfsBackend::rcms())),
        ("san", "instant") => Ok(Arc::new(SanBackend::instant())),
        ("san", "rcms") => Ok(Arc::new(SanBackend::rcms())),
        (k, p) => Err(err_arg(format!("unknown backend/profile {k:?}/{p:?}"))),
    }
}

impl<'c> File<'c> {
    // ------------------------------------------------------------------
    // §7.2.2 File manipulation
    // ------------------------------------------------------------------

    /// Open a file collectively (`MPI_FILE_OPEN`). All ranks of `comm`
    /// must pass identical `filename` and `amode` (checked; violations
    /// raise `MPI_ERR_NOT_SAME` per §7.2.6.4).
    pub fn open(
        comm: &'c dyn Comm,
        filename: &str,
        mode: u32,
        info: Info,
    ) -> Result<File<'c>> {
        let backend = backend_from_info(&info)?;
        Self::open_with_backend(comm, filename, mode, info, backend)
    }

    /// [`File::open`] with an explicit storage backend (the bench harness
    /// path; `Info` hints can only name the built-in profiles).
    pub fn open_with_backend(
        comm: &'c dyn Comm,
        filename: &str,
        mode: u32,
        info: Info,
        backend: Arc<dyn Backend>,
    ) -> Result<File<'c>> {
        validate_amode(mode)?;
        // Collective argument check: every rank must agree on
        // (filename, amode).
        let mut sig = mode.to_le_bytes().to_vec();
        sig.extend_from_slice(filename.as_bytes());
        let all = comm.allgather(&sig);
        if all.iter().any(|s| *s != sig) {
            return Err(err_not_same("fileOpen: filename/amode differ across ranks"));
        }

        let opts = OpenOptions {
            read: mode & (amode::RDONLY | amode::RDWR) != 0,
            write: mode & (amode::WRONLY | amode::RDWR) != 0,
            create: mode & amode::CREATE != 0,
            excl: mode & amode::EXCL != 0,
            truncate: false,
        };
        // Rank 0 performs the create (and the EXCL check) so EXCL races
        // between ranks of one open cannot trip each other; the rest open
        // without CREATE after the barrier. The success flag travels in a
        // named buffer on *both* sides — the broadcast mutates its
        // argument, so handing it a discarded temporary would throw away
        // the flag the collective exists to agree on (regression test:
        // `collective_open_failure_reports_file_error_on_all_ranks`).
        let sfp_path = format!("{filename}.jpio-sfp");
        let storage = if comm.rank() == 0 {
            let st = backend.open(filename, opts);
            // Initialize the shared-file-pointer sidecar. In MODE_APPEND
            // the shared pointer starts at EOF (§7.2.2.1 "all file
            // pointers are set to the end of file"); the default view's
            // etype is BYTE, so EOF in etypes is the byte size.
            if let Ok(f) = &st {
                if mode & amode::APPEND != 0 {
                    let eof = f.size().unwrap_or(0) as i64;
                    let _ = std::fs::write(&sfp_path, eof.to_le_bytes());
                } else if !std::path::Path::new(&sfp_path).exists() {
                    let _ = std::fs::write(&sfp_path, 0u64.to_le_bytes());
                }
            }
            let mut flag = (st.is_ok() as i64).to_le_bytes().to_vec();
            comm.bcast(0, &mut flag);
            comm.barrier();
            st?
        } else {
            let mut flag = vec![0u8; 8];
            comm.bcast(0, &mut flag);
            let rank0_ok = i64::from_le_bytes(flag[..8].try_into().unwrap()) == 1;
            comm.barrier();
            if !rank0_ok {
                return Err(err_file("fileOpen failed at rank 0"));
            }
            let mut opts2 = opts;
            opts2.create = false;
            opts2.excl = false;
            backend.open(filename, opts2)?
        };

        let strategy_name = info.get(keys::ACCESS_STYLE).unwrap_or("view_buffer");
        let strategy: Arc<dyn AccessStrategy> = Arc::from(strategy::by_name(strategy_name)?);
        // MODE_APPEND: the individual pointer also starts at EOF, so
        // pointer-positioned writes append instead of overwriting the
        // head (explicit-offset access is rejected outright by
        // `AccessOp::validate`).
        let indiv_init =
            if mode & amode::APPEND != 0 { storage.size().unwrap_or(0) as i64 } else { 0 };
        // Elastic membership (DESIGN.md §1c): `jpio_rebuild = start`
        // kicks off a background rebuild of a replaced/blank stripe
        // server on the maintenance lane. One driver suffices — the
        // rebuild cursor lives in shared on-disk state — so only rank 0
        // triggers. Backends without membership tracking ignore the
        // hint, and per MPI hint semantics a failed kick-off does not
        // fail the open (the driver reports stalls as advisories).
        if comm.rank() == 0 && info.get(keys::REBUILD) == Some("start") {
            let throttle = info.get_usize(keys::REBUILD_THROTTLE).map(|v| v as u64);
            let _ = storage.start_rebuild(throttle);
        }
        let stats = FileStats::from_info(&info, comm.rank());
        let cache = crate::io::cache::PageCache::from_info(
            &info,
            filename,
            storage.clone(),
            stats.clone(),
            comm.rank(),
        );
        Ok(File {
            comm,
            storage,
            backend,
            path: filename.to_string(),
            amode: mode,
            info: Mutex::new(info),
            view: Mutex::new(Arc::new(FileView::default())),
            indiv_ptr: Mutex::new(indiv_init),
            atomic: AtomicBool::new(false),
            strategy: Mutex::new(strategy),
            sfp_path,
            split: Mutex::new(None),
            plan_cache: PlanCache::new(),
            stats,
            cache,
            reduced_stats: Mutex::new(None),
            lane_seq: AtomicUsize::new(0),
            lane_order: Arc::new(crate::io::engine::OpSequencer::new()),
            closed: AtomicBool::new(false),
        })
    }

    /// Close the file collectively (`MPI_FILE_CLOSE`). Completes pending
    /// split-collective work, synchronizes, and honours
    /// `MODE_DELETE_ON_CLOSE`.
    pub fn close(&self) -> Result<()> {
        self.check_open()?;
        // A pending split collective at close is erroneous in MPI; we
        // complete it defensively instead of leaking the worker.
        if let Some(p) = self.split.lock().unwrap().take() {
            match p {
                SplitPending::Read { req, .. } => {
                    let _ = req.wait();
                }
                SplitPending::Write { req, .. } => {
                    let _ = req.wait();
                }
            }
        }
        // Close is a coherence point (§7.2.6.1): drain the write-behind
        // lane and publish every dirty page — before the stats reduction
        // so the flush counters land in the reduced report.
        if let Some(cache) = &self.cache {
            cache.sync_point()?;
        }
        // Darshan-style shared-file record: reduce the per-rank stats
        // collectively while the handle is still open. `jpio_stats` is a
        // collective hint, so every rank reaches this allgather alike.
        if self.stats.enabled() {
            self.reduce_stats()?;
        }
        self.closed.store(true, Ordering::SeqCst);
        self.comm.barrier();
        if self.amode & amode::DELETE_ON_CLOSE != 0 && self.comm.rank() == 0 {
            self.backend.delete(&self.path)?;
            let _ = std::fs::remove_file(&self.sfp_path);
            let _ = std::fs::remove_file(format!("{}.jpio-cache-lease", self.path));
        }
        self.comm.barrier();
        Ok(())
    }

    /// Delete a file by name (`MPI_FILE_DELETE`, §7.2.2.3).
    pub fn delete(filename: &str, info: &Info) -> Result<()> {
        let backend = backend_from_info(info)?;
        backend.delete(filename)?;
        let _ = std::fs::remove_file(format!("{filename}.jpio-sfp"));
        let _ = std::fs::remove_file(format!("{filename}.jpio-cache-lease"));
        Ok(())
    }

    /// Resize the file (`MPI_FILE_SET_SIZE`, collective).
    pub fn set_size(&self, size: Offset) -> Result<()> {
        self.check_open()?;
        self.check_writable()?;
        if size < 0 {
            return Err(err_arg(format!("setSize: negative size {size}")));
        }
        // Size changes are a coherence point: resident pages past the
        // new EOF (and the cached logical size) would go stale.
        if let Some(cache) = &self.cache {
            cache.flush_and_invalidate()?;
        }
        if self.comm.rank() == 0 {
            self.storage.set_size(size as u64)?;
        }
        self.comm.barrier();
        Ok(())
    }

    /// Preallocate storage (`MPI_FILE_PREALLOCATE`, collective).
    pub fn preallocate(&self, size: Offset) -> Result<()> {
        self.check_open()?;
        self.check_writable()?;
        if size < 0 {
            return Err(err_arg(format!("preallocate: negative size {size}")));
        }
        if let Some(cache) = &self.cache {
            cache.flush_and_invalidate()?;
        }
        if self.comm.rank() == 0 {
            self.storage.preallocate(size as u64)?;
        }
        self.comm.barrier();
        Ok(())
    }

    /// Current file size in bytes (`MPI_FILE_GET_SIZE`). With the page
    /// cache enabled this is the cached logical size — the storage EOF
    /// advanced by this handle's unflushed write-behind data.
    pub fn get_size(&self) -> Result<Offset> {
        self.check_open()?;
        if let Some(cache) = &self.cache {
            return Ok(cache.logical_size() as Offset);
        }
        Ok(self.storage.size()? as Offset)
    }

    /// The group of ranks that opened the file (`MPI_FILE_GET_GROUP`).
    pub fn get_group(&self) -> Group {
        self.comm.group()
    }

    /// The access mode of the open (`MPI_FILE_GET_AMODE`).
    pub fn get_amode(&self) -> u32 {
        self.amode
    }

    /// Set info hints (`MPI_FILE_SET_INFO`, collective). Strategy and
    /// buffer-size hints take effect immediately.
    pub fn set_info(&self, info: &Info) -> Result<()> {
        self.check_open()?;
        let mut cur = self.info.lock().unwrap();
        cur.merge(info);
        if let Some(style) = info.get(keys::ACCESS_STYLE) {
            *self.strategy.lock().unwrap() = Arc::from(strategy::by_name(style)?);
        }
        Ok(())
    }

    /// Get the current info hints (`MPI_FILE_GET_INFO`).
    pub fn get_info(&self) -> Info {
        self.info.lock().unwrap().clone()
    }

    // ------------------------------------------------------------------
    // §7.2.3 File views
    // ------------------------------------------------------------------

    /// Change the view (`MPI_FILE_SET_VIEW`, collective). Resets both the
    /// individual and (collectively) the shared file pointer to zero.
    pub fn set_view(
        &self,
        disp: Offset,
        etype: &Datatype,
        filetype: &Datatype,
        datarep: &str,
        info: &Info,
    ) -> Result<()> {
        self.check_open()?;
        let rep = DataRep::resolve(datarep)?;
        let view = FileView::new(disp, etype.clone(), filetype.clone(), rep)?;
        *self.view.lock().unwrap() = Arc::new(view);
        *self.indiv_ptr.lock().unwrap() = 0;
        self.set_info(info)?;
        // Collective: reset the shared pointer once.
        self.comm.barrier();
        if self.comm.rank() == 0 {
            self.write_sfp(0)?;
        }
        self.comm.barrier();
        Ok(())
    }

    /// Query the view (`MPI_FILE_GET_VIEW`): `(disp, etype, filetype,
    /// datarep)`. (The Java binding smuggles `datarep` out through a
    /// `StringBuffer`; Rust just returns it.)
    pub fn get_view(&self) -> (Offset, Datatype, Datatype, String) {
        let v = self.view.lock().unwrap();
        (v.disp, v.etype.clone(), v.filetype.clone(), v.datarep.name().to_string())
    }

    // ------------------------------------------------------------------
    // §7.2.6.1 Consistency
    // ------------------------------------------------------------------

    /// Enable/disable atomic mode (`MPI_FILE_SET_ATOMICITY`, collective).
    pub fn set_atomicity(&self, flag: bool) -> Result<()> {
        self.check_open()?;
        // Collective agreement check.
        let all = self.comm.allgather(&[flag as u8]);
        if all.iter().any(|v| v[0] != flag as u8) {
            return Err(err_not_same("setAtomicity: flag differs across ranks"));
        }
        // Entering atomic mode is a coherence point: atomic operations
        // serialize under the whole-file lock, and data resident in this
        // handle's pages would hide behind it.
        if flag {
            if let Some(cache) = &self.cache {
                cache.flush_and_invalidate()?;
            }
        }
        self.atomic.store(flag, Ordering::SeqCst);
        Ok(())
    }

    /// Query atomic mode (`MPI_FILE_GET_ATOMICITY`).
    pub fn get_atomicity(&self) -> bool {
        self.atomic.load(Ordering::SeqCst)
    }

    /// Flush this process's writes to storage and make other processes'
    /// synced updates visible (`MPI_FILE_SYNC`, collective). With the
    /// page cache enabled this is *the* coherence point: dirty pages
    /// flush, the write-behind lane drains, and the
    /// `<path>.jpio-cache-lease` protocol makes a writer's sync
    /// invalidate a reader's resident pages at the reader's own sync
    /// (the MPI writer-sync / barrier / reader-sync pattern).
    pub fn sync(&self) -> Result<()> {
        self.check_open()?;
        if let Some(cache) = &self.cache {
            cache.sync_point()?;
        }
        self.storage.sync()
    }

    /// Drain pending degraded-mode advisories (jpio extension): each is
    /// an [`ErrorClass::Degraded`](crate::io::errors::ErrorClass) error
    /// recording an operation that *succeeded* by reconstructing data
    /// around a failed stripe server (`jpio_stripe_redundancy`
    /// replica/parity stripes). Empty on healthy files and on backends
    /// without redundancy. Local to this rank's handle — on collective
    /// operations the rank that performed the degraded storage access
    /// (the aggregator) observes the advisory. Drained advisories are
    /// tallied into the `degraded_advisories` stats counter — the
    /// backend's `degraded_reconstructed_reads` / `parity_rmw_cycles`
    /// counters in [`File::stats`] persist even after the drain.
    pub fn take_advisories(&self) -> Vec<crate::io::errors::IoError> {
        let advisories = self.storage.take_advisories();
        self.stats.add(Counter::DegradedAdvisories, advisories.len() as u64);
        advisories
    }

    /// Plan-cache counters (jpio extension): a hit means a repeated
    /// same-shape access reused its compiled
    /// [`IoPlan`](crate::io::plan::IoPlan) at the scheduler instead of
    /// re-flattening the view.
    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        self.plan_cache.stats()
    }

    // ------------------------------------------------------------------
    // Internal helpers shared by the data-access modules
    // ------------------------------------------------------------------

    pub(crate) fn check_open(&self) -> Result<()> {
        if self.closed.load(Ordering::SeqCst) {
            return Err(err_file(format!("{}: file is closed", self.path)));
        }
        Ok(())
    }

    pub(crate) fn check_writable(&self) -> Result<()> {
        if self.amode & (amode::WRONLY | amode::RDWR) == 0 {
            return Err(err_read_only(format!("{}: opened RDONLY", self.path)));
        }
        Ok(())
    }

    pub(crate) fn check_readable(&self) -> Result<()> {
        if self.amode & (amode::RDONLY | amode::RDWR) == 0 {
            return Err(crate::io::errors::err_amode(format!(
                "{}: opened WRONLY",
                self.path
            )));
        }
        Ok(())
    }

    /// Snapshot the current view.
    pub(crate) fn view_snapshot(&self) -> Arc<FileView> {
        self.view.lock().unwrap().clone()
    }

    /// Snapshot the current strategy.
    pub(crate) fn strategy_snapshot(&self) -> Arc<dyn AccessStrategy> {
        self.strategy.lock().unwrap().clone()
    }

    /// Read the shared file pointer (etype units) from the sidecar.
    pub(crate) fn read_sfp(&self) -> Result<i64> {
        let bytes = std::fs::read(&self.sfp_path)
            .map_err(|e| crate::io::errors::IoError::from_os(e, "shared pointer read"))?;
        Ok(i64::from_le_bytes(bytes[..8].try_into().unwrap()))
    }

    /// Overwrite the shared file pointer.
    pub(crate) fn write_sfp(&self, value: i64) -> Result<()> {
        std::fs::write(&self.sfp_path, value.to_le_bytes())
            .map_err(|e| crate::io::errors::IoError::from_os(e, "shared pointer write"))
    }
}

impl Drop for File<'_> {
    fn drop(&mut self) {
        // Non-collective safety net; proper shutdown is close().
        if let Some(p) = self.split.get_mut().unwrap().take() {
            match p {
                SplitPending::Read { req, .. } => drop(req.wait()),
                SplitPending::Write { req, .. } => drop(req.wait()),
            }
        }
        // Best-effort write-behind drain: data in dirty pages must not
        // die with the handle. Errors have nowhere to go from drop.
        if !self.closed.load(Ordering::SeqCst) {
            if let Some(cache) = &self.cache {
                let _ = cache.sync_point();
            }
        }
    }
}

/// Validate an amode combination (§7.2.2.1).
pub fn validate_amode(mode: u32) -> Result<()> {
    let access = mode & (amode::RDONLY | amode::WRONLY | amode::RDWR);
    let n_access = access.count_ones();
    if n_access != 1 {
        return Err(err_amode(format!(
            "exactly one of RDONLY|WRONLY|RDWR required (got {n_access})"
        )));
    }
    if mode & amode::RDONLY != 0 && mode & (amode::CREATE | amode::EXCL) != 0 {
        return Err(err_amode("RDONLY cannot be combined with CREATE or EXCL"));
    }
    if mode & amode::RDWR != 0 && mode & amode::SEQUENTIAL != 0 {
        return Err(err_amode("SEQUENTIAL cannot be combined with RDWR"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::threads;
    use crate::comm::Comm;
    use crate::io::errors::ErrorClass;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-file-{}-{name}", std::process::id())
    }

    #[test]
    fn amode_validation() {
        assert!(validate_amode(amode::RDWR | amode::CREATE).is_ok());
        assert!(validate_amode(amode::RDONLY).is_ok());
        assert_eq!(validate_amode(0).unwrap_err().class, ErrorClass::Amode);
        assert_eq!(
            validate_amode(amode::RDONLY | amode::RDWR).unwrap_err().class,
            ErrorClass::Amode
        );
        assert_eq!(
            validate_amode(amode::RDONLY | amode::CREATE).unwrap_err().class,
            ErrorClass::Amode
        );
        assert_eq!(
            validate_amode(amode::RDWR | amode::SEQUENTIAL).unwrap_err().class,
            ErrorClass::Amode
        );
    }

    #[test]
    fn striped_backend_resolves_from_hints() {
        let info = Info::from([
            (keys::BACKEND, "striped"),
            (keys::STRIPING_FACTOR, "3"),
            (keys::STRIPING_UNIT, "128"),
        ]);
        let b = backend_from_info(&info).unwrap();
        assert_eq!(b.name(), "striped");
        // Nested striping via hints is rejected.
        let bad = Info::from([
            (keys::BACKEND, "striped"),
            (keys::STRIPE_CHILD_BACKEND, "striped"),
        ]);
        assert_eq!(backend_from_info(&bad).map(|_| ()).unwrap_err().class, ErrorClass::Arg);
    }

    #[test]
    fn stripe_redundancy_resolves_from_hints() {
        // replica:2 over 2 servers: a write through the hint-resolved
        // backend must materialize the replica objects.
        let info = Info::from([
            (keys::BACKEND, "striped"),
            (keys::STRIPING_FACTOR, "2"),
            (keys::STRIPING_UNIT, "8"),
            (keys::STRIPE_REDUNDANCY, "replica:2"),
        ]);
        let b = backend_from_info(&info).unwrap();
        let path = tmp("redhint");
        let f = b.open(&path, crate::storage::OpenOptions::rw_create()).unwrap();
        f.write_at(0, &[1u8; 32]).unwrap();
        drop(f);
        for s in 0..2 {
            assert!(
                std::path::Path::new(&StripedBackend::replica_object_path(&path, s, 2, 1))
                    .exists(),
                "replica object for server {s} missing: hint not applied"
            );
        }
        b.delete(&path).unwrap();
        // Malformed values are ignored per MPI hint semantics.
        let ignored = Info::from([
            (keys::BACKEND, "striped"),
            (keys::STRIPE_REDUNDANCY, "raid6"),
        ]);
        assert!(backend_from_info(&ignored).is_ok());
        // Well-formed but unhostable: more copies than servers.
        let bad = Info::from([
            (keys::BACKEND, "striped"),
            (keys::STRIPING_FACTOR, "4"),
            (keys::STRIPE_REDUNDANCY, "replica:9"),
        ]);
        assert_eq!(backend_from_info(&bad).map(|_| ()).unwrap_err().class, ErrorClass::Arg);
    }

    #[test]
    fn collective_open_close_lifecycle() {
        let path = tmp("lifecycle");
        threads::run(4, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            assert_eq!(f.get_amode(), amode::RDWR | amode::CREATE);
            assert_eq!(f.get_group().size(), 4);
            f.close().unwrap();
            // Use-after-close is MPI_ERR_FILE.
            assert_eq!(f.get_size().unwrap_err().class, ErrorClass::File);
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn delete_on_close_removes_the_file() {
        let path = tmp("doc");
        threads::run(2, |c| {
            let f = File::open(
                c,
                &path,
                amode::RDWR | amode::CREATE | amode::DELETE_ON_CLOSE,
                Info::null(),
            )
            .unwrap();
            f.close().unwrap();
        });
        assert!(!std::path::Path::new(&path).exists());
    }

    #[test]
    fn mismatched_amode_across_ranks_is_not_same() {
        let path = tmp("mismatch");
        threads::run(2, |c| {
            let mode = if c.rank() == 0 {
                amode::RDWR | amode::CREATE
            } else {
                amode::RDONLY
            };
            let err = File::open(c, &path, mode, Info::null()).map(|_| ()).unwrap_err();
            assert_eq!(err.class, ErrorClass::NotSame);
        });
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn size_preallocate_collective() {
        let path = tmp("size");
        threads::run(3, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            f.set_size(8192).unwrap();
            assert_eq!(f.get_size().unwrap(), 8192);
            f.preallocate(16384).unwrap();
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn rdonly_rejects_resize() {
        let path = tmp("ro");
        std::fs::write(&path, b"existing").unwrap();
        threads::run(2, |c| {
            let f = File::open(c, &path, amode::RDONLY, Info::null()).unwrap();
            assert_eq!(f.set_size(10).unwrap_err().class, ErrorClass::ReadOnly);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn info_updates_swap_strategy() {
        let path = tmp("info");
        threads::run(1, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            assert_eq!(f.strategy_snapshot().name(), "view_buffer");
            f.set_info(&Info::from([(keys::ACCESS_STYLE, "mapped")])).unwrap();
            assert_eq!(f.strategy_snapshot().name(), "mapped");
            assert_eq!(f.get_info().get(keys::ACCESS_STYLE), Some("mapped"));
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn set_view_resets_pointers_and_validates() {
        let path = tmp("view");
        threads::run(2, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            f.set_view(64, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let (disp, etype, _ft, rep) = f.get_view();
            assert_eq!(disp, 64);
            assert_eq!(etype, Datatype::INT);
            assert_eq!(rep, "native");
            // Invalid datarep.
            let err = f
                .set_view(0, &Datatype::INT, &Datatype::INT, "klingon", &Info::null())
                .map(|_| ())
                .unwrap_err();
            assert_eq!(err.class, ErrorClass::UnsupportedDatarep);
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }

    #[test]
    fn atomicity_round_trip_and_collective_check() {
        let path = tmp("atomic");
        threads::run(3, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            assert!(!f.get_atomicity());
            f.set_atomicity(true).unwrap();
            assert!(f.get_atomicity());
            f.set_atomicity(false).unwrap();
            f.close().unwrap();
        });
        File::delete(&path, &Info::null()).unwrap();
    }
}
