//! Figure/table reporting: paper-style console tables + CSV files under
//! `results/` so every figure can be re-plotted.

use std::io::Write;

/// One series of a figure: e.g. "read, view_buffer" over thread counts.
#[derive(Clone, Debug)]
pub struct Series {
    /// Series label.
    pub label: String,
    /// (x, MB/s) points.
    pub points: Vec<(usize, f64)>,
}

/// A paper figure: titled set of series over a common x-axis.
#[derive(Debug)]
pub struct FigureReport {
    /// e.g. "Figure 4-3: parallel access to a shared file on local disk".
    pub title: String,
    /// x-axis label (threads / processes).
    pub x_label: String,
    /// All series.
    pub series: Vec<Series>,
}

impl FigureReport {
    /// New empty report.
    pub fn new(title: impl Into<String>, x_label: impl Into<String>) -> FigureReport {
        FigureReport { title: title.into(), x_label: x_label.into(), series: Vec::new() }
    }

    /// Append a series.
    pub fn push(&mut self, label: impl Into<String>, points: Vec<(usize, f64)>) {
        self.series.push(Series { label: label.into(), points });
    }

    /// Look up a point.
    pub fn value(&self, label: &str, x: usize) -> Option<f64> {
        self.series
            .iter()
            .find(|s| s.label == label)?
            .points
            .iter()
            .find(|&&(px, _)| px == x)
            .map(|&(_, v)| v)
    }

    /// Render the console table (rows = x values, columns = series).
    pub fn table(&self) -> String {
        let mut xs: Vec<usize> =
            self.series.iter().flat_map(|s| s.points.iter().map(|&(x, _)| x)).collect();
        xs.sort_unstable();
        xs.dedup();
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        out.push_str(&format!("{:>10}", self.x_label));
        for s in &self.series {
            out.push_str(&format!("  {:>18}", s.label));
        }
        out.push('\n');
        for x in xs {
            out.push_str(&format!("{x:>10}"));
            for s in &self.series {
                match s.points.iter().find(|&&(px, _)| px == x) {
                    Some(&(_, v)) => out.push_str(&format!("  {v:>13.1} MB/s")),
                    None => out.push_str(&format!("  {:>18}", "-")),
                }
            }
            out.push('\n');
        }
        out
    }

    /// Write `results/<file>.csv` (x, series...) and return its path.
    pub fn write_csv(&self, file_stem: &str) -> std::io::Result<String> {
        std::fs::create_dir_all("results")?;
        let path = format!("results/{file_stem}.csv");
        let mut f = std::fs::File::create(&path)?;
        write!(f, "{}", self.x_label)?;
        for s in &self.series {
            write!(f, ",{}", s.label)?;
        }
        writeln!(f)?;
        let mut xs: Vec<usize> =
            self.series.iter().flat_map(|s| s.points.iter().map(|&(x, _)| x)).collect();
        xs.sort_unstable();
        xs.dedup();
        for x in xs {
            write!(f, "{x}")?;
            for s in &self.series {
                match s.points.iter().find(|&&(px, _)| px == x) {
                    Some(&(_, v)) => write!(f, ",{v:.2}")?,
                    None => write!(f, ",")?,
                }
            }
            writeln!(f)?;
        }
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_and_lookup() {
        let mut r = FigureReport::new("Figure T", "threads");
        r.push("read", vec![(1, 100.0), (2, 180.0)]);
        r.push("write", vec![(1, 90.0)]);
        assert_eq!(r.value("read", 2), Some(180.0));
        assert_eq!(r.value("write", 2), None);
        let t = r.table();
        assert!(t.contains("Figure T"));
        assert!(t.contains("180.0"));
        assert!(t.contains('-'));
    }

    #[test]
    fn csv_writes() {
        let mut r = FigureReport::new("f", "x");
        r.push("a", vec![(1, 1.5)]);
        let path = r.write_csv("test-report-unit").unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("x,a"));
        assert!(body.contains("1,1.50"));
        std::fs::remove_file(path).unwrap();
    }
}
