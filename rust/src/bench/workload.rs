//! Workload generators for the figure benches.
//!
//! The paper's evaluation workload (§3.2): N workers share one file;
//! each reads/writes its disjoint partition. `partition` reproduces that
//! layout; `strided` builds the interleaved-view workload used by the
//! collective-I/O ablation.

use crate::testing::SplitMix64;

/// The byte range of `rank`'s partition of a `total`-byte shared file
/// split evenly over `n` workers (the paper's test layout).
pub fn partition(total: usize, n: usize, rank: usize) -> (u64, usize) {
    let base = total / n;
    let rem = total % n;
    let mine = base + usize::from(rank < rem);
    let start: usize = (0..rank).map(|r| base + usize::from(r < rem)).sum();
    (start as u64, mine)
}

/// Deterministic payload for a rank's partition (verifiable on re-read).
pub fn payload(rank: usize, len: usize) -> Vec<u8> {
    let mut rng = SplitMix64::new(0xB10C_0000 ^ rank as u64);
    let mut buf = vec![0u8; len];
    rng.fill_bytes(&mut buf);
    buf
}

/// Interleaved runs: rank's `chunk`-byte pieces every `n * chunk` bytes,
/// covering `total` bytes — the two-phase collective I/O stress shape.
pub fn strided(total: usize, n: usize, rank: usize, chunk: usize) -> Vec<(u64, usize)> {
    let frame = n * chunk;
    let mut out = Vec::new();
    let mut off = rank * chunk;
    while off + chunk <= total {
        out.push((off as u64, chunk));
        off += frame;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Config};

    #[test]
    fn partitions_tile_the_file_exactly() {
        forall(
            Config::default().cases(100),
            |r| (r.range(1, 1 << 20), r.range(1, 32)),
            |&(total, n)| {
                let mut cursor = 0u64;
                for rank in 0..n {
                    let (start, len) = partition(total, n, rank);
                    if start != cursor {
                        return false;
                    }
                    cursor += len as u64;
                }
                cursor == total as u64
            },
        );
    }

    #[test]
    fn payload_is_deterministic_and_rank_distinct() {
        assert_eq!(payload(3, 64), payload(3, 64));
        assert_ne!(payload(3, 64), payload(4, 64));
    }

    #[test]
    fn strided_runs_are_disjoint_across_ranks() {
        let total = 64 * 1024;
        let n = 4;
        let chunk = 256;
        let mut covered = vec![false; total];
        for rank in 0..n {
            for (off, len) in strided(total, n, rank, chunk) {
                for b in off as usize..off as usize + len {
                    assert!(!covered[b], "byte {b} covered twice");
                    covered[b] = true;
                }
            }
        }
        assert!(covered.iter().all(|&c| c)); // total divisible by frame
    }
}
