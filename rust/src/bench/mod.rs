//! Measurement harness — regenerates every table and figure of the
//! paper's evaluation chapter (no criterion offline; this is the
//! substitute documented in DESIGN.md §2).

pub mod harness;
pub mod report;
pub mod testbed;
pub mod workload;

pub use harness::{bench, BenchStats};
pub use report::{FigureReport, Series};
pub use testbed::Testbed;
