//! Timing harness: warmup + repetitions + robust statistics.

use std::time::{Duration, Instant};

/// Statistics of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Case label.
    pub label: String,
    /// Sorted repetition times.
    pub reps: Vec<Duration>,
    /// Payload bytes moved per repetition (0 if not a throughput bench).
    pub bytes: usize,
}

impl BenchStats {
    /// Median repetition time.
    pub fn median(&self) -> Duration {
        self.reps[self.reps.len() / 2]
    }

    /// Minimum repetition time.
    pub fn min(&self) -> Duration {
        self.reps[0]
    }

    /// 95th-percentile repetition time.
    pub fn p95(&self) -> Duration {
        let idx = ((self.reps.len() as f64) * 0.95).ceil() as usize - 1;
        self.reps[idx.min(self.reps.len() - 1)]
    }

    /// Mean repetition time.
    pub fn mean(&self) -> Duration {
        self.reps.iter().sum::<Duration>() / self.reps.len() as u32
    }

    /// Throughput in MB/s from the median time.
    pub fn mbs(&self) -> f64 {
        if self.bytes == 0 {
            return 0.0;
        }
        self.bytes as f64 / 1e6 / self.median().as_secs_f64()
    }
}

/// Run `f` `reps` times after `warmup` unmeasured runs; `bytes` is the
/// payload per repetition (for MB/s).
pub fn bench(
    label: impl Into<String>,
    warmup: usize,
    reps: usize,
    bytes: usize,
    mut f: impl FnMut(),
) -> BenchStats {
    assert!(reps > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let start = Instant::now();
        f();
        times.push(start.elapsed());
    }
    times.sort_unstable();
    BenchStats { label: label.into(), reps: times, bytes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered_and_sane() {
        let s = bench("sleepy", 1, 9, 1_000_000, || {
            std::thread::sleep(Duration::from_millis(1));
        });
        assert_eq!(s.reps.len(), 9);
        assert!(s.min() <= s.median() && s.median() <= s.p95());
        assert!(s.median() >= Duration::from_millis(1));
        // 1 MB in ~1ms ≈ 1000 MB/s; loose bounds for CI noise.
        let mbs = s.mbs();
        assert!(mbs > 50.0 && mbs < 1100.0, "mbs = {mbs}");
    }

    #[test]
    fn zero_bytes_has_zero_mbs() {
        let s = bench("x", 0, 3, 0, || {});
        assert_eq!(s.mbs(), 0.0);
    }
}
