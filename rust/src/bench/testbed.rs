//! Testbed descriptors — Tables 4-1 and 4-2 of the paper, printed at the
//! head of each figure bench so every result names its (simulated)
//! environment.

use std::fmt;

/// A cluster testbed description.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Testbed {
    /// Table 4-1: the Barq cluster (shared-memory machine + GigE/Myrinet
    /// cluster; local disk and NFS storage).
    Barq,
    /// Table 4-2: the RCMS/Afrit cluster (34 nodes, InfiniBand, SAN).
    Rcms,
}

impl Testbed {
    /// The paper's spec rows for this testbed.
    pub fn rows(&self) -> Vec<(&'static str, &'static str)> {
        match self {
            Testbed::Barq => vec![
                ("Cluster Name", "Barq Cluster (simulated)"),
                ("Brand", "Custom Built"),
                ("Total Processors", "36 Intel Xeon"),
                ("Total Nodes", "Nine"),
                ("Total Memory", "36 GB"),
                ("Operating System", "Open SuSE Linux 1.1"),
                ("Interconnects", "Myrinet and Gigabit Ethernet"),
            ],
            Testbed::Rcms => vec![
                ("Cluster Name", "RCMS Cluster (simulated)"),
                ("Brand", "HP ProLiant DL160se G6 / DL380 G6"),
                ("Total Processors", "272 Intel Xeon"),
                ("Total Nodes", "34"),
                ("Total Memory", "816 GB"),
                ("Operating System", "Redhat Enterprise Linux 5.5"),
                ("Interconnects", "InfiniBand, Gigabit Ethernet"),
                ("Storage", "SAN 22TB raw, FC switch with RAID controller"),
                ("GPU", "32 x NVidia Tesla S1070"),
            ],
        }
    }

    /// The paper table number.
    pub fn table_no(&self) -> &'static str {
        match self {
            Testbed::Barq => "Table 4-1",
            Testbed::Rcms => "Table 4-2",
        }
    }
}

impl fmt::Display for Testbed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{} — specification ({:?})", self.table_no(), self)?;
        for (k, v) in self.rows() {
            writeln!(f, "  {k:<20} {v}")?;
        }
        writeln!(
            f,
            "  note: simulated on one host; interconnect/storage behaviour per DESIGN.md §2"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render() {
        let b = Testbed::Barq.to_string();
        assert!(b.contains("Table 4-1"));
        assert!(b.contains("Myrinet"));
        let r = Testbed::Rcms.to_string();
        assert!(r.contains("Table 4-2"));
        assert!(r.contains("InfiniBand"));
        assert!(r.contains("SAN"));
    }
}
