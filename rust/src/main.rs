//! `jpio` — launcher + diagnostics CLI for the library.
//!
//! ```text
//! jpio routines                     # the routine matrix (Table 3-1/7-1 + MPI-3.1)
//! jpio routines --check             # verify the derived matrix: 56 unique
//!                                   # routines, every transfer wrapper
//!                                   # dispatches (exits nonzero on drift)
//! jpio testbed [--cluster rcms]     # Tables 4-1 / 4-2
//! jpio artifacts [--dir artifacts]  # load + list PJRT artifacts
//! jpio demo [--ranks 4] [--backend nfs] [--procs]
//!                                   # small shared-file write/read demo
//! jpio demo --backend striped [--servers 4] [--stripe-unit 64k]
//!                                   # ... on declustered striped storage
//! jpio stats [--ranks 4] [--procs] [--trace /tmp/trace.jsonl]
//!                                   # run an instrumented workload and render
//!                                   # the Darshan-style reduced stats report
//! jpio dataset <path>               # print a dataset container summary
//! jpio dataset --check              # structured-dataset self-test (define →
//!                                   # collective put/get → record append →
//!                                   # reopen; exits nonzero on failure)
//! jpio version
//! ```

use jpio::bench::Testbed;
use jpio::cli::Args;
use jpio::comm::datatype::Datatype;
use jpio::comm::{process, threads, Comm};
use jpio::dataset::{header, Dataset};
use jpio::io::{amode, File, Info};

fn main() {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("routines") => routines(&args),
        Some("testbed") => testbed(&args),
        Some("artifacts") => artifacts(&args),
        Some("demo") => demo(&args),
        Some("stats") => stats(&args),
        Some("dataset") => dataset(&args),
        Some("version") => println!("jpio {}", env!("CARGO_PKG_VERSION")),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown command {cmd:?}\n");
            }
            eprintln!(
                "usage: jpio <routines|testbed|artifacts|demo|stats|dataset|version> [--flags]\n\
                 see `cargo doc` and README.md for the library API"
            );
            std::process::exit(if other.is_some() { 2 } else { 0 });
        }
    }
}

fn routines(args: &Args) {
    println!("MPJ-IO data-access & manipulation routines (Table 3-1 / 7-1):");
    println!("{:<36} {:<36} status", "MPI routine", "jpio binding");
    for (mpi, rust) in jpio::io::routine_matrix() {
        println!("{mpi:<36} {rust:<36} implemented");
    }
    println!(
        "\n56/56 routines implemented: the 52-routine MPI-2.2 matrix plus the \
         MPI-3.1 nonblocking collectives (the paper's prototype had 19). The \
         34 transfer routines are derived from the AccessOp dimensions."
    );
    if args.has("check") {
        routines_check();
    }
}

/// `jpio routines --check`: fail (exit nonzero) if the derived matrix is
/// not 56 unique routines / 34 unique transfer cells, or if any public
/// wrapper fails to dispatch through the `AccessOp` core. The dispatch
/// sweep runs every one of the 34 transfer wrappers on a 2-rank world;
/// the match in [`dispatch_all_cells`] is the compile-time guarantee that
/// a wrapper exists for every derived cell.
fn routines_check() {
    let m = jpio::io::routine_matrix();
    let mut mpi: Vec<String> = m.iter().map(|(a, _)| a.clone()).collect();
    mpi.sort_unstable();
    mpi.dedup();
    let mut methods: Vec<String> = m.iter().map(|(_, b)| b.clone()).collect();
    methods.sort_unstable();
    methods.dedup();
    let cells = jpio::io::access_cells();
    if m.len() != 56 || mpi.len() != 56 || methods.len() != 56 || cells.len() != 34 {
        eprintln!(
            "routine matrix check: FAILED (routines={}, unique mpi={}, unique methods={}, \
             transfer cells={}; expected 56/56/56/34)",
            m.len(),
            mpi.len(),
            methods.len(),
            cells.len()
        );
        std::process::exit(1);
    }
    let path = format!("/tmp/jpio-routines-check-{}.dat", std::process::id());
    // A wrapper that panics or errors fails the rank thread, which
    // propagates out of threads::run and exits nonzero.
    threads::run(2, |c| dispatch_all_cells(c, &path));
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    println!(
        "routine matrix check: OK (56 routines, 34 derived transfer cells, every \
         wrapper dispatches through the AccessOp core)"
    );
}

/// Exercise all 34 transfer wrappers — one call per derived cell — on a
/// small shared file. Layout: ints, rank r owns [r*64, (r+1)*64).
fn dispatch_all_cells(c: &dyn Comm, path: &str) {
    use jpio::io::seek;
    let f = File::open(c, path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
    f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
    let r = c.rank() as i64;
    let k = 64usize;
    let kb = k * 4;
    let data: Vec<i32> = (0..k as i64).map(|i| (r * k as i64 + i) as i32).collect();
    let mut back = vec![0i32; k];
    // Explicit × independent × {blocking, nonblocking}.
    assert_eq!(f.write_at(r * k as i64, data.as_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    assert_eq!(f.read_at(r * k as i64, back.as_mut_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    assert_eq!(back, data);
    f.iwrite_at(r * k as i64, data.as_slice(), 0, k, &Datatype::INT).unwrap().wait().unwrap();
    let (st, owned) = f.iread_at(r * k as i64, vec![0i32; k], 0, k, &Datatype::INT).unwrap().wait().unwrap();
    assert_eq!((st.bytes, &owned), (kb, &data));
    // Explicit × collective × {blocking, nonblocking, split}.
    assert_eq!(f.write_at_all(r * k as i64, data.as_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    assert_eq!(f.read_at_all(r * k as i64, back.as_mut_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    f.iwrite_at_all(r * k as i64, data.as_slice(), 0, k, &Datatype::INT).unwrap().wait().unwrap();
    f.iread_at_all(r * k as i64, vec![0i32; k], 0, k, &Datatype::INT).unwrap().wait().unwrap();
    f.write_at_all_begin(r * k as i64, data.as_slice(), 0, k, &Datatype::INT).unwrap();
    assert_eq!(f.write_at_all_end().unwrap().bytes, kb);
    f.read_at_all_begin(r * k as i64, k, &Datatype::INT).unwrap();
    assert_eq!(f.read_at_all_end(back.as_mut_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    assert_eq!(back, data);
    // Individual × independent × {blocking, nonblocking}.
    f.seek(r * k as i64, seek::SET).unwrap();
    assert_eq!(f.write(data.as_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    f.seek(r * k as i64, seek::SET).unwrap();
    assert_eq!(f.read(back.as_mut_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    f.seek(r * k as i64, seek::SET).unwrap();
    f.iwrite(data.as_slice(), 0, k, &Datatype::INT).unwrap().wait().unwrap();
    f.seek(r * k as i64, seek::SET).unwrap();
    f.iread(vec![0i32; k], 0, k, &Datatype::INT).unwrap().wait().unwrap();
    // Individual × collective × {blocking, nonblocking, split}.
    f.seek(r * k as i64, seek::SET).unwrap();
    assert_eq!(f.write_all(data.as_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    f.seek(r * k as i64, seek::SET).unwrap();
    assert_eq!(f.read_all(back.as_mut_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    f.seek(r * k as i64, seek::SET).unwrap();
    f.iwrite_all(data.as_slice(), 0, k, &Datatype::INT).unwrap().wait().unwrap();
    f.seek(r * k as i64, seek::SET).unwrap();
    f.iread_all(vec![0i32; k], 0, k, &Datatype::INT).unwrap().wait().unwrap();
    f.seek(r * k as i64, seek::SET).unwrap();
    f.write_all_begin(data.as_slice(), 0, k, &Datatype::INT).unwrap();
    assert_eq!(f.write_all_end().unwrap().bytes, kb);
    f.seek(r * k as i64, seek::SET).unwrap();
    f.read_all_begin(k, &Datatype::INT).unwrap();
    assert_eq!(f.read_all_end(back.as_mut_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    assert_eq!(back, data);
    // Shared × independent × {blocking, nonblocking}: racing ranks, so
    // write identical bytes and assert sizes only.
    let same: Vec<i32> = (0..k as i32).collect();
    c.barrier();
    f.seek_shared(0, seek::SET).unwrap();
    c.barrier();
    assert_eq!(f.write_shared(same.as_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    f.iwrite_shared(same.as_slice(), 0, k, &Datatype::INT).unwrap().wait().unwrap();
    c.barrier();
    f.seek_shared(0, seek::SET).unwrap();
    c.barrier();
    assert_eq!(f.read_shared(back.as_mut_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    f.iread_shared(vec![0i32; k], 0, k, &Datatype::INT).unwrap().wait().unwrap();
    // Shared × ordered × {blocking, split}.
    c.barrier();
    f.seek_shared(0, seek::SET).unwrap();
    assert_eq!(f.write_ordered(data.as_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    f.seek_shared(0, seek::SET).unwrap();
    assert_eq!(f.read_ordered(back.as_mut_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    assert_eq!(back, data);
    f.seek_shared(0, seek::SET).unwrap();
    f.write_ordered_begin(data.as_slice(), 0, k, &Datatype::INT).unwrap();
    assert_eq!(f.write_ordered_end().unwrap().bytes, kb);
    f.seek_shared(0, seek::SET).unwrap();
    f.read_ordered_begin(k, &Datatype::INT).unwrap();
    assert_eq!(f.read_ordered_end(back.as_mut_slice(), 0, k, &Datatype::INT).unwrap().bytes, kb);
    assert_eq!(back, data);
    f.close().unwrap();
}

/// `jpio stats`: run the overlap-style workload of `demo` with the
/// `jpio_stats` phase timers on (and tracing, with `--trace <path>`),
/// then render the collectively reduced per-file report — per-op cell
/// counts, run shapes, byte counts, and per-phase wall-clock summed
/// min/max/sum across the ranks.
fn stats(args: &Args) {
    let ranks = args.get_or("ranks", 4usize);
    let trace = args.get("trace").map(str::to_string);
    let path = format!("/tmp/jpio-stats-{}.dat", std::process::id());
    let body = {
        let path = path.clone();
        let trace = trace.clone();
        move |c: &dyn Comm| {
            let mut info = Info::from([("jpio_stats", "true"), ("jpio_cache", "enable")]);
            if let Some(t) = &trace {
                info.set("jpio_stats_trace", t.as_str());
            }
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, info).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let r = c.rank();
            let k = 1024usize;
            let mine: Vec<i32> = (0..k).map(|i| (r * k + i) as i32).collect();
            // Independent explicit-offset write of this rank's block,
            // published by the sync so the strided re-writes below start
            // from a clean cache.
            f.write_at((r * k) as i64, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
            f.sync().unwrap();
            // Small strided re-writes through the page cache: absorbed
            // by dirty pages (write-behind), coalesced at the sync
            // below into one covering run whose gap-filling pre-read is
            // the read-modify-write cycle — the cache_*_bytes /
            // write_behind_flush_bytes / rmw_cycles rows of the report.
            for i in (0..k).step_by(64) {
                f.write_at(
                    (r * k + i) as i64,
                    &mine.as_slice()[i..i + 16],
                    0,
                    16,
                    &Datatype::INT,
                )
                .unwrap();
            }
            f.sync().unwrap();
            c.barrier();
            // Collective read of the whole file (two-phase exchange).
            let n = k * c.size();
            let mut all = vec![0i32; n];
            f.read_at_all(0, all.as_mut_slice(), 0, n, &Datatype::INT).unwrap();
            assert!(all.iter().enumerate().all(|(i, &v)| v == i as i32));
            // Nonblocking collective write + overlapped wait (queue/wait
            // phases) at the second file region.
            let off2 = ((c.size() + r) * k) as i64;
            let req = f.iwrite_at_all(off2, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
            req.wait().unwrap();
            // Close performs the Darshan-style collective reduction.
            f.close().unwrap();
            if c.rank() == 0 {
                print!("{}", f.stats().render());
            }
        }
    };
    if args.has("procs") {
        process::run_local(ranks, |c| body(c));
    } else {
        threads::run(ranks, |c| body(c));
    }
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    let _ = std::fs::remove_file(format!("{path}.jpio-cache-lease"));
    if let Some(t) = &trace {
        println!("trace: one JSONL file per rank at {t}.<rank>");
    }
}

/// `jpio dataset <path>`: print the container summary of a structured
/// dataset (dimensions, attributes, variables). `jpio dataset --check`
/// runs the layer's end-to-end self-test instead.
fn dataset(args: &Args) {
    if args.has("check") {
        dataset_check();
        return;
    }
    let Some(path) = args.positional.first().cloned() else {
        eprintln!("usage: jpio dataset --check | jpio dataset <path>");
        std::process::exit(2);
    };
    threads::run(1, |c| {
        let f = match File::open(c, &path, amode::RDONLY, Info::null()) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("dataset: cannot open {path}: {e}");
                std::process::exit(1);
            }
        };
        let ds = match Dataset::open(f) {
            Ok(ds) => ds,
            Err(e) => {
                eprintln!("dataset: {path} is not a jpio dataset: {e}");
                std::process::exit(1);
            }
        };
        let hdr = ds.header();
        println!(
            "dataset {path}: container v{}, {} record(s)",
            header::VERSION,
            ds.num_records()
        );
        for d in &hdr.dims {
            if d.len == header::UNLIMITED {
                println!("  dim {} = unlimited", d.name);
            } else {
                println!("  dim {} = {}", d.name, d.len);
            }
        }
        for a in &hdr.attrs {
            println!("  att {} = {:?}", a.name, String::from_utf8_lossy(&a.value));
        }
        for v in &hdr.vars {
            let dims: Vec<&str> =
                v.dimids.iter().map(|&d| hdr.dims[d as usize].name.as_str()).collect();
            let rep = if v.external32 { ", external32" } else { "" };
            println!("  var {}({}) : {}{rep}", v.name, dims.join(", "), v.prim.name());
            for a in &v.attrs {
                println!("    att {} = {:?}", a.name, String::from_utf8_lossy(&a.value));
            }
        }
        ds.close().unwrap();
    });
}

/// `jpio dataset --check`: fail (exit nonzero) unless the structured
/// dataset layer can define a container, write a block-decomposed
/// `external32` variable collectively, append records on the unlimited
/// dimension, and re-open + verify the bytes — the CI smoke test of the
/// dataset subsystem. Assertion failures fail the rank thread, which
/// propagates out of `threads::run` and exits nonzero.
fn dataset_check() {
    let path = format!("/tmp/jpio-dataset-check-{}.jpds", std::process::id());
    threads::run(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        let ds = Dataset::create(f).unwrap();
        let t = ds.def_dim("time", header::UNLIMITED).unwrap();
        let x = ds.def_dim("x", 8).unwrap();
        let y = ds.def_dim("y", 6).unwrap();
        let grid = ds.def_var("grid", &Datatype::INT, "external32", &[x, y]).unwrap();
        let series = ds.def_var("series", &Datatype::DOUBLE, "native", &[t, y]).unwrap();
        ds.put_att("title", b"jpio dataset self-test").unwrap();
        ds.enddef().unwrap();
        // Each rank owns a row-block of the 8x6 grid.
        let (starts, counts) = Datatype::block_decompose(&[8, 6], &[2, 1], c.rank()).unwrap();
        let n = counts[0] * counts[1];
        let mine: Vec<i32> = (0..n).map(|i| (c.rank() * 1000 + i) as i32).collect();
        ds.put_vara(grid, &starts, &counts, mine.as_slice()).unwrap();
        let rec: Vec<f64> = (0..6).map(|i| (c.rank() * 10 + i) as f64).collect();
        ds.append_records(series, rec.as_slice()).unwrap();
        let mut back = vec![0i32; n];
        ds.get_vara(grid, &starts, &counts, back.as_mut_slice()).unwrap();
        assert_eq!(back, mine);
        ds.close().unwrap();
        // Re-open read-only and verify the whole variable collectively.
        let f = File::open(c, &path, amode::RDONLY, Info::null()).unwrap();
        let ds = Dataset::open(f).unwrap();
        assert_eq!(ds.num_records(), 2);
        let grid = ds.find_var("grid").unwrap();
        let mut all = vec![0i32; 48];
        ds.get_vara(grid, &[0, 0], &[8, 6], all.as_mut_slice()).unwrap();
        for r in 0..2usize {
            for i in 0..24usize {
                assert_eq!(all[r * 24 + i], (r * 1000 + i) as i32);
            }
        }
        ds.close().unwrap();
    });
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    println!(
        "dataset check: OK (define -> enddef -> collective put/get -> record append -> \
         reopen, external32 on disk)"
    );
}

fn testbed(args: &Args) {
    match args.get("cluster").unwrap_or("barq") {
        "rcms" => print!("{}", Testbed::Rcms),
        _ => print!("{}", Testbed::Barq),
    }
}

fn artifacts(args: &Args) {
    let dir = args.get("dir").unwrap_or("artifacts");
    match jpio::runtime::Runtime::load(dir) {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            println!("artifacts loaded from {dir}:");
            for name in rt.names() {
                println!("  {name}");
            }
        }
        Err(e) => {
            eprintln!("failed to load artifacts: {e}");
            std::process::exit(1);
        }
    }
}

fn demo(args: &Args) {
    let ranks = args.get_or("ranks", 4usize);
    let backend = args.get("backend").unwrap_or("local").to_string();
    let servers = args.get_or("servers", 4usize);
    let stripe_unit = args.get_size_or("stripe-unit", 64 << 10);
    let path = format!("/tmp/jpio-demo-{}.dat", std::process::id());
    if backend == "striped" {
        println!("striped storage: {servers} servers × {stripe_unit} B stripe units");
    }
    let body = {
        let path = path.clone();
        move |c: &dyn Comm| {
            let mut info = Info::from([("jpio_backend", backend.as_str())]);
            if backend == "striped" {
                info.set("striping_factor", servers.to_string());
                info.set("striping_unit", stripe_unit.to_string());
            }
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, info).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null())
                .unwrap();
            let r = c.rank();
            let mine: Vec<i32> = (0..1024).map(|i| (r * 1024 + i) as i32).collect();
            f.write_at_all((r * 1024) as i64, mine.as_slice(), 0, 1024, &Datatype::INT)
                .unwrap();
            c.barrier();
            let n = 1024 * c.size();
            let mut all = vec![0i32; n];
            f.read_at_all(0, all.as_mut_slice(), 0, n, &Datatype::INT).unwrap();
            let ok = all.iter().enumerate().all(|(i, &v)| v == i as i32);
            if c.rank() == 0 {
                println!(
                    "demo: {} ranks wrote+read {} KiB collectively: {}",
                    c.size(),
                    all.len() * 4 / 1024,
                    if ok { "OK" } else { "CORRUPT" }
                );
            }
            assert!(ok);
            // Round 2: the MPI-3.1 nonblocking collectives — the write's
            // I/O phase runs on the request engine while this rank
            // "computes", and completion is a local wait.
            let mine2: Vec<i32> = mine.iter().map(|v| v + 1_000_000).collect();
            let off2 = ((c.size() + r) * 1024) as i64;
            let req = f.iwrite_at_all(off2, mine2.as_slice(), 0, 1024, &Datatype::INT).unwrap();
            let computed: i64 = (0..4096).map(|i| i as i64).sum(); // overlapped work
            let (st, ()) = req.wait().unwrap();
            assert_eq!(st.bytes, 4096);
            c.barrier();
            let req = f.iread_at_all(off2, vec![0i32; 1024], 0, 1024, &Datatype::INT).unwrap();
            let (st, back2) = req.wait().unwrap();
            let ok2 = st.bytes == 4096 && back2 == mine2;
            if c.rank() == 0 {
                println!(
                    "demo: nonblocking collective round (iwrite_at_all/iread_at_all): {} \
                     (overlapped checksum {computed})",
                    if ok2 { "OK" } else { "CORRUPT" }
                );
            }
            assert!(ok2);
            f.close().unwrap();
        }
    };
    if args.has("procs") {
        process::run_local(ranks, |c| body(c));
    } else {
        threads::run(ranks, |c| body(c));
    }
    let _ = std::fs::remove_file(&path);
    for i in 0..servers {
        let _ = std::fs::remove_file(jpio::storage::striped::StripedBackend::object_path(
            &path, i, servers,
        ));
    }
    let _ = std::fs::remove_file(jpio::storage::striped::StripedBackend::size_meta_path(&path));
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}
