//! `jpio` — launcher + diagnostics CLI for the library.
//!
//! ```text
//! jpio routines                     # the routine matrix (Table 3-1/7-1 + MPI-3.1)
//! jpio testbed [--cluster rcms]     # Tables 4-1 / 4-2
//! jpio artifacts [--dir artifacts]  # load + list PJRT artifacts
//! jpio demo [--ranks 4] [--backend nfs] [--procs]
//!                                   # small shared-file write/read demo
//! jpio demo --backend striped [--servers 4] [--stripe-unit 64k]
//!                                   # ... on declustered striped storage
//! jpio version
//! ```

use jpio::bench::Testbed;
use jpio::cli::Args;
use jpio::comm::datatype::Datatype;
use jpio::comm::{process, threads, Comm};
use jpio::io::{amode, File, Info};

fn main() {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("routines") => routines(),
        Some("testbed") => testbed(&args),
        Some("artifacts") => artifacts(&args),
        Some("demo") => demo(&args),
        Some("version") => println!("jpio {}", env!("CARGO_PKG_VERSION")),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown command {cmd:?}\n");
            }
            eprintln!(
                "usage: jpio <routines|testbed|artifacts|demo|version> [--flags]\n\
                 see `cargo doc` and README.md for the library API"
            );
            std::process::exit(if other.is_some() { 2 } else { 0 });
        }
    }
}

fn routines() {
    println!("MPJ-IO data-access & manipulation routines (Table 3-1 / 7-1):");
    println!("{:<36} {:<36} status", "MPI routine", "jpio binding");
    for (mpi, rust) in jpio::io::routine_matrix() {
        println!("{mpi:<36} {rust:<36} implemented");
    }
    println!(
        "\n56/56 routines implemented: the 52-routine MPI-2.2 matrix plus the \
         MPI-3.1 nonblocking collectives (the paper's prototype had 19)."
    );
}

fn testbed(args: &Args) {
    match args.get("cluster").unwrap_or("barq") {
        "rcms" => print!("{}", Testbed::Rcms),
        _ => print!("{}", Testbed::Barq),
    }
}

fn artifacts(args: &Args) {
    let dir = args.get("dir").unwrap_or("artifacts");
    match jpio::runtime::Runtime::load(dir) {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            println!("artifacts loaded from {dir}:");
            for name in rt.names() {
                println!("  {name}");
            }
        }
        Err(e) => {
            eprintln!("failed to load artifacts: {e}");
            std::process::exit(1);
        }
    }
}

fn demo(args: &Args) {
    let ranks = args.get_or("ranks", 4usize);
    let backend = args.get("backend").unwrap_or("local").to_string();
    let servers = args.get_or("servers", 4usize);
    let stripe_unit = args.get_size_or("stripe-unit", 64 << 10);
    let path = format!("/tmp/jpio-demo-{}.dat", std::process::id());
    if backend == "striped" {
        println!("striped storage: {servers} servers × {stripe_unit} B stripe units");
    }
    let body = {
        let path = path.clone();
        move |c: &dyn Comm| {
            let mut info = Info::from([("jpio_backend", backend.as_str())]);
            if backend == "striped" {
                info.set("striping_factor", servers.to_string());
                info.set("striping_unit", stripe_unit.to_string());
            }
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, info).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null())
                .unwrap();
            let r = c.rank();
            let mine: Vec<i32> = (0..1024).map(|i| (r * 1024 + i) as i32).collect();
            f.write_at_all((r * 1024) as i64, mine.as_slice(), 0, 1024, &Datatype::INT)
                .unwrap();
            c.barrier();
            let n = 1024 * c.size();
            let mut all = vec![0i32; n];
            f.read_at_all(0, all.as_mut_slice(), 0, n, &Datatype::INT).unwrap();
            let ok = all.iter().enumerate().all(|(i, &v)| v == i as i32);
            if c.rank() == 0 {
                println!(
                    "demo: {} ranks wrote+read {} KiB collectively: {}",
                    c.size(),
                    all.len() * 4 / 1024,
                    if ok { "OK" } else { "CORRUPT" }
                );
            }
            assert!(ok);
            // Round 2: the MPI-3.1 nonblocking collectives — the write's
            // I/O phase runs on the request engine while this rank
            // "computes", and completion is a local wait.
            let mine2: Vec<i32> = mine.iter().map(|v| v + 1_000_000).collect();
            let off2 = ((c.size() + r) * 1024) as i64;
            let req = f.iwrite_at_all(off2, mine2.as_slice(), 0, 1024, &Datatype::INT).unwrap();
            let computed: i64 = (0..4096).map(|i| i as i64).sum(); // overlapped work
            let (st, ()) = req.wait().unwrap();
            assert_eq!(st.bytes, 4096);
            c.barrier();
            let req = f.iread_at_all(off2, vec![0i32; 1024], 0, 1024, &Datatype::INT).unwrap();
            let (st, back2) = req.wait().unwrap();
            let ok2 = st.bytes == 4096 && back2 == mine2;
            if c.rank() == 0 {
                println!(
                    "demo: nonblocking collective round (iwrite_at_all/iread_at_all): {} \
                     (overlapped checksum {computed})",
                    if ok2 { "OK" } else { "CORRUPT" }
                );
            }
            assert!(ok2);
            f.close().unwrap();
        }
    };
    if args.has("procs") {
        process::run_local(ranks, |c| body(c));
    } else {
        threads::run(ranks, |c| body(c));
    }
    let _ = std::fs::remove_file(&path);
    for i in 0..servers {
        let _ = std::fs::remove_file(jpio::storage::striped::StripedBackend::object_path(
            &path, i, servers,
        ));
    }
    let _ = std::fs::remove_file(jpio::storage::striped::StripedBackend::size_meta_path(&path));
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}
