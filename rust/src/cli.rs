//! Minimal CLI argument parser (the offline environment has no `clap`;
//! DESIGN.md §2). Supports `command [--flag value] [--switch] [positional]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    /// The subcommand (first non-flag argument).
    pub command: Option<String>,
    /// `--key value` pairs (also `--key=value`).
    pub flags: BTreeMap<String, String>,
    /// Bare `--switch` flags.
    pub switches: Vec<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Flag value as string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Flag value parsed, with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Whether a bare switch is present.
    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    /// Flag value parsed as a byte size (`64k`, `1m`, ...), with default.
    pub fn get_size_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(parse_size).unwrap_or(default)
    }
}

/// Parse a byte count with an optional binary suffix: `k`/`K` (KiB),
/// `m`/`M` (MiB), `g`/`G` (GiB). Used by the stripe-unit and buffer-size
/// flags so `--stripe-unit 64k` works.
pub fn parse_size(s: &str) -> Option<u64> {
    let s = s.trim();
    let (digits, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1u64 << 10),
        'm' | 'M' => (&s[..s.len() - 1], 1u64 << 20),
        'g' | 'G' => (&s[..s.len() - 1], 1u64 << 30),
        _ => (s, 1),
    };
    digits.parse::<u64>().ok().and_then(|v| v.checked_mul(mult))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn command_flags_positionals() {
        // Bare switches must not be followed by a positional (they would
        // capture it as a value); place them after positionals or use `=`.
        let a = parse("bench --ranks 8 --backend=nfs file.dat extra --verbose");
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.get("ranks"), Some("8"));
        assert_eq!(a.get_or("ranks", 0usize), 8);
        assert_eq!(a.get("backend"), Some("nfs"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["file.dat", "extra"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_or("threads", 4usize), 4);
        assert!(!a.has("quiet"));
    }

    #[test]
    fn trailing_switch_without_value() {
        let a = parse("x --flag");
        assert!(a.has("flag"));
    }

    #[test]
    fn size_suffixes_parse() {
        assert_eq!(parse_size("512"), Some(512));
        assert_eq!(parse_size("64k"), Some(64 << 10));
        assert_eq!(parse_size("64K"), Some(64 << 10));
        assert_eq!(parse_size("2m"), Some(2 << 20));
        assert_eq!(parse_size("1G"), Some(1 << 30));
        assert_eq!(parse_size(""), None);
        assert_eq!(parse_size("k"), None);
        assert_eq!(parse_size("ten"), None);
        assert_eq!(parse_size("20000000000g"), None, "overflow must not wrap");
        let a = parse("x --stripe-unit 128k");
        assert_eq!(a.get_size_or("stripe-unit", 0), 128 << 10);
        assert_eq!(a.get_size_or("missing", 7), 7);
    }
}
