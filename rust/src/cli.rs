//! Minimal CLI argument parser (the offline environment has no `clap`;
//! DESIGN.md §2). Supports `command [--flag value] [--switch] [positional]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    /// The subcommand (first non-flag argument).
    pub command: Option<String>,
    /// `--key value` pairs (also `--key=value`).
    pub flags: BTreeMap<String, String>,
    /// Bare `--switch` flags.
    pub switches: Vec<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Flag value as string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Flag value parsed, with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Whether a bare switch is present.
    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn command_flags_positionals() {
        // Bare switches must not be followed by a positional (they would
        // capture it as a value); place them after positionals or use `=`.
        let a = parse("bench --ranks 8 --backend=nfs file.dat extra --verbose");
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.get("ranks"), Some("8"));
        assert_eq!(a.get_or("ranks", 0usize), 8);
        assert_eq!(a.get("backend"), Some("nfs"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["file.dat", "extra"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_or("threads", 4usize), 4);
        assert!(!a.has("quiet"));
    }

    #[test]
    fn trailing_switch_without_value() {
        let a = parse("x --flag");
        assert!(a.has("flag"));
    }
}
