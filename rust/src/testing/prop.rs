//! A minimal property-based-testing runner with shrinking.
//!
//! The offline crate cache has no `proptest`, so this module supplies the
//! subset used by jpio's invariant tests: run a property over `n` random
//! inputs produced by a generator closure, and on failure shrink the
//! failing input with a caller-supplied shrinker before reporting.
//!
//! ```no_run
//! use jpio::testing::{forall, Config};
//! forall(Config::default().cases(64), |rng| rng.range(0, 1000), |&n| {
//!     // property: usize addition with 1 never decreases
//!     n + 1 > n
//! });
//! ```

use super::rng::SplitMix64;

/// Runner configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i` so failures name a single seed.
    pub seed: u64,
    /// Maximum shrink iterations.
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0x5EED, max_shrink: 512 }
    }
}

impl Config {
    /// Override the number of cases.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Override the base seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop` over `cases` inputs drawn from `gen`. Panics (with the seed
/// and debug form of the input) on the first falsified case.
pub fn forall<T, G, P>(cfg: Config, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut SplitMix64) -> T,
    P: FnMut(&T) -> bool,
{
    for i in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(i as u64);
        let mut rng = SplitMix64::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property falsified (case {i}, seed {seed:#x}):\n  input = {input:?}"
            );
        }
    }
}

/// Like [`forall`] but with a shrinker: on failure, `shrink` proposes
/// smaller candidates (return `None` when no smaller candidate exists) and
/// the runner reports the smallest falsifying input it can find.
pub fn forall_shrink<T, G, S, P>(cfg: Config, mut gen: G, shrink: S, mut prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut SplitMix64) -> T,
    S: Fn(&T) -> Vec<T>,
    P: FnMut(&T) -> bool,
{
    for i in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(i as u64);
        let mut rng = SplitMix64::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut smallest = input.clone();
            let mut budget = cfg.max_shrink;
            'outer: while budget > 0 {
                for cand in shrink(&smallest) {
                    budget -= 1;
                    if !prop(&cand) {
                        smallest = cand;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property falsified (case {i}, seed {seed:#x}):\n  original = {input:?}\n  shrunk   = {smallest:?}"
            );
        }
    }
}

/// Standard shrinker for vectors: halves, removals, and element shrinks
/// toward zero for integer-like payloads provided by `elem_shrink`.
pub fn shrink_vec<T: Clone>(v: &[T], elem_shrink: impl Fn(&T) -> Option<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    // Halves.
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    // Drop one element (first, middle, last).
    for &idx in &[0, v.len() / 2, v.len() - 1] {
        let mut c = v.to_vec();
        c.remove(idx.min(c.len() - 1));
        out.push(c);
    }
    // Shrink one element.
    for idx in [0, v.len() / 2, v.len() - 1] {
        if let Some(e) = elem_shrink(&v[idx]) {
            let mut c = v.to_vec();
            c[idx] = e;
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(Config::default().cases(50), |r| r.next_u64(), |_| {
            count += 1;
            true
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property falsified")]
    fn failing_property_panics() {
        forall(Config::default().cases(50), |r| r.range(0, 100), |&n| n < 10);
    }

    #[test]
    #[should_panic(expected = "shrunk")]
    fn shrinker_reduces_input() {
        forall_shrink(
            Config::default().cases(20),
            |r| {
                let n = r.range(5, 30);
                r.vec_i32(n)
            },
            |v| shrink_vec(v, |&x| if x != 0 { Some(x / 2) } else { None }),
            |v| v.len() < 3, // fails for any vec of len >= 3; shrinks toward len 3
        );
    }

    #[test]
    fn shrink_vec_produces_smaller_candidates() {
        let v = vec![8, 9, 10, 11];
        let cands = shrink_vec(&v, |&x| if x != 0 { Some(x / 2) } else { None });
        assert!(cands.iter().any(|c| c.len() < v.len()));
    }
}
