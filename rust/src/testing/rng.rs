//! SplitMix64: a tiny, fast, high-quality deterministic PRNG.
//!
//! Used by the property-test runner, the workload generators and the
//! storage fault injector. Deterministic seeding keeps every test and
//! bench reproducible (the paper's evaluation methodology re-runs fixed
//! workloads; so do we).

/// SplitMix64 PRNG (public-domain algorithm by Sebastiano Vigna).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Next u32.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (bound must be > 0). Uses Lemire's method.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply-shift; slight modulo bias is irrelevant for tests.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform i64 in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo) as u64 + 1) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a byte slice with random data.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// A random i32 vector of length `n`.
    pub fn vec_i32(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.next_u64() as i32).collect()
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_respects_bound() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = SplitMix64::new(9);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            match r.range(3, 5) {
                3 => lo_seen = true,
                5 => hi_seen = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(11);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = SplitMix64::new(13);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        // Overwhelmingly unlikely to be all zero if the tail is filled.
        assert!(buf[8..].iter().any(|&b| b != 0) || buf[..8].iter().any(|&b| b != 0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
