//! Test-support utilities: a deterministic PRNG and a small
//! property-based-testing runner (the offline build environment has no
//! `proptest`; `prop` provides the subset we need with shrinking).

pub mod prop;
pub mod rng;

pub use prop::{forall, Config};
pub use rng::SplitMix64;
