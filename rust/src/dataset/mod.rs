//! Self-describing structured datasets over a [`File`] — named N-D
//! variables compiled onto file views (the Parallel netCDF direction).
//!
//! Scientific applications speak in named N-dimensional variables, not
//! byte offsets. This layer stores a versioned, self-describing header
//! (dimensions, variables, attributes — see [`header`]) at the front of
//! an ordinary `jpio` file and compiles every subarray request
//! (`put_vara`/`get_vara`) into a scoped
//! [`Datatype::subarray`] file view submitted through the one
//! [`AccessOp`] core. There is **no new I/O path**: two-phase collective
//! buffering, the multi-lane progress engine, striping/redundancy and
//! the page cache all apply to dataset access unchanged, and repeated
//! same-shape accesses hit the
//! [`PlanCache`](crate::io::schedule::PlanCache) because the per-shape
//! view is cached and reused by pointer identity.
//!
//! ## Life cycle
//!
//! ```text
//!  Dataset::create(file)        Dataset::open(file)
//!        │ define mode                │
//!  def_dim / def_var / put_att       │
//!        │                           │
//!     enddef ──────────────► data mode ◄───── header read + bcast
//!        (layout + header            │
//!         write by rank 0,     put_vara / get_vara / iput / iget /
//!         digest-checked)      append_records / sync
//!                                    │
//!                                 close
//! ```
//!
//! Every `Dataset` method is **collective** over the file's
//! communicator: all ranks call it with matching define-mode arguments
//! (checked with a header digest at [`Dataset::enddef`]) and per-rank
//! `start`/`count` subarrays in data mode. Header coherence follows the
//! MPI sync rules: the header is written by rank 0 and re-read on
//! [`Dataset::sync`], so a reader dataset observes a writer's records
//! after the usual writer-sync / barrier / reader-sync pattern.
//!
//! Bulk variable payloads deliberately bypass the page cache (a per-op
//! `jpio_cache = disable` hint overlay) so scientific sweeps do not
//! evict the small hot header pages; the cache still serves header
//! traffic.

pub mod header;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::comm::datatype::{ArrayOrder, Datatype, IoBuf, IoBufMut};
use crate::comm::Status;
use crate::io::datarep::DataRep;
use crate::io::engine::Request;
use crate::io::errors::{
    err_arg, err_file, err_not_same, err_unsupported_datarep, err_unsupported_op, Result,
};
use crate::io::file::{amode, File};
use crate::io::hints::{keys, Info};
use crate::io::op::{AccessOp, Coordination, Positioning, Synchronism};
use crate::io::stats::Counter;
use crate::io::view::FileView;
use header::{Attr, Dim, Header, Var, UNLIMITED};

/// Alignment of each variable's data region (and of record-row slots).
const VAR_ALIGN: u64 = 8;
/// Alignment of the data section past the header (leaves the header
/// room to breathe on its own pages).
const DATA_ALIGN: u64 = 4096;
/// Per-dataset cap on cached subarray views (one per distinct
/// `(var, start, count)` shape; the same shape re-requested returns the
/// same `Arc`, which is what keys the scheduler's plan cache).
const VIEW_CACHE_CAP: usize = 16;

/// Cache key of a compiled subarray view: `(varid, start, count)`.
type ViewKey = (usize, Vec<usize>, Vec<usize>);

/// A structured dataset bound to an open [`File`]. See the
/// [module docs](self) for the life cycle.
pub struct Dataset<'c> {
    file: File<'c>,
    hdr: Mutex<Header>,
    defining: AtomicBool,
    /// This rank's record-count watermark; collectively agreed on every
    /// record-variable put and persisted into the header at `sync`.
    num_recs: AtomicU64,
    views: Mutex<Vec<(ViewKey, Arc<FileView>)>>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

fn align_up(v: u64, a: u64) -> u64 {
    v.div_ceil(a) * a
}

/// Fill in the data-section layout: fixed variables packed (8-aligned)
/// after the page-aligned header, record variables packed into a record
/// row laid out after the fixed section. Offsets are fixed-width in the
/// serialized header, so sizing the header before and after assigning
/// them yields the same length.
fn layout(hdr: &mut Header) -> Result<()> {
    let lens: Vec<u64> = hdr.dims.iter().map(|d| d.len).collect();
    hdr.data_start = align_up(hdr.encode().len() as u64, DATA_ALIGN);
    let mut off = hdr.data_start;
    let mut rec_off = 0u64;
    let overflow = || err_arg("dataset: variable size overflows the container layout");
    for v in &mut hdr.vars {
        let record = v.dimids.first().is_some_and(|&d| lens[d as usize] == UNLIMITED);
        let mut bytes = v.prim.size() as u64;
        for (i, &d) in v.dimids.iter().enumerate() {
            if i == 0 && record {
                continue;
            }
            bytes = bytes.checked_mul(lens[d as usize]).ok_or_else(overflow)?;
        }
        let slot = align_up(bytes, VAR_ALIGN);
        if record {
            v.data_offset = rec_off;
            rec_off = rec_off.checked_add(slot).ok_or_else(overflow)?;
        } else {
            v.data_offset = off;
            off = off.checked_add(slot).ok_or_else(overflow)?;
        }
    }
    hdr.rec_start = off;
    hdr.rec_size = rec_off;
    Ok(())
}

impl<'c> Dataset<'c> {
    // ------------------------------------------------------------------
    // Define mode
    // ------------------------------------------------------------------

    /// Start a new dataset on `file` in define mode (collective). The
    /// handle's view is reset to the default byte view — the dataset
    /// owns the file's addressing from here on.
    pub fn create(file: File<'c>) -> Result<Dataset<'c>> {
        file.set_view(0, &Datatype::BYTE, &Datatype::BYTE, "native", &Info::null())?;
        Ok(Dataset {
            file,
            hdr: Mutex::new(Header::default()),
            defining: AtomicBool::new(true),
            num_recs: AtomicU64::new(0),
            views: Mutex::new(Vec::new()),
        })
    }

    /// Open an existing dataset on `file` in data mode (collective):
    /// rank 0 reads and validates the header, every rank adopts the
    /// broadcast copy.
    pub fn open(file: File<'c>) -> Result<Dataset<'c>> {
        file.set_view(0, &Datatype::BYTE, &Datatype::BYTE, "native", &Info::null())?;
        let hdr = Self::read_header(&file)?;
        let num_recs = hdr.num_recs;
        Ok(Dataset {
            file,
            hdr: Mutex::new(hdr),
            defining: AtomicBool::new(false),
            num_recs: AtomicU64::new(num_recs),
            views: Mutex::new(Vec::new()),
        })
    }

    fn check_define(&self, what: &str) -> Result<()> {
        if !self.defining.load(Ordering::SeqCst) {
            return Err(err_unsupported_op(format!("{what}: dataset is not in define mode")));
        }
        Ok(())
    }

    fn check_data(&self, what: &str) -> Result<()> {
        if self.defining.load(Ordering::SeqCst) {
            return Err(err_unsupported_op(format!(
                "{what}: dataset is in define mode (call enddef first)"
            )));
        }
        Ok(())
    }

    /// Define a named dimension of `len` elements; pass
    /// [`UNLIMITED`](header::UNLIMITED) (0) for the single growable
    /// record dimension. Returns the dimension id.
    pub fn def_dim(&self, name: &str, len: u64) -> Result<usize> {
        self.check_define("def_dim")?;
        if name.is_empty() {
            return Err(err_arg("def_dim: empty dimension name"));
        }
        let mut hdr = self.hdr.lock().unwrap();
        if hdr.dims.iter().any(|d| d.name == name) {
            return Err(err_arg(format!("def_dim: dimension {name:?} already defined")));
        }
        if len == UNLIMITED && hdr.dims.iter().any(|d| d.len == UNLIMITED) {
            return Err(err_arg("def_dim: only one unlimited (record) dimension is allowed"));
        }
        hdr.dims.push(Dim { name: name.to_string(), len });
        Ok(hdr.dims.len() - 1)
    }

    /// Define a variable of primitive element type `elem` over `dims`
    /// (outermost first), stored in the `datarep` on-disk representation
    /// (`"native"` or the canonical big-endian `"external32"`). The
    /// unlimited dimension, if used, must be the outermost. Returns the
    /// variable id.
    pub fn def_var(
        &self,
        name: &str,
        elem: &Datatype,
        datarep: &str,
        dims: &[usize],
    ) -> Result<usize> {
        self.check_define("def_var")?;
        if name.is_empty() {
            return Err(err_arg("def_var: empty variable name"));
        }
        let prim = match elem {
            Datatype::Prim(p) => *p,
            Datatype::Derived(_) => {
                return Err(err_arg("def_var: variables take primitive element types"))
            }
        };
        let external32 = match DataRep::resolve(datarep)? {
            DataRep::Native => false,
            DataRep::External32 => true,
            DataRep::User { .. } => {
                return Err(err_unsupported_datarep(
                    "def_var: datasets store native or external32 representations",
                ))
            }
        };
        let mut hdr = self.hdr.lock().unwrap();
        if hdr.vars.iter().any(|v| v.name == name) {
            return Err(err_arg(format!("def_var: variable {name:?} already defined")));
        }
        let mut dimids = Vec::with_capacity(dims.len());
        for (i, &d) in dims.iter().enumerate() {
            let len = match hdr.dims.get(d) {
                Some(dim) => dim.len,
                None => return Err(err_arg(format!("def_var: no dimension with id {d}"))),
            };
            if len == UNLIMITED && i != 0 {
                return Err(err_arg(
                    "def_var: the unlimited dimension must be the outermost",
                ));
            }
            dimids.push(d as u32);
        }
        hdr.vars.push(Var {
            name: name.to_string(),
            prim,
            external32,
            dimids,
            attrs: Vec::new(),
            data_offset: 0,
        });
        Ok(hdr.vars.len() - 1)
    }

    /// Set (or replace) a global attribute. Define mode only.
    pub fn put_att(&self, name: &str, value: &[u8]) -> Result<()> {
        self.check_define("put_att")?;
        let mut hdr = self.hdr.lock().unwrap();
        upsert_attr(&mut hdr.attrs, name, value);
        Ok(())
    }

    /// Set (or replace) an attribute of variable `var`. Define mode only.
    pub fn put_var_att(&self, var: usize, name: &str, value: &[u8]) -> Result<()> {
        self.check_define("put_var_att")?;
        let mut hdr = self.hdr.lock().unwrap();
        let v = hdr
            .vars
            .get_mut(var)
            .ok_or_else(|| err_arg(format!("put_var_att: no variable with id {var}")))?;
        upsert_attr(&mut v.attrs, name, value);
        Ok(())
    }

    /// Leave define mode (collective): compute the data-section layout,
    /// verify all ranks defined the same schema (header digest
    /// allgather), then rank 0 writes the header and every rank enters
    /// data mode.
    pub fn enddef(&self) -> Result<()> {
        self.check_define("enddef")?;
        let raw = {
            let mut hdr = self.hdr.lock().unwrap();
            layout(&mut hdr)?;
            hdr.encode()
        };
        let comm = self.file.comm;
        let digest = fnv1a(&raw).to_le_bytes();
        let all = comm.allgather(&digest);
        if all.iter().any(|d| d[..] != digest[..]) {
            return Err(err_not_same("enddef: define-mode calls differ across ranks"));
        }
        // Rank 0 persists the header; the outcome travels in a *named*
        // flag buffer on both sides (see File::open for the why).
        if comm.rank() == 0 {
            let res = self.write_header(&raw);
            let mut flag = (res.is_ok() as i64).to_le_bytes().to_vec();
            comm.bcast(0, &mut flag);
            comm.barrier();
            res?;
        } else {
            let mut flag = vec![0u8; 8];
            comm.bcast(0, &mut flag);
            let ok = i64::from_le_bytes(flag[..8].try_into().unwrap()) == 1;
            comm.barrier();
            if !ok {
                return Err(err_file("enddef: header write failed at rank 0"));
            }
        }
        self.defining.store(false, Ordering::SeqCst);
        Ok(())
    }

    fn write_header(&self, raw: &[u8]) -> Result<()> {
        self.file.write_at(0, raw, 0, raw.len(), &Datatype::BYTE)?;
        self.file.stats.add(Counter::DatasetHeaderBytes, raw.len() as u64);
        Ok(())
    }

    /// Rank 0 reads + validates the header; every rank adopts the
    /// broadcast copy (the open/sync coherence path).
    fn read_header(file: &File<'_>) -> Result<Header> {
        let comm = file.comm;
        if comm.rank() == 0 {
            let res = Self::read_header_local(file);
            let mut flag = (res.is_ok() as i64).to_le_bytes().to_vec();
            comm.bcast(0, &mut flag);
            match res {
                Ok((hdr, raw)) => {
                    let mut payload = raw;
                    comm.bcast(0, &mut payload);
                    Ok(hdr)
                }
                Err(e) => Err(e),
            }
        } else {
            let mut flag = vec![0u8; 8];
            comm.bcast(0, &mut flag);
            if i64::from_le_bytes(flag[..8].try_into().unwrap()) != 1 {
                return Err(err_file("dataset: header read failed at rank 0"));
            }
            let mut payload = Vec::new();
            comm.bcast(0, &mut payload);
            Header::decode(&payload)
        }
    }

    fn read_header_local(file: &File<'_>) -> Result<(Header, Vec<u8>)> {
        let mut pre = vec![0u8; header::PREAMBLE_BYTES];
        file.read_at(0, pre.as_mut_slice(), 0, pre.len(), &Datatype::BYTE)?;
        let total = Header::total_bytes(&pre)?;
        let mut raw = vec![0u8; total];
        file.read_at(0, raw.as_mut_slice(), 0, total, &Datatype::BYTE)?;
        let hdr = Header::decode(&raw)?;
        file.stats.add(Counter::DatasetHeaderBytes, (pre.len() + total) as u64);
        Ok((hdr, raw))
    }

    // ------------------------------------------------------------------
    // Data mode
    // ------------------------------------------------------------------

    /// Collective blocking write of the subarray `start`/`count` (element
    /// coordinates, outermost dimension first) of variable `var`. Each
    /// rank passes its own subarray — e.g. its block of a 2-D
    /// decomposition — and the request rides the two-phase collective
    /// write path under a scoped subarray file view.
    pub fn put_vara(
        &self,
        var: usize,
        start: &[usize],
        count: &[usize],
        buf: &(impl IoBuf + ?Sized),
    ) -> Result<Status> {
        self.check_data("put_vara")?;
        let (view, elem, nelems, record) = self.var_view(var, start, count, false)?;
        if record {
            self.agree_recs((start[0] + count[0]) as u64);
        }
        let op = AccessOp::write(
            Positioning::Explicit(0),
            Coordination::Collective,
            Synchronism::Blocking,
            0,
            nelems,
            &elem,
        );
        let st = self.file.submit_write_overlay(&op, buf, Some(view), Some(&bypass()))?.status()?;
        self.file.stats.add(Counter::VarPutOps, 1);
        Ok(st)
    }

    /// Collective blocking read of the subarray `start`/`count` of
    /// variable `var` into `buf` — the read twin of
    /// [`Dataset::put_vara`].
    pub fn get_vara(
        &self,
        var: usize,
        start: &[usize],
        count: &[usize],
        buf: &mut (impl IoBufMut + ?Sized),
    ) -> Result<Status> {
        self.check_data("get_vara")?;
        let (view, elem, nelems, _) = self.var_view(var, start, count, true)?;
        let op = AccessOp::read(
            Positioning::Explicit(0),
            Coordination::Collective,
            Synchronism::Blocking,
            0,
            nelems,
            &elem,
        );
        let st = self.file.submit_read_overlay(&op, buf, Some(view), Some(&bypass()))?;
        self.file.stats.add(Counter::VarGetOps, 1);
        Ok(st)
    }

    /// Nonblocking collective variant of [`Dataset::put_vara`]: returns
    /// immediately with a [`Request`]; on a progress-lane transport both
    /// two-phase halves run off the calling thread.
    pub fn iput_vara(
        &self,
        var: usize,
        start: &[usize],
        count: &[usize],
        buf: &(impl IoBuf + ?Sized),
    ) -> Result<Request<()>> {
        self.check_data("iput_vara")?;
        let (view, elem, nelems, record) = self.var_view(var, start, count, false)?;
        if record {
            self.agree_recs((start[0] + count[0]) as u64);
        }
        let op = AccessOp::write(
            Positioning::Explicit(0),
            Coordination::Collective,
            Synchronism::Nonblocking,
            0,
            nelems,
            &elem,
        );
        let req = self.file.submit_write_overlay(&op, buf, Some(view), Some(&bypass()))?.request()?;
        self.file.stats.add(Counter::VarPutOps, 1);
        Ok(req)
    }

    /// Nonblocking collective variant of [`Dataset::get_vara`]: takes
    /// the buffer by value, returns it filled through the [`Request`].
    pub fn iget_vara<T>(
        &self,
        var: usize,
        start: &[usize],
        count: &[usize],
        buf: Vec<T>,
    ) -> Result<Request<Vec<T>>>
    where
        T: Send + 'static,
        [T]: IoBufMut,
    {
        self.check_data("iget_vara")?;
        let (view, elem, nelems, _) = self.var_view(var, start, count, true)?;
        let op = AccessOp::read(
            Positioning::Explicit(0),
            Coordination::Collective,
            Synchronism::Nonblocking,
            0,
            nelems,
            &elem,
        );
        let req = self.file.submit_read_owned_overlay(&op, buf, Some(view), Some(&bypass()))?;
        self.file.stats.add(Counter::VarGetOps, 1);
        Ok(req)
    }

    /// Collective record append on record variable `var`: rank `r`
    /// writes whole record `num_records() + r` from `buf` (one record's
    /// worth of elements), and the record counter advances by the
    /// communicator size on every rank.
    pub fn append_records(&self, var: usize, buf: &(impl IoBuf + ?Sized)) -> Result<Status> {
        self.check_data("append_records")?;
        let (shape, record) = {
            let hdr = self.hdr.lock().unwrap();
            let v = hdr
                .vars
                .get(var)
                .ok_or_else(|| err_arg(format!("append_records: no variable with id {var}")))?;
            let shape: Vec<u64> = v.dimids.iter().map(|&d| hdr.dims[d as usize].len).collect();
            (shape, v.dimids.first().is_some_and(|&d| hdr.dims[d as usize].len == UNLIMITED))
        };
        if !record {
            return Err(err_arg("append_records: variable has no record dimension"));
        }
        let base = self.num_recs.load(Ordering::SeqCst) as usize;
        let mut start = vec![0usize; shape.len()];
        start[0] = base + self.file.comm.rank();
        let mut count: Vec<usize> = shape.iter().map(|&l| l as usize).collect();
        count[0] = 1;
        self.put_vara(var, &start, &count, buf)
    }

    /// Collective coherence point: agree on the record count, persist it
    /// (rank 0, writable handles), flush through [`File::sync`], and
    /// re-read the header so reader datasets observe a writer's updates
    /// (writer-sync / barrier / reader-sync, as for plain files).
    pub fn sync(&self) -> Result<()> {
        self.check_data("sync")?;
        let max = self.agree_recs(self.num_recs.load(Ordering::SeqCst));
        let writable = self.file.amode & (amode::WRONLY | amode::RDWR) != 0;
        let readable = self.file.amode & (amode::RDONLY | amode::RDWR) != 0;
        let comm = self.file.comm;
        if writable && comm.rank() == 0 {
            let bytes = max.to_le_bytes();
            self.file.write_at(
                header::NUM_RECS_OFFSET as i64,
                bytes.as_slice(),
                0,
                bytes.len(),
                &Datatype::BYTE,
            )?;
            self.file.stats.add(Counter::DatasetHeaderBytes, bytes.len() as u64);
        }
        comm.barrier();
        self.file.sync()?;
        if readable {
            let hdr = Self::read_header(&self.file)?;
            self.num_recs.fetch_max(hdr.num_recs, Ordering::SeqCst);
            *self.hdr.lock().unwrap() = hdr;
        }
        Ok(())
    }

    /// Collective close: leaves define mode if still in it, runs a final
    /// [`Dataset::sync`], and closes the underlying file.
    pub fn close(self) -> Result<()> {
        if self.defining.load(Ordering::SeqCst) {
            self.enddef()?;
        }
        self.sync()?;
        self.file.close()
    }

    // ------------------------------------------------------------------
    // Inquiry
    // ------------------------------------------------------------------

    /// The underlying file handle (stats, plan-cache counters, degraded
    /// advisories).
    pub fn file(&self) -> &File<'c> {
        &self.file
    }

    /// Records written along the unlimited dimension, as agreed at the
    /// last collective point (put/sync/open).
    pub fn num_records(&self) -> u64 {
        self.num_recs.load(Ordering::SeqCst)
    }

    /// A snapshot of the container header.
    pub fn header(&self) -> Header {
        self.hdr.lock().unwrap().clone()
    }

    /// Look up a dimension id by name.
    pub fn find_dim(&self, name: &str) -> Option<usize> {
        self.hdr.lock().unwrap().dims.iter().position(|d| d.name == name)
    }

    /// Look up a variable id by name.
    pub fn find_var(&self, name: &str) -> Option<usize> {
        self.hdr.lock().unwrap().vars.iter().position(|v| v.name == name)
    }

    /// A global attribute's value.
    pub fn get_att(&self, name: &str) -> Option<Vec<u8>> {
        let hdr = self.hdr.lock().unwrap();
        hdr.attrs.iter().find(|a| a.name == name).map(|a| a.value.clone())
    }

    /// A variable attribute's value.
    pub fn get_var_att(&self, var: usize, name: &str) -> Option<Vec<u8>> {
        let hdr = self.hdr.lock().unwrap();
        let v = hdr.vars.get(var)?;
        v.attrs.iter().find(|a| a.name == name).map(|a| a.value.clone())
    }

    /// The shape of variable `var` (outermost first); the record
    /// dimension reports the current record count.
    pub fn var_shape(&self, var: usize) -> Result<Vec<u64>> {
        let hdr = self.hdr.lock().unwrap();
        let v = hdr
            .vars
            .get(var)
            .ok_or_else(|| err_arg(format!("var_shape: no variable with id {var}")))?;
        Ok(v.dimids
            .iter()
            .map(|&d| {
                let len = hdr.dims[d as usize].len;
                if len == UNLIMITED {
                    self.num_recs.load(Ordering::SeqCst)
                } else {
                    len
                }
            })
            .collect())
    }

    // ------------------------------------------------------------------
    // Subarray → file-view compilation
    // ------------------------------------------------------------------

    /// Collectively agree the record watermark at `candidate` records
    /// (max across ranks), returning the agreed value.
    fn agree_recs(&self, candidate: u64) -> u64 {
        let all = self.file.comm.allgather(&candidate.to_le_bytes());
        let max = all
            .iter()
            .filter(|b| b.len() >= 8)
            .map(|b| u64::from_le_bytes(b[..8].try_into().unwrap()))
            .max()
            .unwrap_or(candidate);
        self.num_recs.fetch_max(max, Ordering::SeqCst);
        max
    }

    /// Validate a subarray request and compile (or reuse) its scoped
    /// file view. Returns `(view, element type, element count, is
    /// record variable)`. The per-shape `Arc<FileView>` is cached so a
    /// repeated same-shape access hands the scheduler the *same* view
    /// by pointer identity — the plan-cache key.
    fn var_view(
        &self,
        var: usize,
        start: &[usize],
        count: &[usize],
        bound_records: bool,
    ) -> Result<(Arc<FileView>, Datatype, usize, bool)> {
        let hdr = self.hdr.lock().unwrap();
        let v = hdr
            .vars
            .get(var)
            .ok_or_else(|| err_arg(format!("dataset: no variable with id {var}")))?;
        let shape: Vec<u64> = v.dimids.iter().map(|&d| hdr.dims[d as usize].len).collect();
        let ndims = shape.len();
        if start.len() != ndims || count.len() != ndims {
            return Err(err_arg(format!(
                "dataset: variable {:?} has {ndims} dimensions; got start[{}], count[{}]",
                v.name,
                start.len(),
                count.len()
            )));
        }
        let record = ndims > 0 && shape[0] == UNLIMITED;
        for d in 0..ndims {
            if count[d] == 0 {
                return Err(err_arg(format!("dataset: zero count in dimension {d}")));
            }
            let limit = if d == 0 && record {
                if bound_records {
                    self.num_recs.load(Ordering::SeqCst)
                } else {
                    u64::MAX
                }
            } else {
                shape[d]
            };
            if (start[d] as u64).saturating_add(count[d] as u64) > limit {
                return Err(err_arg(format!(
                    "dataset: start {} + count {} exceeds dimension {d} bound {limit}",
                    start[d], count[d]
                )));
            }
        }
        let elem = Datatype::Prim(v.prim);
        let nelems: usize = count.iter().product();
        let key = (var, start.to_vec(), count.to_vec());
        {
            let views = self.views.lock().unwrap();
            if let Some((_, view)) = views.iter().find(|(k, _)| *k == key) {
                return Ok((view.clone(), elem, nelems, record));
            }
        }
        let type_err = |e| err_arg(format!("dataset: subarray view: {e}"));
        let rep = if v.external32 { DataRep::External32 } else { DataRep::Native };
        let (disp, filetype) = if record {
            let rec_size = hdr.rec_size;
            let inner = if ndims == 1 {
                elem.clone()
            } else {
                let sizes: Vec<usize> = shape[1..].iter().map(|&l| l as usize).collect();
                Datatype::subarray(&sizes, &count[1..], &start[1..], ArrayOrder::C, &elem)
                    .map_err(type_err)?
            };
            let ft = Datatype::hvector(count[0], 1, rec_size as i64, &inner).map_err(type_err)?;
            let disp = hdr.rec_start + v.data_offset + start[0] as u64 * rec_size;
            (disp as i64, ft)
        } else if ndims == 0 {
            (v.data_offset as i64, elem.clone())
        } else {
            let sizes: Vec<usize> = shape.iter().map(|&l| l as usize).collect();
            let ft = Datatype::subarray(&sizes, count, start, ArrayOrder::C, &elem)
                .map_err(type_err)?;
            (v.data_offset as i64, ft)
        };
        let view = Arc::new(FileView::new(disp, elem.clone(), filetype, rep)?);
        let mut cache = self.views.lock().unwrap();
        if cache.len() >= VIEW_CACHE_CAP {
            cache.remove(0);
        }
        cache.push((key, view.clone()));
        Ok((view, elem, nelems, record))
    }
}

fn upsert_attr(attrs: &mut Vec<Attr>, name: &str, value: &[u8]) {
    if let Some(a) = attrs.iter_mut().find(|a| a.name == name) {
        a.value = value.to_vec();
    } else {
        attrs.push(Attr { name: name.to_string(), value: value.to_vec() });
    }
}

/// The per-op hint overlay that keeps bulk variable payloads out of the
/// page cache (satellite of the LRU budget: sweeps must not evict the
/// hot header pages).
fn bypass() -> Info {
    Info::from([(keys::CACHE, "disable")])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{threads, Comm};
    use crate::io::errors::ErrorClass;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-dataset-{}-{name}.jpds", std::process::id())
    }

    fn cleanup(path: &str) {
        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
        let _ = std::fs::remove_file(format!("{path}.jpio-cache-lease"));
    }

    #[test]
    fn define_then_roundtrip_fixed_var() {
        let path = tmp("fixed");
        threads::run(2, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let ds = Dataset::create(f).unwrap();
            let x = ds.def_dim("x", 4).unwrap();
            let y = ds.def_dim("y", 6).unwrap();
            let grid = ds.def_var("grid", &Datatype::INT, "native", &[x, y]).unwrap();
            ds.put_att("title", b"unit test").unwrap();
            ds.put_var_att(grid, "units", b"K").unwrap();
            ds.enddef().unwrap();
            // Each rank owns two rows of the 4×6 grid.
            let r = c.rank();
            let mine: Vec<i32> = (0..12).map(|i| (r * 100 + i) as i32).collect();
            ds.put_vara(grid, &[r * 2, 0], &[2, 6], mine.as_slice()).unwrap();
            let mut back = vec![0i32; 12];
            ds.get_vara(grid, &[r * 2, 0], &[2, 6], back.as_mut_slice()).unwrap();
            assert_eq!(back, mine);
            assert_eq!(ds.get_att("title").unwrap(), b"unit test");
            assert_eq!(ds.get_var_att(grid, "units").unwrap(), b"K");
            ds.close().unwrap();
            // Reopen and cross-read the other rank's rows.
            let f = File::open(c, &path, amode::RDONLY, Info::null()).unwrap();
            let ds = Dataset::open(f).unwrap();
            let grid = ds.find_var("grid").unwrap();
            assert_eq!(ds.var_shape(grid).unwrap(), vec![4, 6]);
            let other = 1 - r;
            let mut theirs = vec![0i32; 12];
            ds.get_vara(grid, &[other * 2, 0], &[2, 6], theirs.as_mut_slice()).unwrap();
            let expect: Vec<i32> = (0..12).map(|i| (other * 100 + i) as i32).collect();
            assert_eq!(theirs, expect);
            ds.close().unwrap();
        });
        cleanup(&path);
    }

    #[test]
    fn record_append_and_nonblocking_cells() {
        let path = tmp("records");
        threads::run(2, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let ds = Dataset::create(f).unwrap();
            let t = ds.def_dim("time", UNLIMITED).unwrap();
            let s = ds.def_dim("sample", 8).unwrap();
            let series = ds.def_var("series", &Datatype::DOUBLE, "native", &[t, s]).unwrap();
            ds.enddef().unwrap();
            let r = c.rank();
            // Two collective appends: records 0..2, then 2..4.
            for round in 0..2usize {
                let rec: Vec<f64> = (0..8).map(|i| (round * 100 + r * 10 + i) as f64).collect();
                ds.append_records(series, rec.as_slice()).unwrap();
            }
            assert_eq!(ds.num_records(), 4);
            // Nonblocking read-back of this rank's two records.
            for round in 0..2usize {
                let rec = round * 2 + r;
                let req = ds.iget_vara(series, &[rec, 0], &[1, 8], vec![0f64; 8]).unwrap();
                let (st, got) = req.wait().unwrap();
                assert_eq!(st.bytes, 64);
                let expect: Vec<f64> = (0..8).map(|i| (round * 100 + r * 10 + i) as f64).collect();
                assert_eq!(got, expect);
            }
            // Nonblocking overwrite of record `r`, then blocking verify.
            let new: Vec<f64> = (0..8).map(|i| (900 + i) as f64).collect();
            ds.iput_vara(series, &[r, 0], &[1, 8], new.as_slice()).unwrap().wait().unwrap();
            let mut back = vec![0f64; 8];
            ds.get_vara(series, &[r, 0], &[1, 8], back.as_mut_slice()).unwrap();
            assert_eq!(back, new);
            ds.close().unwrap();
            // Reopen: the record count survived in the header.
            let f = File::open(c, &path, amode::RDONLY, Info::null()).unwrap();
            let ds = Dataset::open(f).unwrap();
            assert_eq!(ds.num_records(), 4);
            ds.close().unwrap();
        });
        cleanup(&path);
    }

    #[test]
    fn mode_state_machine_is_enforced() {
        let path = tmp("modes");
        threads::run(1, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let ds = Dataset::create(f).unwrap();
            let x = ds.def_dim("x", 4).unwrap();
            let v = ds.def_var("v", &Datatype::INT, "native", &[x]).unwrap();
            // Data-mode calls are rejected in define mode.
            let e = ds.put_vara(v, &[0], &[4], [0i32; 4].as_slice()).unwrap_err();
            assert_eq!(e.class, ErrorClass::UnsupportedOperation);
            // Schema errors.
            assert_eq!(ds.def_dim("x", 9).unwrap_err().class, ErrorClass::Arg);
            let dup = ds.def_var("v", &Datatype::INT, "native", &[x]).unwrap_err();
            assert_eq!(dup.class, ErrorClass::Arg);
            let bad = ds.def_var("w", &Datatype::INT, "native", &[7]).unwrap_err();
            assert_eq!(bad.class, ErrorClass::Arg);
            let t = ds.def_dim("t", UNLIMITED).unwrap();
            assert_eq!(ds.def_dim("t2", UNLIMITED).unwrap_err().class, ErrorClass::Arg);
            assert_eq!(
                ds.def_var("w", &Datatype::INT, "native", &[x, t]).unwrap_err().class,
                ErrorClass::Arg
            );
            ds.enddef().unwrap();
            // Define-mode calls are rejected in data mode.
            assert_eq!(ds.def_dim("y", 3).unwrap_err().class, ErrorClass::UnsupportedOperation);
            assert_eq!(ds.enddef().unwrap_err().class, ErrorClass::UnsupportedOperation);
            // Out-of-bounds subarrays.
            assert_eq!(
                ds.put_vara(v, &[2], &[4], [0i32; 4].as_slice()).unwrap_err().class,
                ErrorClass::Arg
            );
            let mut b = [0i32; 4];
            let zero = ds.get_vara(v, &[0], &[0], b.as_mut_slice()).unwrap_err();
            assert_eq!(zero.class, ErrorClass::Arg);
            ds.close().unwrap();
        });
        cleanup(&path);
    }

    #[test]
    fn same_shape_access_reuses_the_cached_view_and_plan() {
        let path = tmp("plancache");
        threads::run(1, |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let ds = Dataset::create(f).unwrap();
            let x = ds.def_dim("x", 16).unwrap();
            let y = ds.def_dim("y", 16).unwrap();
            let v = ds.def_var("v", &Datatype::INT, "native", &[x, y]).unwrap();
            ds.enddef().unwrap();
            let block: Vec<i32> = (0..64).collect();
            let mut hits = Vec::new();
            for _ in 0..4 {
                ds.put_vara(v, &[4, 4], &[8, 8], block.as_slice()).unwrap();
                hits.push(ds.file().plan_cache_stats().hits);
            }
            assert!(
                hits.windows(2).all(|w| w[1] > w[0]),
                "same-shape put_vara must hit the plan cache on every repeat: {hits:?}"
            );
            ds.close().unwrap();
        });
        cleanup(&path);
    }
}
