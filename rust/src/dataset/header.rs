//! On-disk container header codec for the dataset layer.
//!
//! The header is a single little-endian record at byte 0 of the file,
//! ahead of the page-aligned data section. Layout (version 1):
//!
//! ```text
//! offset  field
//! 0       magic "JPDS"
//! 4       version          u32  (= 1)
//! 8       header_bytes     u64  total serialized header length
//! 16      num_recs         u64  record count (rewritten in place at sync)
//! 24      data_start       u64  fixed-variable data section offset
//! 32      rec_start        u64  record section offset
//! 40      rec_size         u64  bytes per whole record row
//! 48      ndims / nattrs / nvars   u32 × 3
//! 60      dims   [name, len u64]            (len 0 = unlimited)
//!         attrs  [name, value bytes]         (global attributes)
//!         vars   [name, prim u8, external32 u8, ndims u32, dim ids u32×n,
//!                 nattrs u32, attrs, data_offset u64]
//! ```
//!
//! Strings and byte values are length-prefixed with a `u32`. A fixed
//! variable's `data_offset` is absolute; a record variable's is its
//! offset *within a record row* (its record `r` element lives at
//! `rec_start + r * rec_size + data_offset`). `num_recs` sits at a fixed
//! offset ([`NUM_RECS_OFFSET`]) so [`sync`](super::Dataset::sync) can
//! persist it with one 8-byte in-place write instead of rewriting the
//! whole header. The format is frozen per version: the committed golden
//! fixture in `rust/tests/fixtures/` must keep decoding — and
//! re-encoding byte-identically — forever.

use crate::comm::datatype::Prim;
use crate::io::errors::{err_arg, err_io, Result};

/// File magic: the first four bytes of every dataset container.
pub const MAGIC: [u8; 4] = *b"JPDS";

/// Current container format version.
pub const VERSION: u32 = 1;

/// Byte offset of the `num_recs` field (rewritten in place at sync).
pub const NUM_RECS_OFFSET: u64 = 16;

/// Bytes of header needed to learn the full header length (through the
/// `header_bytes` field).
pub const PREAMBLE_BYTES: usize = 16;

/// Dimension length marking the (single) unlimited record dimension.
pub const UNLIMITED: u64 = 0;

/// A named dimension: fixed length, or [`UNLIMITED`] for the record
/// dimension.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Dim {
    /// Dimension name, unique within the dataset.
    pub name: String,
    /// Length in elements; [`UNLIMITED`] (0) for the record dimension.
    pub len: u64,
}

/// A named attribute: uninterpreted bytes attached to the dataset or to
/// one variable (applications conventionally store UTF-8 text or
/// little-endian scalars).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Attr {
    /// Attribute name, unique within its scope.
    pub name: String,
    /// Attribute payload.
    pub value: Vec<u8>,
}

/// Metadata of one N-dimensional variable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Var {
    /// Variable name, unique within the dataset.
    pub name: String,
    /// Element primitive type.
    pub prim: Prim,
    /// Whether elements are stored in the canonical big-endian
    /// `external32` representation on disk.
    pub external32: bool,
    /// Dimension ids, outermost first; `dims[0]` may be the record
    /// dimension.
    pub dimids: Vec<u32>,
    /// Per-variable attributes.
    pub attrs: Vec<Attr>,
    /// Fixed variables: absolute data offset. Record variables: offset
    /// within a record row.
    pub data_offset: u64,
}

/// The decoded container header.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Header {
    /// Records written along the unlimited dimension.
    pub num_recs: u64,
    /// Fixed-variable data section offset (page aligned past the header).
    pub data_start: u64,
    /// Record section offset (past the fixed variables).
    pub rec_start: u64,
    /// Bytes per whole record row (sum over record variables).
    pub rec_size: u64,
    /// Named dimensions.
    pub dims: Vec<Dim>,
    /// Global attributes.
    pub attrs: Vec<Attr>,
    /// Variables.
    pub vars: Vec<Var>,
}

fn prim_code(p: Prim) -> u8 {
    match p {
        Prim::Byte => 0,
        Prim::Short => 1,
        Prim::Int => 2,
        Prim::Long => 3,
        Prim::Float => 4,
        Prim::Double => 5,
        Prim::Char => 6,
        Prim::Boolean => 7,
    }
}

fn prim_from_code(c: u8) -> Result<Prim> {
    Ok(match c {
        0 => Prim::Byte,
        1 => Prim::Short,
        2 => Prim::Int,
        3 => Prim::Long,
        4 => Prim::Float,
        5 => Prim::Double,
        6 => Prim::Char,
        7 => Prim::Boolean,
        _ => return Err(err_io(format!("dataset header: unknown element-type code {c}"))),
    })
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

fn put_attrs(out: &mut Vec<u8>, attrs: &[Attr]) {
    for a in attrs {
        put_bytes(out, a.name.as_bytes());
        put_bytes(out, &a.value);
    }
}

/// Little-endian cursor over a serialized header.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(err_io(format!(
                "dataset header: truncated at byte {} (need {n} more of {})",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    fn string(&mut self) -> Result<String> {
        String::from_utf8(self.bytes()?)
            .map_err(|_| err_io("dataset header: name is not UTF-8"))
    }

    fn attrs(&mut self, n: usize) -> Result<Vec<Attr>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(Attr { name: self.string()?, value: self.bytes()? });
        }
        Ok(out)
    }
}

impl Header {
    /// Serialize the header. Deterministic: the same header always
    /// produces the same bytes (the golden-fixture drift test depends on
    /// this).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(256);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&0u64.to_le_bytes()); // header_bytes, patched below
        out.extend_from_slice(&self.num_recs.to_le_bytes());
        out.extend_from_slice(&self.data_start.to_le_bytes());
        out.extend_from_slice(&self.rec_start.to_le_bytes());
        out.extend_from_slice(&self.rec_size.to_le_bytes());
        out.extend_from_slice(&(self.dims.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.attrs.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.vars.len() as u32).to_le_bytes());
        for d in &self.dims {
            put_bytes(&mut out, d.name.as_bytes());
            out.extend_from_slice(&d.len.to_le_bytes());
        }
        put_attrs(&mut out, &self.attrs);
        for v in &self.vars {
            put_bytes(&mut out, v.name.as_bytes());
            out.push(prim_code(v.prim));
            out.push(v.external32 as u8);
            out.extend_from_slice(&(v.dimids.len() as u32).to_le_bytes());
            for &id in &v.dimids {
                out.extend_from_slice(&id.to_le_bytes());
            }
            out.extend_from_slice(&(v.attrs.len() as u32).to_le_bytes());
            put_attrs(&mut out, &v.attrs);
            out.extend_from_slice(&v.data_offset.to_le_bytes());
        }
        let total = out.len() as u64;
        out[8..16].copy_from_slice(&total.to_le_bytes());
        out
    }

    /// Parse the `header_bytes` field out of the first
    /// [`PREAMBLE_BYTES`] of the file, validating magic and version.
    pub fn total_bytes(preamble: &[u8]) -> Result<usize> {
        if preamble.len() < PREAMBLE_BYTES {
            return Err(err_io("dataset header: file shorter than the preamble"));
        }
        if preamble[..4] != MAGIC {
            return Err(err_io("dataset header: bad magic (not a jpio dataset)"));
        }
        let version = u32::from_le_bytes(preamble[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(err_io(format!(
                "dataset header: unsupported container version {version} (expected {VERSION})"
            )));
        }
        let total = u64::from_le_bytes(preamble[8..16].try_into().unwrap());
        if (total as usize) < PREAMBLE_BYTES {
            return Err(err_io(format!("dataset header: implausible header length {total}")));
        }
        Ok(total as usize)
    }

    /// Decode a complete serialized header.
    pub fn decode(raw: &[u8]) -> Result<Header> {
        let total = Self::total_bytes(raw)?;
        if raw.len() < total {
            return Err(err_io(format!(
                "dataset header: {} bytes supplied, header declares {total}",
                raw.len()
            )));
        }
        let mut c = Cursor { buf: &raw[..total], pos: PREAMBLE_BYTES };
        let num_recs = c.u64()?;
        let data_start = c.u64()?;
        let rec_start = c.u64()?;
        let rec_size = c.u64()?;
        let ndims = c.u32()? as usize;
        let nattrs = c.u32()? as usize;
        let nvars = c.u32()? as usize;
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            dims.push(Dim { name: c.string()?, len: c.u64()? });
        }
        let attrs = c.attrs(nattrs)?;
        let mut vars = Vec::with_capacity(nvars);
        for _ in 0..nvars {
            let name = c.string()?;
            let prim = prim_from_code(c.u8()?)?;
            let external32 = c.u8()? != 0;
            let nvdims = c.u32()? as usize;
            let mut dimids = Vec::with_capacity(nvdims);
            for _ in 0..nvdims {
                let id = c.u32()?;
                if id as usize >= ndims {
                    return Err(err_io(format!(
                        "dataset header: variable {name:?} names dimension {id} of {ndims}"
                    )));
                }
                dimids.push(id);
            }
            let nvattrs = c.u32()? as usize;
            let vattrs = c.attrs(nvattrs)?;
            let data_offset = c.u64()?;
            vars.push(Var { name, prim, external32, dimids, attrs: vattrs, data_offset });
        }
        if c.pos != total {
            return Err(err_io(format!(
                "dataset header: {} trailing bytes after the last variable",
                total - c.pos
            )));
        }
        Ok(Header { num_recs, data_start, rec_start, rec_size, dims, attrs, vars })
    }

    /// The declared length of a dimension, by id.
    pub fn dim_len(&self, id: u32) -> Result<u64> {
        self.dims
            .get(id as usize)
            .map(|d| d.len)
            .ok_or_else(|| err_arg(format!("dataset: no dimension with id {id}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Header {
        Header {
            num_recs: 3,
            data_start: 4096,
            rec_start: 4096 + 96,
            rec_size: 24,
            dims: vec![
                Dim { name: "time".into(), len: UNLIMITED },
                Dim { name: "x".into(), len: 4 },
                Dim { name: "y".into(), len: 6 },
            ],
            attrs: vec![Attr { name: "title".into(), value: b"demo".to_vec() }],
            vars: vec![
                Var {
                    name: "grid".into(),
                    prim: Prim::Int,
                    external32: true,
                    dimids: vec![1, 2],
                    attrs: vec![Attr { name: "units".into(), value: b"K".to_vec() }],
                    data_offset: 4096,
                },
                Var {
                    name: "series".into(),
                    prim: Prim::Double,
                    external32: false,
                    dimids: vec![0, 2],
                    attrs: vec![],
                    data_offset: 0,
                },
            ],
        }
    }

    #[test]
    fn round_trip_is_identity() {
        let h = sample();
        let raw = h.encode();
        assert_eq!(Header::total_bytes(&raw).unwrap(), raw.len());
        let back = Header::decode(&raw).unwrap();
        assert_eq!(back, h);
        // Deterministic re-encode: the drift-check invariant.
        assert_eq!(back.encode(), raw);
    }

    #[test]
    fn rejects_bad_magic_version_and_truncation() {
        let raw = sample().encode();
        let mut bad = raw.clone();
        bad[0] = b'X';
        assert!(Header::total_bytes(&bad).is_err());
        let mut bad = raw.clone();
        bad[4] = 99;
        assert!(Header::total_bytes(&bad).is_err());
        assert!(Header::decode(&raw[..raw.len() - 1]).is_err());
        assert!(Header::total_bytes(&raw[..8]).is_err());
    }

    #[test]
    fn rejects_dangling_dimension_ids() {
        let mut h = sample();
        h.vars[0].dimids = vec![7];
        assert!(Header::decode(&h.encode()).is_err());
    }

    #[test]
    fn prim_codes_round_trip() {
        for p in [
            Prim::Byte,
            Prim::Short,
            Prim::Int,
            Prim::Long,
            Prim::Float,
            Prim::Double,
            Prim::Char,
            Prim::Boolean,
        ] {
            assert_eq!(prim_from_code(prim_code(p)).unwrap(), p);
        }
        assert!(prim_from_code(42).is_err());
    }
}
