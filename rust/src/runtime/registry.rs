//! Artifact registry: name → compiled PJRT executable.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::io::errors::{err_io, err_no_such_file, IoError, Result};

/// A dense float32 tensor crossing the Rust↔PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF32 {
    /// Row-major data.
    pub data: Vec<f32>,
    /// Dimensions.
    pub dims: Vec<usize>,
}

impl TensorF32 {
    /// Construct, checking the element count.
    pub fn new(data: Vec<f32>, dims: Vec<usize>) -> TensorF32 {
        assert_eq!(data.len(), dims.iter().product::<usize>(), "shape mismatch");
        TensorF32 { data, dims }
    }

    /// A zero tensor.
    pub fn zeros(dims: &[usize]) -> TensorF32 {
        TensorF32 { data: vec![0.0; dims.iter().product()], dims: dims.to_vec() }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// The PJRT client plus every compiled artifact.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Dispatch counters per artifact (perf §L2 accounting).
    counters: Mutex<HashMap<String, u64>>,
}

impl Runtime {
    /// Create the CPU PJRT client and compile every `*.hlo.txt` artifact
    /// in `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| err_io(format!("PJRT client: {e}")))?;
        let mut exes = HashMap::new();
        let entries = std::fs::read_dir(dir)
            .map_err(|e| IoError::from_os(e, format!("artifact dir {}", dir.display())))?;
        for entry in entries {
            let entry = entry.map_err(|e| IoError::from_os(e, "artifact dir entry"))?;
            let path = entry.path();
            let fname = path.file_name().unwrap_or_default().to_string_lossy().to_string();
            if let Some(name) = fname.strip_suffix(".hlo.txt") {
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().expect("artifact path is utf-8"),
                )
                .map_err(|e| err_io(format!("parse {fname}: {e}")))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| err_io(format!("compile {fname}: {e}")))?;
                exes.insert(name.to_string(), exe);
            }
        }
        if exes.is_empty() {
            return Err(err_no_such_file(format!(
                "no *.hlo.txt artifacts in {} (run `make artifacts`)",
                dir.display()
            )));
        }
        Ok(Runtime { client, exes, counters: Mutex::new(HashMap::new()) })
    }

    /// Load from the conventional `artifacts/` directory next to the
    /// working directory, if present.
    pub fn load_default() -> Result<Runtime> {
        Runtime::load("artifacts")
    }

    /// Names of all loaded artifacts, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.exes.keys().cloned().collect();
        v.sort();
        v
    }

    /// Whether an artifact is available.
    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Dispatch counts per artifact since load.
    pub fn dispatch_counts(&self) -> HashMap<String, u64> {
        self.counters.lock().unwrap().clone()
    }

    /// Execute artifact `name` on float32 inputs, returning the tuple of
    /// float32 outputs. (All jpio artifacts are lowered with
    /// `return_tuple=True`.)
    pub fn exec_f32(&self, name: &str, inputs: &[TensorF32]) -> Result<Vec<TensorF32>> {
        self.exec_literals(
            name,
            inputs
                .iter()
                .map(|t| {
                    xla::Literal::vec1(&t.data)
                        .reshape(&t.dims.iter().map(|&d| d as i64).collect::<Vec<_>>())
                        .map_err(|e| err_io(format!("reshape input for {name}: {e}")))
                })
                .collect::<Result<Vec<_>>>()?,
        )?
        .into_iter()
        .map(|lit| {
            let shape = lit
                .shape()
                .map_err(|e| err_io(format!("output shape of {name}: {e}")))?;
            let dims = match &shape {
                xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
                _ => vec![],
            };
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| err_io(format!("output of {name} is not f32: {e}")))?;
            Ok(TensorF32 { data, dims })
        })
        .collect()
    }

    /// Execute artifact `name` where some outputs may be int32 (e.g. the
    /// byteswap payload viewed as raw words). Returns raw literals.
    pub fn exec_literals(
        &self,
        name: &str,
        inputs: Vec<xla::Literal>,
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| err_no_such_file(format!("artifact {name:?} not loaded")))?;
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += 1;
        let result = exe
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| err_io(format!("execute {name}: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| err_io(format!("fetch result of {name}: {e}")))?;
        lit.to_tuple().map_err(|e| err_io(format!("untuple result of {name}: {e}")))
    }

    /// Execute `init` for a rank at grid coordinates `(gy, gx)`.
    pub fn exec_init(&self, gy: i32, gx: i32) -> Result<TensorF32> {
        let exe = self
            .exes
            .get("init")
            .ok_or_else(|| err_no_such_file("artifact \"init\" not loaded"))?;
        *self.counters.lock().unwrap().entry("init".into()).or_insert(0) += 1;
        let input = xla::Literal::vec1(&[gy, gx]);
        let result = exe
            .execute::<xla::Literal>(&[input])
            .map_err(|e| err_io(format!("execute init: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| err_io(format!("fetch init: {e}")))?;
        let out = lit.to_tuple1().map_err(|e| err_io(format!("untuple init: {e}")))?;
        let shape = out.shape().map_err(|e| err_io(format!("init shape: {e}")))?;
        let dims = match &shape {
            xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
            _ => vec![],
        };
        let data =
            out.to_vec::<f32>().map_err(|e| err_io(format!("init output: {e}")))?;
        Ok(TensorF32 { data, dims })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Runtime tests need `make artifacts` to have run; they skip (with a
    /// loud note) when the artifacts are absent so `cargo test` stays
    /// usable before the first build.
    fn runtime() -> Option<Runtime> {
        let dir = std::path::Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
            return None;
        }
        Some(Runtime::load(dir).expect("artifacts present but unloadable"))
    }

    #[test]
    fn loads_all_artifacts() {
        let Some(rt) = runtime() else { return };
        for name in ["stencil", "pack", "unpack", "byteswap", "checksum", "tick", "init"] {
            assert!(rt.has(name), "missing artifact {name}");
        }
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn stencil_artifact_matches_reference_numerics() {
        let Some(rt) = runtime() else { return };
        // Constant field is a fixed point of the Jacobi average.
        let halo = 258;
        let x = TensorF32::new(vec![2.0; halo * halo], vec![halo, halo]);
        let out = rt.exec_f32("stencil", &[x]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, vec![256, 256]);
        assert!(out[0].data.iter().all(|&v| (v - 2.0).abs() < 1e-6));
    }

    #[test]
    fn pack_unpack_roundtrip_through_pjrt() {
        let Some(rt) = runtime() else { return };
        let halo = 258;
        let mut base = TensorF32::zeros(&[halo, halo]);
        for (i, v) in base.data.iter_mut().enumerate() {
            *v = (i % 1000) as f32;
        }
        let packed = rt.exec_f32("pack", &[base.clone()]).unwrap().remove(0);
        assert_eq!(packed.dims, vec![256, 256]);
        let rebuilt = rt.exec_f32("unpack", &[base.clone(), packed]).unwrap().remove(0);
        assert_eq!(rebuilt.data, base.data);
    }

    #[test]
    fn tick_produces_state_and_checksum() {
        let Some(rt) = runtime() else { return };
        let halo = 258;
        let x = TensorF32::new(vec![1.0; halo * halo], vec![halo, halo]);
        let out = rt.exec_f32("tick", &[x]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].dims, vec![256, 256]);
        assert_eq!(out[1].dims, vec![2]);
        // Checksum of an all-ones 256x256 field: sum = 65536.
        assert!((out[1].data[0] - 65536.0).abs() < 1.0);
        assert!(rt.dispatch_counts()["tick"] >= 1);
    }

    #[test]
    fn init_differs_per_rank() {
        let Some(rt) = runtime() else { return };
        let a = rt.exec_init(0, 0).unwrap();
        let b = rt.exec_init(1, 1).unwrap();
        assert_eq!(a.dims, vec![258, 258]);
        assert_ne!(a.data, b.data);
    }

    #[test]
    fn unknown_artifact_is_a_clean_error() {
        let Some(rt) = runtime() else { return };
        let err = rt.exec_f32("warp_drive", &[]).map(|_| ()).unwrap_err();
        assert_eq!(err.class, crate::io::errors::ErrorClass::NoSuchFile);
    }
}
