//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and executes
//! them from the Rust side — the L3↔L2 bridge of the three-layer stack.
//!
//! `make artifacts` runs `python/compile/aot.py` once; afterwards the
//! Rust binary is self-contained: artifacts are HLO *text* (see
//! aot.py for why), parsed by `HloModuleProto::from_text_file`, compiled
//! by the PJRT CPU client at startup, and executed on the hot path with
//! no Python anywhere.

pub mod registry;

pub use registry::{Runtime, TensorF32};
