//! Striped parallel-file-system backend.
//!
//! The paper's evaluation stops at single-server storage — local disk, one
//! NFS server, a SAN — so aggregate write bandwidth is capped by one
//! server's ingest rate (the ~250 MB/s plateau of Fig 4-4). Parallel file
//! systems remove that cap by *declustering* the logical file over many
//! I/O servers (ViPIOS; PVFS; Lustre). [`StripedBackend`] does exactly
//! that: a logical file is split into fixed-size stripe units laid out
//! round-robin over N child [`Backend`]s (any mix of local/NFS/SAN
//! backends, each with its own performance model and fault injector), each
//! holding one *stripe object* — a plain file on that child.
//!
//! * **Data path** — `read_at`/`write_at`/`read_runs`/`write_runs` split
//!   logical runs at stripe boundaries ([`StripeMap`]), group
//!   the pieces per server, and issue one vectored transfer per server
//!   *concurrently* on the [`engine`](crate::io::engine) stripe pool, so
//!   aggregate bandwidth scales with servers instead of serializing at
//!   one ingest lock.
//! * **Redundancy** — the `jpio_stripe_redundancy` hint
//!   ([`Redundancy`]) makes a lost server degrade service instead of
//!   failing the file (the ViPIOS case for pushing redundancy into the
//!   parallel I/O layer):
//!   - `replica:<k>` mirrors every stripe object onto the next `k-1`
//!     servers round-robin (separate *replica objects*); reads fall
//!     over to a surviving copy, writes update all copies.
//!   - `parity` interleaves one rotating parity unit per stripe row
//!     into the stripe objects themselves (RAID-5; see
//!     [`layout`](super::layout)); a failed server's slot — data or
//!     parity — is reconstructed as the XOR of the surviving slots.
//!     Parity updates are read-modify-write over the affected rows and
//!     serialize on a per-file stripe-consistency lock
//!     (`<name>.jpio-plock`) — the classic RAID-5 small-write penalty,
//!     measured in ablation 6c.
//!   Operations that survive a failure report it out-of-band as an
//!   [`ErrorClass::Degraded`] advisory ([`StorageFile::take_advisories`])
//!   instead of an `Err`; failures beyond the mode's tolerance surface
//!   as plain errors. A server that fails a write is assumed
//!   *failed-stop* (dead for the file's lifetime): redundant copies and
//!   parity are updated with the intended contents, so a server that
//!   "comes back" with stale data is outside the model.
//! * **Metadata** — the logical size lives in a flocked metadata sidecar
//!   (`<name>.jpio-size`), the substitution for a parallel file system's
//!   metadata server (PVFS's mgr, ViPIOS's directory service): `size()`
//!   reads one 8-byte sidecar instead of issuing a GETATTR to every
//!   child server, writes that extend the file publish the new EOF *after*
//!   the data dispatch succeeded (an unlocked 8-byte sidecar check skips
//!   the flock cycle when the file already covers the write), and
//!   `set_size`/`truncate`/`preallocate` invalidate by publishing the
//!   exact new size. A missing sidecar (objects created by other means)
//!   is rebuilt from a one-time full child poll at open, and a sidecar
//!   that cannot be read or published falls back to that same GETATTR
//!   fan-out instead of serving (or leaving behind) a stale EOF.
//! * **Locking** — `lock_exclusive` acquires every child's lock in server
//!   order (the classic total-order protocol), so concurrent distributed
//!   lockers cannot deadlock; the guard releases all of them.
//! * **Mapped mode** — a buffered region emulation (like the NFS one):
//!   loaded from the stripes on creation, dirty ranges written back
//!   vectored on `flush`.
//!
//! The collective layer reads [`StorageFile::stripe_layout`] off these
//! files to align two-phase file domains to stripe boundaries — see
//! `io::collective`.
//!
//! ## Elastic membership (DESIGN.md §1c)
//!
//! Server membership is no longer frozen at first open:
//!
//! * **Background rebuild** — a replaced/blank server (its objects
//!   shorter than the layout prescribes) is re-materialized from the
//!   survivors: replica rows are copied from a surviving copy, parity
//!   rows are the XOR of the surviving slots. The rebuild runs in
//!   row batches under the stripe-consistency lock (writes interleave
//!   between batches), persists its position in a `<name>.jpio-rebuild`
//!   cursor sidecar so it resumes across opens, and runs on the shared
//!   maintenance lane ([`crate::comm::progress::maintenance_engine`])
//!   when started via the `jpio_rebuild = start` hint.
//! * **Live restriping** — opening a file whose recorded layout
//!   (`<name>.jpio-layout` sidecar) differs from the requested
//!   `striping_factor`/`jpio_stripe_redundancy` starts a background
//!   migration into a new layout *generation* (objects
//!   `<name>.jpio-g<g>-s<i>of<f>`; generation 0 keeps the legacy
//!   names). A high-water byte cursor in the layout sidecar routes
//!   every read/write: bytes below the cursor live in the new
//!   generation, bytes at or above it in the old
//!   ([`LayoutRouter`]); each migration step copies the next chunk
//!   under the stripe-consistency lock and advances the cursor.
//!   Metadata ops (`set_size`/`preallocate`/`map`/`lock_exclusive`)
//!   complete the migration synchronously first.
//! * **Health tracking** — a server that failed an operation is marked
//!   dead in this handle's health vector
//!   ([`StorageFile::server_health`]); the collective layer biases
//!   stripe-cyclic file domains away from dead servers, and a
//!   completed rebuild marks its target healthy again.

use std::os::unix::fs::FileExt;
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::comm::progress;
use crate::io::engine;
use crate::io::errors::{err_arg, err_io, ErrorClass, IoError, Result};

use super::layout::{LayoutRouter, Redundancy, Segment, StripeLayout, StripeMap};
use super::local::{check_bounds, lock_cell_for, LocalBackend};
use super::nfs::{NfsBackend, NfsConfig};
use super::{Backend, FileLockGuard, MappedRegion, OpenOptions, StorageFile};

/// A backend declustering files round-robin across child backends.
pub struct StripedBackend {
    children: Vec<Arc<dyn Backend>>,
    map: StripeMap,
}

impl StripedBackend {
    /// Stripe across the given children with `unit`-byte stripe units.
    /// The striping factor is `children.len()`.
    pub fn new(children: Vec<Arc<dyn Backend>>, unit: u64) -> Result<StripedBackend> {
        StripedBackend::with_redundancy(children, unit, Redundancy::None)
    }

    /// [`StripedBackend::new`] with a redundancy mode (replica/parity
    /// stripes; see the module docs).
    pub fn with_redundancy(
        children: Vec<Arc<dyn Backend>>,
        unit: u64,
        redundancy: Redundancy,
    ) -> Result<StripedBackend> {
        let layout = StripeLayout::new(unit, children.len())?;
        let map = StripeMap::new(layout, redundancy)?;
        Ok(StripedBackend { children, map })
    }

    /// `factor` unmodelled local children (functional tests).
    pub fn local(factor: usize, unit: u64) -> StripedBackend {
        StripedBackend::local_redundant(factor, unit, Redundancy::None)
    }

    /// [`StripedBackend::local`] with a redundancy mode.
    pub fn local_redundant(factor: usize, unit: u64, redundancy: Redundancy) -> StripedBackend {
        let children = (0..factor)
            .map(|_| Arc::new(LocalBackend::instant()) as Arc<dyn Backend>)
            .collect();
        StripedBackend::with_redundancy(children, unit, redundancy)
            .expect("valid stripe parameters")
    }

    /// `factor` simulated NFS servers, each with its own copy of `cfg`
    /// (so each server serializes its own ingest, independently).
    pub fn nfs(factor: usize, unit: u64, cfg: NfsConfig) -> StripedBackend {
        let children = (0..factor)
            .map(|_| Arc::new(NfsBackend::new(cfg)) as Arc<dyn Backend>)
            .collect();
        StripedBackend::new(children, unit).expect("valid stripe parameters")
    }

    /// The stripe layout of this backend.
    pub fn layout(&self) -> StripeLayout {
        self.map.layout
    }

    /// The redundancy mode of this backend.
    pub fn redundancy(&self) -> Redundancy {
        self.map.redundancy
    }

    /// Path of `server`'s stripe object for logical file `path`. Public
    /// so tests and tooling can inspect physical placement.
    pub fn object_path(path: &str, server: usize, factor: usize) -> String {
        format!("{path}.jpio-s{server}of{factor}")
    }

    /// Path of replica copy `copy` (1-based) of `server`'s stripe
    /// object; the object physically lives on child `(server + copy) %
    /// factor`.
    pub fn replica_object_path(path: &str, server: usize, factor: usize, copy: usize) -> String {
        format!("{path}.jpio-s{server}of{factor}.r{copy}")
    }

    /// [`StripedBackend::object_path`] for layout generation `gen`:
    /// restriping rewrites the file into a fresh object namespace per
    /// generation; generation 0 keeps the legacy names.
    pub fn object_path_gen(path: &str, server: usize, factor: usize, gen: u64) -> String {
        if gen == 0 {
            Self::object_path(path, server, factor)
        } else {
            format!("{path}.jpio-g{gen}-s{server}of{factor}")
        }
    }

    /// [`StripedBackend::replica_object_path`] for layout generation
    /// `gen`.
    pub fn replica_object_path_gen(
        path: &str,
        server: usize,
        factor: usize,
        copy: usize,
        gen: u64,
    ) -> String {
        if gen == 0 {
            Self::replica_object_path(path, server, factor, copy)
        } else {
            format!("{path}.jpio-g{gen}-s{server}of{factor}.r{copy}")
        }
    }

    /// Path of the layout sidecar recording the file's current layout
    /// generation and, during a live restriping, the old generation
    /// plus the migration's high-water byte cursor.
    pub fn layout_meta_path(path: &str) -> String {
        format!("{path}.jpio-layout")
    }

    /// Path of the rebuild cursor sidecar: while a redundancy rebuild
    /// is in flight it records the target server and the next stripe
    /// row to re-materialize, so the rebuild resumes across opens.
    pub fn rebuild_cursor_path(path: &str) -> String {
        format!("{path}.jpio-rebuild")
    }

    /// Path of the logical-size metadata sidecar for logical file `path`
    /// (the metadata-server substitution; see the module docs).
    pub fn size_meta_path(path: &str) -> String {
        format!("{path}.jpio-size")
    }

    /// Path of the stripe-consistency lock serializing parity
    /// read-modify-write cycles across handles and processes.
    pub fn parity_lock_path(path: &str) -> String {
        format!("{path}.jpio-plock")
    }
}

/// The logical-EOF metadata sidecar: an 8-byte LE size updated under an
/// OS file lock, shared across handles, threads and forked processes.
/// Every decision reads the *shared* sidecar, never a per-handle copy —
/// a cached skip would be unsound the moment another handle shrinks the
/// file (`set_size` runs on rank 0 only), and a stale-high cache would
/// then suppress the publish that readers depend on.
struct SizeMeta {
    path: String,
}

impl SizeMeta {
    fn new(path: &str) -> SizeMeta {
        SizeMeta { path: StripedBackend::size_meta_path(path) }
    }

    fn with_locked_file<T>(&self, f: impl FnOnce(&std::fs::File) -> Result<T>) -> Result<T> {
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&self.path)
            .map_err(|e| IoError::from_os(e, "striped size metadata"))?;
        let fd = file.as_raw_fd();
        if unsafe { libc::flock(fd, libc::LOCK_EX) } != 0 {
            return Err(err_io("flock striped size metadata"));
        }
        let out = f(&file);
        unsafe { libc::flock(fd, libc::LOCK_UN) };
        out
    }

    fn read_value(file: &std::fs::File) -> Result<Option<u64>> {
        let mut buf = [0u8; 8];
        match file.read_exact_at(&mut buf, 0) {
            Ok(()) => Ok(Some(u64::from_le_bytes(buf))),
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Ok(None),
            Err(e) => Err(IoError::from_os(e, "striped size metadata read")),
        }
    }

    fn write_value(file: &std::fs::File, value: u64) -> Result<()> {
        file.write_all_at(&value.to_le_bytes(), 0)
            .map_err(|e| IoError::from_os(e, "striped size metadata write"))
    }

    /// The current logical size, or `None` when the sidecar does not
    /// exist yet (rebuild via [`SizeMeta::read_or_init`]).
    fn read_fast(&self) -> Result<Option<u64>> {
        let file = match std::fs::File::open(&self.path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(IoError::from_os(e, "striped size metadata")),
        };
        Self::read_value(&file)
    }

    /// Read the size, initializing the sidecar from `init` (a full child
    /// poll) when missing — all under the lock, so concurrent openers
    /// cannot clobber a published extension with a stale poll.
    fn read_or_init(&self, init: impl FnOnce() -> Result<u64>) -> Result<u64> {
        self.with_locked_file(|file| {
            if let Some(v) = Self::read_value(file)? {
                return Ok(v);
            }
            let v = init()?;
            Self::write_value(file, v)?;
            Ok(v)
        })
    }

    /// A successful write reached logical offset `end`: grow the shared
    /// size monotonically. The covered-already check reads the shared
    /// sidecar unlocked (one 8-byte pread, no flock cycle); a write
    /// racing a truncation is unsynchronized application behaviour, so
    /// the lock-free check cannot lose a legitimate extension.
    fn publish_extend(&self, end: u64) -> Result<()> {
        if let Some(cur) = self.read_fast()? {
            if cur >= end {
                return Ok(());
            }
        }
        self.with_locked_file(|file| {
            let cur = Self::read_value(file)?.unwrap_or(0);
            if end > cur {
                Self::write_value(file, end)?;
            }
            Ok(())
        })
    }

    /// Truncate/resize invalidation: publish the exact new size.
    fn publish_exact(&self, size: u64) -> Result<()> {
        self.with_locked_file(|file| Self::write_value(file, size))
    }

    /// Remove the sidecar so the next `size()` rebuilds from the child
    /// GETATTR fan-out. Returns whether the stale sidecar is gone.
    fn invalidate(&self) -> bool {
        match std::fs::remove_file(&self.path) {
            Ok(()) => true,
            Err(e) => e.kind() == std::io::ErrorKind::NotFound,
        }
    }
}

/// Magic tag of the layout sidecar ("JPIOLYT1").
const LAYOUT_MAGIC: u64 = 0x4A50_494F_4C59_5431;
/// Magic tag of the rebuild cursor sidecar ("JPIORBLD").
const REBUILD_MAGIC: u64 = 0x4A50_494F_5242_4C44;

/// The layout sidecar record: the file's current layout generation and,
/// while a restriping migration is in flight, the generation being
/// migrated away from plus the high-water byte cursor — logical bytes
/// below the cursor live in the new generation, bytes at or above it in
/// the old one (see [`LayoutRouter`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct LayoutRecord {
    gen: u64,
    map: StripeMap,
    /// `(old_gen, old_map, cursor)` while a migration is in flight.
    old: Option<(u64, StripeMap, u64)>,
}

/// The layout sidecar (`<name>.jpio-layout`): fourteen LE `u64` fields
/// updated under an OS file lock, shared across handles and processes.
/// It makes the striping parameters a property of the *file* rather
/// than of whichever backend happens to open it, which is what lets an
/// open with different `striping_factor`/redundancy hints start a
/// migration instead of silently reading garbage.
struct LayoutMeta {
    path: String,
}

impl LayoutMeta {
    fn new(path: &str) -> LayoutMeta {
        LayoutMeta { path: StripedBackend::layout_meta_path(path) }
    }

    fn with_locked_file<T>(&self, f: impl FnOnce(&std::fs::File) -> Result<T>) -> Result<T> {
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&self.path)
            .map_err(|e| IoError::from_os(e, "striped layout sidecar"))?;
        let fd = file.as_raw_fd();
        if unsafe { libc::flock(fd, libc::LOCK_EX) } != 0 {
            return Err(err_io("flock striped layout sidecar"));
        }
        let out = f(&file);
        unsafe { libc::flock(fd, libc::LOCK_UN) };
        out
    }

    fn encode(rec: &LayoutRecord) -> [u8; 112] {
        let (rtag, rk) = rec.map.redundancy.tag();
        let (state, old_gen, old_factor, old_unit, old_rtag, old_rk, cursor) = match rec.old {
            None => (0, 0, 0, 0, 0, 0, 0),
            Some((og, om, cur)) => {
                let (ot, ok_) = om.redundancy.tag();
                (1, og, om.layout.factor as u64, om.layout.unit, ot, ok_, cur)
            }
        };
        let fields: [u64; 14] = [
            LAYOUT_MAGIC,
            1, // version
            state,
            rec.gen,
            rec.map.layout.factor as u64,
            rec.map.layout.unit,
            rtag,
            rk,
            old_gen,
            old_factor,
            old_unit,
            old_rtag,
            old_rk,
            cursor,
        ];
        let mut buf = [0u8; 112];
        for (i, v) in fields.iter().enumerate() {
            buf[i * 8..(i + 1) * 8].copy_from_slice(&v.to_le_bytes());
        }
        buf
    }

    fn decode_map(factor: u64, unit: u64, rtag: u64, rk: u64) -> Result<StripeMap> {
        let layout = StripeLayout::new(unit, factor as usize)?;
        let red = Redundancy::from_tag(rtag, rk)
            .ok_or_else(|| err_io("striped layout sidecar: unknown redundancy tag"))?;
        StripeMap::new(layout, red)
    }

    fn read_value(file: &std::fs::File) -> Result<Option<LayoutRecord>> {
        let mut buf = [0u8; 112];
        match file.read_exact_at(&mut buf, 0) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
            Err(e) => return Err(IoError::from_os(e, "striped layout sidecar read")),
        }
        let f = |i: usize| u64::from_le_bytes(buf[i * 8..(i + 1) * 8].try_into().unwrap());
        if f(0) != LAYOUT_MAGIC || f(1) != 1 {
            return Err(err_io("striped layout sidecar corrupt"));
        }
        let map = Self::decode_map(f(4), f(5), f(6), f(7))?;
        let old = match f(2) {
            0 => None,
            _ => Some((f(8), Self::decode_map(f(9), f(10), f(11), f(12))?, f(13))),
        };
        Ok(Some(LayoutRecord { gen: f(3), map, old }))
    }

    /// The current record, or `None` when the sidecar does not exist or
    /// is empty (a legacy pre-sidecar file). Lock-free: writers only
    /// mutate it under the stripe-consistency lock or at open (under
    /// the sidecar flock), and 112-byte records are rewritten in place.
    fn read_fast(&self) -> Result<Option<LayoutRecord>> {
        let file = match std::fs::File::open(&self.path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(IoError::from_os(e, "striped layout sidecar")),
        };
        Self::read_value(&file)
    }

    /// Read-decide-write under the sidecar flock — the open-time layout
    /// negotiation, serialized against concurrent openers.
    fn update<T>(
        &self,
        f: impl FnOnce(Option<LayoutRecord>) -> Result<(Option<LayoutRecord>, T)>,
    ) -> Result<T> {
        self.with_locked_file(|file| {
            let (write_back, out) = f(Self::read_value(file)?)?;
            if let Some(rec) = write_back {
                file.write_all_at(&Self::encode(&rec), 0)
                    .map_err(|e| IoError::from_os(e, "striped layout sidecar write"))?;
            }
            Ok(out)
        })
    }

    /// Advance the migration cursor. Caller holds the stripe
    /// consistency lock; the sidecar flock still guards against
    /// open-time negotiation racing the in-place rewrite.
    fn set_cursor(&self, cursor: u64) -> Result<()> {
        self.update(|rec| match rec {
            Some(mut r) => {
                if let Some((_, _, c)) = r.old.as_mut() {
                    *c = cursor;
                }
                Ok((Some(r), ()))
            }
            None => Err(err_io("striped layout sidecar vanished mid-migration")),
        })
    }

    /// Record migration completion: a stable layout at `gen`.
    fn write_stable(&self, gen: u64, map: StripeMap) -> Result<()> {
        self.update(|_| Ok((Some(LayoutRecord { gen, map, old: None }), ())))
    }
}

/// The rebuild cursor sidecar (`<name>.jpio-rebuild`): three LE `u64`
/// fields (magic, target server, next stripe row). Present exactly
/// while a rebuild is pending — its existence is what tells replica
/// writers to serialize against the rebuild copy loop, and its removal
/// is the filesystem-visible completion signal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct RebuildCursor {
    target: u64,
    next_row: u64,
}

fn read_rebuild_cursor(path: &str) -> Result<Option<RebuildCursor>> {
    let buf = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(IoError::from_os(e, "striped rebuild cursor")),
    };
    if buf.len() < 24 {
        return Ok(None);
    }
    let f = |i: usize| u64::from_le_bytes(buf[i * 8..(i + 1) * 8].try_into().unwrap());
    if f(0) != REBUILD_MAGIC {
        return Err(err_io("striped rebuild cursor corrupt"));
    }
    Ok(Some(RebuildCursor { target: f(1), next_row: f(2) }))
}

fn write_rebuild_cursor(path: &str, c: &RebuildCursor) -> Result<()> {
    let mut buf = [0u8; 24];
    for (i, v) in [REBUILD_MAGIC, c.target, c.next_row].iter().enumerate() {
        buf[i * 8..(i + 1) * 8].copy_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, buf).map_err(|e| IoError::from_os(e, "striped rebuild cursor write"))
}

impl StripedBackend {
    /// Open `path` as a concretely-typed striped file. Pending
    /// maintenance — a persisted rebuild cursor, an in-flight restriping
    /// migration, or a migration this open's changed parameters start —
    /// continues in the background on the process-wide maintenance lane.
    /// [`Backend::open`] routes here.
    pub fn open_striped(&self, path: &str, opts: OpenOptions) -> Result<Arc<StripedFile>> {
        self.open_impl(path, opts, true)
    }

    /// [`StripedBackend::open_striped`] without spawning background
    /// maintenance: tests and tools that want deterministic stepping
    /// drive the work explicitly via [`StripedFile::migrate_step`] /
    /// [`StripedFile::rebuild_now`].
    pub fn open_striped_manual(&self, path: &str, opts: OpenOptions) -> Result<Arc<StripedFile>> {
        self.open_impl(path, opts, false)
    }

    /// The open-time layout negotiation: reconcile this backend's
    /// constructed parameters with the file's recorded layout. Returns
    /// the record to run under and whether it must be persisted.
    fn decide_layout(
        &self,
        rec: Option<LayoutRecord>,
        writable: bool,
    ) -> Result<(LayoutRecord, bool)> {
        let want = self.map;
        match rec {
            // Legacy / fresh file: generation 0 under this backend's
            // parameters (the pre-sidecar naming scheme).
            None => Ok((LayoutRecord { gen: 0, map: want, old: None }, writable)),
            // An in-flight migration is honored regardless of this
            // opener's parameters — generations never chain; the next
            // parameter change waits until the current one completes.
            Some(r) if r.old.is_some() => Ok((r, false)),
            Some(r) if r.map == want => Ok((r, false)),
            // Recorded layout differs: a read-only open honors the disk
            // layout; a writable open starts a migration into the next
            // generation behind a zero cursor.
            Some(r) if !writable => Ok((r, false)),
            Some(r) => Ok((
                LayoutRecord { gen: r.gen + 1, map: want, old: Some((r.gen, r.map, 0)) },
                true,
            )),
        }
    }

    /// Open the per-server objects of one layout generation.
    fn build_inner(
        &self,
        path: &str,
        map: StripeMap,
        gen: u64,
        opts: OpenOptions,
    ) -> Result<StripedInner> {
        let factor = map.layout.factor;
        if factor > self.children.len() {
            return Err(err_arg(format!(
                "recorded striping factor {factor} exceeds the {} configured servers",
                self.children.len()
            )));
        }
        let mut files = Vec::with_capacity(factor);
        for (i, child) in self.children.iter().take(factor).enumerate() {
            files.push(child.open(&Self::object_path_gen(path, i, factor, gen), opts)?);
        }
        // Replica objects: copy c of server s's object lives on child
        // (s + c) % factor.
        let mut replicas = Vec::new();
        if let Redundancy::Replica(k) = map.redundancy {
            for c in 1..k {
                let mut copies = Vec::with_capacity(factor);
                for s in 0..factor {
                    let holder = &self.children[replica_holder(s, c, factor)];
                    copies
                        .push(holder.open(&Self::replica_object_path_gen(path, s, factor, c, gen), opts)?);
                }
                replicas.push(copies);
            }
        }
        Ok(StripedInner {
            children: files,
            replicas,
            map,
            gen,
            meta: SizeMeta::new(path),
            plock_path: StripedBackend::parity_lock_path(path),
            rebuild_path: StripedBackend::rebuild_cursor_path(path),
            advisories: Mutex::new(Vec::new()),
            health: (0..factor).map(|_| AtomicBool::new(true)).collect(),
            degraded_reads: AtomicU64::new(0),
            parity_rmw_cycles: AtomicU64::new(0),
            fanout_bytes: AtomicU64::new(0),
            rebuild_bytes: AtomicU64::new(0),
            restripe_rows: AtomicU64::new(0),
        })
    }

    fn open_impl(&self, path: &str, opts: OpenOptions, auto: bool) -> Result<Arc<StripedFile>> {
        if path.is_empty() {
            return Err(crate::io::errors::err_bad_file("empty file name"));
        }
        let layout_meta = LayoutMeta::new(path);
        let writable = opts.write || opts.create || opts.truncate;
        let rec = if writable {
            layout_meta.update(|rec| {
                let (r, persist) = self.decide_layout(rec, true)?;
                Ok((persist.then_some(r), r))
            })?
        } else {
            self.decide_layout(layout_meta.read_fast()?, false)?.0
        };
        let cur = Arc::new(self.build_inner(path, rec.map, rec.gen, opts)?);
        let mig = match rec.old {
            Some((old_gen, old_map, _)) => {
                // The old generation's objects hold live data: never
                // truncate them at open, and tolerate sparse rows whose
                // objects were never materialized.
                let oopts = OpenOptions {
                    read: true,
                    write: writable,
                    create: writable,
                    excl: false,
                    truncate: false,
                };
                Some(MigState {
                    old: Arc::new(self.build_inner(path, old_map, old_gen, oopts)?),
                    done: AtomicBool::new(false),
                })
            }
            None => None,
        };
        if opts.truncate {
            // Children were truncated at open; the sidecar must follow.
            cur.meta.publish_exact(0)?;
        }
        // Ensure the size sidecar exists (rebuilding from a one-time
        // child poll for pre-existing objects) so the data path never
        // GETATTRs every server again. During a migration the old
        // generation holds the data, so the poll goes there.
        match &mig {
            Some(m) => {
                m.old.logical_size()?;
            }
            None => {
                cur.logical_size()?;
            }
        }
        let shared = Arc::new(StripedShared {
            cur,
            mig,
            layout_meta,
            throttle: AtomicU64::new(0),
        });
        if auto && writable {
            if shared.mig.is_some() {
                shared.spawn_migration_driver();
            }
            if shared.cur.rebuild_active() {
                shared.spawn_rebuild_driver();
            }
        }
        Ok(Arc::new(StripedFile { shared }))
    }
}

impl Backend for StripedBackend {
    fn open(&self, path: &str, opts: OpenOptions) -> Result<Arc<dyn StorageFile>> {
        let f = self.open_striped(path, opts)?;
        Ok(f)
    }

    fn delete(&self, path: &str) -> Result<()> {
        let rec = LayoutMeta::new(path).read_fast().ok().flatten();
        let _ = std::fs::remove_file(Self::size_meta_path(path));
        let _ = std::fs::remove_file(Self::parity_lock_path(path));
        let _ = std::fs::remove_file(Self::layout_meta_path(path));
        let _ = std::fs::remove_file(Self::rebuild_cursor_path(path));
        // Generations to sweep: the recorded current one first (its
        // stripe-0 object decides existence), then the migration
        // source and the legacy generation-0 namespace.
        let mut gens: Vec<(u64, StripeMap)> = Vec::new();
        match rec {
            Some(r) => {
                gens.push((r.gen, r.map));
                if let Some((og, om, _)) = r.old {
                    gens.push((og, om));
                }
                if !gens.iter().any(|&(g, _)| g == 0) {
                    gens.push((0, self.map));
                }
            }
            None => gens.push((0, self.map)),
        }
        let mut first_err = None;
        for (which, (gen, map)) in gens.into_iter().enumerate() {
            let factor = map.layout.factor;
            for i in 0..factor {
                let child = self.children.get(i).unwrap_or(&self.children[0]);
                match child.delete(&Self::object_path_gen(path, i, factor, gen)) {
                    Ok(()) => {}
                    // A logical file whose later stripes were never
                    // touched has no objects there; only the current
                    // generation's stripe 0 decides existence.
                    Err(e) if (which > 0 || i > 0) && e.class == ErrorClass::NoSuchFile => {}
                    Err(e) => {
                        first_err.get_or_insert(e);
                    }
                }
            }
            if let Redundancy::Replica(k) = map.redundancy {
                for c in 1..k {
                    for s in 0..factor {
                        let h = replica_holder(s, c, factor);
                        let holder = self.children.get(h).unwrap_or(&self.children[0]);
                        match holder.delete(&Self::replica_object_path_gen(path, s, factor, c, gen))
                        {
                            Ok(()) => {}
                            Err(e) if e.class == ErrorClass::NoSuchFile => {}
                            Err(e) => {
                                first_err.get_or_insert(e);
                            }
                        }
                    }
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    fn name(&self) -> &'static str {
        "striped"
    }
}

/// Boxed per-server dispatch job: data, replica, and parity transfers
/// of one operation mix in a single fan-out, so the closure type is
/// erased.
type IoJob<T> = Box<dyn FnOnce() -> Result<T> + Send>;

/// Copy a per-server packed read result back into the caller's buffer.
fn scatter(segs: &[Segment], tmp: &[u8], buf: &mut [u8]) {
    let mut cursor = 0usize;
    for seg in segs {
        buf[seg.buf_pos..seg.buf_pos + seg.len].copy_from_slice(&tmp[cursor..cursor + seg.len]);
        cursor += seg.len;
    }
}

/// Pack the caller bytes of `segs` back-to-back — the inverse of
/// [`scatter`], shared by every write dispatch path. The per-server
/// packed transfer is built straight off the [`Payload`] view, so the
/// zero-copy collective path never materializes the logical buffer.
fn gather(segs: &[Segment], pay: &Payload<'_>) -> Vec<u8> {
    let total: usize = segs.iter().map(|s| s.len).sum();
    let mut payload = Vec::with_capacity(total);
    for seg in segs {
        payload.extend_from_slice(pay.slice(seg.buf_pos, seg.len));
    }
    payload
}

/// The caller bytes behind a set of segments: either one packed buffer
/// (`Segment::buf_pos` indexes it directly) or the collective layer's
/// exchange pieces viewed as a virtual concatenation (`buf_pos` indexes
/// the concatenation; bytes are served from each piece in place — the
/// zero-copy collective-write path). Every segment is split from a
/// single run/piece, so any `(buf_pos, len)` range lies inside exactly
/// one piece and is served as a borrowed slice, never a copy.
enum Payload<'a> {
    Flat(&'a [u8]),
    Pieces {
        pieces: &'a [(u64, &'a [u8])],
        /// `starts[i]` = virtual position of `pieces[i]`'s first byte.
        starts: Vec<usize>,
    },
}

impl<'a> Payload<'a> {
    fn pieces(pieces: &'a [(u64, &'a [u8])]) -> Payload<'a> {
        let mut starts = Vec::with_capacity(pieces.len());
        let mut pos = 0usize;
        for &(_, bytes) in pieces {
            starts.push(pos);
            pos += bytes.len();
        }
        Payload::Pieces { pieces, starts }
    }

    /// The payload bytes at virtual range `[pos, pos + len)`.
    fn slice(&self, pos: usize, len: usize) -> &[u8] {
        match self {
            Payload::Flat(buf) => &buf[pos..pos + len],
            Payload::Pieces { pieces, starts } => {
                // Last piece starting at or before `pos` — empty pieces
                // share a start with their successor and own no range.
                let i = starts.partition_point(|&s| s <= pos) - 1;
                let within = pos - starts[i];
                &pieces[i].1[within..within + len]
            }
        }
    }
}

/// Child physically holding replica copy `copy` (1-based) of `server`'s
/// stripe object — the one place the replica placement rule lives.
fn replica_holder(server: usize, copy: usize, factor: usize) -> usize {
    (server + copy) % factor
}

fn xor_into(acc: &mut [u8], src: &[u8]) {
    for (a, b) in acc.iter_mut().zip(src) {
        *a ^= b;
    }
}

/// Whether the (unsorted, possibly overlapping) intervals cover the
/// whole `[0, unit)` slot. Sorts in place.
fn covers_unit(iv: &mut [(u64, u64)], unit: u64) -> bool {
    iv.sort_unstable();
    let mut end = 0u64;
    for &(a, b) in iv.iter() {
        if a > end {
            return false;
        }
        end = end.max(b);
    }
    end >= unit
}

/// Record the first error seen per child; the degraded-mode tolerance
/// counts *distinct failed children*, not failed operations.
fn record_failure(failed: &mut Vec<(usize, IoError)>, child: usize, err: IoError) {
    if !failed.iter().any(|(c, _)| *c == child) {
        failed.push((child, err));
    }
}

/// Shared state of one layout generation of an open striped file.
struct StripedInner {
    children: Vec<Arc<dyn StorageFile>>,
    /// `replicas[c-1][s]` = copy `c` of server `s`'s stripe object,
    /// physically on child `(s + c) % factor`. Empty unless
    /// `Redundancy::Replica`.
    replicas: Vec<Vec<Arc<dyn StorageFile>>>,
    map: StripeMap,
    /// Layout generation these objects belong to (0 = legacy names).
    gen: u64,
    meta: SizeMeta,
    /// Stripe-consistency lock file path (parity read-modify-write).
    plock_path: String,
    /// Rebuild cursor sidecar path (`<name>.jpio-rebuild`).
    rebuild_path: String,
    /// Pending degraded-mode advisories, drained by `take_advisories`.
    advisories: Mutex<Vec<IoError>>,
    /// `health[s]` is cleared once server `s` fails an operation on
    /// this handle; a completed rebuild restores it. Sampled by the
    /// collective layer for degraded-aware domain placement.
    health: Vec<AtomicBool>,
    /// Reads served by replica fall-over or parity XOR reconstruction.
    degraded_reads: AtomicU64,
    /// Parity read-modify-write cycles (partial-stripe writes that had
    /// to pre-read; full-stripe writes skip the cycle).
    parity_rmw_cycles: AtomicU64,
    /// Bytes dispatched to individual servers, redundancy traffic
    /// included — the fan-out amplification of the caller's bytes.
    fanout_bytes: AtomicU64,
    /// Bytes re-materialized onto a replaced server by the rebuild
    /// engine.
    rebuild_bytes: AtomicU64,
    /// Stripe rows this handle migrated into a new layout generation.
    restripe_rows: AtomicU64,
}

impl StripedInner {
    fn factor(&self) -> usize {
        self.map.layout.factor
    }

    fn unit(&self) -> u64 {
        self.map.layout.unit
    }

    /// Count bytes dispatched to individual servers (data, replica, and
    /// parity traffic alike) for the close-time backend record.
    fn note_fanout(&self, bytes: u64) {
        self.fanout_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Push a degraded-mode advisory for a survived failure on `child`,
    /// and mark the child dead for degraded-aware collective placement.
    /// The buffer is bounded: an application that never drains it (the
    /// plain MPI surface has no advisory call) must not leak one
    /// formatted advisory per operation while running degraded — past
    /// the cap the freshest advisory replaces the last slot.
    fn advise_degraded(&self, op: &str, child: usize, err: &IoError) {
        self.note_dead(child);
        self.push_advisory(IoError::new(
            ErrorClass::Degraded,
            format!("{op}: stripe server {child} failed ({err}); served degraded"),
        ));
    }

    /// Append an advisory (background maintenance failures included),
    /// bounded by the same cap as `advise_degraded`.
    fn push_advisory(&self, advisory: IoError) {
        const ADVISORY_CAP: usize = 128;
        let mut pending = self.advisories.lock().unwrap();
        if pending.len() < ADVISORY_CAP {
            pending.push(advisory);
        } else {
            *pending.last_mut().expect("cap > 0") = advisory;
        }
    }

    /// Record a failed child for [`StorageFile::server_health`].
    fn note_dead(&self, child: usize) {
        if let Some(h) = self.health.get(child) {
            h.store(false, Ordering::Relaxed);
        }
    }

    fn take_advisories(&self) -> Vec<IoError> {
        std::mem::take(&mut *self.advisories.lock().unwrap())
    }

    /// Acquire the per-file stripe-consistency lock: an in-process
    /// queue for threads sharing this process plus an OS flock for
    /// sibling processes — the same two-level protocol the child
    /// backends use for `lock_exclusive`. Parity read-modify-write
    /// cycles serialize on it (the RAID-5 small-write cost); the lock
    /// file is opened per acquisition so forked children never inherit
    /// a locked fd.
    fn lock_parity(&self) -> Result<FileLockGuard> {
        let release_cell = lock_cell_for(&self.plock_path).acquire();
        let file = match std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&self.plock_path)
        {
            Ok(f) => f,
            Err(e) => {
                release_cell();
                return Err(IoError::from_os(e, "stripe parity lock"));
            }
        };
        if unsafe { libc::flock(file.as_raw_fd(), libc::LOCK_EX) } != 0 {
            release_cell();
            return Err(err_io("flock stripe parity lock"));
        }
        Ok(FileLockGuard {
            os_unlock: Some(Box::new(move || {
                unsafe { libc::flock(file.as_raw_fd(), libc::LOCK_UN) };
                drop(file);
                release_cell();
            })),
        })
    }

    /// Logical file size, from the metadata sidecar — one 8-byte read
    /// instead of a GETATTR fan-out over every child server. A missing
    /// sidecar is rebuilt (under its lock) from a full child poll; a
    /// sidecar that cannot be read or locked degrades to the poll
    /// instead of failing reads that only needed an EOF clamp.
    fn logical_size(&self) -> Result<u64> {
        match self.meta.read_fast() {
            Ok(Some(size)) => Ok(size),
            // Seed the sidecar only from a strict poll: a degraded poll
            // may under-report (see poll_children_size) and must stay
            // transient, never persisted as the published EOF.
            Ok(None) => match self.meta.read_or_init(|| self.poll_children_size_strict()) {
                Ok(v) => Ok(v),
                Err(_) => self.poll_children_size(),
            },
            Err(_) => self.poll_children_size(),
        }
    }

    /// [`StripedInner::poll_children_size`] with no failure tolerance —
    /// the sidecar (re)build seed, where an under-reported degraded
    /// value must never be persisted.
    fn poll_children_size_strict(&self) -> Result<u64> {
        let mut max = 0u64;
        for (s, child) in self.children.iter().enumerate() {
            max = max.max(self.map.logical_end(s, child.size()?));
        }
        Ok(max)
    }

    /// The furthest logical byte implied by any stripe object's length —
    /// the pre-sidecar fan-out, now the serve-only fallback path.
    /// Redundancy-aware: up to `tolerates()` children may refuse
    /// the GETATTR. A failed replica source is recovered exactly from a
    /// surviving copy's length; under parity the max over survivors is
    /// exact unless the dead server held the unique last data unit, in
    /// which case the poll may under-report by at most one unit — still
    /// strictly better than failing every size-clamped read, and only
    /// reachable when the sidecar itself is already gone.
    fn poll_children_size(&self) -> Result<u64> {
        let mut max = 0u64;
        let mut failed = 0usize;
        let mut first_err = None;
        for (s, child) in self.children.iter().enumerate() {
            match child.size() {
                Ok(len) => max = max.max(self.map.logical_end(s, len)),
                Err(e) => {
                    let mut recovered = false;
                    for copies in &self.replicas {
                        if let Ok(len) = copies[s].size() {
                            max = max.max(self.map.logical_end(s, len));
                            recovered = true;
                            break;
                        }
                    }
                    if !recovered {
                        failed += 1;
                        first_err.get_or_insert(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) if failed > self.map.redundancy.tolerates() => Err(e),
            _ => Ok(max),
        }
    }

    /// Shared fallback of the publish paths: if the sidecar cannot be
    /// updated, drop it entirely (the next `size()` rebuilds from the
    /// GETATTR fan-out) — a successful data operation must never leave
    /// a sidecar claiming a stale size *or* fail over metadata
    /// bookkeeping it can route around.
    fn or_invalidate(&self, published: Result<()>) -> Result<()> {
        match published {
            Ok(()) => Ok(()),
            Err(e) => {
                if self.meta.invalidate() {
                    Ok(())
                } else {
                    Err(e)
                }
            }
        }
    }

    /// Publish an extended EOF after a successful data dispatch.
    fn publish_extend(&self, end: u64) -> Result<()> {
        let published = self.meta.publish_extend(end);
        self.or_invalidate(published)
    }

    /// Publish the exact EOF after a truncate/resize.
    fn publish_exact(&self, size: u64) -> Result<()> {
        let published = self.meta.publish_exact(size);
        self.or_invalidate(published)
    }

    /// Group segments per server, sorted by child offset. The sort is
    /// load-bearing for reads: a child's default `read_runs` stops at its
    /// first short read, which on a sparse stripe object is only correct
    /// (everything after is past that object's EOF, i.e. zeros) when the
    /// runs are issued in ascending child order — unsorted vectored
    /// requests would otherwise drop real data behind a hole.
    fn group(&self, segs: &[Segment]) -> Vec<Vec<Segment>> {
        let mut per = vec![Vec::new(); self.factor()];
        for seg in segs {
            per[seg.server].push(*seg);
        }
        for server in &mut per {
            server.sort_unstable_by_key(|s: &Segment| s.child_off);
        }
        per
    }

    /// Concurrent vectored read of `segs` into `buf`. Pieces inside the
    /// logical file but beyond a child object's end (holes) read as
    /// zeros; the caller has already clamped `segs` to the logical size.
    /// A failed server within the redundancy tolerance is reconstructed
    /// from replicas or parity and reported as a `Degraded` advisory.
    fn read_segments(&self, segs: &[Segment], buf: &mut [u8]) -> Result<()> {
        self.read_segments_ext(segs, buf, false)
    }

    /// [`StripedInner::read_segments`] with lock ownership: `locked`
    /// callers (migration routing) already hold the stripe-consistency
    /// lock, so the parity reconstruction path must not re-acquire it.
    fn read_segments_ext(&self, segs: &[Segment], buf: &mut [u8], locked: bool) -> Result<()> {
        let per = self.group(segs);
        let mut jobs = Vec::new();
        let mut dests: Vec<(usize, Vec<Segment>)> = Vec::new();
        for (server, segs) in per.into_iter().enumerate() {
            if segs.is_empty() {
                continue;
            }
            let child = self.children[server].clone();
            let runs: Vec<(u64, usize)> = segs.iter().map(|s| (s.child_off, s.len)).collect();
            let total: usize = segs.iter().map(|s| s.len).sum();
            self.note_fanout(total as u64);
            dests.push((server, segs));
            jobs.push(move || -> Result<Vec<u8>> {
                // Zero-filled so short child reads (sparse holes) leave
                // zeros — the POSIX hole semantics of the logical file.
                let mut tmp = vec![0u8; total];
                child.read_runs(&runs, &mut tmp)?;
                Ok(tmp)
            });
        }
        let mut failed: Vec<(usize, Vec<Segment>, IoError)> = Vec::new();
        for (result, (server, segs)) in engine::fanout(jobs).into_iter().zip(dests) {
            match result {
                Ok(tmp) => scatter(&segs, &tmp, buf),
                Err(e) => failed.push((server, segs, e)),
            }
        }
        if failed.is_empty() {
            return Ok(());
        }
        if failed.len() > self.map.redundancy.tolerates() {
            return Err(failed.swap_remove(0).2);
        }
        for (server, segs, err) in failed {
            let tmp = self.reconstruct_segments(server, &segs, locked)?;
            scatter(&segs, &tmp, buf);
            self.degraded_reads.fetch_add(1, Ordering::Relaxed);
            self.advise_degraded("read", server, &err);
        }
        Ok(())
    }

    /// Rebuild the packed bytes of `segs` (all on failed server
    /// `server`, sorted by child offset) from the surviving redundancy.
    fn reconstruct_segments(
        &self,
        server: usize,
        segs: &[Segment],
        locked: bool,
    ) -> Result<Vec<u8>> {
        let total: usize = segs.iter().map(|s| s.len).sum();
        match self.map.redundancy {
            Redundancy::None => Err(err_io(format!(
                "stripe server {server} failed and the file has no redundancy"
            ))),
            Redundancy::Replica(k) => {
                // Fall over to the first surviving copy; the replica
                // objects are byte-identical at the same child offsets.
                let runs: Vec<(u64, usize)> = segs.iter().map(|s| (s.child_off, s.len)).collect();
                let mut last = None;
                for c in 1..k {
                    let mut tmp = vec![0u8; total];
                    self.note_fanout(total as u64);
                    match self.replicas[c - 1][server].read_runs(&runs, &mut tmp) {
                        Ok(_) => return Ok(tmp),
                        Err(e) => last = Some(e),
                    }
                }
                Err(last.expect("replica:<k> has k >= 2"))
            }
            Redundancy::Parity => {
                // Any one row slot is the XOR of the other factor-1
                // slots (data XOR parity == 0 per row), and every
                // server stores a row's slot at the same child offset —
                // so the lost bytes are the XOR of the *same vectored
                // run set* read from each survivor, one concurrent
                // fan-out like the healthy path. Serialize against
                // parity read-modify-write cycles so a half-updated row
                // is never used for reconstruction.
                let _guard = if locked { None } else { Some(self.lock_parity()?) };
                let runs: Vec<(u64, usize)> = segs.iter().map(|s| (s.child_off, s.len)).collect();
                self.note_fanout((self.factor() as u64 - 1) * total as u64);
                let jobs: Vec<_> = (0..self.factor())
                    .filter(|&s| s != server)
                    .map(|s| {
                        let child = self.children[s].clone();
                        let runs = runs.clone();
                        move || -> Result<Vec<u8>> {
                            let mut tmp = vec![0u8; total];
                            child.read_runs(&runs, &mut tmp)?;
                            Ok(tmp)
                        }
                    })
                    .collect();
                let mut out = vec![0u8; total];
                for result in engine::fanout(jobs) {
                    xor_into(&mut out, &result?);
                }
                Ok(out)
            }
        }
    }

    /// Concurrent vectored write of `segs` from `buf`, updating
    /// replicas/parity per the redundancy mode. Failures on at most
    /// `tolerates()` distinct children degrade (advisory) instead of
    /// failing the operation.
    fn write_segments(&self, segs: &[Segment], buf: &[u8]) -> Result<()> {
        self.write_segments_payload(segs, &Payload::Flat(buf), false)
    }

    /// [`StripedInner::write_segments`] over a [`Payload`] view — the
    /// shared dispatch of the packed-buffer and zero-copy piece paths.
    /// `locked` callers (migration routing) already hold the stripe
    /// consistency lock.
    fn write_segments_payload(
        &self,
        segs: &[Segment],
        pay: &Payload<'_>,
        locked: bool,
    ) -> Result<()> {
        if segs.is_empty() {
            return Ok(());
        }
        match self.map.redundancy {
            Redundancy::None => self.write_segments_plain(segs, pay),
            Redundancy::Replica(k) => self.write_segments_replica(segs, pay, k, locked),
            Redundancy::Parity => self.write_segments_parity(segs, pay, locked),
        }
    }

    fn write_segments_plain(&self, segs: &[Segment], pay: &Payload<'_>) -> Result<()> {
        let per = self.group(segs);
        let mut jobs = Vec::new();
        for (server, segs) in per.into_iter().enumerate() {
            if segs.is_empty() {
                continue;
            }
            let child = self.children[server].clone();
            let runs: Vec<(u64, usize)> = segs.iter().map(|s| (s.child_off, s.len)).collect();
            let payload = gather(&segs, pay);
            self.note_fanout(payload.len() as u64);
            jobs.push(move || -> Result<usize> { child.write_runs(&runs, &payload) });
        }
        for result in engine::fanout(jobs) {
            result?;
        }
        Ok(())
    }

    fn write_segments_replica(
        &self,
        segs: &[Segment],
        pay: &Payload<'_>,
        k: usize,
        locked: bool,
    ) -> Result<()> {
        // While a rebuild cursor is persisted, replica writes serialize
        // against the rebuild copy loop on the stripe-consistency lock:
        // otherwise the rebuild could read a source copy, lose the race
        // to a concurrent write, and clobber the fresh row on the
        // target with stale bytes. Healthy operation (no cursor on
        // disk) stays lock-free — the check is one stat.
        let _guard = if !locked && self.rebuild_active() {
            Some(self.lock_parity()?)
        } else {
            None
        };
        let factor = self.factor();
        let per = self.group(segs);
        let mut jobs: Vec<IoJob<usize>> = Vec::new();
        let mut holders = Vec::new();
        for (server, segs) in per.into_iter().enumerate() {
            if segs.is_empty() {
                continue;
            }
            let runs: Vec<(u64, usize)> = segs.iter().map(|s| (s.child_off, s.len)).collect();
            // All k copies read the same packed bytes — share them
            // instead of materializing the payload once per copy.
            let runs = Arc::new(runs);
            let payload = Arc::new(gather(&segs, pay));
            self.note_fanout(k as u64 * payload.len() as u64);
            for c in 0..k {
                let handle = if c == 0 {
                    self.children[server].clone()
                } else {
                    self.replicas[c - 1][server].clone()
                };
                let runs = runs.clone();
                let payload = payload.clone();
                jobs.push(Box::new(move || handle.write_runs(&runs, &payload)));
                holders.push(replica_holder(server, c, factor));
            }
        }
        let mut failed: Vec<(usize, IoError)> = Vec::new();
        for (holder, result) in holders.into_iter().zip(engine::fanout(jobs)) {
            if let Err(e) = result {
                record_failure(&mut failed, holder, e);
            }
        }
        self.settle_write_failures("write", failed)
    }

    /// For each affected row, whether the write fully overlays every
    /// data slot of that row — the RAID-5 full-stripe case whose parity
    /// needs no pre-read. Overlapping caller runs merge like any other
    /// intervals, so coverage is never over-counted.
    fn fully_covered_rows(&self, segs: &[Segment], rows: &[u64]) -> Vec<bool> {
        let unit = self.unit();
        let factor = self.factor();
        let mut intervals: Vec<Vec<Vec<(u64, u64)>>> =
            vec![vec![Vec::new(); factor]; rows.len()];
        for seg in segs {
            let r = self.map.layout.row_of_child_off(seg.child_off);
            let idx = rows.binary_search(&r).expect("affected row present");
            let start = seg.child_off % unit;
            intervals[idx][seg.server].push((start, start + seg.len as u64));
        }
        rows.iter()
            .enumerate()
            .map(|(idx, &r)| {
                let p = self.map.parity_server(r);
                (0..factor)
                    .filter(|&s| s != p)
                    .all(|s| covers_unit(&mut intervals[idx][s], unit))
            })
            .collect()
    }

    /// Parity read-modify-write: read the affected rows' current slots
    /// from every server, reconstruct a single failed server's slots as
    /// the XOR of the rest, overlay the new payload, recompute each
    /// row's parity slot, then dispatch the seg-exact data writes and
    /// the full-unit parity writes concurrently. The whole cycle holds
    /// the stripe-consistency lock; see the module docs.
    fn write_segments_parity(&self, segs: &[Segment], pay: &Payload<'_>, locked: bool) -> Result<()> {
        let unit = self.unit() as usize;
        let factor = self.factor();
        let _guard = if locked { None } else { Some(self.lock_parity()?) };

        // Affected rows, ascending.
        let mut rows: Vec<u64> =
            segs.iter().map(|s| self.map.layout.row_of_child_off(s.child_off)).collect();
        rows.sort_unstable();
        rows.dedup();
        let nrows = rows.len();

        // Full-stripe rows (every data slot fully overlaid) need no
        // pre-read: their parity is computable from the payload alone —
        // the classic RAID-5 full-stripe-write fast path that spares
        // sequential and data_width-aligned collective writes the
        // read-modify-write cost.
        let full = self.fully_covered_rows(segs, &rows);
        let read_idx: Vec<usize> = (0..nrows).filter(|&i| !full[i]).collect();

        // RAID-5 parity-delta small write: a partial write confined to
        // one row and one data server needs only that slot and the
        // parity slot — new_parity = old_parity ^ old_data ^ new_data —
        // two unit reads instead of the factor-wide pre-read below. A
        // failed probe read falls through to the general path, which
        // knows how to degrade.
        if nrows == 1 && !full[0] && segs.iter().all(|s| s.server == segs[0].server) {
            if let Some(out) = self.try_parity_delta(segs, pay, rows[0]) {
                return out;
            }
        }

        let mut failed: Vec<(usize, IoError)> = Vec::new();

        // 1. Read every server's slots for the partially-covered rows
        //    (one vectored read per server), zero-filled past each
        //    object's EOF.
        let mut slots: Vec<Vec<u8>> = vec![vec![0u8; nrows * unit]; factor];
        if !read_idx.is_empty() {
            // A genuine read-modify-write cycle: at least one affected
            // row is partially covered and its slots must be pre-read.
            self.parity_rmw_cycles.fetch_add(1, Ordering::Relaxed);
            let row_runs: Vec<(u64, usize)> =
                read_idx.iter().map(|&i| (rows[i] * unit as u64, unit)).collect();
            self.note_fanout((factor * read_idx.len() * unit) as u64);
            let read_jobs: Vec<_> = self
                .children
                .iter()
                .map(|child| {
                    let child = child.clone();
                    let runs = row_runs.clone();
                    let total = runs.len() * unit;
                    move || -> Result<Vec<u8>> {
                        let mut tmp = vec![0u8; total];
                        child.read_runs(&runs, &mut tmp)?;
                        Ok(tmp)
                    }
                })
                .collect();
            for (server, result) in engine::fanout(read_jobs).into_iter().enumerate() {
                match result {
                    Ok(tmp) => {
                        for (j, &i) in read_idx.iter().enumerate() {
                            slots[server][i * unit..(i + 1) * unit]
                                .copy_from_slice(&tmp[j * unit..(j + 1) * unit]);
                        }
                    }
                    Err(e) => record_failure(&mut failed, server, e),
                }
            }
            if failed.len() > 1 {
                return Err(failed.swap_remove(0).1);
            }
        }
        let dead = failed.first().map(|&(c, _)| c);

        // 2. A failed server's old slots are the XOR of everyone
        //    else's (the per-row invariant: data XOR parity == 0).
        //    Full-stripe rows are wholly overlaid below and need no
        //    reconstruction.
        if let Some(d) = dead {
            for &idx in &read_idx {
                let span = idx * unit..(idx + 1) * unit;
                let mut acc = vec![0u8; unit];
                for (s, slot) in slots.iter().enumerate() {
                    if s != d {
                        xor_into(&mut acc, &slot[span.clone()]);
                    }
                }
                slots[d][span].copy_from_slice(&acc);
            }
        }

        // 3. Overlay the new payload into the data slots — served
        //    straight off the payload view (exchange pieces stay in
        //    their receive buffers on the zero-copy path).
        for seg in segs {
            let r = self.map.layout.row_of_child_off(seg.child_off);
            let idx = rows.binary_search(&r).expect("affected row present");
            let within = (seg.child_off % unit as u64) as usize;
            slots[seg.server][idx * unit + within..idx * unit + within + seg.len]
                .copy_from_slice(pay.slice(seg.buf_pos, seg.len));
        }

        // 4. Recompute each affected row's parity slot (XOR of its
        //    factor-1 data slots), grouped into one vectored write per
        //    parity server. Rows whose parity slot sits on the dead
        //    server skip the update — nothing there can be written, and
        //    reconstruction never consults a dead server's slots.
        let mut parity_runs: Vec<Vec<(u64, usize)>> = vec![Vec::new(); factor];
        let mut parity_payloads: Vec<Vec<u8>> = vec![Vec::new(); factor];
        for (idx, &r) in rows.iter().enumerate() {
            let p = self.map.parity_server(r);
            if Some(p) == dead {
                continue;
            }
            let mut acc = vec![0u8; unit];
            for (s, slot) in slots.iter().enumerate() {
                if s != p {
                    xor_into(&mut acc, &slot[idx * unit..(idx + 1) * unit]);
                }
            }
            parity_runs[p].push((r * unit as u64, unit));
            parity_payloads[p].extend_from_slice(&acc);
        }

        // 5. Dispatch the seg-exact data writes and the parity writes
        //    concurrently (skipping the dead server).
        let per = self.group(segs);
        let mut jobs: Vec<IoJob<usize>> = Vec::new();
        let mut holders = Vec::new();
        for (server, segs) in per.into_iter().enumerate() {
            if segs.is_empty() || Some(server) == dead {
                continue;
            }
            let child = self.children[server].clone();
            let runs: Vec<(u64, usize)> = segs.iter().map(|s| (s.child_off, s.len)).collect();
            let payload = gather(&segs, pay);
            self.note_fanout(payload.len() as u64);
            jobs.push(Box::new(move || child.write_runs(&runs, &payload)));
            holders.push(server);
        }
        for (p, (runs, payload)) in
            parity_runs.into_iter().zip(parity_payloads).enumerate()
        {
            if runs.is_empty() {
                continue;
            }
            let child = self.children[p].clone();
            self.note_fanout(payload.len() as u64);
            jobs.push(Box::new(move || child.write_runs(&runs, &payload)));
            holders.push(p);
        }
        for (holder, result) in holders.into_iter().zip(engine::fanout(jobs)) {
            if let Err(e) = result {
                record_failure(&mut failed, holder, e);
            }
        }
        self.settle_write_failures("write", failed)
    }

    /// The parity-delta small-write body: `segs` all live in `row` on
    /// one data server and partially cover it. Returns `None` to fall
    /// back to the general read-modify-write path (a probe read failed
    /// — a dead server needs the reconstructing path); the caller
    /// already holds the stripe-consistency lock.
    fn try_parity_delta(
        &self,
        segs: &[Segment],
        pay: &Payload<'_>,
        row: u64,
    ) -> Option<Result<()>> {
        let unit = self.unit() as usize;
        let server = segs[0].server;
        let p = self.map.parity_server(row);
        let row_off = row * unit as u64;
        let mut old_data = vec![0u8; unit];
        let mut old_parity = vec![0u8; unit];
        // Zero-filled probes: short reads past an object's EOF are holes.
        self.note_fanout(2 * unit as u64);
        if self.children[server].read_at(row_off, &mut old_data).is_err() {
            return None;
        }
        if self.children[p].read_at(row_off, &mut old_parity).is_err() {
            return None;
        }
        // Committed: this is a genuine read-modify-write cycle.
        self.parity_rmw_cycles.fetch_add(1, Ordering::Relaxed);
        let mut new_data = old_data.clone();
        for seg in segs {
            let within = (seg.child_off % unit as u64) as usize;
            new_data[within..within + seg.len].copy_from_slice(pay.slice(seg.buf_pos, seg.len));
        }
        let mut new_parity = old_parity;
        xor_into(&mut new_parity, &old_data);
        xor_into(&mut new_parity, &new_data);
        // Seg-exact data write plus full-unit parity write, concurrent.
        let runs: Vec<(u64, usize)> = segs.iter().map(|s| (s.child_off, s.len)).collect();
        let payload = gather(segs, pay);
        self.note_fanout(payload.len() as u64 + unit as u64);
        let dchild = self.children[server].clone();
        let pchild = self.children[p].clone();
        let jobs: Vec<IoJob<usize>> = vec![
            Box::new(move || dchild.write_runs(&runs, &payload)),
            Box::new(move || pchild.write_at(row_off, &new_parity)),
        ];
        let mut failed = Vec::new();
        for (holder, result) in [server, p].into_iter().zip(engine::fanout(jobs)) {
            if let Err(e) = result {
                record_failure(&mut failed, holder, e);
            }
        }
        Some(self.settle_write_failures("write", failed))
    }

    /// Whether a rebuild cursor sidecar is on disk — one stat, checked
    /// by replica writes to serialize against the rebuild copy loop.
    fn rebuild_active(&self) -> bool {
        std::path::Path::new(&self.rebuild_path).exists()
    }

    /// Every object physically hosted on child `target`, as `(source
    /// server, copy)` pairs — the primary object plus every replica
    /// copy placed there by the rotation rule.
    fn hosted_objects(&self, target: usize) -> Vec<(usize, usize)> {
        let factor = self.factor();
        let mut hosted = vec![(target, 0)];
        if let Redundancy::Replica(k) = self.map.redundancy {
            for c in 1..k {
                for src in 0..factor {
                    if replica_holder(src, c, factor) == target {
                        hosted.push((src, c));
                    }
                }
            }
        }
        hosted
    }

    /// Detect a blank/replaced server: one whose objects are shorter
    /// than the layout prescribes for the current logical size. Runs
    /// only when a rebuild is requested (`jpio_rebuild = start` or the
    /// explicit APIs) — a sparse file that legitimately never
    /// materialized its tail can false-positive here, in which case
    /// the rebuild re-writes the reconstructed bytes (identical
    /// contents, densified objects). A server whose size probe itself
    /// fails is skipped: nothing can be rebuilt onto a dead server.
    fn detect_blank_server(&self) -> Result<Option<usize>> {
        if self.map.redundancy == Redundancy::None {
            return Ok(None);
        }
        let size = self.logical_size()?;
        if size == 0 {
            return Ok(None);
        }
        for target in 0..self.factor() {
            for (src, copy) in self.hosted_objects(target) {
                let expected = self.map.child_len(src, size);
                let handle = if copy == 0 {
                    &self.children[target]
                } else {
                    &self.replicas[copy - 1][src]
                };
                match handle.size() {
                    Ok(actual) if actual < expected => return Ok(Some(target)),
                    _ => {}
                }
            }
        }
        Ok(None)
    }

    /// Synchronous rebuild prelude: under the stripe-consistency lock,
    /// resume a persisted cursor or detect a blank server and persist a
    /// fresh one. Returns whether a rebuild is pending. Persisting
    /// *before* any batch runs is what lets every replica write issued
    /// after this point observe `rebuild_active()`.
    fn rebuild_prepare(&self) -> Result<bool> {
        let _guard = self.lock_parity()?;
        if read_rebuild_cursor(&self.rebuild_path)?.is_some() {
            return Ok(true);
        }
        match self.detect_blank_server()? {
            Some(target) => {
                write_rebuild_cursor(
                    &self.rebuild_path,
                    &RebuildCursor { target: target as u64, next_row: 0 },
                )?;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// One locked rebuild batch of up to `max_rows` stripe rows.
    /// Returns `(bytes written, finished)`; the lock is released
    /// between batches so foreground writes interleave. On completion
    /// the cursor sidecar is removed and the target marked healthy.
    fn rebuild_batch(&self, max_rows: u64) -> Result<(u64, bool)> {
        let _guard = self.lock_parity()?;
        let cursor = match read_rebuild_cursor(&self.rebuild_path)? {
            Some(c) => c,
            None => return Ok((0, true)),
        };
        let target = cursor.target as usize;
        if target >= self.factor() {
            // Corrupt or foreign cursor (e.g. left over from a
            // different layout generation): drop it.
            let _ = std::fs::remove_file(&self.rebuild_path);
            return Ok((0, true));
        }
        let size = self.logical_size()?;
        let total_rows = self.map.rows_for_size(size);
        let end_row = total_rows.min(cursor.next_row + max_rows.max(1));
        let mut bytes = 0u64;
        for row in cursor.next_row..end_row {
            bytes += self.rebuild_row(target, row, size)?;
        }
        self.rebuild_bytes.fetch_add(bytes, Ordering::Relaxed);
        if end_row >= total_rows {
            let _ = std::fs::remove_file(&self.rebuild_path);
            if let Some(h) = self.health.get(target) {
                h.store(true, Ordering::Relaxed);
            }
            Ok((bytes, true))
        } else {
            write_rebuild_cursor(
                &self.rebuild_path,
                &RebuildCursor { target: cursor.target, next_row: end_row },
            )?;
            Ok((bytes, false))
        }
    }

    /// Re-materialize stripe row `row` of every object hosted on the
    /// replaced child `target` from the survivors: parity rows are the
    /// XOR of the surviving slots, replica rows are copied from any
    /// surviving copy (falling over copy by copy — a second failure
    /// within `replica:<k>`'s tolerance continues from the remaining
    /// survivors). A loss beyond the tolerance surfaces as a
    /// `Degraded`-class error. Caller holds the stripe-consistency
    /// lock.
    fn rebuild_row(&self, target: usize, row: u64, size: u64) -> Result<u64> {
        let unit = self.unit() as usize;
        let row_off = row * unit as u64;
        let mut written = 0u64;
        match self.map.redundancy {
            Redundancy::None => {
                return Err(IoError::new(
                    ErrorClass::Degraded,
                    "rebuild: file has no redundancy to rebuild from",
                ))
            }
            Redundancy::Parity => {
                let expected = self.map.child_len(target, size);
                if row_off >= expected {
                    return Ok(0);
                }
                let want = unit.min((expected - row_off) as usize);
                let mut acc = vec![0u8; unit];
                let mut piece = vec![0u8; unit];
                self.note_fanout((self.factor() as u64 - 1) * unit as u64);
                for (s, child) in self.children.iter().enumerate() {
                    if s == target {
                        continue;
                    }
                    piece.fill(0);
                    if let Err(e) = child.read_at(row_off, &mut piece) {
                        self.note_dead(s);
                        return Err(IoError::new(
                            ErrorClass::Degraded,
                            format!(
                                "rebuild: survivor {s} failed ({e}); \
                                 loss exceeds the parity tolerance"
                            ),
                        ));
                    }
                    xor_into(&mut acc, &piece);
                }
                self.children[target].write_at(row_off, &acc[..want])?;
                self.note_fanout(want as u64);
                written += want as u64;
            }
            Redundancy::Replica(k) => {
                for (src, copy) in self.hosted_objects(target) {
                    let expected = self.map.child_len(src, size);
                    if row_off >= expected {
                        continue;
                    }
                    let want = unit.min((expected - row_off) as usize);
                    let mut data = vec![0u8; want];
                    let mut recovered = false;
                    let mut last: Option<IoError> = None;
                    for c2 in (0..k).filter(|&c2| c2 != copy) {
                        let source = if c2 == 0 {
                            &self.children[src]
                        } else {
                            &self.replicas[c2 - 1][src]
                        };
                        data.fill(0);
                        self.note_fanout(want as u64);
                        match source.read_at(row_off, &mut data) {
                            Ok(_) => {
                                recovered = true;
                                break;
                            }
                            Err(e) => {
                                self.note_dead(replica_holder(src, c2, self.factor()));
                                last = Some(e);
                            }
                        }
                    }
                    if !recovered {
                        let e = last.expect("replica:<k> has k >= 2 copies");
                        return Err(IoError::new(
                            ErrorClass::Degraded,
                            format!(
                                "rebuild: every surviving copy of server {src} failed ({e}); \
                                 loss exceeds the replica tolerance"
                            ),
                        ));
                    }
                    let dest = if copy == 0 {
                        &self.children[target]
                    } else {
                        &self.replicas[copy - 1][src]
                    };
                    dest.write_at(row_off, &data)?;
                    self.note_fanout(want as u64);
                    written += want as u64;
                }
            }
        }
        Ok(written)
    }

    /// Degrade or fail a write based on how many distinct children
    /// failed versus the redundancy tolerance.
    fn settle_write_failures(&self, op: &str, mut failed: Vec<(usize, IoError)>) -> Result<()> {
        if failed.len() > self.map.redundancy.tolerates() {
            return Err(failed.swap_remove(0).1);
        }
        for (child, err) in &failed {
            self.advise_degraded(op, *child, err);
        }
        Ok(())
    }

    /// Recompute one row's parity slot from its current data slots —
    /// the truncate/resize repair path (strict: no degraded mode on
    /// metadata ops). Caller holds the stripe-consistency lock.
    fn recompute_row_parity(&self, row: u64) -> Result<()> {
        let unit = self.unit() as usize;
        let p = self.map.parity_server(row);
        let mut acc = vec![0u8; unit];
        let mut piece = vec![0u8; unit];
        for (s, child) in self.children.iter().enumerate() {
            if s == p {
                continue;
            }
            piece.fill(0);
            child.read_at(row * unit as u64, &mut piece)?;
            xor_into(&mut acc, &piece);
        }
        self.children[p].write_at(row * unit as u64, &acc)?;
        Ok(())
    }

    fn set_size(&self, size: u64) -> Result<()> {
        let _guard = match self.map.redundancy {
            Redundancy::Parity => Some(self.lock_parity()?),
            _ => None,
        };
        // Shrink detection for the parity repair below; an unknowable
        // old size conservatively repairs. Read before truncating.
        let shrinks = self.map.redundancy == Redundancy::Parity
            && self.logical_size().map(|old| size < old).unwrap_or(true);
        for (s, child) in self.children.iter().enumerate() {
            child.set_size(self.map.child_len(s, size))?;
        }
        for copies in &self.replicas {
            for (s, replica) in copies.iter().enumerate() {
                replica.set_size(self.map.child_len(s, size))?;
            }
        }
        if shrinks && size > 0 && size % self.map.data_width() != 0 {
            // A shrink that cuts mid-row leaves the boundary row's
            // parity covering bytes that no longer exist; rebuild it
            // from the now-zero-padded data slots. Growth appends
            // zeros, which never change a XOR — no repair (and no
            // strict child reads that a degraded file would fail).
            if let Err(e) = self.recompute_row_parity((size - 1) / self.map.data_width()) {
                // The children are already truncated: drop the sidecar
                // so size() repolls the new physical lengths instead of
                // serving the stale pre-truncate EOF behind this error.
                self.meta.invalidate();
                return Err(e);
            }
        }
        // Truncate/extend publishes the exact new EOF.
        self.publish_exact(size)
    }
}

/// A live restriping migration: the generation being drained plus the
/// completion flag that retires per-operation routing once the cursor
/// reaches EOF.
struct MigState {
    old: Arc<StripedInner>,
    done: AtomicBool,
}

/// How one data operation routes during (or after) a migration.
enum Route {
    /// No active migration: every byte lives in the current generation.
    Current,
    /// Live restriping: bytes below `cursor` are in the current
    /// generation, bytes at or above it in the old one. The guard
    /// holds the stripe-consistency lock for the whole operation, so
    /// the cursor cannot advance underneath it.
    Split {
        cursor: u64,
        #[allow(dead_code)]
        guard: FileLockGuard,
    },
}

/// State behind an open striped file handle: the current generation,
/// the optional in-flight restriping, and the maintenance knobs shared
/// by the rebuild and migration drivers.
struct StripedShared {
    cur: Arc<StripedInner>,
    mig: Option<MigState>,
    layout_meta: LayoutMeta,
    /// Maintenance batch size in bytes (`jpio_rebuild_throttle`); 0
    /// means the default of 64 stripe units per locked batch.
    throttle: AtomicU64,
}

impl StripedShared {
    /// Bytes moved per locked maintenance batch.
    fn batch_bytes(&self) -> u64 {
        match self.throttle.load(Ordering::Relaxed) {
            0 => 64 * self.cur.unit(),
            t => t,
        }
    }

    /// Stripe rows per locked rebuild batch, derived from the byte
    /// throttle.
    fn rebuild_batch_rows(&self) -> u64 {
        (self.batch_bytes() / self.cur.unit()).max(1)
    }

    /// Route one data operation. The common no-migration case is a
    /// branch on an atomic; during a live migration the operation takes
    /// the stripe-consistency lock and re-reads the cursor under it.
    fn route(&self) -> Result<Route> {
        let Some(m) = &self.mig else { return Ok(Route::Current) };
        if m.done.load(Ordering::Acquire) {
            return Ok(Route::Current);
        }
        let guard = self.cur.lock_parity()?;
        match self.layout_meta.read_fast()? {
            Some(rec) => match rec.old {
                Some((_, _, cursor)) => Ok(Route::Split { cursor, guard }),
                None => {
                    // Another handle finished the migration.
                    m.done.store(true, Ordering::Release);
                    Ok(Route::Current)
                }
            },
            None => {
                m.done.store(true, Ordering::Release);
                Ok(Route::Current)
            }
        }
    }

    /// Vectored, EOF-clamped read routed per byte range. Implements the
    /// `read_runs` contract (stop at the first short run); `read_at` is
    /// the single-run case.
    fn read_runs_routed(&self, runs: &[(u64, usize)], buf: &mut [u8]) -> Result<usize> {
        let route = self.route()?;
        let size = self.cur.logical_size()?;
        let mut cur_segs = Vec::new();
        let mut old_segs = Vec::new();
        let mut pos = 0usize;
        let mut total = 0usize;
        for &(off, len) in runs {
            let avail = (size.saturating_sub(off) as usize).min(len);
            if avail > 0 {
                self.split_routed(&route, off, avail, pos, &mut cur_segs, &mut old_segs);
            }
            total += avail;
            if avail < len {
                // Short at logical EOF: stop, same contract as the
                // default implementation.
                break;
            }
            pos += len;
        }
        match &route {
            Route::Current => self.cur.read_segments_ext(&cur_segs, buf, false)?,
            Route::Split { .. } => {
                let old = &self.mig.as_ref().expect("split route implies migration").old;
                self.cur.read_segments_ext(&cur_segs, buf, true)?;
                old.read_segments_ext(&old_segs, buf, true)?;
            }
        }
        Ok(total)
    }

    /// Vectored write routed per byte range; publishes the extended
    /// EOF. Zero-length runs move no bytes and (POSIX zero-length write
    /// semantics) must not extend the file.
    fn write_payload_routed(&self, runs: &[(u64, usize)], pay: &Payload<'_>) -> Result<usize> {
        let route = self.route()?;
        let mut cur_segs = Vec::new();
        let mut old_segs = Vec::new();
        let mut pos = 0usize;
        let mut end = 0u64;
        for &(off, len) in runs {
            self.split_routed(&route, off, len, pos, &mut cur_segs, &mut old_segs);
            pos += len;
            if len > 0 {
                end = end.max(off + len as u64);
            }
        }
        match &route {
            Route::Current => self.cur.write_segments_payload(&cur_segs, pay, false)?,
            Route::Split { .. } => {
                let old = &self.mig.as_ref().expect("split route implies migration").old;
                self.cur.write_segments_payload(&cur_segs, pay, true)?;
                old.write_segments_payload(&old_segs, pay, true)?;
            }
        }
        if end > 0 {
            self.cur.publish_extend(end)?;
        }
        Ok(pos)
    }

    /// Split one logical run at the migration cursor into per-server
    /// segments of the matching generation. Payload positions stay
    /// relative to the run's own position (`pos`), so each segment
    /// still indexes the original payload view.
    fn split_routed(
        &self,
        route: &Route,
        off: u64,
        len: usize,
        pos: usize,
        cur_segs: &mut Vec<Segment>,
        old_segs: &mut Vec<Segment>,
    ) {
        match route {
            Route::Current => self.cur.map.split_run(off, len, pos, cur_segs),
            Route::Split { cursor, .. } => {
                let old = &self.mig.as_ref().expect("split route implies migration").old;
                let (new_part, old_part) = LayoutRouter::split_at(*cursor, off, len);
                if let Some((o, l)) = new_part {
                    self.cur.map.split_run(o, l, pos + (o - off) as usize, cur_segs);
                }
                if let Some((o, l)) = old_part {
                    old.map.split_run(o, l, pos + (o - off) as usize, old_segs);
                }
            }
        }
    }

    /// Copy the next row-aligned chunk (at most ~`max_bytes`) from the
    /// old generation into the current one and advance the persisted
    /// cursor — one locked migration step. Returns the bytes moved; 0
    /// means no migration is pending. Steps are cooperative across
    /// handles and processes: the cursor is re-read under the lock, so
    /// two drivers interleave instead of double-copying.
    fn migrate_step(&self, max_bytes: u64) -> Result<u64> {
        let Some(m) = &self.mig else { return Ok(0) };
        if m.done.load(Ordering::Acquire) {
            return Ok(0);
        }
        let _guard = self.cur.lock_parity()?;
        let cursor = match self.layout_meta.read_fast()? {
            Some(LayoutRecord { old: Some((_, _, c)), .. }) => c,
            _ => {
                m.done.store(true, Ordering::Release);
                return Ok(0);
            }
        };
        let size = self.cur.logical_size()?;
        if cursor >= size {
            self.finalize_migration(m)?;
            return Ok(0);
        }
        // Row-align the step end in the new layout (exact
        // `restripe_rows_migrated` accounting); the final step runs to
        // EOF.
        let dw = self.cur.map.data_width();
        let mut end = cursor + max_bytes.max(dw);
        end -= end % dw;
        if end <= cursor {
            end = cursor + dw;
        }
        let end = end.min(size);
        let len = (end - cursor) as usize;
        let mut buf = vec![0u8; len];
        let mut rsegs = Vec::new();
        m.old.map.split_run(cursor, len, 0, &mut rsegs);
        m.old.read_segments_ext(&rsegs, &mut buf, true)?;
        let mut wsegs = Vec::new();
        self.cur.map.split_run(cursor, len, 0, &mut wsegs);
        self.cur.write_segments_payload(&wsegs, &Payload::Flat(&buf), true)?;
        self.cur.restripe_rows.fetch_add((end - cursor).div_ceil(dw), Ordering::Relaxed);
        self.layout_meta.set_cursor(end)?;
        if end >= size {
            self.finalize_migration(m)?;
        }
        Ok(len as u64)
    }

    /// Retire the old generation: truncate its objects (delete removes
    /// them physically) and record the stable layout at the current
    /// generation. Caller holds the stripe-consistency lock.
    fn finalize_migration(&self, m: &MigState) -> Result<()> {
        for child in &m.old.children {
            let _ = child.set_size(0);
        }
        for copies in &m.old.replicas {
            for replica in copies {
                let _ = replica.set_size(0);
            }
        }
        self.layout_meta.write_stable(self.cur.gen, self.cur.map)?;
        m.done.store(true, Ordering::Release);
        Ok(())
    }

    /// Drive a pending migration to completion synchronously — the
    /// metadata ops (`set_size`/`preallocate`/`map`/`lock_exclusive`)
    /// need a single-generation view and are rare enough that finishing
    /// the copy beats routing them.
    fn ensure_migrated(&self) -> Result<()> {
        while let Some(m) = &self.mig {
            if m.done.load(Ordering::Acquire) {
                break;
            }
            if self.migrate_step(self.batch_bytes())? == 0 {
                break;
            }
        }
        Ok(())
    }

    /// Run the migration on the process-wide maintenance lane. The
    /// driver holds only a weak reference: dropping every file handle
    /// stops it at the next batch boundary (the persisted cursor
    /// resumes it on the next open).
    fn spawn_migration_driver(self: &Arc<Self>) {
        let weak = Arc::downgrade(self);
        progress::maintenance_engine().submit(move || loop {
            let Some(s) = weak.upgrade() else { return };
            match s.migrate_step(s.batch_bytes()) {
                Ok(0) => return,
                Ok(_) => {}
                Err(e) => {
                    s.cur.push_advisory(IoError::new(
                        ErrorClass::Degraded,
                        format!("restripe migration stalled: {e}"),
                    ));
                    return;
                }
            }
            drop(s);
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
    }

    /// Run a prepared rebuild on the process-wide maintenance lane,
    /// one throttled batch at a time (same weak-reference lifetime as
    /// the migration driver).
    fn spawn_rebuild_driver(self: &Arc<Self>) {
        let weak = Arc::downgrade(self);
        progress::maintenance_engine().submit(move || loop {
            let Some(s) = weak.upgrade() else { return };
            match s.cur.rebuild_batch(s.rebuild_batch_rows()) {
                Ok((_, true)) => return,
                Ok(_) => {}
                Err(e) => {
                    s.cur.push_advisory(IoError::new(
                        ErrorClass::Degraded,
                        format!("background rebuild stalled: {e}"),
                    ));
                    return;
                }
            }
            drop(s);
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
    }
}

/// An open file declustered over the child backends.
pub struct StripedFile {
    shared: Arc<StripedShared>,
}

impl StripedFile {
    /// Whether a restriping migration is still routing operations
    /// between two layout generations.
    pub fn migration_active(&self) -> bool {
        match &self.shared.mig {
            Some(m) => !m.done.load(Ordering::Acquire),
            None => false,
        }
    }

    /// Copy the next ~`max_bytes` chunk of a pending restriping
    /// migration (row-aligned in the new layout). Returns the bytes
    /// moved; 0 means nothing is pending. The deterministic-stepping
    /// companion of the background driver.
    pub fn migrate_step(&self, max_bytes: u64) -> Result<u64> {
        self.shared.migrate_step(max_bytes)
    }

    /// Drive a pending restriping migration to completion
    /// synchronously; returns the total bytes moved.
    pub fn drive_migration(&self) -> Result<u64> {
        let mut total = 0u64;
        loop {
            match self.shared.migrate_step(self.shared.batch_bytes())? {
                0 => return Ok(total),
                n => total += n,
            }
        }
    }

    /// Detect (or resume) a redundancy rebuild and run it to
    /// completion synchronously; returns the bytes re-materialized
    /// onto the replaced server (0 when nothing needed rebuilding).
    pub fn rebuild_now(&self) -> Result<u64> {
        self.shared.ensure_migrated()?;
        if !self.shared.cur.rebuild_prepare()? {
            return Ok(0);
        }
        let mut total = 0u64;
        loop {
            let (bytes, done) = self.shared.cur.rebuild_batch(self.shared.rebuild_batch_rows())?;
            total += bytes;
            if done {
                return Ok(total);
            }
        }
    }

    /// Detect (or resume) a rebuild and run at most `max_rows` stripe
    /// rows of it — the deterministic-stepping companion of the
    /// background driver. Returns `(bytes written, finished)`.
    pub fn rebuild_rows(&self, max_rows: u64) -> Result<(u64, bool)> {
        self.shared.ensure_migrated()?;
        if !self.shared.cur.rebuild_prepare()? {
            return Ok((0, true));
        }
        self.shared.cur.rebuild_batch(max_rows)
    }
}

impl StorageFile for StripedFile {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        self.shared.read_runs_routed(&[(offset, buf.len())], buf)
    }

    fn write_at(&self, offset: u64, buf: &[u8]) -> Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        self.shared.write_payload_routed(&[(offset, buf.len())], &Payload::Flat(buf))
    }

    fn read_runs(&self, runs: &[(u64, usize)], buf: &mut [u8]) -> Result<usize> {
        self.shared.read_runs_routed(runs, buf)
    }

    fn write_runs(&self, runs: &[(u64, usize)], buf: &[u8]) -> Result<usize> {
        self.shared.write_payload_routed(runs, &Payload::Flat(buf))
    }

    fn write_pieces(&self, pieces: &[(u64, &[u8])]) -> Result<usize> {
        // The zero-copy collective path: split each exchange piece at
        // stripe boundaries against its *virtual* position in the
        // concatenation, then dispatch per-server transfers straight
        // off the pieces — the payload is never packed into one
        // logical buffer first.
        let runs: Vec<(u64, usize)> = pieces.iter().map(|&(off, b)| (off, b.len())).collect();
        self.shared.write_payload_routed(&runs, &Payload::pieces(pieces))
    }

    fn size(&self) -> Result<u64> {
        self.shared.cur.logical_size()
    }

    fn set_size(&self, size: u64) -> Result<()> {
        self.shared.ensure_migrated()?;
        self.shared.cur.set_size(size)
    }

    fn preallocate(&self, size: u64) -> Result<()> {
        self.shared.ensure_migrated()?;
        let inner = &self.shared.cur;
        for (s, child) in inner.children.iter().enumerate() {
            let len = inner.map.child_len(s, size);
            if len > 0 {
                child.preallocate(len)?;
            }
        }
        for copies in &inner.replicas {
            for (s, replica) in copies.iter().enumerate() {
                let len = inner.map.child_len(s, size);
                if len > 0 {
                    replica.preallocate(len)?;
                }
            }
        }
        // Preallocation makes the file at least `size` bytes. (The
        // zero extension never changes a parity XOR, so no repair.)
        inner.publish_extend(size)
    }

    fn sync(&self) -> Result<()> {
        let mut inners = vec![&self.shared.cur];
        if let Some(m) = &self.shared.mig {
            if !m.done.load(Ordering::Acquire) {
                // The old generation still holds live data.
                inners.push(&m.old);
            }
        }
        for inner in inners {
            let factor = inner.factor();
            let mut jobs: Vec<IoJob<()>> = Vec::new();
            let mut holders = Vec::new();
            for (s, c) in inner.children.iter().enumerate() {
                let c = c.clone();
                jobs.push(Box::new(move || c.sync()));
                holders.push(s);
            }
            for (c, copies) in inner.replicas.iter().enumerate() {
                for (s, replica) in copies.iter().enumerate() {
                    let replica = replica.clone();
                    jobs.push(Box::new(move || replica.sync()));
                    holders.push(replica_holder(s, c + 1, factor));
                }
            }
            let mut failed: Vec<(usize, IoError)> = Vec::new();
            for (holder, result) in holders.into_iter().zip(engine::fanout(jobs)) {
                if let Err(e) = result {
                    record_failure(&mut failed, holder, e);
                }
            }
            inner.settle_write_failures("sync", failed)?;
        }
        Ok(())
    }

    fn map(&self, offset: u64, len: usize, writable: bool) -> Result<Box<dyn MappedRegion>> {
        if len == 0 {
            return Err(err_arg("map: zero-length region"));
        }
        self.shared.ensure_migrated()?;
        let inner = &self.shared.cur;
        // One metadata fan-out serves both the grow check and the prefill
        // clamp; any grown region is zeros, which the buffer already is.
        let old_size = inner.logical_size()?;
        if writable && old_size < offset + len as u64 {
            inner.set_size(offset + len as u64)?;
        }
        let mut buf = vec![0u8; len];
        if offset < old_size {
            let want = len.min((old_size - offset) as usize);
            let mut segs = Vec::new();
            inner.map.split_run(offset, want, 0, &mut segs);
            inner.read_segments(&segs, &mut buf)?;
        }
        Ok(Box::new(StripedMap {
            inner: inner.clone(),
            base: offset,
            buf,
            dirty: Vec::new(),
            writable,
        }))
    }

    fn lock_exclusive(&self) -> Result<FileLockGuard> {
        self.shared.ensure_migrated()?;
        // Acquire the child locks in server order — every holder uses the
        // same total order, so distributed acquisition cannot deadlock.
        let mut guards = Vec::with_capacity(self.shared.cur.children.len());
        for child in &self.shared.cur.children {
            guards.push(child.lock_exclusive()?);
        }
        Ok(FileLockGuard {
            os_unlock: Some(Box::new(move || drop(guards))),
        })
    }

    fn backend_name(&self) -> &'static str {
        "striped"
    }

    fn stripe_layout(&self) -> Option<StripeLayout> {
        Some(self.shared.cur.map.layout)
    }

    fn stripe_map(&self) -> Option<StripeMap> {
        Some(self.shared.cur.map)
    }

    fn prefers_plan_execution(&self) -> bool {
        // Multi-run plans become one per-server concurrent fan-out here;
        // staging them through a strategy would fragment the dispatch.
        true
    }

    fn take_advisories(&self) -> Vec<IoError> {
        let mut out = self.shared.cur.take_advisories();
        if let Some(m) = &self.shared.mig {
            out.extend(m.old.take_advisories());
        }
        out
    }

    fn server_health(&self) -> Option<Vec<bool>> {
        Some(
            self.shared
                .cur
                .health
                .iter()
                .map(|h| h.load(Ordering::Relaxed))
                .collect(),
        )
    }

    fn start_rebuild(&self, throttle: Option<u64>) -> Result<bool> {
        if let Some(t) = throttle {
            self.shared.throttle.store(t, Ordering::Relaxed);
        }
        // A rebuild re-materializes current-generation objects; a
        // half-migrated file first finishes moving into them.
        self.shared.ensure_migrated()?;
        if !self.shared.cur.rebuild_prepare()? {
            return Ok(false);
        }
        self.shared.spawn_rebuild_driver();
        Ok(true)
    }

    fn backend_counters(&self) -> super::BackendCounters {
        let cur = &self.shared.cur;
        let mut c = super::BackendCounters {
            degraded_reads: cur.degraded_reads.load(Ordering::Relaxed),
            parity_rmw_cycles: cur.parity_rmw_cycles.load(Ordering::Relaxed),
            fanout_bytes: cur.fanout_bytes.load(Ordering::Relaxed),
            rebuild_bytes_reconstructed: cur.rebuild_bytes.load(Ordering::Relaxed),
            restripe_rows_migrated: cur.restripe_rows.load(Ordering::Relaxed),
        };
        if let Some(m) = &self.shared.mig {
            c.degraded_reads += m.old.degraded_reads.load(Ordering::Relaxed);
            c.parity_rmw_cycles += m.old.parity_rmw_cycles.load(Ordering::Relaxed);
            c.fanout_bytes += m.old.fanout_bytes.load(Ordering::Relaxed);
        }
        c
    }
}

/// Buffered mapped-region emulation over the stripes: the region is read
/// at creation; writes record dirty byte ranges; `flush` writes the dirty
/// ranges back with one vectored striped transfer (so gap bytes between
/// writes are never clobbered).
struct StripedMap {
    inner: Arc<StripedInner>,
    base: u64,
    buf: Vec<u8>,
    dirty: Vec<(usize, usize)>, // (start, end) byte ranges, unmerged
    writable: bool,
}

impl MappedRegion for StripedMap {
    fn read(&mut self, region_off: usize, buf: &mut [u8]) -> Result<()> {
        check_bounds(region_off, buf.len(), self.buf.len())?;
        buf.copy_from_slice(&self.buf[region_off..region_off + buf.len()]);
        Ok(())
    }

    fn write(&mut self, region_off: usize, data: &[u8]) -> Result<()> {
        if !self.writable {
            return Err(crate::io::errors::err_read_only("write to read-only mapping"));
        }
        check_bounds(region_off, data.len(), self.buf.len())?;
        if data.is_empty() {
            return Ok(());
        }
        self.buf[region_off..region_off + data.len()].copy_from_slice(data);
        self.dirty.push((region_off, region_off + data.len()));
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        if self.dirty.is_empty() {
            return Ok(());
        }
        // Merge overlapping/adjacent dirty ranges into maximal runs.
        self.dirty.sort_unstable();
        let mut merged: Vec<(usize, usize)> = Vec::with_capacity(self.dirty.len());
        for &(s, e) in &self.dirty {
            if let Some(last) = merged.last_mut() {
                if s <= last.1 {
                    last.1 = last.1.max(e);
                    continue;
                }
            }
            merged.push((s, e));
        }
        let mut segs = Vec::new();
        let mut payload = Vec::new();
        for &(s, e) in &merged {
            self.inner
                .map
                .split_run(self.base + s as u64, e - s, payload.len(), &mut segs);
            payload.extend_from_slice(&self.buf[s..e]);
        }
        self.inner.write_segments(&segs, &payload)?;
        if let Some(&(_, e)) = merged.last() {
            self.inner.publish_extend(self.base + e as u64)?;
        }
        // Only a successful write-back retires the dirty state: a failed
        // flush (e.g. transient child fault) must stay retryable instead
        // of silently reporting Ok on the next call.
        self.dirty.clear();
        Ok(())
    }

    fn len(&self) -> usize {
        self.buf.len()
    }
}

impl Drop for StripedMap {
    fn drop(&mut self) {
        if self.writable && !self.dirty.is_empty() {
            let _ = self.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-striped-{}-{name}", std::process::id())
    }

    #[test]
    fn roundtrip_spanning_stripe_boundaries() {
        let b = StripedBackend::local(4, 16);
        let path = tmp("rt");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        // 100 bytes at offset 5 cross six unit boundaries.
        let data: Vec<u8> = (0..100u8).collect();
        assert_eq!(f.write_at(5, &data).unwrap(), 100);
        assert_eq!(f.size().unwrap(), 105);
        let mut back = vec![0u8; 100];
        assert_eq!(f.read_at(5, &mut back).unwrap(), 100);
        assert_eq!(back, data);
        b.delete(&path).unwrap();
    }

    #[test]
    fn physical_placement_is_round_robin() {
        let b = StripedBackend::local(2, 8);
        let path = tmp("placement");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let data: Vec<u8> = (0..32u8).collect();
        f.write_at(0, &data).unwrap();
        drop(f);
        // Server 0: stripes 0 and 2 → bytes 0..8 and 16..24.
        let s0 = std::fs::read(StripedBackend::object_path(&path, 0, 2)).unwrap();
        let s1 = std::fs::read(StripedBackend::object_path(&path, 1, 2)).unwrap();
        let want0: Vec<u8> = (0..8u8).chain(16..24).collect();
        let want1: Vec<u8> = (8..16u8).chain(24..32).collect();
        assert_eq!(s0, want0);
        assert_eq!(s1, want1);
        b.delete(&path).unwrap();
    }

    #[test]
    fn sparse_write_reads_zero_holes() {
        let b = StripedBackend::local(4, 10);
        let path = tmp("sparse");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(95, b"tail").unwrap(); // only touches server (95/10)%4 = 1
        assert_eq!(f.size().unwrap(), 99);
        let mut buf = vec![0xAAu8; 40];
        assert_eq!(f.read_at(30, &mut buf).unwrap(), 40);
        assert!(buf.iter().all(|&v| v == 0), "holes must read as zeros");
        b.delete(&path).unwrap();
    }

    #[test]
    fn set_size_distributes_and_shrinks() {
        let b = StripedBackend::local(3, 10);
        let path = tmp("setsize");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.set_size(65).unwrap(); // 6 full units + 5 → objects of 25, 20, 20
        assert_eq!(f.size().unwrap(), 65);
        f.set_size(7).unwrap(); // shrink below one unit
        assert_eq!(f.size().unwrap(), 7);
        let meta1 = std::fs::metadata(StripedBackend::object_path(&path, 1, 3)).unwrap();
        assert_eq!(meta1.len(), 0, "shrink must truncate later servers");
        f.set_size(0).unwrap();
        assert_eq!(f.size().unwrap(), 0);
        b.delete(&path).unwrap();
    }

    #[test]
    fn vectored_runs_roundtrip() {
        let b = StripedBackend::local(4, 8);
        let path = tmp("runs");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.set_size(256).unwrap();
        let runs = [(3u64, 20usize), (40, 9), (100, 30)];
        let data: Vec<u8> = (0..59u8).collect();
        assert_eq!(f.write_runs(&runs, &data).unwrap(), 59);
        let mut back = vec![0u8; 59];
        assert_eq!(f.read_runs(&runs, &mut back).unwrap(), 59);
        assert_eq!(back, data);
        b.delete(&path).unwrap();
    }

    #[test]
    fn write_pieces_roundtrip_across_redundancy_modes() {
        for (mode, name) in [
            (Redundancy::None, "wp-none"),
            (Redundancy::Replica(2), "wp-replica"),
            (Redundancy::Parity, "wp-parity"),
        ] {
            let b = StripedBackend::local_redundant(4, 8, mode);
            let path = tmp(name);
            let f = b.open(&path, OpenOptions::rw_create()).unwrap();
            // Disjoint pieces spanning stripe boundaries, with a gap
            // and an empty piece (shares its virtual start with the
            // successor) — the zero-copy collective dispatch shape.
            let a: Vec<u8> = (1..=20u8).collect();
            let c: Vec<u8> = (100..130u8).collect();
            let empty: [u8; 0] = [];
            let pieces: [(u64, &[u8]); 3] = [(3, &a[..]), (23, &empty[..]), (40, &c[..])];
            assert_eq!(f.write_pieces(&pieces).unwrap(), 50);
            assert_eq!(f.size().unwrap(), 70);
            // A second, partial overlay exercises the parity RMW path.
            let over = [0xEEu8; 7];
            assert_eq!(f.write_pieces(&[(5, &over[..])]).unwrap(), 7);
            let mut back = vec![0u8; 70];
            assert_eq!(f.read_at(0, &mut back).unwrap(), 70);
            assert!(back[..3].iter().all(|&v| v == 0));
            assert_eq!(&back[3..5], &a[..2]);
            assert_eq!(&back[5..12], &over[..]);
            assert_eq!(&back[12..23], &a[9..]);
            assert!(back[23..40].iter().all(|&v| v == 0), "gap must read as zeros");
            assert_eq!(&back[40..70], &c[..]);
            drop(f);
            if mode == Redundancy::Parity {
                // Physical invariant: every row slot still XORs to zero.
                let objs: Vec<Vec<u8>> = (0..4)
                    .map(|s| std::fs::read(StripedBackend::object_path(&path, s, 4)).unwrap())
                    .collect();
                let max_len = objs.iter().map(|o| o.len()).max().unwrap();
                for i in 0..max_len {
                    let x = objs.iter().fold(0u8, |a, o| a ^ o.get(i).copied().unwrap_or(0));
                    assert_eq!(x, 0, "row-slot XOR broken at object byte {i} ({name})");
                }
            }
            b.delete(&path).unwrap();
        }
    }

    #[test]
    fn zero_length_write_runs_do_not_extend_the_file() {
        // Regression (PR 3): a zero-length run used to feed the
        // published EOF even though it writes nothing.
        let b = StripedBackend::local(4, 8);
        let path = tmp("zerorun");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &[1u8; 10]).unwrap();
        assert_eq!(f.write_runs(&[(0, 4), (1000, 0)], &[2u8; 4]).unwrap(), 4);
        assert_eq!(f.size().unwrap(), 10, "zero-length run must not move the EOF");
        assert_eq!(f.write_runs(&[(500, 0)], &[]).unwrap(), 0);
        assert_eq!(f.size().unwrap(), 10);
        b.delete(&path).unwrap();
    }

    #[test]
    fn mapped_region_roundtrip_and_persistence() {
        let b = StripedBackend::local(4, 16);
        let path = tmp("map");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        {
            let mut m = f.map(10, 100, true).unwrap();
            m.write(5, b"across the stripes").unwrap();
            m.flush().unwrap();
            let mut back = [0u8; 18];
            m.read(5, &mut back).unwrap();
            assert_eq!(&back, b"across the stripes");
        }
        let mut check = [0u8; 18];
        f.read_at(15, &mut check).unwrap();
        assert_eq!(&check, b"across the stripes");
        b.delete(&path).unwrap();
    }

    #[test]
    fn exclusive_lock_serializes_threads() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let b = StripedBackend::local(4, 8);
        let path = tmp("lock");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let in_section = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..8 {
                        let _g = f.lock_exclusive().unwrap();
                        let v = in_section.fetch_add(1, Ordering::SeqCst);
                        assert_eq!(v, 0, "two threads inside the distributed lock");
                        std::thread::yield_now();
                        in_section.fetch_sub(1, Ordering::SeqCst);
                    }
                });
            }
        });
        b.delete(&path).unwrap();
    }

    // ------------------------------------------------------------------
    // Redundancy: healthy-path behaviour (degraded-mode coverage lives
    // in tests/degraded_redundancy.rs).
    // ------------------------------------------------------------------

    #[test]
    fn replica_roundtrip_and_physical_copies() {
        let b = StripedBackend::local_redundant(4, 8, Redundancy::Replica(2));
        let path = tmp("replica");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let data: Vec<u8> = (0..64u8).collect();
        f.write_at(0, &data).unwrap();
        let mut back = vec![0u8; 64];
        assert_eq!(f.read_at(0, &mut back).unwrap(), 64);
        assert_eq!(back, data);
        drop(f);
        // Every replica object is byte-identical to its source.
        for s in 0..4 {
            let primary = std::fs::read(StripedBackend::object_path(&path, s, 4)).unwrap();
            let copy =
                std::fs::read(StripedBackend::replica_object_path(&path, s, 4, 1)).unwrap();
            assert_eq!(primary, copy, "server {s} replica diverged");
        }
        b.delete(&path).unwrap();
        for s in 0..4 {
            assert!(!std::path::Path::new(&StripedBackend::replica_object_path(&path, s, 4, 1))
                .exists());
        }
    }

    #[test]
    fn parity_roundtrip_and_row_xor_invariant() {
        let b = StripedBackend::local_redundant(4, 8, Redundancy::Parity);
        let path = tmp("parity");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        // Two writes: one spanning several rows, one overwrite in the
        // middle (exercises the read-modify-write path).
        let data: Vec<u8> = (0..200u8).collect();
        f.write_at(0, &data).unwrap();
        f.write_at(30, &[0xEEu8; 40]).unwrap();
        let mut want = data.clone();
        want[30..70].fill(0xEE);
        let mut back = vec![0u8; 200];
        assert_eq!(f.read_at(0, &mut back).unwrap(), 200);
        assert_eq!(back, want);
        assert_eq!(f.size().unwrap(), 200);
        drop(f);
        // Physical invariant: the XOR of all four objects' bytes at
        // every row slot is zero (zero-filled past each object's EOF).
        let objs: Vec<Vec<u8>> = (0..4)
            .map(|s| std::fs::read(StripedBackend::object_path(&path, s, 4)).unwrap())
            .collect();
        let max_len = objs.iter().map(|o| o.len()).max().unwrap();
        for i in 0..max_len {
            let x = objs.iter().fold(0u8, |a, o| a ^ o.get(i).copied().unwrap_or(0));
            assert_eq!(x, 0, "row-slot XOR broken at object byte {i}");
        }
        b.delete(&path).unwrap();
    }

    #[test]
    fn parity_set_size_repairs_boundary_row() {
        let b = StripedBackend::local_redundant(3, 4, Redundancy::Parity);
        let path = tmp("paritytrunc");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let data: Vec<u8> = (1..=48u8).collect();
        f.write_at(0, &data).unwrap();
        f.set_size(13).unwrap(); // mid-row shrink
        assert_eq!(f.size().unwrap(), 13);
        let mut back = vec![0u8; 13];
        assert_eq!(f.read_at(0, &mut back).unwrap(), 13);
        assert_eq!(&back[..], &data[..13]);
        drop(f);
        let objs: Vec<Vec<u8>> = (0..3)
            .map(|s| std::fs::read(StripedBackend::object_path(&path, s, 3)).unwrap())
            .collect();
        let max_len = objs.iter().map(|o| o.len()).max().unwrap();
        for i in 0..max_len {
            let x = objs.iter().fold(0u8, |a, o| a ^ o.get(i).copied().unwrap_or(0));
            assert_eq!(x, 0, "parity not repaired after truncate, byte {i}");
        }
        b.delete(&path).unwrap();
    }

    #[test]
    fn redundant_config_validation() {
        assert!(StripedBackend::with_redundancy(
            (0..2).map(|_| Arc::new(LocalBackend::instant()) as Arc<dyn Backend>).collect(),
            8,
            Redundancy::Replica(3),
        )
        .is_err());
        assert!(StripedBackend::with_redundancy(
            vec![Arc::new(LocalBackend::instant()) as Arc<dyn Backend>],
            8,
            Redundancy::Parity,
        )
        .is_err());
    }

    /// A child backend that counts `StorageFile::size` calls — the
    /// GETATTR fan-out the metadata sidecar is supposed to eliminate.
    struct CountingBackend {
        inner: LocalBackend,
        size_calls: Arc<std::sync::atomic::AtomicUsize>,
    }

    struct CountingFile {
        inner: Arc<dyn StorageFile>,
        size_calls: Arc<std::sync::atomic::AtomicUsize>,
    }

    impl Backend for CountingBackend {
        fn open(&self, path: &str, opts: OpenOptions) -> Result<Arc<dyn StorageFile>> {
            Ok(Arc::new(CountingFile {
                inner: self.inner.open(path, opts)?,
                size_calls: self.size_calls.clone(),
            }))
        }

        fn delete(&self, path: &str) -> Result<()> {
            self.inner.delete(path)
        }

        fn name(&self) -> &'static str {
            "counting"
        }
    }

    impl StorageFile for CountingFile {
        fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
            self.inner.read_at(offset, buf)
        }

        fn write_at(&self, offset: u64, buf: &[u8]) -> Result<usize> {
            self.inner.write_at(offset, buf)
        }

        fn size(&self) -> Result<u64> {
            self.size_calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            self.inner.size()
        }

        fn set_size(&self, size: u64) -> Result<()> {
            self.inner.set_size(size)
        }

        fn preallocate(&self, size: u64) -> Result<()> {
            self.inner.preallocate(size)
        }

        fn sync(&self) -> Result<()> {
            self.inner.sync()
        }

        fn map(&self, offset: u64, len: usize, writable: bool) -> Result<Box<dyn MappedRegion>> {
            self.inner.map(offset, len, writable)
        }

        fn lock_exclusive(&self) -> Result<super::FileLockGuard> {
            self.inner.lock_exclusive()
        }

        fn backend_name(&self) -> &'static str {
            "counting"
        }
    }

    #[test]
    fn size_queries_do_not_fan_out_to_children() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let size_calls = Arc::new(AtomicUsize::new(0));
        let children: Vec<Arc<dyn Backend>> = (0..4)
            .map(|_| {
                Arc::new(CountingBackend {
                    inner: LocalBackend::instant(),
                    size_calls: size_calls.clone(),
                }) as Arc<dyn Backend>
            })
            .collect();
        let b = StripedBackend::new(children, 16).unwrap();
        let path = tmp("eofcache");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        // Opening rebuilt the missing sidecar: exactly one poll of all
        // four children.
        assert_eq!(size_calls.load(Ordering::SeqCst), 4);
        f.write_at(0, &[7u8; 100]).unwrap();
        for _ in 0..5 {
            assert_eq!(f.size().unwrap(), 100);
        }
        let mut back = vec![0u8; 100];
        assert_eq!(f.read_at(0, &mut back).unwrap(), 100);
        // Every size query and read clamp above came from the cached
        // sidecar — zero additional GETATTRs on the children.
        assert_eq!(size_calls.load(Ordering::SeqCst), 4);
        // Truncation invalidates through the sidecar, still fan-out-free.
        f.set_size(40).unwrap();
        assert_eq!(f.size().unwrap(), 40);
        f.preallocate(80).unwrap();
        assert_eq!(f.size().unwrap(), 80);
        assert_eq!(size_calls.load(Ordering::SeqCst), 4);
        b.delete(&path).unwrap();
    }

    #[test]
    fn missing_size_sidecar_is_rebuilt_from_children() {
        let b = StripedBackend::local(3, 8);
        let path = tmp("szrebuild");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &[3u8; 50]).unwrap();
        drop(f);
        std::fs::remove_file(StripedBackend::size_meta_path(&path)).unwrap();
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        assert_eq!(f.size().unwrap(), 50);
        b.delete(&path).unwrap();
        assert!(!std::path::Path::new(&StripedBackend::size_meta_path(&path)).exists());
    }

    #[test]
    fn parity_size_sidecar_rebuild_discounts_parity_slots() {
        // The sidecar rebuild (GETATTR fan-out) must invert the
        // parity-aware layout: materialized parity slots do not extend
        // the logical size.
        let b = StripedBackend::local_redundant(4, 8, Redundancy::Parity);
        let path = tmp("parityrebuild");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &[9u8; 75]).unwrap();
        drop(f);
        std::fs::remove_file(StripedBackend::size_meta_path(&path)).unwrap();
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        assert_eq!(f.size().unwrap(), 75);
        let mut back = vec![0u8; 75];
        assert_eq!(f.read_at(0, &mut back).unwrap(), 75);
        assert!(back.iter().all(|&v| v == 9));
        b.delete(&path).unwrap();
    }

    #[test]
    fn unreadable_size_sidecar_falls_back_to_getattr_fanout() {
        // A sidecar that exists but cannot be read (here: a directory)
        // must degrade size() to the child poll, not fail reads.
        let b = StripedBackend::local(3, 8);
        let path = tmp("szfallback");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &[5u8; 40]).unwrap();
        drop(f);
        let meta = StripedBackend::size_meta_path(&path);
        std::fs::remove_file(&meta).unwrap();
        std::fs::create_dir(&meta).unwrap();
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        assert_eq!(f.size().unwrap(), 40);
        let mut back = vec![0u8; 40];
        assert_eq!(f.read_at(0, &mut back).unwrap(), 40);
        assert!(back.iter().all(|&v| v == 5));
        drop(f);
        std::fs::remove_dir(&meta).unwrap();
        b.delete(&path).unwrap();
    }

    #[test]
    fn shrink_by_one_handle_then_extend_by_another_republishes() {
        // Regression: a handle that once knew a larger size must not
        // skip publishing after another handle shrank the file — the
        // covered-check has to consult the shared sidecar, not a
        // per-handle cache.
        let b = StripedBackend::local(4, 8);
        let path = tmp("szshrink");
        let f1 = b.open(&path, OpenOptions::rw_create()).unwrap();
        let f2 = b.open(&path, OpenOptions::rw_create()).unwrap();
        f2.write_at(0, &[9u8; 100]).unwrap(); // f2 observes size 100
        f1.set_size(40).unwrap(); // shrink through the other handle
        assert_eq!(f2.size().unwrap(), 40);
        f2.write_at(0, &[1u8; 50]).unwrap(); // 50 < 100: must still publish
        assert_eq!(f1.size().unwrap(), 50);
        let mut back = [0u8; 50];
        assert_eq!(f1.read_at(0, &mut back).unwrap(), 50);
        assert!(back.iter().all(|&v| v == 1), "bytes past the stale shrink point lost");
        b.delete(&path).unwrap();
    }

    #[test]
    fn cross_handle_extension_is_visible_immediately() {
        // The EOF lives in the shared sidecar, so one handle's cached
        // value can never hide another handle's extension — the
        // invalidation property the barrier-only access patterns rely on.
        let b = StripedBackend::local(4, 8);
        let path = tmp("szxhandle");
        let f1 = b.open(&path, OpenOptions::rw_create()).unwrap();
        let f2 = b.open(&path, OpenOptions::rw_create()).unwrap();
        assert_eq!(f1.size().unwrap(), 0);
        f2.write_at(0, &[1u8; 64]).unwrap();
        assert_eq!(f1.size().unwrap(), 64);
        let mut back = [0u8; 64];
        assert_eq!(f1.read_at(0, &mut back).unwrap(), 64);
        assert!(back.iter().all(|&v| v == 1));
        b.delete(&path).unwrap();
    }

    #[test]
    fn delete_removes_all_objects_and_missing_is_no_such_file() {
        let b = StripedBackend::local(3, 8);
        let path = tmp("del");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &[1u8; 64]).unwrap();
        drop(f);
        b.delete(&path).unwrap();
        for i in 0..3 {
            assert!(!std::path::Path::new(&StripedBackend::object_path(&path, i, 3)).exists());
        }
        let err = b.delete(&path).unwrap_err();
        assert_eq!(err.class, ErrorClass::NoSuchFile);
    }
}
