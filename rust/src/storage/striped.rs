//! Striped parallel-file-system backend.
//!
//! The paper's evaluation stops at single-server storage — local disk, one
//! NFS server, a SAN — so aggregate write bandwidth is capped by one
//! server's ingest rate (the ~250 MB/s plateau of Fig 4-4). Parallel file
//! systems remove that cap by *declustering* the logical file over many
//! I/O servers (ViPIOS; PVFS; Lustre). [`StripedBackend`] does exactly
//! that: a logical file is split into fixed-size stripe units laid out
//! round-robin over N child [`Backend`]s (any mix of local/NFS/SAN
//! backends, each with its own performance model and fault injector), each
//! holding one *stripe object* — a plain file on that child.
//!
//! * **Data path** — `read_at`/`write_at`/`read_runs`/`write_runs` split
//!   logical runs at stripe boundaries ([`StripeLayout`]), group
//!   the pieces per server, and issue one vectored transfer per server
//!   *concurrently* on the [`engine`](crate::io::engine) stripe pool, so
//!   aggregate bandwidth scales with servers instead of serializing at
//!   one ingest lock.
//! * **Metadata** — the logical size lives in a flocked metadata sidecar
//!   (`<name>.jpio-size`), the substitution for a parallel file system's
//!   metadata server (PVFS's mgr, ViPIOS's directory service): `size()`
//!   reads one 8-byte sidecar instead of issuing a GETATTR to every
//!   child server, writes that extend the file publish the new EOF (an
//!   unlocked 8-byte sidecar check skips the flock cycle when the file
//!   already covers the write), and `set_size`/`truncate`/`preallocate`
//!   invalidate by publishing the exact new size. A missing sidecar
//!   (objects created by other means) is rebuilt from a one-time full
//!   child poll at open.
//! * **Locking** — `lock_exclusive` acquires every child's lock in server
//!   order (the classic total-order protocol), so concurrent distributed
//!   lockers cannot deadlock; the guard releases all of them.
//! * **Mapped mode** — a buffered region emulation (like the NFS one):
//!   loaded from the stripes on creation, dirty ranges written back
//!   vectored on `flush`.
//!
//! The collective layer reads [`StorageFile::stripe_layout`] off these
//! files to align two-phase file domains to stripe boundaries — see
//! `io::collective`.

use std::os::unix::fs::FileExt;
use std::os::unix::io::AsRawFd;
use std::sync::Arc;

use crate::io::engine;
use crate::io::errors::{err_arg, err_io, ErrorClass, IoError, Result};

use super::layout::{Segment, StripeLayout};
use super::local::{check_bounds, LocalBackend};
use super::nfs::{NfsBackend, NfsConfig};
use super::{Backend, FileLockGuard, MappedRegion, OpenOptions, StorageFile};

/// A backend declustering files round-robin across child backends.
pub struct StripedBackend {
    children: Vec<Arc<dyn Backend>>,
    layout: StripeLayout,
}

impl StripedBackend {
    /// Stripe across the given children with `unit`-byte stripe units.
    /// The striping factor is `children.len()`.
    pub fn new(children: Vec<Arc<dyn Backend>>, unit: u64) -> Result<StripedBackend> {
        let layout = StripeLayout::new(unit, children.len())?;
        Ok(StripedBackend { children, layout })
    }

    /// `factor` unmodelled local children (functional tests).
    pub fn local(factor: usize, unit: u64) -> StripedBackend {
        let children = (0..factor)
            .map(|_| Arc::new(LocalBackend::instant()) as Arc<dyn Backend>)
            .collect();
        StripedBackend::new(children, unit).expect("valid stripe parameters")
    }

    /// `factor` simulated NFS servers, each with its own copy of `cfg`
    /// (so each server serializes its own ingest, independently).
    pub fn nfs(factor: usize, unit: u64, cfg: NfsConfig) -> StripedBackend {
        let children = (0..factor)
            .map(|_| Arc::new(NfsBackend::new(cfg)) as Arc<dyn Backend>)
            .collect();
        StripedBackend::new(children, unit).expect("valid stripe parameters")
    }

    /// The stripe layout of this backend.
    pub fn layout(&self) -> StripeLayout {
        self.layout
    }

    /// Path of `server`'s stripe object for logical file `path`. Public
    /// so tests and tooling can inspect physical placement.
    pub fn object_path(path: &str, server: usize, factor: usize) -> String {
        format!("{path}.jpio-s{server}of{factor}")
    }

    /// Path of the logical-size metadata sidecar for logical file `path`
    /// (the metadata-server substitution; see the module docs).
    pub fn size_meta_path(path: &str) -> String {
        format!("{path}.jpio-size")
    }
}

/// The logical-EOF metadata sidecar: an 8-byte LE size updated under an
/// OS file lock, shared across handles, threads and forked processes.
/// Every decision reads the *shared* sidecar, never a per-handle copy —
/// a cached skip would be unsound the moment another handle shrinks the
/// file (`set_size` runs on rank 0 only), and a stale-high cache would
/// then suppress the publish that readers depend on.
struct SizeMeta {
    path: String,
}

impl SizeMeta {
    fn new(path: &str) -> SizeMeta {
        SizeMeta { path: StripedBackend::size_meta_path(path) }
    }

    fn with_locked_file<T>(&self, f: impl FnOnce(&std::fs::File) -> Result<T>) -> Result<T> {
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&self.path)
            .map_err(|e| IoError::from_os(e, "striped size metadata"))?;
        let fd = file.as_raw_fd();
        if unsafe { libc::flock(fd, libc::LOCK_EX) } != 0 {
            return Err(err_io("flock striped size metadata"));
        }
        let out = f(&file);
        unsafe { libc::flock(fd, libc::LOCK_UN) };
        out
    }

    fn read_value(file: &std::fs::File) -> Result<Option<u64>> {
        let mut buf = [0u8; 8];
        match file.read_exact_at(&mut buf, 0) {
            Ok(()) => Ok(Some(u64::from_le_bytes(buf))),
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Ok(None),
            Err(e) => Err(IoError::from_os(e, "striped size metadata read")),
        }
    }

    fn write_value(file: &std::fs::File, value: u64) -> Result<()> {
        file.write_all_at(&value.to_le_bytes(), 0)
            .map_err(|e| IoError::from_os(e, "striped size metadata write"))
    }

    /// The current logical size, or `None` when the sidecar does not
    /// exist yet (rebuild via [`SizeMeta::read_or_init`]).
    fn read_fast(&self) -> Result<Option<u64>> {
        let file = match std::fs::File::open(&self.path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(IoError::from_os(e, "striped size metadata")),
        };
        Self::read_value(&file)
    }

    /// Read the size, initializing the sidecar from `init` (a full child
    /// poll) when missing — all under the lock, so concurrent openers
    /// cannot clobber a published extension with a stale poll.
    fn read_or_init(&self, init: impl FnOnce() -> Result<u64>) -> Result<u64> {
        self.with_locked_file(|file| {
            if let Some(v) = Self::read_value(file)? {
                return Ok(v);
            }
            let v = init()?;
            Self::write_value(file, v)?;
            Ok(v)
        })
    }

    /// A successful write reached logical offset `end`: grow the shared
    /// size monotonically. The covered-already check reads the shared
    /// sidecar unlocked (one 8-byte pread, no flock cycle); a write
    /// racing a truncation is unsynchronized application behaviour, so
    /// the lock-free check cannot lose a legitimate extension.
    fn publish_extend(&self, end: u64) -> Result<()> {
        if let Some(cur) = self.read_fast()? {
            if cur >= end {
                return Ok(());
            }
        }
        self.with_locked_file(|file| {
            let cur = Self::read_value(file)?.unwrap_or(0);
            if end > cur {
                Self::write_value(file, end)?;
            }
            Ok(())
        })
    }

    /// Truncate/resize invalidation: publish the exact new size.
    fn publish_exact(&self, size: u64) -> Result<()> {
        self.with_locked_file(|file| Self::write_value(file, size))
    }
}

impl Backend for StripedBackend {
    fn open(&self, path: &str, opts: OpenOptions) -> Result<Arc<dyn StorageFile>> {
        if path.is_empty() {
            return Err(crate::io::errors::err_bad_file("empty file name"));
        }
        let factor = self.layout.factor;
        let mut files = Vec::with_capacity(factor);
        for (i, child) in self.children.iter().enumerate() {
            files.push(child.open(&Self::object_path(path, i, factor), opts)?);
        }
        let inner =
            StripedInner { children: files, layout: self.layout, meta: SizeMeta::new(path) };
        if opts.truncate {
            // Children were truncated at open; the sidecar must follow.
            inner.meta.publish_exact(0)?;
        }
        // Ensure the size sidecar exists (rebuilding from a one-time
        // child poll for pre-existing objects) so the data path never
        // GETATTRs every server again.
        inner.logical_size()?;
        Ok(Arc::new(StripedFile { inner: Arc::new(inner) }))
    }

    fn delete(&self, path: &str) -> Result<()> {
        let _ = std::fs::remove_file(Self::size_meta_path(path));
        let factor = self.layout.factor;
        let mut first_err = None;
        for (i, child) in self.children.iter().enumerate() {
            match child.delete(&Self::object_path(path, i, factor)) {
                Ok(()) => {}
                // A logical file whose later stripes were never touched
                // has no objects there; only stripe 0 decides existence.
                Err(e) if i > 0 && e.class == ErrorClass::NoSuchFile => {}
                Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    fn name(&self) -> &'static str {
        "striped"
    }
}

/// Shared state of an open striped file.
struct StripedInner {
    children: Vec<Arc<dyn StorageFile>>,
    layout: StripeLayout,
    meta: SizeMeta,
}

impl StripedInner {
    /// Logical file size, from the metadata sidecar — one 8-byte read
    /// instead of a GETATTR fan-out over every child server. A missing
    /// sidecar is rebuilt (under its lock) from a full child poll.
    fn logical_size(&self) -> Result<u64> {
        if let Some(size) = self.meta.read_fast()? {
            return Ok(size);
        }
        self.meta.read_or_init(|| self.poll_children_size())
    }

    /// The furthest logical byte implied by any stripe object's length —
    /// the pre-sidecar fan-out, now only the sidecar (re)build path.
    fn poll_children_size(&self) -> Result<u64> {
        let mut max = 0u64;
        for (s, child) in self.children.iter().enumerate() {
            max = max.max(self.layout.logical_end(s, child.size()?));
        }
        Ok(max)
    }

    /// Group segments per server, sorted by child offset. The sort is
    /// load-bearing for reads: a child's default `read_runs` stops at its
    /// first short read, which on a sparse stripe object is only correct
    /// (everything after is past that object's EOF, i.e. zeros) when the
    /// runs are issued in ascending child order — unsorted vectored
    /// requests would otherwise drop real data behind a hole.
    fn group(&self, segs: &[Segment]) -> Vec<Vec<Segment>> {
        let mut per = vec![Vec::new(); self.layout.factor];
        for seg in segs {
            per[seg.server].push(*seg);
        }
        for server in &mut per {
            server.sort_unstable_by_key(|s: &Segment| s.child_off);
        }
        per
    }

    /// Concurrent vectored read of `segs` into `buf`. Pieces inside the
    /// logical file but beyond a child object's end (holes) read as
    /// zeros; the caller has already clamped `segs` to the logical size.
    fn read_segments(&self, segs: &[Segment], buf: &mut [u8]) -> Result<()> {
        let per = self.group(segs);
        let mut jobs = Vec::new();
        let mut dests: Vec<Vec<Segment>> = Vec::new();
        for (server, segs) in per.into_iter().enumerate() {
            if segs.is_empty() {
                continue;
            }
            let child = self.children[server].clone();
            let runs: Vec<(u64, usize)> = segs.iter().map(|s| (s.child_off, s.len)).collect();
            let total: usize = segs.iter().map(|s| s.len).sum();
            dests.push(segs);
            jobs.push(move || -> Result<Vec<u8>> {
                // Zero-filled so short child reads (sparse holes) leave
                // zeros — the POSIX hole semantics of the logical file.
                let mut tmp = vec![0u8; total];
                child.read_runs(&runs, &mut tmp)?;
                Ok(tmp)
            });
        }
        for (result, segs) in engine::fanout(jobs).into_iter().zip(dests) {
            let tmp = result?;
            let mut cursor = 0usize;
            for seg in segs {
                buf[seg.buf_pos..seg.buf_pos + seg.len]
                    .copy_from_slice(&tmp[cursor..cursor + seg.len]);
                cursor += seg.len;
            }
        }
        Ok(())
    }

    /// Concurrent vectored write of `segs` from `buf`.
    fn write_segments(&self, segs: &[Segment], buf: &[u8]) -> Result<()> {
        let per = self.group(segs);
        let mut jobs = Vec::new();
        for (server, segs) in per.into_iter().enumerate() {
            if segs.is_empty() {
                continue;
            }
            let child = self.children[server].clone();
            let runs: Vec<(u64, usize)> = segs.iter().map(|s| (s.child_off, s.len)).collect();
            let total: usize = segs.iter().map(|s| s.len).sum();
            let mut payload = Vec::with_capacity(total);
            for seg in &segs {
                payload.extend_from_slice(&buf[seg.buf_pos..seg.buf_pos + seg.len]);
            }
            jobs.push(move || -> Result<usize> { child.write_runs(&runs, &payload) });
        }
        for result in engine::fanout(jobs) {
            result?;
        }
        Ok(())
    }

    fn set_size(&self, size: u64) -> Result<()> {
        for (s, child) in self.children.iter().enumerate() {
            child.set_size(self.layout.child_len(s, size))?;
        }
        // Truncate/extend publishes the exact new EOF.
        self.meta.publish_exact(size)
    }
}

/// An open file declustered over the child backends.
pub struct StripedFile {
    inner: Arc<StripedInner>,
}

impl StorageFile for StripedFile {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        let size = self.inner.logical_size()?;
        if offset >= size {
            return Ok(0);
        }
        let want = buf.len().min((size - offset) as usize);
        let mut segs = Vec::new();
        self.inner.layout.split_run(offset, want, 0, &mut segs);
        self.inner.read_segments(&segs, buf)?;
        Ok(want)
    }

    fn write_at(&self, offset: u64, buf: &[u8]) -> Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        let mut segs = Vec::new();
        self.inner.layout.split_run(offset, buf.len(), 0, &mut segs);
        self.inner.write_segments(&segs, buf)?;
        self.inner.meta.publish_extend(offset + buf.len() as u64)?;
        Ok(buf.len())
    }

    fn read_runs(&self, runs: &[(u64, usize)], buf: &mut [u8]) -> Result<usize> {
        let size = self.inner.logical_size()?;
        let mut segs = Vec::new();
        let mut pos = 0usize;
        let mut total = 0usize;
        for &(off, len) in runs {
            let avail = (size.saturating_sub(off) as usize).min(len);
            if avail > 0 {
                self.inner.layout.split_run(off, avail, pos, &mut segs);
            }
            total += avail;
            if avail < len {
                // Short at logical EOF: stop, same contract as the
                // default implementation.
                break;
            }
            pos += len;
        }
        self.inner.read_segments(&segs, buf)?;
        Ok(total)
    }

    fn write_runs(&self, runs: &[(u64, usize)], buf: &[u8]) -> Result<usize> {
        let mut segs = Vec::new();
        let mut pos = 0usize;
        let mut end = 0u64;
        for &(off, len) in runs {
            self.inner.layout.split_run(off, len, pos, &mut segs);
            pos += len;
            end = end.max(off + len as u64);
        }
        self.inner.write_segments(&segs, buf)?;
        if pos > 0 {
            self.inner.meta.publish_extend(end)?;
        }
        Ok(pos)
    }

    fn size(&self) -> Result<u64> {
        self.inner.logical_size()
    }

    fn set_size(&self, size: u64) -> Result<()> {
        self.inner.set_size(size)
    }

    fn preallocate(&self, size: u64) -> Result<()> {
        for (s, child) in self.inner.children.iter().enumerate() {
            let len = self.inner.layout.child_len(s, size);
            if len > 0 {
                child.preallocate(len)?;
            }
        }
        // Preallocation makes the file at least `size` bytes.
        self.inner.meta.publish_extend(size)
    }

    fn sync(&self) -> Result<()> {
        let jobs: Vec<_> = self
            .inner
            .children
            .iter()
            .map(|c| {
                let c = c.clone();
                move || c.sync()
            })
            .collect();
        for result in engine::fanout(jobs) {
            result?;
        }
        Ok(())
    }

    fn map(&self, offset: u64, len: usize, writable: bool) -> Result<Box<dyn MappedRegion>> {
        if len == 0 {
            return Err(err_arg("map: zero-length region"));
        }
        // One metadata fan-out serves both the grow check and the prefill
        // clamp; any grown region is zeros, which the buffer already is.
        let old_size = self.inner.logical_size()?;
        if writable && old_size < offset + len as u64 {
            self.inner.set_size(offset + len as u64)?;
        }
        let mut buf = vec![0u8; len];
        if offset < old_size {
            let want = len.min((old_size - offset) as usize);
            let mut segs = Vec::new();
            self.inner.layout.split_run(offset, want, 0, &mut segs);
            self.inner.read_segments(&segs, &mut buf)?;
        }
        Ok(Box::new(StripedMap {
            inner: self.inner.clone(),
            base: offset,
            buf,
            dirty: Vec::new(),
            writable,
        }))
    }

    fn lock_exclusive(&self) -> Result<FileLockGuard> {
        // Acquire the child locks in server order — every holder uses the
        // same total order, so distributed acquisition cannot deadlock.
        let mut guards = Vec::with_capacity(self.inner.children.len());
        for child in &self.inner.children {
            guards.push(child.lock_exclusive()?);
        }
        Ok(FileLockGuard {
            os_unlock: Some(Box::new(move || drop(guards))),
        })
    }

    fn backend_name(&self) -> &'static str {
        "striped"
    }

    fn stripe_layout(&self) -> Option<StripeLayout> {
        Some(self.inner.layout)
    }

    fn prefers_plan_execution(&self) -> bool {
        // Multi-run plans become one per-server concurrent fan-out here;
        // staging them through a strategy would fragment the dispatch.
        true
    }
}

/// Buffered mapped-region emulation over the stripes: the region is read
/// at creation; writes record dirty byte ranges; `flush` writes the dirty
/// ranges back with one vectored striped transfer (so gap bytes between
/// writes are never clobbered).
struct StripedMap {
    inner: Arc<StripedInner>,
    base: u64,
    buf: Vec<u8>,
    dirty: Vec<(usize, usize)>, // (start, end) byte ranges, unmerged
    writable: bool,
}

impl MappedRegion for StripedMap {
    fn read(&mut self, region_off: usize, buf: &mut [u8]) -> Result<()> {
        check_bounds(region_off, buf.len(), self.buf.len())?;
        buf.copy_from_slice(&self.buf[region_off..region_off + buf.len()]);
        Ok(())
    }

    fn write(&mut self, region_off: usize, data: &[u8]) -> Result<()> {
        if !self.writable {
            return Err(crate::io::errors::err_read_only("write to read-only mapping"));
        }
        check_bounds(region_off, data.len(), self.buf.len())?;
        if data.is_empty() {
            return Ok(());
        }
        self.buf[region_off..region_off + data.len()].copy_from_slice(data);
        self.dirty.push((region_off, region_off + data.len()));
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        if self.dirty.is_empty() {
            return Ok(());
        }
        // Merge overlapping/adjacent dirty ranges into maximal runs.
        self.dirty.sort_unstable();
        let mut merged: Vec<(usize, usize)> = Vec::with_capacity(self.dirty.len());
        for &(s, e) in &self.dirty {
            if let Some(last) = merged.last_mut() {
                if s <= last.1 {
                    last.1 = last.1.max(e);
                    continue;
                }
            }
            merged.push((s, e));
        }
        let mut segs = Vec::new();
        let mut payload = Vec::new();
        for &(s, e) in &merged {
            self.inner
                .layout
                .split_run(self.base + s as u64, e - s, payload.len(), &mut segs);
            payload.extend_from_slice(&self.buf[s..e]);
        }
        self.inner.write_segments(&segs, &payload)?;
        if let Some(&(_, e)) = merged.last() {
            self.inner.meta.publish_extend(self.base + e as u64)?;
        }
        // Only a successful write-back retires the dirty state: a failed
        // flush (e.g. transient child fault) must stay retryable instead
        // of silently reporting Ok on the next call.
        self.dirty.clear();
        Ok(())
    }

    fn len(&self) -> usize {
        self.buf.len()
    }
}

impl Drop for StripedMap {
    fn drop(&mut self) {
        if self.writable && !self.dirty.is_empty() {
            let _ = self.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-striped-{}-{name}", std::process::id())
    }

    #[test]
    fn roundtrip_spanning_stripe_boundaries() {
        let b = StripedBackend::local(4, 16);
        let path = tmp("rt");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        // 100 bytes at offset 5 cross six unit boundaries.
        let data: Vec<u8> = (0..100u8).collect();
        assert_eq!(f.write_at(5, &data).unwrap(), 100);
        assert_eq!(f.size().unwrap(), 105);
        let mut back = vec![0u8; 100];
        assert_eq!(f.read_at(5, &mut back).unwrap(), 100);
        assert_eq!(back, data);
        b.delete(&path).unwrap();
    }

    #[test]
    fn physical_placement_is_round_robin() {
        let b = StripedBackend::local(2, 8);
        let path = tmp("placement");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let data: Vec<u8> = (0..32u8).collect();
        f.write_at(0, &data).unwrap();
        drop(f);
        // Server 0: stripes 0 and 2 → bytes 0..8 and 16..24.
        let s0 = std::fs::read(StripedBackend::object_path(&path, 0, 2)).unwrap();
        let s1 = std::fs::read(StripedBackend::object_path(&path, 1, 2)).unwrap();
        let want0: Vec<u8> = (0..8u8).chain(16..24).collect();
        let want1: Vec<u8> = (8..16u8).chain(24..32).collect();
        assert_eq!(s0, want0);
        assert_eq!(s1, want1);
        b.delete(&path).unwrap();
    }

    #[test]
    fn sparse_write_reads_zero_holes() {
        let b = StripedBackend::local(4, 10);
        let path = tmp("sparse");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(95, b"tail").unwrap(); // only touches server (95/10)%4 = 1
        assert_eq!(f.size().unwrap(), 99);
        let mut buf = vec![0xAAu8; 40];
        assert_eq!(f.read_at(30, &mut buf).unwrap(), 40);
        assert!(buf.iter().all(|&v| v == 0), "holes must read as zeros");
        b.delete(&path).unwrap();
    }

    #[test]
    fn set_size_distributes_and_shrinks() {
        let b = StripedBackend::local(3, 10);
        let path = tmp("setsize");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.set_size(65).unwrap(); // 6 full units + 5 → objects of 25, 20, 20
        assert_eq!(f.size().unwrap(), 65);
        f.set_size(7).unwrap(); // shrink below one unit
        assert_eq!(f.size().unwrap(), 7);
        let meta1 = std::fs::metadata(StripedBackend::object_path(&path, 1, 3)).unwrap();
        assert_eq!(meta1.len(), 0, "shrink must truncate later servers");
        f.set_size(0).unwrap();
        assert_eq!(f.size().unwrap(), 0);
        b.delete(&path).unwrap();
    }

    #[test]
    fn vectored_runs_roundtrip() {
        let b = StripedBackend::local(4, 8);
        let path = tmp("runs");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.set_size(256).unwrap();
        let runs = [(3u64, 20usize), (40, 9), (100, 30)];
        let data: Vec<u8> = (0..59u8).collect();
        assert_eq!(f.write_runs(&runs, &data).unwrap(), 59);
        let mut back = vec![0u8; 59];
        assert_eq!(f.read_runs(&runs, &mut back).unwrap(), 59);
        assert_eq!(back, data);
        b.delete(&path).unwrap();
    }

    #[test]
    fn mapped_region_roundtrip_and_persistence() {
        let b = StripedBackend::local(4, 16);
        let path = tmp("map");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        {
            let mut m = f.map(10, 100, true).unwrap();
            m.write(5, b"across the stripes").unwrap();
            m.flush().unwrap();
            let mut back = [0u8; 18];
            m.read(5, &mut back).unwrap();
            assert_eq!(&back, b"across the stripes");
        }
        let mut check = [0u8; 18];
        f.read_at(15, &mut check).unwrap();
        assert_eq!(&check, b"across the stripes");
        b.delete(&path).unwrap();
    }

    #[test]
    fn exclusive_lock_serializes_threads() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let b = StripedBackend::local(4, 8);
        let path = tmp("lock");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let in_section = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..8 {
                        let _g = f.lock_exclusive().unwrap();
                        let v = in_section.fetch_add(1, Ordering::SeqCst);
                        assert_eq!(v, 0, "two threads inside the distributed lock");
                        std::thread::yield_now();
                        in_section.fetch_sub(1, Ordering::SeqCst);
                    }
                });
            }
        });
        b.delete(&path).unwrap();
    }

    /// A child backend that counts `StorageFile::size` calls — the
    /// GETATTR fan-out the metadata sidecar is supposed to eliminate.
    struct CountingBackend {
        inner: LocalBackend,
        size_calls: Arc<std::sync::atomic::AtomicUsize>,
    }

    struct CountingFile {
        inner: Arc<dyn StorageFile>,
        size_calls: Arc<std::sync::atomic::AtomicUsize>,
    }

    impl Backend for CountingBackend {
        fn open(&self, path: &str, opts: OpenOptions) -> Result<Arc<dyn StorageFile>> {
            Ok(Arc::new(CountingFile {
                inner: self.inner.open(path, opts)?,
                size_calls: self.size_calls.clone(),
            }))
        }

        fn delete(&self, path: &str) -> Result<()> {
            self.inner.delete(path)
        }

        fn name(&self) -> &'static str {
            "counting"
        }
    }

    impl StorageFile for CountingFile {
        fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
            self.inner.read_at(offset, buf)
        }

        fn write_at(&self, offset: u64, buf: &[u8]) -> Result<usize> {
            self.inner.write_at(offset, buf)
        }

        fn size(&self) -> Result<u64> {
            self.size_calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            self.inner.size()
        }

        fn set_size(&self, size: u64) -> Result<()> {
            self.inner.set_size(size)
        }

        fn preallocate(&self, size: u64) -> Result<()> {
            self.inner.preallocate(size)
        }

        fn sync(&self) -> Result<()> {
            self.inner.sync()
        }

        fn map(&self, offset: u64, len: usize, writable: bool) -> Result<Box<dyn MappedRegion>> {
            self.inner.map(offset, len, writable)
        }

        fn lock_exclusive(&self) -> Result<super::FileLockGuard> {
            self.inner.lock_exclusive()
        }

        fn backend_name(&self) -> &'static str {
            "counting"
        }
    }

    #[test]
    fn size_queries_do_not_fan_out_to_children() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let size_calls = Arc::new(AtomicUsize::new(0));
        let children: Vec<Arc<dyn Backend>> = (0..4)
            .map(|_| {
                Arc::new(CountingBackend {
                    inner: LocalBackend::instant(),
                    size_calls: size_calls.clone(),
                }) as Arc<dyn Backend>
            })
            .collect();
        let b = StripedBackend::new(children, 16).unwrap();
        let path = tmp("eofcache");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        // Opening rebuilt the missing sidecar: exactly one poll of all
        // four children.
        assert_eq!(size_calls.load(Ordering::SeqCst), 4);
        f.write_at(0, &[7u8; 100]).unwrap();
        for _ in 0..5 {
            assert_eq!(f.size().unwrap(), 100);
        }
        let mut back = vec![0u8; 100];
        assert_eq!(f.read_at(0, &mut back).unwrap(), 100);
        // Every size query and read clamp above came from the cached
        // sidecar — zero additional GETATTRs on the children.
        assert_eq!(size_calls.load(Ordering::SeqCst), 4);
        // Truncation invalidates through the sidecar, still fan-out-free.
        f.set_size(40).unwrap();
        assert_eq!(f.size().unwrap(), 40);
        f.preallocate(80).unwrap();
        assert_eq!(f.size().unwrap(), 80);
        assert_eq!(size_calls.load(Ordering::SeqCst), 4);
        b.delete(&path).unwrap();
    }

    #[test]
    fn missing_size_sidecar_is_rebuilt_from_children() {
        let b = StripedBackend::local(3, 8);
        let path = tmp("szrebuild");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &[3u8; 50]).unwrap();
        drop(f);
        std::fs::remove_file(StripedBackend::size_meta_path(&path)).unwrap();
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        assert_eq!(f.size().unwrap(), 50);
        b.delete(&path).unwrap();
        assert!(!std::path::Path::new(&StripedBackend::size_meta_path(&path)).exists());
    }

    #[test]
    fn shrink_by_one_handle_then_extend_by_another_republishes() {
        // Regression: a handle that once knew a larger size must not
        // skip publishing after another handle shrank the file — the
        // covered-check has to consult the shared sidecar, not a
        // per-handle cache.
        let b = StripedBackend::local(4, 8);
        let path = tmp("szshrink");
        let f1 = b.open(&path, OpenOptions::rw_create()).unwrap();
        let f2 = b.open(&path, OpenOptions::rw_create()).unwrap();
        f2.write_at(0, &[9u8; 100]).unwrap(); // f2 observes size 100
        f1.set_size(40).unwrap(); // shrink through the other handle
        assert_eq!(f2.size().unwrap(), 40);
        f2.write_at(0, &[1u8; 50]).unwrap(); // 50 < 100: must still publish
        assert_eq!(f1.size().unwrap(), 50);
        let mut back = [0u8; 50];
        assert_eq!(f1.read_at(0, &mut back).unwrap(), 50);
        assert!(back.iter().all(|&v| v == 1), "bytes past the stale shrink point lost");
        b.delete(&path).unwrap();
    }

    #[test]
    fn cross_handle_extension_is_visible_immediately() {
        // The EOF lives in the shared sidecar, so one handle's cached
        // value can never hide another handle's extension — the
        // invalidation property the barrier-only access patterns rely on.
        let b = StripedBackend::local(4, 8);
        let path = tmp("szxhandle");
        let f1 = b.open(&path, OpenOptions::rw_create()).unwrap();
        let f2 = b.open(&path, OpenOptions::rw_create()).unwrap();
        assert_eq!(f1.size().unwrap(), 0);
        f2.write_at(0, &[1u8; 64]).unwrap();
        assert_eq!(f1.size().unwrap(), 64);
        let mut back = [0u8; 64];
        assert_eq!(f1.read_at(0, &mut back).unwrap(), 64);
        assert!(back.iter().all(|&v| v == 1));
        b.delete(&path).unwrap();
    }

    #[test]
    fn delete_removes_all_objects_and_missing_is_no_such_file() {
        let b = StripedBackend::local(3, 8);
        let path = tmp("del");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &[1u8; 64]).unwrap();
        drop(f);
        b.delete(&path).unwrap();
        for i in 0..3 {
            assert!(!std::path::Path::new(&StripedBackend::object_path(&path, i, 3)).exists());
        }
        let err = b.delete(&path).unwrap_err();
        assert_eq!(err.class, ErrorClass::NoSuchFile);
    }
}
