//! Simulated NFS backend.
//!
//! The paper's shared file "residing on NFS storage" (Figures 4-4, 4-5) is
//! reproduced with a protocol-level cost model over a real local backing
//! file: data always lands for real (other ranks and processes observe it
//! through the same backing file), while each operation pays the NFS costs
//! that produced the paper's shapes:
//!
//! * **per-RPC latency** — every READ/WRITE/GETATTR round trip;
//! * **server ingest bandwidth** — WRITE RPC payloads are serialized at
//!   the single server (modelled by a cross-process file lock around the
//!   modelled transfer), capping aggregate write bandwidth — the paper's
//!   ~250 MB/s plateau in Fig 4-4;
//! * **commit bandwidth** — UNSTABLE write-back batches (the mmap/writeback
//!   path) commit at a higher rate than per-RPC stable writes — the
//!   mechanism behind mapped mode *winning* on the RCMS cluster
//!   (Fig 4-5, ~375 vs ~275 MB/s);
//! * **per-page lock faults** — the Barq-era client takes a lock-manager
//!   round trip per touched page of a mapped region, serialized at the
//!   server. This is the "locking (mapping) mechanisms" collapse the
//!   paper reports for mapped mode on NFS (Fig 4-4);
//! * **client page cache** — re-reads are served locally (the paper's
//!   reads scale with clients, to ~40 GB/s aggregate in Fig 4-5).

use std::sync::Arc;
use std::time::Duration;

use crate::comm::netmodel::TimeScale;
use crate::io::errors::{err_arg, Result};

use super::local::{check_bounds, lock_cell_for, LocalConfig, LocalFile};
use std::os::unix::io::AsRawFd;
use super::{Backend, FileLockGuard, MappedRegion, OpenOptions, StorageFile};

/// NFS protocol/cost parameters.
#[derive(Clone, Copy, Debug)]
pub struct NfsConfig {
    /// Round-trip latency of one RPC, microseconds.
    pub rpc_latency_us: f64,
    /// Client wire bandwidth, MB/s (cold reads, page fault fills).
    pub wire_bw_mbs: f64,
    /// Server ingest bandwidth for stable WRITE RPCs, MB/s (shared across
    /// all clients — serialized at the server).
    pub server_ingest_mbs: f64,
    /// Server commit bandwidth for batched UNSTABLE write-back, MB/s.
    pub server_commit_mbs: f64,
    /// Max payload of one WRITE/READ RPC (wsize/rsize).
    pub io_size: usize,
    /// Page size for mapped regions.
    pub page_size: usize,
    /// Barq-era client: every mapped-region page fault takes a
    /// lock-manager RPC serialized at the server (collapses mapped mode).
    pub map_lock_faults: bool,
    /// Warm client page cache: repeat reads are free.
    pub cached_reads: bool,
    /// Delay scale.
    pub scale: TimeScale,
}

impl NfsConfig {
    /// Functional testing: full protocol paths, zero injected delay.
    pub fn instant() -> Self {
        NfsConfig {
            rpc_latency_us: 0.0,
            wire_bw_mbs: f64::INFINITY,
            server_ingest_mbs: f64::INFINITY,
            server_commit_mbs: f64::INFINITY,
            io_size: 1 << 20,
            page_size: 4096,
            map_lock_faults: false,
            cached_reads: true,
            scale: TimeScale::OFF,
        }
    }

    /// The NFS storage attached to the Barq shared-memory machine
    /// (Fig 4-4): GigE wire, lock-manager faults on mapped regions.
    pub fn barq() -> Self {
        NfsConfig {
            rpc_latency_us: 55.0,
            wire_bw_mbs: 110.0,
            server_ingest_mbs: 250.0,
            server_commit_mbs: 300.0,
            io_size: 1 << 20,
            page_size: 4096,
            map_lock_faults: true,
            cached_reads: true,
            scale: TimeScale::default(),
        }
    }

    /// The SAN-backed NFS of the RCMS cluster (Fig 4-5): InfiniBand wire,
    /// modern client (no per-page lock faults), faster commit path.
    pub fn rcms() -> Self {
        NfsConfig {
            rpc_latency_us: 8.0,
            wire_bw_mbs: 3200.0,
            server_ingest_mbs: 275.0,
            server_commit_mbs: 375.0,
            io_size: 1 << 20,
            page_size: 4096,
            map_lock_faults: false,
            cached_reads: true,
            scale: TimeScale::default(),
        }
    }

    fn latency(&self) -> Duration {
        Duration::from_secs_f64(self.rpc_latency_us * 1e-6)
    }

    fn wire(&self, bytes: usize) -> Duration {
        if self.wire_bw_mbs.is_infinite() {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(bytes as f64 / (self.wire_bw_mbs * 1e6))
        }
    }

    fn ingest(&self, bytes: usize) -> Duration {
        if self.server_ingest_mbs.is_infinite() {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(bytes as f64 / (self.server_ingest_mbs * 1e6))
        }
    }

    fn commit(&self, bytes: usize) -> Duration {
        if self.server_commit_mbs.is_infinite() {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(bytes as f64 / (self.server_commit_mbs * 1e6))
        }
    }
}

/// The simulated-NFS backend.
pub struct NfsBackend {
    cfg: NfsConfig,
}

impl NfsBackend {
    /// Backend with explicit protocol parameters.
    pub fn new(cfg: NfsConfig) -> Self {
        NfsBackend { cfg }
    }

    /// Functional (instant) configuration.
    pub fn instant() -> Self {
        NfsBackend::new(NfsConfig::instant())
    }

    /// Barq NFS (Fig 4-4).
    pub fn barq() -> Self {
        NfsBackend::new(NfsConfig::barq())
    }

    /// RCMS NFS (Fig 4-5).
    pub fn rcms() -> Self {
        NfsBackend::new(NfsConfig::rcms())
    }
}

impl Backend for NfsBackend {
    fn open(&self, path: &str, opts: OpenOptions) -> Result<Arc<dyn StorageFile>> {
        self.cfg.scale.pay(self.cfg.latency()); // LOOKUP/OPEN round trip
        let local = LocalFile::open(path, opts, LocalConfig::instant(), "nfs")?;
        // Server-serialization sidecar (cross-process lock target).
        let srv_path = format!("{path}.jpio-srv");
        let srv = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&srv_path)
            .map_err(|e| crate::io::errors::IoError::from_os(e, "nfs server sidecar"))?;
        Ok(Arc::new(NfsFile {
            inner: Arc::new(NfsInner { local, cfg: self.cfg, srv, srv_key: format!("{path}#server") }),
        }))
    }

    fn delete(&self, path: &str) -> Result<()> {
        self.cfg.scale.pay(self.cfg.latency()); // REMOVE round trip
        let _ = std::fs::remove_file(format!("{path}.jpio-srv"));
        std::fs::remove_file(path)
            .map_err(|e| crate::io::errors::IoError::from_os(e, format!("nfs delete {path}")))
    }

    fn name(&self) -> &'static str {
        "nfs"
    }
}

struct NfsInner {
    local: LocalFile,
    cfg: NfsConfig,
    /// Sidecar file whose flock models single-server serialization across
    /// processes. A *separate* lock domain from the data file's advisory
    /// lock, so holding `lock_exclusive` (atomic mode, RMW sieving) across
    /// writes cannot self-deadlock.
    srv: std::fs::File,
    srv_key: String,
}

impl NfsInner {
    /// Pay a modelled cost *inside* the server's serialization section
    /// (threads via the named lock cell, processes via the sidecar flock).
    fn pay_serialized(&self, d: Duration) -> Result<()> {
        if self.cfg.scale.scale(d) == Duration::ZERO {
            return Ok(());
        }
        let release = lock_cell_for(&self.srv_key).acquire();
        let fd = self.srv.as_raw_fd();
        unsafe { libc::flock(fd, libc::LOCK_EX) };
        self.cfg.scale.pay(d);
        unsafe { libc::flock(fd, libc::LOCK_UN) };
        release();
        Ok(())
    }
}

/// An open file over the simulated NFS mount.
pub struct NfsFile {
    inner: Arc<NfsInner>,
}

impl StorageFile for NfsFile {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        let cfg = &self.inner.cfg;
        if cfg.cached_reads {
            // Revalidation GETATTR once per call; payload from local cache.
            cfg.scale.pay(cfg.latency());
        } else {
            // Cold read: one RPC per rsize chunk over the wire.
            let chunks = buf.len().div_ceil(cfg.io_size).max(1);
            for _ in 0..chunks {
                cfg.scale.pay(cfg.latency());
            }
            cfg.scale.pay(cfg.wire(buf.len()));
        }
        self.inner.local.read_at(offset, buf)
    }

    fn write_at(&self, offset: u64, buf: &[u8]) -> Result<usize> {
        let cfg = &self.inner.cfg;
        let mut pos = 0;
        while pos < buf.len() {
            let chunk = (buf.len() - pos).min(cfg.io_size);
            // Client-side RPC issue + wire occupancy (parallel across
            // clients) ...
            cfg.scale.pay(cfg.latency());
            cfg.scale.pay(cfg.wire(chunk));
            // ... then the server applies the write (serialized).
            self.inner.pay_serialized(cfg.ingest(chunk))?;
            self.inner.local.write_at(offset + pos as u64, &buf[pos..pos + chunk])?;
            pos += chunk;
        }
        Ok(buf.len())
    }

    fn size(&self) -> Result<u64> {
        self.inner.cfg.scale.pay(self.inner.cfg.latency()); // GETATTR
        self.inner.local.size()
    }

    fn set_size(&self, size: u64) -> Result<()> {
        self.inner.cfg.scale.pay(self.inner.cfg.latency()); // SETATTR
        self.inner.local.set_size(size)
    }

    fn preallocate(&self, size: u64) -> Result<()> {
        self.inner.cfg.scale.pay(self.inner.cfg.latency());
        self.inner.local.preallocate(size)
    }

    fn sync(&self) -> Result<()> {
        // COMMIT round trip + real durability of the backing file.
        self.inner.cfg.scale.pay(self.inner.cfg.latency());
        self.inner.local.sync()
    }

    fn map(&self, offset: u64, len: usize, writable: bool) -> Result<Box<dyn MappedRegion>> {
        if len == 0 {
            return Err(err_arg("map: zero-length region"));
        }
        if writable {
            let need = offset + len as u64;
            if self.inner.local.size()? < need {
                self.inner.local.set_size(need)?;
            }
        }
        let cfg = &self.inner.cfg;
        let pages = len.div_ceil(cfg.page_size);
        Ok(Box::new(NfsMap {
            inner: self.inner.clone(),
            base: offset,
            buf: vec![0u8; len],
            present: vec![false; pages],
            dirty: vec![false; pages],
            writable,
        }))
    }

    fn lock_exclusive(&self) -> Result<FileLockGuard> {
        // Lock-manager round trip, then the actual lock.
        self.inner.cfg.scale.pay(self.inner.cfg.latency());
        self.inner.local.lock_exclusive()
    }

    fn backend_name(&self) -> &'static str {
        "nfs"
    }
}

/// Demand-paged emulation of a mapped region over NFS.
struct NfsMap {
    inner: Arc<NfsInner>,
    base: u64,
    buf: Vec<u8>,
    present: Vec<bool>,
    dirty: Vec<bool>,
    writable: bool,
}

impl NfsMap {
    /// Fault in the pages overlapping `[off, off+len)`. `load` fetches
    /// page contents from the server; a full-page overwrite skips the
    /// fetch (write allocation).
    fn fault_range(&mut self, off: usize, len: usize, load: bool) -> Result<()> {
        let cfg = self.inner.cfg;
        let psz = cfg.page_size;
        let first = off / psz;
        let last = (off + len - 1) / psz;
        for p in first..=last {
            if self.present[p] {
                continue;
            }
            let page_off = p * psz;
            let page_len = psz.min(self.buf.len() - page_off);
            // Whole-page overwrite needs no server data...
            let covered = off <= page_off && off + len >= page_off + page_len;
            let need_load = load || !covered;
            if cfg.map_lock_faults {
                // Barq-era client: lock-manager RPC per page, serialized
                // at the server — the Fig 4-4 mapped-mode collapse.
                self.inner.pay_serialized(cfg.latency())?;
            }
            if need_load {
                cfg.scale.pay(cfg.latency());
                cfg.scale.pay(cfg.wire(page_len));
                self.inner
                    .local
                    .read_at(self.base + page_off as u64, &mut self.buf[page_off..page_off + page_len])?;
            }
            self.present[p] = true;
        }
        Ok(())
    }
}

impl MappedRegion for NfsMap {
    fn read(&mut self, region_off: usize, buf: &mut [u8]) -> Result<()> {
        check_bounds(region_off, buf.len(), self.buf.len())?;
        if buf.is_empty() {
            return Ok(());
        }
        self.fault_range(region_off, buf.len(), true)?;
        buf.copy_from_slice(&self.buf[region_off..region_off + buf.len()]);
        Ok(())
    }

    fn write(&mut self, region_off: usize, data: &[u8]) -> Result<()> {
        if !self.writable {
            return Err(crate::io::errors::err_read_only("write to read-only mapping"));
        }
        check_bounds(region_off, data.len(), self.buf.len())?;
        if data.is_empty() {
            return Ok(());
        }
        self.fault_range(region_off, data.len(), false)?;
        self.buf[region_off..region_off + data.len()].copy_from_slice(data);
        let psz = self.inner.cfg.page_size;
        for p in region_off / psz..=(region_off + data.len() - 1) / psz {
            self.dirty[p] = true;
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        let cfg = self.inner.cfg;
        let psz = cfg.page_size;
        // Coalesce dirty pages into maximal runs; each run is one batched
        // UNSTABLE write-back + its share of the final COMMIT.
        let mut p = 0;
        while p < self.dirty.len() {
            if !self.dirty[p] {
                p += 1;
                continue;
            }
            let start = p;
            while p < self.dirty.len() && self.dirty[p] {
                self.dirty[p] = false;
                p += 1;
            }
            let off = start * psz;
            let len = (p * psz).min(self.buf.len()) - off;
            // Wire (parallel) then commit at the server (serialized).
            cfg.scale.pay(cfg.wire(len));
            self.inner.pay_serialized(cfg.commit(len))?;
            self.inner.local.write_at(self.base + off as u64, &self.buf[off..off + len])?;
        }
        // Closing COMMIT round trip. (Durability of the backing file is
        // the job of file-level sync(); a real NFS client's write-back
        // does not fsync the server disk per msync.)
        cfg.scale.pay(cfg.latency());
        Ok(())
    }

    fn len(&self) -> usize {
        self.buf.len()
    }
}

impl Drop for NfsMap {
    fn drop(&mut self) {
        if self.writable && self.dirty.iter().any(|&d| d) {
            let _ = self.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::errors::ErrorClass;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-nfs-{}-{name}", std::process::id())
    }

    #[test]
    fn functional_roundtrip_through_protocol_paths() {
        let b = NfsBackend::instant();
        let path = tmp("rw");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        // Multi-chunk write (io_size boundary crossing).
        let data: Vec<u8> = (0..3_000_000u32).map(|i| i as u8).collect();
        f.write_at(7, &data).unwrap();
        let mut back = vec![0u8; data.len()];
        assert_eq!(f.read_at(7, &mut back).unwrap(), data.len());
        assert_eq!(back, data);
        f.sync().unwrap();
        b.delete(&path).unwrap();
    }

    #[test]
    fn mapped_region_demand_pages_and_persists() {
        let b = NfsBackend::instant();
        let path = tmp("map");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &vec![9u8; 16384]).unwrap();
        {
            let mut m = f.map(0, 16384, true).unwrap();
            let mut buf = [0u8; 100];
            m.read(5000, &mut buf).unwrap();
            assert_eq!(buf, [9u8; 100]);
            m.write(8000, b"over-nfs").unwrap();
            m.flush().unwrap();
        }
        let mut check = [0u8; 8];
        f.read_at(8000, &mut check).unwrap();
        assert_eq!(&check, b"over-nfs");
        b.delete(&path).unwrap();
    }

    #[test]
    fn mapped_write_unflushed_is_flushed_on_drop() {
        let b = NfsBackend::instant();
        let path = tmp("drop");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        {
            let mut m = f.map(0, 4096, true).unwrap();
            m.write(0, b"dropped").unwrap();
            // no explicit flush
        }
        let mut check = [0u8; 7];
        f.read_at(0, &mut check).unwrap();
        assert_eq!(&check, b"dropped");
        b.delete(&path).unwrap();
    }

    #[test]
    fn read_only_mapping_rejects_writes() {
        let b = NfsBackend::instant();
        let path = tmp("ro");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.set_size(4096).unwrap();
        let mut m = f.map(0, 4096, false).unwrap();
        let err = m.write(0, b"x").unwrap_err();
        assert_eq!(err.class, ErrorClass::ReadOnly);
        b.delete(&path).unwrap();
    }

    #[test]
    fn lock_faults_collapse_mapped_writes() {
        // With map_lock_faults, writing N pages costs ≥ N serialized
        // latencies; without, a full-page overwrite is free of RPCs.
        let mut cfg = NfsConfig::instant();
        cfg.rpc_latency_us = 2000.0; // 2 ms, measurable
        cfg.map_lock_faults = true;
        cfg.scale = TimeScale(1.0);
        let b = NfsBackend::new(cfg);
        let path = tmp("collapse");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.set_size(8 * 4096).unwrap();
        let mut m = f.map(0, 8 * 4096, true).unwrap();
        let start = std::time::Instant::now();
        m.write(0, &vec![1u8; 8 * 4096]).unwrap(); // 8 pages
        let locked = start.elapsed();
        assert!(locked >= Duration::from_millis(16), "lock faults not paid: {locked:?}");

        let mut cfg2 = NfsConfig::instant();
        cfg2.rpc_latency_us = 2000.0;
        cfg2.map_lock_faults = false;
        cfg2.scale = TimeScale(1.0);
        let b2 = NfsBackend::new(cfg2);
        let path2 = tmp("nocollapse");
        let f2 = b2.open(&path2, OpenOptions::rw_create()).unwrap();
        f2.set_size(8 * 4096).unwrap();
        let mut m2 = f2.map(0, 8 * 4096, true).unwrap();
        let start = std::time::Instant::now();
        m2.write(0, &vec![1u8; 8 * 4096]).unwrap(); // full-page overwrites
        assert!(start.elapsed() < Duration::from_millis(8));
        b.delete(&path).unwrap();
        b2.delete(&path2).unwrap();
    }

    #[test]
    fn server_ingest_is_serialized_across_threads() {
        // Two threads writing 1 MB each at 100 MB/s ingest must take ≥
        // ~20 ms total because the server section is exclusive.
        let mut cfg = NfsConfig::instant();
        cfg.server_ingest_mbs = 100.0;
        cfg.scale = TimeScale(1.0);
        let b = NfsBackend::new(cfg);
        let path = tmp("serial");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let start = std::time::Instant::now();
        std::thread::scope(|s| {
            for t in 0..2 {
                let f = &f;
                s.spawn(move || {
                    f.write_at(t as u64 * (1 << 20), &vec![0u8; 1 << 20]).unwrap();
                });
            }
        });
        assert!(start.elapsed() >= Duration::from_millis(19), "{:?}", start.elapsed());
        b.delete(&path).unwrap();
    }
}
