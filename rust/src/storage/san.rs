//! SAN backend: the RCMS cluster's fibre-channel SAN (Table 4-2).
//!
//! "A high-performance and reliable SAN storage is linked by Servers,
//! accessible by all computational nodes." Modelled as a shared-disk
//! device: much higher ingest bandwidth than NFS, negligible per-op
//! latency, no client-side protocol costs. Used by the checkpoint examples
//! and the ablation benches as the fast-storage contrast to NFS.

use std::sync::Arc;

use crate::comm::netmodel::TimeScale;
use crate::io::errors::Result;

use super::local::{LocalConfig, LocalFile};
use super::{Backend, OpenOptions, StorageFile};

/// SAN device model.
#[derive(Clone, Copy, Debug)]
pub struct SanConfig {
    /// Aggregate device write bandwidth, MB/s.
    pub write_bw_mbs: f64,
    /// Delay scale.
    pub scale: TimeScale,
}

impl SanConfig {
    /// Functional (instant) configuration.
    pub fn instant() -> Self {
        SanConfig { write_bw_mbs: f64::INFINITY, scale: TimeScale::OFF }
    }

    /// The RCMS 22 TB fibre-channel SAN with RAID controller.
    pub fn rcms() -> Self {
        SanConfig { write_bw_mbs: 1200.0, scale: TimeScale::default() }
    }
}

/// The SAN backend.
pub struct SanBackend {
    cfg: SanConfig,
}

impl SanBackend {
    /// Backend with the given model.
    pub fn new(cfg: SanConfig) -> Self {
        SanBackend { cfg }
    }

    /// Functional configuration.
    pub fn instant() -> Self {
        SanBackend::new(SanConfig::instant())
    }

    /// RCMS SAN model.
    pub fn rcms() -> Self {
        SanBackend::new(SanConfig::rcms())
    }
}

impl Backend for SanBackend {
    fn open(&self, path: &str, opts: OpenOptions) -> Result<Arc<dyn StorageFile>> {
        let local_cfg = LocalConfig {
            write_bw_mbs: if self.cfg.write_bw_mbs.is_infinite() {
                None
            } else {
                Some(self.cfg.write_bw_mbs)
            },
            read_bw_mbs: None,
            scale: self.cfg.scale,
        };
        Ok(Arc::new(LocalFile::open(path, opts, local_cfg, "san")?))
    }

    fn delete(&self, path: &str) -> Result<()> {
        std::fs::remove_file(path)
            .map_err(|e| crate::io::errors::IoError::from_os(e, format!("san delete {path}")))
    }

    fn name(&self) -> &'static str {
        "san"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn san_behaves_like_a_fast_local_disk() {
        let b = SanBackend::instant();
        let path = format!("/tmp/jpio-san-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, b"on the san").unwrap();
        let mut buf = [0u8; 10];
        f.read_at(0, &mut buf).unwrap();
        assert_eq!(&buf, b"on the san");
        assert_eq!(f.backend_name(), "san");
        b.delete(&path).unwrap();
    }
}
