//! Storage substrates: where the shared file lives.
//!
//! The paper evaluates three placements of the shared file: the local disk
//! of the shared-memory machine (Fig 4-3), NFS storage attached to it
//! (Fig 4-4), and the NFS/SAN storage of the distributed-memory RCMS
//! cluster (Fig 4-5). A fourth placement goes past the paper's evaluation:
//! [`striped`] declusters the logical file round-robin over N child
//! backends ([`layout`] holds the stripe arithmetic), removing the
//! single-server ingest bottleneck the way a parallel file system (ViPIOS,
//! PVFS) does. We model each as a [`Backend`] producing
//! [`StorageFile`] handles with positioned I/O, an mmap-style interface
//! (so the *mapped-mode* access strategy works on every backend, with
//! backend-appropriate costs), byte-range/whole-file locking (for MPI
//! atomic mode), and durability (`sync`).
//!
//! Real bytes always land in a real local file — data correctness is never
//! simulated — while *performance* (NFS RPC latency, server ingest
//! bandwidth, disk write bandwidth) is modelled per backend, per the
//! substitution table in DESIGN.md §2.

pub mod faults;
pub mod layout;
pub mod local;
pub mod nfs;
pub mod san;
pub mod striped;

use crate::io::errors::Result;
use std::sync::Arc;

/// Open options for a storage file.
#[derive(Clone, Copy, Debug, Default)]
pub struct OpenOptions {
    /// Open for reading.
    pub read: bool,
    /// Open for writing.
    pub write: bool,
    /// Create if missing.
    pub create: bool,
    /// Fail if the file already exists.
    pub excl: bool,
    /// Truncate on open.
    pub truncate: bool,
}

impl OpenOptions {
    /// Read/write + create — the common test configuration.
    pub fn rw_create() -> Self {
        OpenOptions { read: true, write: true, create: true, ..Default::default() }
    }

    /// Read-only.
    pub fn read_only() -> Self {
        OpenOptions { read: true, ..Default::default() }
    }
}

/// A storage backend: a place files live, with a performance model.
pub trait Backend: Send + Sync {
    /// Open (or create) a file.
    fn open(&self, path: &str, opts: OpenOptions) -> Result<Arc<dyn StorageFile>>;

    /// Delete a file (`MPI_FILE_DELETE`).
    fn delete(&self, path: &str) -> Result<()>;

    /// Backend name for reports ("local", "nfs", "san").
    fn name(&self) -> &'static str;
}

/// An open file on some backend. Handles are shared between ranks of a
/// thread world (`Arc`) and duplicated across processes (each process
/// opens its own).
pub trait StorageFile: Send + Sync {
    /// Positioned read; returns bytes read (short only at EOF).
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize>;

    /// Positioned write; returns bytes written (never short on success).
    fn write_at(&self, offset: u64, buf: &[u8]) -> Result<usize>;

    /// Vectored positioned read of disjoint runs: `(file_offset, len)`
    /// pairs filled into `buf` back-to-back. Default loops `read_at`,
    /// stopping at the first short (EOF) read so every byte returned sits
    /// at the position its run prescribes — continuing past a short read
    /// would misalign all subsequent runs within `buf`.
    fn read_runs(&self, runs: &[(u64, usize)], buf: &mut [u8]) -> Result<usize> {
        let mut pos = 0;
        for &(off, len) in runs {
            let got = self.read_at(off, &mut buf[pos..pos + len])?;
            pos += got;
            if got < len {
                break;
            }
        }
        Ok(pos)
    }

    /// Vectored positioned write; mirror of [`StorageFile::read_runs`].
    fn write_runs(&self, runs: &[(u64, usize)], buf: &[u8]) -> Result<usize> {
        let mut pos = 0;
        for &(off, len) in runs {
            pos += self.write_at(off, &buf[pos..pos + len])?;
        }
        Ok(pos)
    }

    /// Plan-execution entry point: read the whole coalesced run set of a
    /// compiled [`IoPlan`](crate::io::plan::IoPlan) in one call. `runs`
    /// are disjoint and sorted with payload packed back-to-back in `buf`.
    /// Single-device backends delegate to the vectored helpers; backends
    /// that dispatch runs concurrently themselves (striped) see the
    /// entire plan at once instead of strategy-sized fragments.
    fn read_plan(&self, runs: &[(u64, usize)], buf: &mut [u8]) -> Result<usize> {
        self.read_runs(runs, buf)
    }

    /// Plan-execution entry point for writes; mirror of
    /// [`StorageFile::read_plan`].
    fn write_plan(&self, runs: &[(u64, usize)], buf: &[u8]) -> Result<usize> {
        self.write_runs(runs, buf)
    }

    /// Scatter `(file_offset, bytes)` pieces — sorted by offset and
    /// non-overlapping — in one call. This is the zero-copy I/O phase
    /// of a collective write: the aggregator hands over its inbound
    /// exchange payloads while they still sit in the receive buffers,
    /// instead of staging them through a payload-sized copy first. The
    /// default gathers the pieces into one packed buffer and delegates
    /// to [`StorageFile::write_plan`]; backends that execute whole
    /// plans themselves (striped) override it to split each piece
    /// straight into per-server transfers with no intermediate
    /// gather. Returns the total bytes written.
    fn write_pieces(&self, pieces: &[(u64, &[u8])]) -> Result<usize> {
        let total: usize = pieces.iter().map(|(_, b)| b.len()).sum();
        let mut runs = Vec::with_capacity(pieces.len());
        let mut buf = Vec::with_capacity(total);
        for &(off, bytes) in pieces {
            runs.push((off, bytes.len()));
            buf.extend_from_slice(bytes);
        }
        self.write_plan(&runs, &buf)
    }

    /// True when this backend executes whole vectored plans itself (the
    /// striped backend's concurrent per-server dispatch) and the
    /// scheduler should hand it complete multi-run plans rather than
    /// staging them through an access strategy. Access-style hints stay
    /// advisory on such backends, per the MPI hint semantics.
    fn prefers_plan_execution(&self) -> bool {
        false
    }

    /// Current size in bytes (`MPI_FILE_GET_SIZE`).
    fn size(&self) -> Result<u64>;

    /// Truncate/extend (`MPI_FILE_SET_SIZE`).
    fn set_size(&self, size: u64) -> Result<()>;

    /// Preallocate storage (`MPI_FILE_PREALLOCATE`).
    fn preallocate(&self, size: u64) -> Result<()>;

    /// Flush this handle's writes to the storage device
    /// (`MPI_FILE_SYNC`). On NFS this is the COMMIT that makes updates
    /// visible to other clients (close-to-open consistency).
    fn sync(&self) -> Result<()>;

    /// Create a mapped region of `[offset, offset+len)` — the *mapped
    /// mode* strategy. Local backends return a real `mmap`; NFS returns a
    /// fault-accounted emulation.
    fn map(&self, offset: u64, len: usize, writable: bool) -> Result<Box<dyn MappedRegion>>;

    /// Acquire an exclusive whole-file lock shared across ranks *and*
    /// processes (used by MPI atomic mode and by the NFS server model for
    /// request serialization). Returns a guard; dropping it unlocks.
    fn lock_exclusive(&self) -> Result<FileLockGuard>;

    /// Backend name (for metrics labels).
    fn backend_name(&self) -> &'static str;

    /// Stripe layout when this file is declustered across multiple
    /// servers ([`striped::StripedBackend`]); `None` for single-device
    /// backends. The collective layer queries this to hand two-phase
    /// aggregators file domains aligned to stripe boundaries.
    fn stripe_layout(&self) -> Option<layout::StripeLayout> {
        None
    }

    /// The redundancy-aware stripe mapping, when striped. Defaults to
    /// the plain layout with no redundancy; the striped backend
    /// overrides it so the collective layer can assign stripe-aligned
    /// file domains that follow the *data* placement, which the parity
    /// rotation permutes away from the plain unit cycle.
    fn stripe_map(&self) -> Option<layout::StripeMap> {
        self.stripe_layout()
            .map(|layout| layout::StripeMap { layout, redundancy: layout::Redundancy::None })
    }

    /// Preferred alignment (bytes) for large coalesced writes, queried
    /// by the client-side page cache ([`crate::io::cache`]) to size its
    /// pages: a flush that covers whole aligned extents lands as full
    /// stripe rows and never pays a parity read-modify-write. Defaults
    /// to one data row on striped storage and `None` (no preference)
    /// on single-device backends.
    fn preferred_flush_alignment(&self) -> Option<u64> {
        self.stripe_map().map(|m| m.data_width())
    }

    /// Drain pending advisory errors: conditions where an operation
    /// *succeeded* but the file is running degraded — today the striped
    /// backend's replica/parity reconstruction around a failed server
    /// (class [`ErrorClass::Degraded`](crate::io::errors::ErrorClass)).
    /// Returning them as `Err` would turn a survivable failure into a
    /// failed operation, so they travel out-of-band; single-device
    /// backends have none.
    fn take_advisories(&self) -> Vec<crate::io::errors::IoError> {
        Vec::new()
    }

    /// Cumulative backend-side event counters since open. Unlike
    /// [`take_advisories`](StorageFile::take_advisories) these are *not*
    /// drained on read — the instrumentation layer samples them at close
    /// for the Darshan-style per-file record. Single-device backends
    /// report all zeros.
    fn backend_counters(&self) -> BackendCounters {
        BackendCounters::default()
    }

    /// Per-server health as observed by this handle: `health[s]` is
    /// `false` once server `s` has failed an I/O (degraded read
    /// fallover, settled write failure). The collective layer samples
    /// this to bias stripe-cyclic file domains away from dead servers;
    /// `None` on single-device backends.
    fn server_health(&self) -> Option<Vec<bool>> {
        None
    }

    /// Kick off a background redundancy rebuild of any blank/replaced
    /// stripe server (the `jpio_rebuild = start` hint path). `throttle`
    /// is the per-lock-batch byte budget from `jpio_rebuild_throttle`.
    /// Returns `true` when a rebuild task was started or resumed;
    /// single-device backends have nothing to rebuild.
    fn start_rebuild(&self, throttle: Option<u64>) -> Result<bool> {
        let _ = throttle;
        Ok(false)
    }
}

/// Snapshot of per-file backend event counters, sampled by the stats
/// subsystem ([`crate::io::stats`]). The striped backend is the only
/// producer today: it counts redundancy-path events that the byte
/// counters in the I/O layer cannot see.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BackendCounters {
    /// Reads served by reconstructing data from a replica or parity
    /// group instead of the primary server (degraded mode).
    pub degraded_reads: u64,
    /// Read-modify-write cycles taken to update parity for partial
    /// stripe writes.
    pub parity_rmw_cycles: u64,
    /// Total bytes dispatched to individual servers, including
    /// redundancy traffic — the per-server fan-out amplification of
    /// the bytes the caller asked to move.
    pub fanout_bytes: u64,
    /// Bytes re-materialized onto a replaced/blank server by the
    /// background rebuild engine (replica copy or parity XOR).
    pub rebuild_bytes_reconstructed: u64,
    /// Stripe rows rewritten into a new layout generation by the live
    /// restriping migration.
    pub restripe_rows_migrated: u64,
}

/// A mapped view of a file region. The local implementation is a real
/// memory mapping; the NFS implementation emulates demand paging with
/// modelled RPC costs per faulted page (which is exactly why the paper's
/// mapped mode "performed inefficiently when file was moved to NFS
/// storage").
pub trait MappedRegion: Send {
    /// Copy `buf.len()` bytes from the region at `region_off`.
    fn read(&mut self, region_off: usize, buf: &mut [u8]) -> Result<()>;

    /// Copy `data` into the region at `region_off`.
    fn write(&mut self, region_off: usize, data: &[u8]) -> Result<()>;

    /// Write dirty pages back (`msync` analogue).
    fn flush(&mut self) -> Result<()>;

    /// Region length.
    fn len(&self) -> usize;

    /// True if the region is empty.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// RAII guard for [`StorageFile::lock_exclusive`]. Combines an in-process
/// mutex guard (threads) with an OS `flock` (processes).
pub struct FileLockGuard {
    /// Keeps the fd-level flock alive; unlocked on drop.
    pub(crate) os_unlock: Option<Box<dyn FnOnce() + Send>>,
}

impl Drop for FileLockGuard {
    fn drop(&mut self) {
        if let Some(f) = self.os_unlock.take() {
            f();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::local::LocalBackend;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-storage-{}-{name}", std::process::id())
    }

    #[test]
    fn default_run_helpers_compose() {
        let b = LocalBackend::instant();
        let path = tmp("runs");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.set_size(100).unwrap();
        let data = [1u8, 2, 3, 4, 5, 6];
        f.write_runs(&[(0, 3), (10, 3)], &data).unwrap();
        let mut out = [0u8; 6];
        f.read_runs(&[(0, 3), (10, 3)], &mut out).unwrap();
        assert_eq!(out, data);
        b.delete(&path).unwrap();
    }

    #[test]
    fn default_read_runs_stops_at_short_read() {
        let b = LocalBackend::instant();
        let path = tmp("shortruns");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, b"abcdefghij").unwrap(); // 10-byte file
        // Second run crosses EOF: the read must stop there, not continue
        // with the third run at a misaligned buffer position.
        let mut buf = [0xEEu8; 16];
        let got = f.read_runs(&[(0, 4), (8, 4), (20, 4)], &mut buf).unwrap();
        assert_eq!(got, 6);
        assert_eq!(&buf[..6], b"abcdij");
        assert_eq!(&buf[6..], &[0xEEu8; 10], "bytes past the short read must be untouched");
        // Unsorted runs: a short first run must not shift the second run's
        // bytes to the wrong position.
        let mut buf = [0u8; 8];
        let got = f.read_runs(&[(8, 4), (0, 4)], &mut buf).unwrap();
        assert_eq!(got, 2);
        assert_eq!(&buf[..2], b"ij");
        b.delete(&path).unwrap();
    }
}
