//! Fault-injection wrapper backend.
//!
//! Wraps any [`Backend`] and injects MPJ-IO error classes on chosen
//! operations — used by the error-handling tests (§7.2.7/7.2.8) to prove
//! that failures surface with the right class instead of corrupting state,
//! and by the collective-I/O tests to exercise partial-failure paths.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::io::errors::{ErrorClass, IoError, Result};

use super::{Backend, FileLockGuard, MappedRegion, OpenOptions, StorageFile};

/// Which operation kind to fail.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FaultOp {
    /// Fail `read_at`.
    Read,
    /// Fail `write_at`.
    Write,
    /// Fail `sync`.
    Sync,
}

/// A single fault rule: fail the `nth` invocation (0-based) of `op` with
/// `class`. Each rule fires once.
#[derive(Debug)]
pub struct FaultRule {
    /// Operation to intercept.
    pub op: FaultOp,
    /// Which invocation to fail (0 = the first).
    pub nth: u64,
    /// Error class to inject.
    pub class: ErrorClass,
}

/// Shared fault schedule + counters.
pub struct FaultPlan {
    rules: Vec<FaultRule>,
    reads: AtomicU64,
    writes: AtomicU64,
    syncs: AtomicU64,
}

impl FaultPlan {
    /// Build a plan from rules.
    pub fn new(rules: Vec<FaultRule>) -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            rules,
            reads: AtomicU64::new(0),
            writes: AtomicU64::new(0),
            syncs: AtomicU64::new(0),
        })
    }

    fn check(&self, op: FaultOp) -> Result<()> {
        let counter = match op {
            FaultOp::Read => &self.reads,
            FaultOp::Write => &self.writes,
            FaultOp::Sync => &self.syncs,
        };
        let n = counter.fetch_add(1, Ordering::SeqCst);
        for r in &self.rules {
            if r.op == op && r.nth == n {
                return Err(IoError::new(r.class, format!("injected fault on {op:?} #{n}")));
            }
        }
        Ok(())
    }

    /// Number of intercepted operations so far, by kind.
    pub fn counts(&self) -> (u64, u64, u64) {
        (
            self.reads.load(Ordering::SeqCst),
            self.writes.load(Ordering::SeqCst),
            self.syncs.load(Ordering::SeqCst),
        )
    }
}

/// Backend wrapper injecting the plan's faults into every opened file.
pub struct FaultBackend<B: Backend> {
    inner: B,
    plan: Arc<FaultPlan>,
}

impl<B: Backend> FaultBackend<B> {
    /// Wrap `inner` with `plan`.
    pub fn new(inner: B, plan: Arc<FaultPlan>) -> Self {
        FaultBackend { inner, plan }
    }
}

impl<B: Backend> Backend for FaultBackend<B> {
    fn open(&self, path: &str, opts: OpenOptions) -> Result<Arc<dyn StorageFile>> {
        let f = self.inner.open(path, opts)?;
        Ok(Arc::new(FaultFile { inner: f, plan: self.plan.clone() }))
    }

    fn delete(&self, path: &str) -> Result<()> {
        self.inner.delete(path)
    }

    fn name(&self) -> &'static str {
        "faulty"
    }
}

struct FaultFile {
    inner: Arc<dyn StorageFile>,
    plan: Arc<FaultPlan>,
}

impl StorageFile for FaultFile {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        self.plan.check(FaultOp::Read)?;
        self.inner.read_at(offset, buf)
    }

    fn write_at(&self, offset: u64, buf: &[u8]) -> Result<usize> {
        self.plan.check(FaultOp::Write)?;
        self.inner.write_at(offset, buf)
    }

    fn size(&self) -> Result<u64> {
        self.inner.size()
    }

    fn set_size(&self, size: u64) -> Result<()> {
        self.inner.set_size(size)
    }

    fn preallocate(&self, size: u64) -> Result<()> {
        self.inner.preallocate(size)
    }

    fn sync(&self) -> Result<()> {
        self.plan.check(FaultOp::Sync)?;
        self.inner.sync()
    }

    fn map(&self, offset: u64, len: usize, writable: bool) -> Result<Box<dyn MappedRegion>> {
        self.inner.map(offset, len, writable)
    }

    fn lock_exclusive(&self) -> Result<FileLockGuard> {
        self.inner.lock_exclusive()
    }

    fn backend_name(&self) -> &'static str {
        "faulty"
    }

    fn stripe_layout(&self) -> Option<super::layout::StripeLayout> {
        self.inner.stripe_layout()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::local::LocalBackend;

    #[test]
    fn injects_on_the_scheduled_invocation() {
        let plan = FaultPlan::new(vec![FaultRule {
            op: FaultOp::Write,
            nth: 1,
            class: ErrorClass::NoSpace,
        }]);
        let b = FaultBackend::new(LocalBackend::instant(), plan.clone());
        let path = format!("/tmp/jpio-fault-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, b"ok").unwrap(); // write #0 passes
        let err = f.write_at(2, b"boom").unwrap_err(); // write #1 fails
        assert_eq!(err.class, ErrorClass::NoSpace);
        f.write_at(2, b"ok").unwrap(); // rule fired once
        assert_eq!(plan.counts().1, 3);
        b.delete(&path).unwrap();
    }

    #[test]
    fn sync_faults() {
        let plan = FaultPlan::new(vec![FaultRule {
            op: FaultOp::Sync,
            nth: 0,
            class: ErrorClass::Io,
        }]);
        let b = FaultBackend::new(LocalBackend::instant(), plan);
        let path = format!("/tmp/jpio-fault-sync-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        assert_eq!(f.sync().unwrap_err().class, ErrorClass::Io);
        f.sync().unwrap();
        b.delete(&path).unwrap();
    }
}
