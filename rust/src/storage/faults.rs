//! Fault-injection wrapper backend.
//!
//! Wraps any [`Backend`] and injects MPJ-IO error classes on chosen
//! operations — used by the error-handling tests (§7.2.7/7.2.8) to prove
//! that failures surface with the right class instead of corrupting state,
//! by the collective-I/O tests to exercise partial-failure paths, and by
//! the redundancy tests to kill a stripe server outright.
//!
//! Every data-path method of [`StorageFile`] is intercepted under its own
//! [`FaultOp`], including the PR 2 plan entry points (`read_plan` /
//! `write_plan`) and the vectored helpers (`read_runs` / `write_runs`)
//! the striped backend's per-server fan-out actually calls — a rule on
//! `FaultOp::Write` alone would never see a striped child's vectored
//! dispatch. Rules fire once (`nth`) or persistently (`sticky`, from
//! `nth` onward); [`FaultPlan::kill`] arms sticky rules on every op,
//! modelling a failed-stop server, and rules can be injected after open
//! ([`FaultPlan::inject`]) to kill a server mid-workload.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::io::errors::{ErrorClass, IoError, Result};

use super::{Backend, FileLockGuard, MappedRegion, OpenOptions, StorageFile};

/// Which operation kind to fail.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FaultOp {
    /// Fail `read_at`.
    Read,
    /// Fail `write_at`.
    Write,
    /// Fail `sync`.
    Sync,
    /// Fail the vectored `read_runs` (the striped read fan-out unit).
    ReadRuns,
    /// Fail the vectored `write_runs` (the striped write fan-out unit).
    WriteRuns,
    /// Fail the whole-plan `read_plan` dispatch.
    ReadPlan,
    /// Fail the whole-plan `write_plan` dispatch.
    WritePlan,
}

/// Every interceptable operation, in counter order.
const ALL_OPS: [FaultOp; 7] = [
    FaultOp::Read,
    FaultOp::Write,
    FaultOp::Sync,
    FaultOp::ReadRuns,
    FaultOp::WriteRuns,
    FaultOp::ReadPlan,
    FaultOp::WritePlan,
];

impl FaultOp {
    fn index(self) -> usize {
        match self {
            FaultOp::Read => 0,
            FaultOp::Write => 1,
            FaultOp::Sync => 2,
            FaultOp::ReadRuns => 3,
            FaultOp::WriteRuns => 4,
            FaultOp::ReadPlan => 5,
            FaultOp::WritePlan => 6,
        }
    }
}

/// A single fault rule: fail invocation(s) of `op` with `class` — the
/// `nth` invocation (0-based) when `sticky` is false, every invocation
/// from the `nth` onward when true.
#[derive(Clone, Copy, Debug)]
pub struct FaultRule {
    /// Operation to intercept.
    pub op: FaultOp,
    /// Which invocation to fail (0 = the first).
    pub nth: u64,
    /// Error class to inject.
    pub class: ErrorClass,
    /// Fail every invocation from `nth` onward instead of just `nth`.
    pub sticky: bool,
}

impl FaultRule {
    /// A one-shot rule: fail the `nth` invocation of `op`.
    pub fn once(op: FaultOp, nth: u64, class: ErrorClass) -> FaultRule {
        FaultRule { op, nth, class, sticky: false }
    }

    /// A persistent rule: fail every invocation of `op` from the `nth`
    /// onward (a server that dies partway through a workload).
    pub fn from_nth(op: FaultOp, nth: u64, class: ErrorClass) -> FaultRule {
        FaultRule { op, nth, class, sticky: true }
    }

    /// Fail every invocation of `op`.
    pub fn always(op: FaultOp, class: ErrorClass) -> FaultRule {
        FaultRule::from_nth(op, 0, class)
    }
}

/// Shared fault schedule + counters.
pub struct FaultPlan {
    rules: Mutex<Vec<FaultRule>>,
    counters: [AtomicU64; 7],
}

impl FaultPlan {
    /// Build a plan from rules.
    pub fn new(rules: Vec<FaultRule>) -> Arc<FaultPlan> {
        Arc::new(FaultPlan { rules: Mutex::new(rules), counters: Default::default() })
    }

    /// A failed-stop server: every *data-path* operation
    /// (read/write/sync and their vectored/plan variants) fails with
    /// `class`, forever. Metadata ops (`size`/`set_size`/`preallocate`)
    /// and `open` still answer — the model is a failed data service,
    /// not a vanished host; the striped GETATTR fallback additionally
    /// tolerates children whose metadata is gone too.
    pub fn kill(class: ErrorClass) -> Arc<FaultPlan> {
        FaultPlan::new(ALL_OPS.iter().map(|&op| FaultRule::always(op, class)).collect())
    }

    /// Arm additional rules on a live plan (kill a server mid-workload).
    pub fn inject(&self, rules: impl IntoIterator<Item = FaultRule>) {
        self.rules.lock().unwrap().extend(rules);
    }

    /// Arm failed-stop rules on every op of a live plan.
    pub fn inject_kill(&self, class: ErrorClass) {
        self.inject(ALL_OPS.iter().map(|&op| FaultRule::always(op, class)));
    }

    /// Clear every armed rule: the failed server has been *replaced* by
    /// a healthy (blank) one. Invocation counters keep counting — the
    /// replacement is a new data service behind the same slot, not a
    /// rollback of history. Pair with truncating/removing the dead
    /// server's stripe objects to model a blank disk, then run a
    /// rebuild to re-materialize them.
    pub fn revive(&self) {
        self.rules.lock().unwrap().clear();
    }

    fn check(&self, op: FaultOp) -> Result<()> {
        let n = self.counters[op.index()].fetch_add(1, Ordering::SeqCst);
        for r in self.rules.lock().unwrap().iter() {
            if r.op == op && (n == r.nth || (r.sticky && n >= r.nth)) {
                return Err(IoError::new(r.class, format!("injected fault on {op:?} #{n}")));
            }
        }
        Ok(())
    }

    /// Number of intercepted invocations so far, by kind.
    pub fn count(&self, op: FaultOp) -> u64 {
        self.counters[op.index()].load(Ordering::SeqCst)
    }

    /// `(read_at, write_at, sync)` invocation counts — the original
    /// counter triple; use [`FaultPlan::count`] for the runs/plan ops.
    pub fn counts(&self) -> (u64, u64, u64) {
        (self.count(FaultOp::Read), self.count(FaultOp::Write), self.count(FaultOp::Sync))
    }
}

/// Backend wrapper injecting the plan's faults into every opened file.
pub struct FaultBackend<B: Backend> {
    inner: B,
    plan: Arc<FaultPlan>,
}

impl<B: Backend> FaultBackend<B> {
    /// Wrap `inner` with `plan`.
    pub fn new(inner: B, plan: Arc<FaultPlan>) -> Self {
        FaultBackend { inner, plan }
    }
}

impl<B: Backend> Backend for FaultBackend<B> {
    fn open(&self, path: &str, opts: OpenOptions) -> Result<Arc<dyn StorageFile>> {
        let f = self.inner.open(path, opts)?;
        Ok(Arc::new(FaultFile { inner: f, plan: self.plan.clone() }))
    }

    fn delete(&self, path: &str) -> Result<()> {
        self.inner.delete(path)
    }

    fn name(&self) -> &'static str {
        "faulty"
    }
}

struct FaultFile {
    inner: Arc<dyn StorageFile>,
    plan: Arc<FaultPlan>,
}

impl StorageFile for FaultFile {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        self.plan.check(FaultOp::Read)?;
        self.inner.read_at(offset, buf)
    }

    fn write_at(&self, offset: u64, buf: &[u8]) -> Result<usize> {
        self.plan.check(FaultOp::Write)?;
        self.inner.write_at(offset, buf)
    }

    fn read_runs(&self, runs: &[(u64, usize)], buf: &mut [u8]) -> Result<usize> {
        self.plan.check(FaultOp::ReadRuns)?;
        self.inner.read_runs(runs, buf)
    }

    fn write_runs(&self, runs: &[(u64, usize)], buf: &[u8]) -> Result<usize> {
        self.plan.check(FaultOp::WriteRuns)?;
        self.inner.write_runs(runs, buf)
    }

    fn read_plan(&self, runs: &[(u64, usize)], buf: &mut [u8]) -> Result<usize> {
        self.plan.check(FaultOp::ReadPlan)?;
        self.inner.read_plan(runs, buf)
    }

    fn write_plan(&self, runs: &[(u64, usize)], buf: &[u8]) -> Result<usize> {
        self.plan.check(FaultOp::WritePlan)?;
        self.inner.write_plan(runs, buf)
    }

    fn write_pieces(&self, pieces: &[(u64, &[u8])]) -> Result<usize> {
        // Same fault class as the plan write it replaces on the
        // zero-copy collective path.
        self.plan.check(FaultOp::WritePlan)?;
        self.inner.write_pieces(pieces)
    }

    fn prefers_plan_execution(&self) -> bool {
        // Forwarded so a fault wrapper around the striped backend still
        // exercises the whole-plan dispatch it is meant to test.
        self.inner.prefers_plan_execution()
    }

    fn size(&self) -> Result<u64> {
        self.inner.size()
    }

    fn set_size(&self, size: u64) -> Result<()> {
        self.inner.set_size(size)
    }

    fn preallocate(&self, size: u64) -> Result<()> {
        self.inner.preallocate(size)
    }

    fn sync(&self) -> Result<()> {
        self.plan.check(FaultOp::Sync)?;
        self.inner.sync()
    }

    fn map(&self, offset: u64, len: usize, writable: bool) -> Result<Box<dyn MappedRegion>> {
        self.inner.map(offset, len, writable)
    }

    fn lock_exclusive(&self) -> Result<FileLockGuard> {
        self.inner.lock_exclusive()
    }

    fn backend_name(&self) -> &'static str {
        "faulty"
    }

    fn stripe_layout(&self) -> Option<super::layout::StripeLayout> {
        self.inner.stripe_layout()
    }

    fn stripe_map(&self) -> Option<super::layout::StripeMap> {
        self.inner.stripe_map()
    }

    fn preferred_flush_alignment(&self) -> Option<u64> {
        self.inner.preferred_flush_alignment()
    }

    fn take_advisories(&self) -> Vec<IoError> {
        self.inner.take_advisories()
    }

    fn backend_counters(&self) -> super::BackendCounters {
        // Forwarded so fault-injection tests can assert on the striped
        // backend's degraded/rebuild counters through the wrapper.
        self.inner.backend_counters()
    }

    fn server_health(&self) -> Option<Vec<bool>> {
        self.inner.server_health()
    }

    fn start_rebuild(&self, throttle: Option<u64>) -> Result<bool> {
        self.inner.start_rebuild(throttle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::local::LocalBackend;

    #[test]
    fn injects_on_the_scheduled_invocation() {
        let plan = FaultPlan::new(vec![FaultRule::once(FaultOp::Write, 1, ErrorClass::NoSpace)]);
        let b = FaultBackend::new(LocalBackend::instant(), plan.clone());
        let path = format!("/tmp/jpio-fault-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, b"ok").unwrap(); // write #0 passes
        let err = f.write_at(2, b"boom").unwrap_err(); // write #1 fails
        assert_eq!(err.class, ErrorClass::NoSpace);
        f.write_at(2, b"ok").unwrap(); // rule fired once
        assert_eq!(plan.counts().1, 3);
        b.delete(&path).unwrap();
    }

    #[test]
    fn sync_faults() {
        let plan = FaultPlan::new(vec![FaultRule::once(FaultOp::Sync, 0, ErrorClass::Io)]);
        let b = FaultBackend::new(LocalBackend::instant(), plan);
        let path = format!("/tmp/jpio-fault-sync-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        assert_eq!(f.sync().unwrap_err().class, ErrorClass::Io);
        f.sync().unwrap();
        b.delete(&path).unwrap();
    }

    #[test]
    fn runs_and_plan_paths_are_interceptable() {
        // Regression (PR 3): the plan pipeline reaches storage through
        // read_runs/write_runs/read_plan/write_plan; rules on those ops
        // must fire there instead of being bypassed.
        let plan = FaultPlan::new(vec![
            FaultRule::once(FaultOp::WriteRuns, 0, ErrorClass::NoSpace),
            FaultRule::once(FaultOp::ReadRuns, 0, ErrorClass::Io),
            FaultRule::once(FaultOp::WritePlan, 0, ErrorClass::Quota),
            FaultRule::once(FaultOp::ReadPlan, 0, ErrorClass::Access),
        ]);
        let b = FaultBackend::new(LocalBackend::instant(), plan.clone());
        let path = format!("/tmp/jpio-fault-runs-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let runs = [(0u64, 4usize), (8, 4)];
        assert_eq!(f.write_runs(&runs, b"abcdefgh").unwrap_err().class, ErrorClass::NoSpace);
        assert_eq!(f.write_runs(&runs, b"abcdefgh").unwrap(), 8);
        let mut buf = [0u8; 8];
        assert_eq!(f.read_runs(&runs, &mut buf).unwrap_err().class, ErrorClass::Io);
        assert_eq!(f.read_runs(&runs, &mut buf).unwrap(), 8);
        assert_eq!(f.write_plan(&runs, b"abcdefgh").unwrap_err().class, ErrorClass::Quota);
        assert_eq!(f.read_plan(&runs, &mut buf).unwrap_err().class, ErrorClass::Access);
        assert_eq!(&buf, b"abcdefgh");
        assert_eq!(plan.count(FaultOp::WriteRuns), 2);
        // write_plan/read_plan delegate to the runs helpers underneath
        // the interception point, so their counters saw exactly one call.
        assert_eq!(plan.count(FaultOp::WritePlan), 1);
        assert_eq!(plan.count(FaultOp::ReadPlan), 1);
        b.delete(&path).unwrap();
    }

    #[test]
    fn sticky_rules_model_a_dead_server() {
        let plan = FaultPlan::new(vec![FaultRule::from_nth(FaultOp::Read, 1, ErrorClass::Io)]);
        let b = FaultBackend::new(LocalBackend::instant(), plan.clone());
        let path = format!("/tmp/jpio-fault-sticky-{}", std::process::id());
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, b"data").unwrap();
        let mut buf = [0u8; 4];
        f.read_at(0, &mut buf).unwrap(); // read #0 passes
        for _ in 0..3 {
            assert_eq!(f.read_at(0, &mut buf).unwrap_err().class, ErrorClass::Io);
        }
        // Killing mid-workload arms every op.
        plan.inject_kill(ErrorClass::Io);
        assert!(f.write_at(0, b"x").is_err());
        assert!(f.sync().is_err());
        b.delete(&path).unwrap();
    }
}
