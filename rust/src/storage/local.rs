//! Local-disk backend: a real file on the local filesystem.
//!
//! Reads go through the OS page cache (the mechanism behind the paper's
//! multi-GB/s read bandwidths in Fig 4-3); writes optionally pay a
//! modelled device-write bandwidth so the *shape* of the paper's local
//! write results (≈94 MB/s, flat in thread count) is reproduced
//! independently of this host's actual disk.

use std::collections::HashMap;
use std::fs;
use std::os::unix::fs::FileExt;
use std::os::unix::io::AsRawFd;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use once_cell::sync::Lazy;

use crate::comm::netmodel::TimeScale;
use crate::io::errors::{err_file_exists, err_io, IoError, Result};

use super::{Backend, FileLockGuard, MappedRegion, OpenOptions, StorageFile};

/// Performance model for the local device.
#[derive(Clone, Copy, Debug)]
pub struct LocalConfig {
    /// Modelled device write bandwidth in MB/s (`None` = unmodelled).
    pub write_bw_mbs: Option<f64>,
    /// Modelled device read bandwidth in MB/s (`None` = page cache only).
    pub read_bw_mbs: Option<f64>,
    /// Delay scale.
    pub scale: TimeScale,
}

impl LocalConfig {
    /// No modelling at all: functional tests.
    pub fn instant() -> Self {
        LocalConfig { write_bw_mbs: None, read_bw_mbs: None, scale: TimeScale::OFF }
    }

    /// The Barq shared-memory machine's local disk (Fig 4-3): writes cap
    /// at ~94 MB/s; reads are served from the page cache.
    pub fn barq_disk() -> Self {
        LocalConfig { write_bw_mbs: Some(94.0), read_bw_mbs: None, scale: TimeScale::default() }
    }
}

/// The local-disk backend.
pub struct LocalBackend {
    cfg: LocalConfig,
}

impl LocalBackend {
    /// Backend with the given model.
    pub fn new(cfg: LocalConfig) -> Self {
        LocalBackend { cfg }
    }

    /// Unmodelled backend (functional tests).
    pub fn instant() -> Self {
        LocalBackend::new(LocalConfig::instant())
    }

    /// Barq local-disk model (Fig 4-3).
    pub fn barq() -> Self {
        LocalBackend::new(LocalConfig::barq_disk())
    }
}

impl Backend for LocalBackend {
    fn open(&self, path: &str, opts: OpenOptions) -> Result<Arc<dyn StorageFile>> {
        Ok(Arc::new(LocalFile::open(path, opts, self.cfg, "local")?))
    }

    fn delete(&self, path: &str) -> Result<()> {
        fs::remove_file(path).map_err(|e| IoError::from_os(e, format!("delete {path}")))
    }

    fn name(&self) -> &'static str {
        "local"
    }
}

// ----------------------------------------------------------------------
// In-process lock registry: serializes *threads* that share a path; the
// fd-level flock serializes *processes*. Both are taken by
// `lock_exclusive`.
// ----------------------------------------------------------------------

pub(crate) struct LockCell {
    locked: Mutex<bool>,
    cv: Condvar,
}

impl LockCell {
    pub(crate) fn acquire(self: &Arc<Self>) -> impl FnOnce() + Send {
        let mut locked = self.locked.lock().unwrap();
        while *locked {
            locked = self.cv.wait(locked).unwrap();
        }
        *locked = true;
        drop(locked);
        let cell = self.clone();
        move || {
            *cell.locked.lock().unwrap() = false;
            cell.cv.notify_one();
        }
    }
}

static LOCK_REGISTRY: Lazy<Mutex<HashMap<String, Arc<LockCell>>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

pub(crate) fn lock_cell_for(path: &str) -> Arc<LockCell> {
    LOCK_REGISTRY
        .lock()
        .unwrap()
        .entry(path.to_string())
        .or_insert_with(|| Arc::new(LockCell { locked: Mutex::new(false), cv: Condvar::new() }))
        .clone()
}

/// An open local file with optional device modelling.
pub struct LocalFile {
    file: fs::File,
    path: String,
    cfg: LocalConfig,
    label: &'static str,
}

impl LocalFile {
    pub(crate) fn open(
        path: &str,
        opts: OpenOptions,
        cfg: LocalConfig,
        label: &'static str,
    ) -> Result<LocalFile> {
        if path.is_empty() {
            return Err(crate::io::errors::err_bad_file("empty file name"));
        }
        let mut oo = fs::OpenOptions::new();
        oo.read(opts.read).write(opts.write);
        if opts.create && opts.excl {
            oo.create_new(true);
        } else if opts.create {
            oo.create(true);
        }
        if opts.truncate {
            oo.truncate(true);
        }
        let file = oo.open(path).map_err(|e| {
            if e.kind() == std::io::ErrorKind::AlreadyExists {
                err_file_exists(format!("open EXCL {path}"))
            } else {
                IoError::from_os(e, format!("open {path}"))
            }
        })?;
        Ok(LocalFile { file, path: path.to_string(), cfg, label })
    }

    /// Pay the modelled device-write time *under the device lock*: the
    /// disk is one shared resource, so aggregate write bandwidth stays
    /// flat as threads/processes are added (the paper's Fig 4-3 shape).
    fn pay_write(&self, bytes: usize) {
        if let Some(bw) = self.cfg.write_bw_mbs {
            let d = Duration::from_secs_f64(bytes as f64 / (bw * 1e6));
            if self.cfg.scale.scale(d) > Duration::ZERO {
                // Separate lock domain from lock_exclusive(): the device
                // queue is its own resource, and a caller may legally hold
                // the file lock (atomic mode / RMW sieving) across writes.
                let release = lock_cell_for(&format!("{}#device", self.path)).acquire();
                self.cfg.scale.pay(d);
                release();
            }
        }
    }

    fn pay_read(&self, bytes: usize) {
        if let Some(bw) = self.cfg.read_bw_mbs {
            self.cfg.scale.pay(Duration::from_secs_f64(bytes as f64 / (bw * 1e6)));
        }
    }
}

impl StorageFile for LocalFile {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        self.pay_read(buf.len());
        // read_at can return short counts mid-file on signals; loop.
        let mut pos = 0;
        while pos < buf.len() {
            match self.file.read_at(&mut buf[pos..], offset + pos as u64) {
                Ok(0) => break, // EOF
                Ok(n) => pos += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(IoError::from_os(e, format!("read {}", self.path))),
            }
        }
        Ok(pos)
    }

    fn write_at(&self, offset: u64, buf: &[u8]) -> Result<usize> {
        self.pay_write(buf.len());
        self.file
            .write_all_at(buf, offset)
            .map_err(|e| IoError::from_os(e, format!("write {}", self.path)))?;
        Ok(buf.len())
    }

    fn size(&self) -> Result<u64> {
        Ok(self
            .file
            .metadata()
            .map_err(|e| IoError::from_os(e, format!("stat {}", self.path)))?
            .len())
    }

    fn set_size(&self, size: u64) -> Result<()> {
        self.file
            .set_len(size)
            .map_err(|e| IoError::from_os(e, format!("truncate {}", self.path)))
    }

    fn preallocate(&self, size: u64) -> Result<()> {
        let rc = unsafe { libc::posix_fallocate(self.file.as_raw_fd(), 0, size as libc::off_t) };
        if rc != 0 {
            return Err(IoError::from_os(
                std::io::Error::from_raw_os_error(rc),
                format!("preallocate {}", self.path),
            ));
        }
        Ok(())
    }

    fn sync(&self) -> Result<()> {
        self.file
            .sync_data()
            .map_err(|e| IoError::from_os(e, format!("fsync {}", self.path)))
    }

    fn map(&self, offset: u64, len: usize, writable: bool) -> Result<Box<dyn MappedRegion>> {
        if len == 0 {
            return Err(crate::io::errors::err_arg("map: zero-length region"));
        }
        if writable {
            // Ensure the backing file covers the region (mmap past EOF
            // faults with SIGBUS).
            let need = offset + len as u64;
            if self.size()? < need {
                self.set_size(need)?;
            }
        }
        let prot = if writable { libc::PROT_READ | libc::PROT_WRITE } else { libc::PROT_READ };
        // mmap requires a page-aligned file offset: align down and skip.
        let page = unsafe { libc::sysconf(libc::_SC_PAGESIZE) } as u64;
        let aligned = offset & !(page - 1);
        let delta = (offset - aligned) as usize;
        let map_len = len + delta;
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                map_len,
                prot,
                libc::MAP_SHARED,
                self.file.as_raw_fd(),
                aligned as libc::off_t,
            )
        };
        if ptr == libc::MAP_FAILED {
            return Err(IoError::from_os(
                std::io::Error::last_os_error(),
                format!("mmap {}", self.path),
            ));
        }
        Ok(Box::new(LocalMap {
            ptr: ptr as *mut u8,
            delta,
            len,
            map_len,
            cfg: self.cfg,
            lock: lock_cell_for(&format!("{}#device", self.path)),
            dirty_bytes: 0,
        }))
    }

    fn lock_exclusive(&self) -> Result<FileLockGuard> {
        // Threads first (in-process), then processes (flock).
        let release_cell = lock_cell_for(&self.path).acquire();
        let fd = self.file.as_raw_fd();
        let rc = unsafe { libc::flock(fd, libc::LOCK_EX) };
        if rc != 0 {
            release_cell();
            return Err(err_io(format!("flock {}", self.path)));
        }
        Ok(FileLockGuard {
            os_unlock: Some(Box::new(move || {
                unsafe { libc::flock(fd, libc::LOCK_UN) };
                release_cell();
            })),
        })
    }

    fn backend_name(&self) -> &'static str {
        self.label
    }
}

/// A real memory mapping. `ptr` points at the page-aligned base; user
/// offsets are shifted by `delta` (the sub-page part of the file offset).
struct LocalMap {
    ptr: *mut u8,
    delta: usize,
    len: usize,
    map_len: usize,
    cfg: LocalConfig,
    lock: Arc<LockCell>,
    dirty_bytes: usize,
}

// Safety: the mapping is owned by this region and unmapped on drop; access
// is through &mut self.
unsafe impl Send for LocalMap {}

impl MappedRegion for LocalMap {
    fn read(&mut self, region_off: usize, buf: &mut [u8]) -> Result<()> {
        check_bounds(region_off, buf.len(), self.len)?;
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.ptr.add(self.delta + region_off),
                buf.as_mut_ptr(),
                buf.len(),
            );
        }
        Ok(())
    }

    fn write(&mut self, region_off: usize, data: &[u8]) -> Result<()> {
        check_bounds(region_off, data.len(), self.len)?;
        unsafe {
            std::ptr::copy_nonoverlapping(
                data.as_ptr(),
                self.ptr.add(self.delta + region_off),
                data.len(),
            );
        }
        self.dirty_bytes += data.len();
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        // Writeback pays the modelled device bandwidth (serialized at the
        // device) for the bytes written through the mapping.
        if let Some(bw) = self.cfg.write_bw_mbs {
            if self.dirty_bytes > 0 {
                let d = Duration::from_secs_f64(self.dirty_bytes as f64 / (bw * 1e6));
                if self.cfg.scale.scale(d) > Duration::ZERO {
                    let release = self.lock.acquire();
                    self.cfg.scale.pay(d);
                    release();
                }
                self.dirty_bytes = 0;
            }
        }
        let rc =
            unsafe { libc::msync(self.ptr as *mut libc::c_void, self.map_len, libc::MS_SYNC) };
        if rc != 0 {
            return Err(IoError::from_os(std::io::Error::last_os_error(), "msync"));
        }
        Ok(())
    }

    fn len(&self) -> usize {
        self.len
    }
}

impl Drop for LocalMap {
    fn drop(&mut self) {
        unsafe { libc::munmap(self.ptr as *mut libc::c_void, self.map_len) };
    }
}

pub(crate) fn check_bounds(off: usize, len: usize, region: usize) -> Result<()> {
    if off + len > region {
        return Err(crate::io::errors::err_arg(format!(
            "mapped access [{off}, {}) outside region of {region}",
            off + len
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::errors::ErrorClass;

    fn tmp(name: &str) -> String {
        format!("/tmp/jpio-local-{}-{name}", std::process::id())
    }

    #[test]
    fn write_read_roundtrip() {
        let b = LocalBackend::instant();
        let path = tmp("rw");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(10, b"hello").unwrap();
        let mut buf = [0u8; 5];
        assert_eq!(f.read_at(10, &mut buf).unwrap(), 5);
        assert_eq!(&buf, b"hello");
        assert_eq!(f.size().unwrap(), 15);
        b.delete(&path).unwrap();
    }

    #[test]
    fn read_past_eof_is_short() {
        let b = LocalBackend::instant();
        let path = tmp("eof");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, b"abc").unwrap();
        let mut buf = [0u8; 10];
        assert_eq!(f.read_at(0, &mut buf).unwrap(), 3);
        assert_eq!(f.read_at(100, &mut buf).unwrap(), 0);
        b.delete(&path).unwrap();
    }

    #[test]
    fn excl_create_fails_on_existing() {
        let b = LocalBackend::instant();
        let path = tmp("excl");
        let _ = b.open(&path, OpenOptions::rw_create()).unwrap();
        let mut opts = OpenOptions::rw_create();
        opts.excl = true;
        let err = b.open(&path, opts).map(|_| ()).unwrap_err();
        assert_eq!(err.class, ErrorClass::FileExists);
        b.delete(&path).unwrap();
    }

    #[test]
    fn missing_file_maps_to_no_such_file() {
        let b = LocalBackend::instant();
        let err = b.open("/tmp/jpio-definitely-missing-9x7", OpenOptions::read_only()).map(|_| ()).unwrap_err();
        assert_eq!(err.class, ErrorClass::NoSuchFile);
        let err = b.delete("/tmp/jpio-definitely-missing-9x7").unwrap_err();
        assert_eq!(err.class, ErrorClass::NoSuchFile);
    }

    #[test]
    fn set_size_and_preallocate() {
        let b = LocalBackend::instant();
        let path = tmp("size");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.set_size(4096).unwrap();
        assert_eq!(f.size().unwrap(), 4096);
        f.preallocate(8192).unwrap();
        assert!(f.size().unwrap() >= 4096);
        f.set_size(100).unwrap();
        assert_eq!(f.size().unwrap(), 100);
        b.delete(&path).unwrap();
    }

    #[test]
    fn mmap_roundtrip_and_persistence() {
        let b = LocalBackend::instant();
        let path = tmp("map");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        {
            let mut m = f.map(0, 4096, true).unwrap();
            m.write(100, b"mapped data").unwrap();
            m.flush().unwrap();
            let mut back = [0u8; 11];
            m.read(100, &mut back).unwrap();
            assert_eq!(&back, b"mapped data");
        }
        // Visible through normal reads after unmap.
        let mut buf = [0u8; 11];
        f.read_at(100, &mut buf).unwrap();
        assert_eq!(&buf, b"mapped data");
        b.delete(&path).unwrap();
    }

    #[test]
    fn mmap_bounds_checked() {
        let b = LocalBackend::instant();
        let path = tmp("mapbounds");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let mut m = f.map(0, 1024, true).unwrap();
        let mut buf = [0u8; 16];
        let err = m.read(1020, &mut buf).unwrap_err();
        assert_eq!(err.class, ErrorClass::Arg);
        b.delete(&path).unwrap();
    }

    #[test]
    fn lock_exclusive_serializes_threads() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let b = LocalBackend::instant();
        let path = tmp("lock");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let in_section = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..10 {
                        let _g = f.lock_exclusive().unwrap();
                        let v = in_section.fetch_add(1, Ordering::SeqCst);
                        assert_eq!(v, 0, "two threads inside the exclusive section");
                        std::thread::yield_now();
                        in_section.fetch_sub(1, Ordering::SeqCst);
                    }
                });
            }
        });
        b.delete(&path).unwrap();
    }

    #[test]
    fn modelled_write_bandwidth_is_paid() {
        let b = LocalBackend::new(LocalConfig {
            write_bw_mbs: Some(100.0),
            read_bw_mbs: None,
            scale: TimeScale(1.0),
        });
        let path = tmp("bw");
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let start = std::time::Instant::now();
        f.write_at(0, &vec![0u8; 1 << 20]).unwrap(); // 1 MiB @100MB/s ≈ 10.5ms
        assert!(start.elapsed() >= Duration::from_millis(9));
        b.delete(&path).unwrap();
    }
}
