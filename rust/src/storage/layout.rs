//! Stripe-layout arithmetic for the declustered [`striped`] backend.
//!
//! A logical file is declustered round-robin across `factor` servers in
//! fixed-size *stripe units* (the ViPIOS/PVFS regular declustering):
//! logical stripe `i` — the byte range `[i*unit, (i+1)*unit)` — lives on
//! server `i % factor`, at offset `(i / factor) * unit` inside that
//! server's *stripe object* (a plain file on the child backend). All the
//! offset mapping lives here so the backend, the collective layer (file-
//! domain alignment) and the tests share one set of formulas.
//!
//! [`striped`]: super::striped

use crate::io::errors::{err_arg, Result};

/// Round-robin stripe layout: `factor` servers × `unit`-byte stripe units.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StripeLayout {
    /// Stripe unit in bytes (ROMIO `striping_unit`).
    pub unit: u64,
    /// Number of stripe servers (ROMIO `striping_factor`).
    pub factor: usize,
}

/// One server-local piece of a logical byte range.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    /// Server (stripe object) index.
    pub server: usize,
    /// Offset within the server's stripe object.
    pub child_off: u64,
    /// Piece length in bytes.
    pub len: usize,
    /// Position of this piece within the flattened payload buffer.
    pub buf_pos: usize,
}

impl StripeLayout {
    /// A layout of `factor` servers with `unit`-byte stripe units.
    pub fn new(unit: u64, factor: usize) -> Result<StripeLayout> {
        if unit == 0 {
            return Err(err_arg("stripe layout: unit must be > 0"));
        }
        if factor == 0 {
            return Err(err_arg("stripe layout: factor must be > 0"));
        }
        Ok(StripeLayout { unit, factor })
    }

    /// Width of one full stripe row (`unit * factor` bytes).
    pub fn width(&self) -> u64 {
        self.unit * self.factor as u64
    }

    /// Index of the stripe unit holding logical offset `off`.
    pub fn stripe_of(&self, off: u64) -> u64 {
        off / self.unit
    }

    /// Server holding logical offset `off`.
    pub fn server_of(&self, off: u64) -> usize {
        (self.stripe_of(off) % self.factor as u64) as usize
    }

    /// Offset of logical offset `off` within its server's stripe object.
    pub fn child_offset(&self, off: u64) -> u64 {
        let stripe = self.stripe_of(off);
        (stripe / self.factor as u64) * self.unit + off % self.unit
    }

    /// Walk the logical range `[off, off+len)` piece by piece, where a
    /// piece is the largest sub-range not crossing a stripe boundary.
    /// Calls `f(server, logical_off, piece_len)` in logical order. The
    /// collective layer reuses this walk (with `factor = cb_nodes`) to
    /// assign stripe-aligned file domains, so the boundary arithmetic
    /// lives in exactly one place.
    pub fn for_each_piece(&self, off: u64, len: usize, mut f: impl FnMut(usize, u64, usize)) {
        let end = off + len as u64;
        let mut cur = off;
        while cur < end {
            let boundary = (self.stripe_of(cur) + 1) * self.unit;
            let piece_end = boundary.min(end);
            f(self.server_of(cur), cur, (piece_end - cur) as usize);
            cur = piece_end;
        }
    }

    /// Split the logical range `[off, off+len)` at stripe boundaries,
    /// appending one [`Segment`] per piece (in logical-offset order) to
    /// `out`. `buf_pos` is the payload position of the range's first byte.
    pub fn split_run(&self, off: u64, len: usize, buf_pos: usize, out: &mut Vec<Segment>) {
        self.for_each_piece(off, len, |server, cur, piece_len| {
            out.push(Segment {
                server,
                child_off: self.child_offset(cur),
                len: piece_len,
                buf_pos: buf_pos + (cur - off) as usize,
            });
        });
    }

    /// Size of `server`'s stripe object for a logical file of
    /// `logical_size` bytes with no holes.
    pub fn child_len(&self, server: usize, logical_size: u64) -> u64 {
        let full_units = logical_size / self.unit;
        let rem = logical_size % self.unit;
        let cycles = full_units / self.factor as u64;
        let extra = full_units % self.factor as u64;
        let s = server as u64;
        cycles * self.unit
            + if s < extra {
                self.unit
            } else if s == extra {
                rem
            } else {
                0
            }
    }

    /// The logical file size implied by `server`'s stripe object being
    /// `child_len` bytes long (logical offset just past its last byte).
    /// The logical size of a striped file is the max of this over servers.
    pub fn logical_end(&self, server: usize, child_len: u64) -> u64 {
        if child_len == 0 {
            return 0;
        }
        let last = child_len - 1;
        let child_stripe = last / self.unit;
        let within = last % self.unit;
        let logical_stripe = child_stripe * self.factor as u64 + server as u64;
        logical_stripe * self.unit + within + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_degenerate_layouts() {
        assert!(StripeLayout::new(0, 4).is_err());
        assert!(StripeLayout::new(64, 0).is_err());
        assert!(StripeLayout::new(1, 1).is_ok());
    }

    #[test]
    fn round_robin_mapping() {
        let l = StripeLayout::new(10, 3).unwrap();
        // Stripes: [0,10)→s0, [10,20)→s1, [20,30)→s2, [30,40)→s0@10, ...
        assert_eq!(l.server_of(0), 0);
        assert_eq!(l.server_of(9), 0);
        assert_eq!(l.server_of(10), 1);
        assert_eq!(l.server_of(29), 2);
        assert_eq!(l.server_of(30), 0);
        assert_eq!(l.child_offset(0), 0);
        assert_eq!(l.child_offset(35), 15);
        assert_eq!(l.child_offset(29), 9);
        assert_eq!(l.width(), 30);
    }

    #[test]
    fn split_covers_exactly_and_respects_boundaries() {
        let l = StripeLayout::new(16, 4).unwrap();
        let mut segs = Vec::new();
        l.split_run(5, 100, 7, &mut segs);
        // Total coverage, in order, without gaps.
        let total: usize = segs.iter().map(|s| s.len).sum();
        assert_eq!(total, 100);
        assert_eq!(segs[0].buf_pos, 7);
        let mut logical = 5u64;
        let mut pos = 7usize;
        for s in &segs {
            assert_eq!(s.server, l.server_of(logical));
            assert_eq!(s.child_off, l.child_offset(logical));
            assert_eq!(s.buf_pos, pos);
            assert!(s.len <= 16, "piece crosses a stripe boundary");
            // A piece never straddles a unit boundary.
            assert_eq!(logical / 16, (logical + s.len as u64 - 1) / 16);
            logical += s.len as u64;
            pos += s.len;
        }
        assert_eq!(logical, 105);
    }

    #[test]
    fn child_len_and_logical_end_are_inverse() {
        for (unit, factor) in [(1u64, 1usize), (7, 3), (16, 4), (4096, 2)] {
            let l = StripeLayout::new(unit, factor).unwrap();
            for logical in [0u64, 1, unit - 1, unit, unit + 1, 3 * unit, l.width(), l.width() + 5, 10 * l.width() + unit / 2 + 1]
            {
                let sum: u64 = (0..factor).map(|s| l.child_len(s, logical)).sum();
                assert_eq!(sum, logical, "children must hold exactly the file");
                let back = (0..factor)
                    .map(|s| l.logical_end(s, l.child_len(s, logical)))
                    .max()
                    .unwrap();
                assert_eq!(back, logical, "unit={unit} factor={factor} L={logical}");
            }
        }
    }

    #[test]
    fn logical_end_of_partial_object() {
        let l = StripeLayout::new(10, 4).unwrap();
        // Server 2's object is 15 bytes: its last byte sits in child
        // stripe 1 (offset 4), i.e. logical stripe 1*4+2 = 6, offset 64.
        assert_eq!(l.logical_end(2, 15), 65);
        assert_eq!(l.logical_end(0, 0), 0);
    }
}
