//! Stripe-layout arithmetic for the declustered [`striped`] backend.
//!
//! A logical file is declustered round-robin across `factor` servers in
//! fixed-size *stripe units* (the ViPIOS/PVFS regular declustering):
//! logical stripe `i` — the byte range `[i*unit, (i+1)*unit)` — lives on
//! server `i % factor`, at offset `(i / factor) * unit` inside that
//! server's *stripe object* (a plain file on the child backend). All the
//! offset mapping lives here so the backend, the collective layer (file-
//! domain alignment) and the tests share one set of formulas.
//!
//! ## Redundancy mapping
//!
//! [`Redundancy`] changes how logical bytes map onto the stripe
//! objects; [`StripeMap`] (layout + redundancy) is the mapping every
//! data path uses, so the formulas live here next to the plain ones.
//!
//! * `replica:<k>` keeps the round-robin data mapping untouched and
//!   adds `k-1` *replica objects* per server: copy `c` (1 ≤ c < k) of
//!   server `s`'s stripe object lives on server `(s + c) % factor`,
//!   byte-identical at the same child offsets, so any `k-1` lost
//!   servers leave one intact copy of every unit.
//! * `parity` interleaves one parity unit per stripe *row* into the
//!   data objects themselves (RAID-5): row `r` consists of `factor`
//!   unit-sized *slots*, one per server, all at child offset
//!   `[r*unit, (r+1)*unit)`. The slot on server
//!   [`StripeMap::parity_server`]`(r) = r % factor` holds the XOR of
//!   the other `factor-1` slots (each zero-filled past its object's
//!   EOF); those `factor-1` slots hold data units
//!   `i = r*(factor-1) + q` in server order, skipping the parity
//!   server. The XOR of all `factor` slots of a row is therefore zero,
//!   so *any* one lost server's slot — data or parity — is the XOR of
//!   the surviving `factor-1` slots, and the rotation spreads
//!   parity-update traffic over all servers instead of bottlenecking
//!   one (the RAID-4 → RAID-5 step).
//!
//! [`striped`]: super::striped

use crate::io::errors::{err_arg, Result};

/// Redundancy mode of a striped file (the `jpio_stripe_redundancy`
/// hint): how many server losses the data path survives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Redundancy {
    /// No redundancy: any server failure fails the operation.
    None,
    /// `k` total copies of every stripe unit (primary + `k-1` replicas
    /// on the next servers round-robin); tolerates `k-1` lost servers.
    Replica(usize),
    /// One rotating parity unit per stripe row (RAID-4/5 style);
    /// tolerates one lost server.
    Parity,
}

impl Redundancy {
    /// Parse a `jpio_stripe_redundancy` hint value: `none`,
    /// `replica:<k>`, or `parity`. Malformed values return `None`
    /// (MPI hint semantics: unrecognized hints are ignored).
    pub fn parse(s: &str) -> Option<Redundancy> {
        match s {
            "none" => Some(Redundancy::None),
            "parity" => Some(Redundancy::Parity),
            _ => {
                let k = s.strip_prefix("replica:")?.parse().ok()?;
                Some(Redundancy::Replica(k))
            }
        }
    }

    /// Number of simultaneous server losses the mode survives.
    pub fn tolerates(&self) -> usize {
        match *self {
            Redundancy::None => 0,
            Redundancy::Replica(k) => k - 1,
            Redundancy::Parity => 1,
        }
    }

    /// Wire encoding for the `.jpio-layout` sidecar: `(tag, k)` where
    /// the tag is 0 = none, 1 = replica, 2 = parity and `k` is the
    /// replica count (0 otherwise). Stable across builds — part of the
    /// on-disk sidecar format.
    pub fn tag(&self) -> (u64, u64) {
        match *self {
            Redundancy::None => (0, 0),
            Redundancy::Replica(k) => (1, k as u64),
            Redundancy::Parity => (2, 0),
        }
    }

    /// Inverse of [`Redundancy::tag`]; `None` on an unknown tag or a
    /// nonsensical replica count.
    pub fn from_tag(tag: u64, k: u64) -> Option<Redundancy> {
        match tag {
            0 => Some(Redundancy::None),
            1 if k >= 2 => Some(Redundancy::Replica(k as usize)),
            2 => Some(Redundancy::Parity),
            _ => None,
        }
    }

    /// Reject configurations the layout cannot host: `replica:<k>`
    /// needs `2 ≤ k ≤ factor` distinct servers per unit, parity needs
    /// at least two servers.
    pub fn validate(&self, factor: usize) -> Result<()> {
        match *self {
            Redundancy::None => Ok(()),
            Redundancy::Replica(k) if k < 2 || k > factor => Err(err_arg(format!(
                "stripe redundancy replica:{k} needs 2 <= k <= striping_factor ({factor})"
            ))),
            Redundancy::Replica(_) => Ok(()),
            Redundancy::Parity if factor < 2 => {
                Err(err_arg("stripe redundancy parity needs striping_factor >= 2"))
            }
            Redundancy::Parity => Ok(()),
        }
    }
}

/// Round-robin stripe layout: `factor` servers × `unit`-byte stripe units.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StripeLayout {
    /// Stripe unit in bytes (ROMIO `striping_unit`).
    pub unit: u64,
    /// Number of stripe servers (ROMIO `striping_factor`).
    pub factor: usize,
}

/// One server-local piece of a logical byte range.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    /// Server (stripe object) index.
    pub server: usize,
    /// Offset within the server's stripe object.
    pub child_off: u64,
    /// Piece length in bytes.
    pub len: usize,
    /// Position of this piece within the flattened payload buffer.
    pub buf_pos: usize,
}

impl StripeLayout {
    /// A layout of `factor` servers with `unit`-byte stripe units.
    pub fn new(unit: u64, factor: usize) -> Result<StripeLayout> {
        if unit == 0 {
            return Err(err_arg("stripe layout: unit must be > 0"));
        }
        if factor == 0 {
            return Err(err_arg("stripe layout: factor must be > 0"));
        }
        Ok(StripeLayout { unit, factor })
    }

    /// Width of one full stripe row (`unit * factor` bytes).
    pub fn width(&self) -> u64 {
        self.unit * self.factor as u64
    }

    /// Index of the stripe unit holding logical offset `off`.
    pub fn stripe_of(&self, off: u64) -> u64 {
        off / self.unit
    }

    /// Server holding logical offset `off`.
    pub fn server_of(&self, off: u64) -> usize {
        (self.stripe_of(off) % self.factor as u64) as usize
    }

    /// Offset of logical offset `off` within its server's stripe object.
    pub fn child_offset(&self, off: u64) -> u64 {
        let stripe = self.stripe_of(off);
        (stripe / self.factor as u64) * self.unit + off % self.unit
    }

    /// Walk the logical range `[off, off+len)` piece by piece, where a
    /// piece is the largest sub-range not crossing a stripe boundary.
    /// Calls `f(server, logical_off, piece_len)` in logical order. The
    /// collective layer reuses this walk (with `factor = cb_nodes`) to
    /// assign stripe-aligned file domains, so the boundary arithmetic
    /// lives in exactly one place.
    pub fn for_each_piece(&self, off: u64, len: usize, mut f: impl FnMut(usize, u64, usize)) {
        let end = off + len as u64;
        let mut cur = off;
        while cur < end {
            let boundary = (self.stripe_of(cur) + 1) * self.unit;
            let piece_end = boundary.min(end);
            f(self.server_of(cur), cur, (piece_end - cur) as usize);
            cur = piece_end;
        }
    }

    /// Split the logical range `[off, off+len)` at stripe boundaries,
    /// appending one [`Segment`] per piece (in logical-offset order) to
    /// `out`. `buf_pos` is the payload position of the range's first byte.
    pub fn split_run(&self, off: u64, len: usize, buf_pos: usize, out: &mut Vec<Segment>) {
        self.for_each_piece(off, len, |server, cur, piece_len| {
            out.push(Segment {
                server,
                child_off: self.child_offset(cur),
                len: piece_len,
                buf_pos: buf_pos + (cur - off) as usize,
            });
        });
    }

    /// Size of `server`'s stripe object for a logical file of
    /// `logical_size` bytes with no holes.
    pub fn child_len(&self, server: usize, logical_size: u64) -> u64 {
        let full_units = logical_size / self.unit;
        let rem = logical_size % self.unit;
        let cycles = full_units / self.factor as u64;
        let extra = full_units % self.factor as u64;
        let s = server as u64;
        cycles * self.unit
            + if s < extra {
                self.unit
            } else if s == extra {
                rem
            } else {
                0
            }
    }

    /// Index of the stripe row containing the byte at offset
    /// `child_off` of any server's stripe object: row `r` occupies the
    /// slot `[r*unit, (r+1)*unit)` in every object.
    pub fn row_of_child_off(&self, child_off: u64) -> u64 {
        child_off / self.unit
    }

    /// The logical file size implied by `server`'s stripe object being
    /// `child_len` bytes long (logical offset just past its last byte).
    /// The logical size of a striped file is the max of this over servers.
    pub fn logical_end(&self, server: usize, child_len: u64) -> u64 {
        if child_len == 0 {
            return 0;
        }
        let last = child_len - 1;
        let child_stripe = last / self.unit;
        let within = last % self.unit;
        let logical_stripe = child_stripe * self.factor as u64 + server as u64;
        logical_stripe * self.unit + within + 1
    }
}

/// The redundancy-aware stripe mapping: where each logical byte (and,
/// under `parity`, each row's parity unit) physically lives. With
/// `Redundancy::None`/`Replica` this is exactly the plain round-robin
/// [`StripeLayout`] mapping; with `Redundancy::Parity` each row
/// dedicates one rotating slot to parity and declusters data over the
/// remaining `factor-1` slots (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StripeMap {
    /// The raw unit/factor geometry.
    pub layout: StripeLayout,
    /// The redundancy mode shaping the data mapping.
    pub redundancy: Redundancy,
}

impl StripeMap {
    /// Build a map, validating the redundancy against the factor.
    pub fn new(layout: StripeLayout, redundancy: Redundancy) -> Result<StripeMap> {
        redundancy.validate(layout.factor)?;
        Ok(StripeMap { layout, redundancy })
    }

    /// Data units per stripe row (`factor`, or `factor-1` under parity).
    pub fn data_units_per_row(&self) -> usize {
        match self.redundancy {
            Redundancy::Parity => self.layout.factor - 1,
            _ => self.layout.factor,
        }
    }

    /// Logical bytes per stripe row.
    pub fn data_width(&self) -> u64 {
        self.layout.unit * self.data_units_per_row() as u64
    }

    /// Server whose slot holds row `r`'s parity unit (rotating RAID-5
    /// placement). Only meaningful under `Redundancy::Parity`.
    pub fn parity_server(&self, row: u64) -> usize {
        (row % self.layout.factor as u64) as usize
    }

    /// Server holding data unit `q` (0-based within its row) of row
    /// `r`: server order with the parity slot skipped.
    pub fn data_server(&self, row: u64, q: usize) -> usize {
        match self.redundancy {
            Redundancy::Parity => {
                let p = self.parity_server(row);
                if q < p {
                    q
                } else {
                    q + 1
                }
            }
            _ => q,
        }
    }

    /// `(server, child_offset)` of the logical byte at `off`.
    pub fn locate(&self, off: u64) -> (usize, u64) {
        match self.redundancy {
            Redundancy::Parity => {
                let unit = self.layout.unit;
                let du = self.data_units_per_row() as u64;
                let i = off / unit; // data unit index
                let row = i / du;
                let q = (i % du) as usize;
                (self.data_server(row, q), row * unit + off % unit)
            }
            _ => (self.layout.server_of(off), self.layout.child_offset(off)),
        }
    }

    /// Split the logical range `[off, off+len)` at data-unit
    /// boundaries, appending one [`Segment`] per piece in logical
    /// order — the redundancy-aware version of
    /// [`StripeLayout::split_run`].
    pub fn split_run(&self, off: u64, len: usize, buf_pos: usize, out: &mut Vec<Segment>) {
        match self.redundancy {
            Redundancy::Parity => {
                let unit = self.layout.unit;
                let end = off + len as u64;
                let mut cur = off;
                while cur < end {
                    let boundary = (cur / unit + 1) * unit;
                    let piece_end = boundary.min(end);
                    let (server, child_off) = self.locate(cur);
                    out.push(Segment {
                        server,
                        child_off,
                        len: (piece_end - cur) as usize,
                        buf_pos: buf_pos + (cur - off) as usize,
                    });
                    cur = piece_end;
                }
            }
            _ => self.layout.split_run(off, len, buf_pos, out),
        }
    }

    /// Size of `server`'s stripe object for a hole-free logical file of
    /// `logical_size` bytes, *including* the interleaved parity slots
    /// under `Redundancy::Parity` (the parity unit of a partial final
    /// row is materialized full-length: parity covers the zero-padded
    /// row).
    pub fn child_len(&self, server: usize, logical_size: u64) -> u64 {
        match self.redundancy {
            Redundancy::Parity => {
                if logical_size == 0 {
                    return 0;
                }
                let unit = self.layout.unit;
                let du = self.data_units_per_row() as u64;
                let last_unit = (logical_size - 1) / unit;
                let last_row = last_unit / du;
                let q_last = (last_unit % du) as usize;
                let rem = logical_size - last_unit * unit; // 1..=unit
                let base = last_row * unit; // full slots of earlier rows
                let p = self.parity_server(last_row);
                if server == p {
                    return base + unit;
                }
                let q = if server < p { server } else { server - 1 };
                if q < q_last {
                    base + unit
                } else if q == q_last {
                    base + rem
                } else {
                    base
                }
            }
            _ => self.layout.child_len(server, logical_size),
        }
    }

    /// The logical file size implied by `server`'s stripe object being
    /// `child_len` bytes long. Under parity the object's last byte may
    /// sit in a parity slot, which only proves the row exists; the max
    /// over all servers is still exact, because the server holding the
    /// last *data* unit yields the exact size.
    pub fn logical_end(&self, server: usize, child_len: u64) -> u64 {
        match self.redundancy {
            Redundancy::Parity => {
                if child_len == 0 {
                    return 0;
                }
                let unit = self.layout.unit;
                let du = self.data_units_per_row() as u64;
                let last = child_len - 1;
                let row = last / unit;
                let within = last % unit;
                let p = self.parity_server(row);
                if server == p {
                    // A materialized parity slot implies the row holds
                    // at least one data byte.
                    row * self.data_width() + 1
                } else {
                    let q = if server < p { server } else { server - 1 };
                    let i = row * du + q as u64;
                    i * unit + within + 1
                }
            }
            _ => self.layout.logical_end(server, child_len),
        }
    }
    /// Physical slot rows materialized for a hole-free logical file of
    /// `logical_size` bytes: the max over servers of their object
    /// length in whole-or-partial units. This is the row count the
    /// rebuild engine must re-materialize for a blank server.
    pub fn rows_for_size(&self, logical_size: u64) -> u64 {
        (0..self.layout.factor)
            .map(|s| self.child_len(s, logical_size).div_ceil(self.layout.unit))
            .max()
            .unwrap_or(0)
    }
}

/// Byte-cursor router between two layout generations while a live
/// restriping migration is in flight. The migration rewrites logical
/// bytes in ascending order behind a high-water `cursor` persisted in
/// the `.jpio-layout` sidecar: bytes below the cursor have already
/// been rewritten into the *new* map's objects, bytes at or above it
/// still live in the *old* map's objects, so every data path splits
/// its range at the cursor and routes each part to the matching
/// generation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayoutRouter {
    /// The generation being migrated away from (owns `[cursor, ∞)`).
    pub old: StripeMap,
    /// The generation being migrated into (owns `[0, cursor)`).
    pub new: StripeMap,
}

impl LayoutRouter {
    /// Split the logical range `[off, off+len)` at the migration
    /// cursor: returns `(new_part, old_part)` as `(off, len)` pairs,
    /// either of which may be `None` when the range sits entirely on
    /// one side.
    pub fn split_at(
        cursor: u64,
        off: u64,
        len: usize,
    ) -> (Option<(u64, usize)>, Option<(u64, usize)>) {
        if len == 0 {
            return (None, None);
        }
        let end = off + len as u64;
        if end <= cursor {
            (Some((off, len)), None)
        } else if off >= cursor {
            (None, Some((off, len)))
        } else {
            (Some((off, (cursor - off) as usize)), Some((cursor, (end - cursor) as usize)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_degenerate_layouts() {
        assert!(StripeLayout::new(0, 4).is_err());
        assert!(StripeLayout::new(64, 0).is_err());
        assert!(StripeLayout::new(1, 1).is_ok());
    }

    #[test]
    fn round_robin_mapping() {
        let l = StripeLayout::new(10, 3).unwrap();
        // Stripes: [0,10)→s0, [10,20)→s1, [20,30)→s2, [30,40)→s0@10, ...
        assert_eq!(l.server_of(0), 0);
        assert_eq!(l.server_of(9), 0);
        assert_eq!(l.server_of(10), 1);
        assert_eq!(l.server_of(29), 2);
        assert_eq!(l.server_of(30), 0);
        assert_eq!(l.child_offset(0), 0);
        assert_eq!(l.child_offset(35), 15);
        assert_eq!(l.child_offset(29), 9);
        assert_eq!(l.width(), 30);
    }

    #[test]
    fn split_covers_exactly_and_respects_boundaries() {
        let l = StripeLayout::new(16, 4).unwrap();
        let mut segs = Vec::new();
        l.split_run(5, 100, 7, &mut segs);
        // Total coverage, in order, without gaps.
        let total: usize = segs.iter().map(|s| s.len).sum();
        assert_eq!(total, 100);
        assert_eq!(segs[0].buf_pos, 7);
        let mut logical = 5u64;
        let mut pos = 7usize;
        for s in &segs {
            assert_eq!(s.server, l.server_of(logical));
            assert_eq!(s.child_off, l.child_offset(logical));
            assert_eq!(s.buf_pos, pos);
            assert!(s.len <= 16, "piece crosses a stripe boundary");
            // A piece never straddles a unit boundary.
            assert_eq!(logical / 16, (logical + s.len as u64 - 1) / 16);
            logical += s.len as u64;
            pos += s.len;
        }
        assert_eq!(logical, 105);
    }

    #[test]
    fn child_len_and_logical_end_are_inverse() {
        for (unit, factor) in [(1u64, 1usize), (7, 3), (16, 4), (4096, 2)] {
            let l = StripeLayout::new(unit, factor).unwrap();
            for logical in [0u64, 1, unit - 1, unit, unit + 1, 3 * unit, l.width(), l.width() + 5, 10 * l.width() + unit / 2 + 1]
            {
                let sum: u64 = (0..factor).map(|s| l.child_len(s, logical)).sum();
                assert_eq!(sum, logical, "children must hold exactly the file");
                let back = (0..factor)
                    .map(|s| l.logical_end(s, l.child_len(s, logical)))
                    .max()
                    .unwrap();
                assert_eq!(back, logical, "unit={unit} factor={factor} L={logical}");
            }
        }
    }

    #[test]
    fn logical_end_of_partial_object() {
        let l = StripeLayout::new(10, 4).unwrap();
        // Server 2's object is 15 bytes: its last byte sits in child
        // stripe 1 (offset 4), i.e. logical stripe 1*4+2 = 6, offset 64.
        assert_eq!(l.logical_end(2, 15), 65);
        assert_eq!(l.logical_end(0, 0), 0);
    }

    #[test]
    fn redundancy_parses_and_validates() {
        assert_eq!(Redundancy::parse("none"), Some(Redundancy::None));
        assert_eq!(Redundancy::parse("parity"), Some(Redundancy::Parity));
        assert_eq!(Redundancy::parse("replica:2"), Some(Redundancy::Replica(2)));
        assert_eq!(Redundancy::parse("replica:"), None);
        assert_eq!(Redundancy::parse("replica:x"), None);
        assert_eq!(Redundancy::parse("raid6"), None);
        assert_eq!(Redundancy::None.tolerates(), 0);
        assert_eq!(Redundancy::Replica(3).tolerates(), 2);
        assert_eq!(Redundancy::Parity.tolerates(), 1);
        assert!(Redundancy::Replica(2).validate(4).is_ok());
        assert!(Redundancy::Replica(4).validate(4).is_ok());
        assert!(Redundancy::Replica(1).validate(4).is_err());
        assert!(Redundancy::Replica(5).validate(4).is_err());
        assert!(Redundancy::Parity.validate(1).is_err());
        assert!(Redundancy::Parity.validate(2).is_ok());
    }

    #[test]
    fn parity_map_rotates_and_skips_the_parity_slot() {
        let l = StripeLayout::new(10, 4).unwrap();
        let m = StripeMap::new(l, Redundancy::Parity).unwrap();
        assert_eq!(m.data_units_per_row(), 3);
        assert_eq!(m.data_width(), 30);
        // Rotation: row r's parity slot is on server r % 4.
        assert_eq!(m.parity_server(0), 0);
        assert_eq!(m.parity_server(3), 3);
        assert_eq!(m.parity_server(4), 0);
        // Row 0 (parity on 0): data units 0,1,2 → servers 1,2,3.
        assert_eq!(m.locate(0), (1, 0));
        assert_eq!(m.locate(10), (2, 0));
        assert_eq!(m.locate(25), (3, 5));
        // Row 1 (parity on 1): data units 3,4,5 → servers 0,2,3 at
        // child slot [10, 20).
        assert_eq!(m.locate(30), (0, 10));
        assert_eq!(m.locate(40), (2, 10));
        assert_eq!(m.locate(59), (3, 19));
        // Row of a child-object byte: slot r spans [r*unit, (r+1)*unit)
        // in every object.
        assert_eq!(l.row_of_child_off(0), 0);
        assert_eq!(l.row_of_child_off(9), 0);
        assert_eq!(l.row_of_child_off(10), 1);
    }

    #[test]
    fn parity_split_covers_exactly_and_respects_units() {
        let m = StripeMap::new(StripeLayout::new(16, 4).unwrap(), Redundancy::Parity).unwrap();
        let mut segs = Vec::new();
        m.split_run(5, 100, 7, &mut segs);
        let total: usize = segs.iter().map(|s| s.len).sum();
        assert_eq!(total, 100);
        let mut logical = 5u64;
        let mut pos = 7usize;
        for s in &segs {
            let (server, child_off) = m.locate(logical);
            assert_eq!(s.server, server);
            assert_eq!(s.child_off, child_off);
            assert_eq!(s.buf_pos, pos);
            assert!(s.len <= 16, "piece crosses a unit boundary");
            assert_eq!(logical / 16, (logical + s.len as u64 - 1) / 16);
            // A data segment never lands on its row's parity slot.
            let row = child_off / 16;
            assert_ne!(s.server, m.parity_server(row));
            logical += s.len as u64;
            pos += s.len;
        }
        assert_eq!(logical, 105);
    }

    #[test]
    fn parity_child_len_and_logical_end_are_inverse() {
        for (unit, factor) in [(7u64, 3usize), (10, 4), (16, 2), (4096, 5)] {
            let m =
                StripeMap::new(StripeLayout::new(unit, factor).unwrap(), Redundancy::Parity)
                    .unwrap();
            let dw = m.data_width();
            for logical in
                [0u64, 1, unit - 1, unit, unit + 1, dw - 1, dw, dw + 1, 3 * dw + unit / 2 + 1, 10 * dw]
            {
                let back = (0..factor)
                    .map(|s| m.logical_end(s, m.child_len(s, logical)))
                    .max()
                    .unwrap();
                assert_eq!(back, logical, "unit={unit} factor={factor} L={logical}");
                // Every slot of every spanned row is materialized: the
                // object byte total is (data + one parity unit per row),
                // with only the last data unit allowed to be partial.
                let sum: u64 = (0..factor).map(|s| m.child_len(s, logical)).sum();
                let rows = logical.div_ceil(dw);
                assert_eq!(sum, logical + rows * unit, "unit={unit} factor={factor} L={logical}");
            }
        }
    }

    #[test]
    fn redundancy_tag_round_trips() {
        for r in [Redundancy::None, Redundancy::Replica(2), Redundancy::Replica(5), Redundancy::Parity] {
            let (tag, k) = r.tag();
            assert_eq!(Redundancy::from_tag(tag, k), Some(r));
        }
        assert_eq!(Redundancy::from_tag(9, 0), None);
        assert_eq!(Redundancy::from_tag(1, 1), None, "replica:1 is not a valid mode");
    }

    #[test]
    fn rows_for_size_counts_materialized_slots() {
        let plain = StripeMap::new(StripeLayout::new(10, 4).unwrap(), Redundancy::None).unwrap();
        assert_eq!(plain.rows_for_size(0), 0);
        assert_eq!(plain.rows_for_size(1), 1);
        assert_eq!(plain.rows_for_size(40), 1);
        assert_eq!(plain.rows_for_size(41), 2);
        // Parity: 3 data units per row of width 30; any spanned row
        // materializes its parity slot too.
        let par = StripeMap::new(StripeLayout::new(10, 4).unwrap(), Redundancy::Parity).unwrap();
        assert_eq!(par.rows_for_size(0), 0);
        assert_eq!(par.rows_for_size(1), 1);
        assert_eq!(par.rows_for_size(30), 1);
        assert_eq!(par.rows_for_size(31), 2);
    }

    #[test]
    fn router_splits_at_cursor() {
        assert_eq!(LayoutRouter::split_at(50, 10, 20), (Some((10, 20)), None));
        assert_eq!(LayoutRouter::split_at(50, 50, 20), (None, Some((50, 20))));
        assert_eq!(LayoutRouter::split_at(50, 60, 20), (None, Some((60, 20))));
        assert_eq!(LayoutRouter::split_at(50, 40, 20), (Some((40, 10)), Some((50, 10))));
        assert_eq!(LayoutRouter::split_at(50, 40, 0), (None, None));
        assert_eq!(LayoutRouter::split_at(0, 0, 5), (None, Some((0, 5))));
    }

    #[test]
    fn replica_map_matches_plain_layout() {
        let l = StripeLayout::new(16, 4).unwrap();
        let m = StripeMap::new(l, Redundancy::Replica(2)).unwrap();
        for off in [0u64, 5, 16, 63, 64, 129] {
            assert_eq!(m.locate(off), (l.server_of(off), l.child_offset(off)));
        }
        for size in [0u64, 1, 64, 65, 1000] {
            for s in 0..4 {
                assert_eq!(m.child_len(s, size), l.child_len(s, size));
            }
        }
        assert_eq!(m.data_width(), l.width());
    }
}
