//! Shared-memory communicator: ranks as threads of one process.
//!
//! This is the configuration of the paper's Figures 4-3 and 4-4 ("Java
//! threads ... for parallel access to a shared file"). Message passing is
//! mailbox-based (per-rank queue + condvar); the barrier is the native
//! shared-memory barrier.

use std::collections::VecDeque;
use std::sync::{Arc, Barrier, Condvar, Mutex};

use super::progress::{self, ProgressLane};
use super::Comm;

struct Msg {
    src: usize,
    tag: i32,
    data: Vec<u8>,
}

struct Mailbox {
    q: Mutex<VecDeque<Msg>>,
    cv: Condvar,
}

struct Shared {
    n: usize,
    mailboxes: Vec<Mailbox>,
    barrier: Barrier,
    /// Native shared-memory barriers for the progress lanes, created on
    /// demand per lane index. Each lane's engines are FIFO (at most one
    /// job per rank per lane at a time), so a dedicated n-thread barrier
    /// per lane is exactly the app-lane fast path, replayed per band.
    lane_barriers: Mutex<Vec<Arc<Barrier>>>,
    /// Per-rank banks of progress-lane engines, spawned lazily on first
    /// [`Comm::progress_lane_at`] use. Engines hold only a job sender
    /// (never the `Shared` itself), so a world with idle lanes tears
    /// down normally: dropping the last handle drops the engines, which
    /// ends the worker threads.
    progress: Vec<progress::LaneBank>,
}

impl Shared {
    fn lane_barrier(&self, lane: usize) -> Arc<Barrier> {
        let mut v = self.lane_barriers.lock().unwrap();
        while v.len() <= lane {
            v.push(Arc::new(Barrier::new(self.n)));
        }
        v[lane].clone()
    }
}

/// A thread-transport communicator handle; one per rank.
pub struct ThreadComm {
    rank: usize,
    shared: Arc<Shared>,
    /// Tag displacement of this endpoint (0 for the application lane;
    /// [`progress::lane_shift`] for a progress lane's native endpoint,
    /// keeping each lane's traffic in its own band of the same shared
    /// mailboxes).
    band: i32,
    /// The lane's dedicated shared-memory barrier (`None` = the app
    /// lane, which uses the world barrier).
    lane_barrier: Option<Arc<Barrier>>,
}

impl ThreadComm {
    /// Create the `n` communicator handles of a new thread "world".
    /// Usually you want [`run`] instead.
    pub fn world(n: usize) -> Vec<ThreadComm> {
        assert!(n > 0, "communicator must have at least one rank");
        let shared = Arc::new(Shared {
            n,
            mailboxes: (0..n)
                .map(|_| Mailbox { q: Mutex::new(VecDeque::new()), cv: Condvar::new() })
                .collect(),
            barrier: Barrier::new(n),
            lane_barriers: Mutex::new(Vec::new()),
            progress: (0..n).map(|_| progress::LaneBank::new()).collect(),
        });
        (0..n)
            .map(|rank| ThreadComm { rank, shared: shared.clone(), band: 0, lane_barrier: None })
            .collect()
    }
}

impl Comm for ThreadComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.shared.n
    }

    fn send(&self, dest: usize, tag: i32, data: &[u8]) {
        assert!(dest < self.shared.n, "send to rank {dest} of {}", self.shared.n);
        let mb = &self.shared.mailboxes[dest];
        let mut q = mb.q.lock().unwrap();
        q.push_back(Msg { src: self.rank, tag: tag - self.band, data: data.to_vec() });
        mb.cv.notify_all();
    }

    fn recv(&self, src: usize, tag: i32) -> Vec<u8> {
        let tag = tag - self.band;
        let mb = &self.shared.mailboxes[self.rank];
        let mut q = mb.q.lock().unwrap();
        loop {
            if let Some(pos) = q.iter().position(|m| m.src == src && m.tag == tag) {
                return q.remove(pos).unwrap().data;
            }
            q = mb.cv.wait(q).unwrap();
        }
    }

    fn try_recv(&self, src: usize, tag: i32) -> Option<Vec<u8>> {
        let tag = tag - self.band;
        let mb = &self.shared.mailboxes[self.rank];
        let mut q = mb.q.lock().unwrap();
        let pos = q.iter().position(|m| m.src == src && m.tag == tag)?;
        Some(q.remove(pos).unwrap().data)
    }

    fn barrier(&self) {
        // Native shared-memory barrier — the app lane uses the world
        // barrier, each progress lane its own (FIFO engines guarantee at
        // most one collective per lane at a time, so the lanes' barriers
        // never mix generations with the app's or each other's).
        match &self.lane_barrier {
            None => {
                self.shared.barrier.wait();
            }
            Some(b) => {
                b.wait();
            }
        }
    }

    fn progress_lane_at(&self, lane: usize) -> Option<ProgressLane> {
        // A fresh endpoint per call: only in-flight jobs keep the world
        // alive, never the engine stored inside it. The endpoint is a
        // *native* banded ThreadComm — same shared mailboxes, tags
        // displaced into the lane's band, plus the lane's own native
        // barrier — so the progress band gets the full shared-memory
        // fast path instead of generic message-based collectives.
        let endpoint: Arc<dyn Comm> = Arc::new(ThreadComm {
            rank: self.rank,
            shared: self.shared.clone(),
            band: progress::lane_shift(lane),
            lane_barrier: Some(self.shared.lane_barrier(lane)),
        });
        Some(ProgressLane {
            engine: self.shared.progress[self.rank].engine(self.rank, lane),
            comm: endpoint,
        })
    }
}

/// Run `f` on `n` ranks as threads of this process and return the per-rank
/// results in rank order. Panics in any rank propagate.
pub fn run<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(&ThreadComm) -> R + Send + Sync,
{
    let world = ThreadComm::world(n);
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = world
            .into_iter()
            .map(|comm| {
                scope.spawn(move || {
                    let name = format!("jpio-rank-{}", comm.rank());
                    let _ = name; // thread naming via Builder is not worth the plumbing here
                    f(&comm)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ReduceOp;

    #[test]
    fn world_has_distinct_ranks() {
        let ranks = run(4, |c| c.rank());
        assert_eq!(ranks, vec![0, 1, 2, 3]);
        assert!(run(3, |c| c.size() == 3).iter().all(|&b| b));
    }

    #[test]
    fn send_recv_in_order() {
        run(2, |c| {
            if c.rank() == 0 {
                c.send(1, 7, b"first");
                c.send(1, 7, b"second");
            } else {
                assert_eq!(c.recv(0, 7), b"first");
                assert_eq!(c.recv(0, 7), b"second");
            }
        });
    }

    #[test]
    fn recv_matches_tag_out_of_order() {
        run(2, |c| {
            if c.rank() == 0 {
                c.send(1, 1, b"tag1");
                c.send(1, 2, b"tag2");
            } else {
                // Receive tag 2 first even though tag 1 was sent first.
                assert_eq!(c.recv(0, 2), b"tag2");
                assert_eq!(c.recv(0, 1), b"tag1");
            }
        });
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        run(8, |c| {
            counter.fetch_add(1, Ordering::SeqCst);
            c.barrier();
            // After the barrier every rank must observe all increments.
            assert_eq!(counter.load(Ordering::SeqCst), 8);
        });
    }

    #[test]
    fn bcast_from_each_root() {
        for root in 0..5 {
            run(5, |c| {
                let mut data = if c.rank() == root {
                    vec![42u8; 10]
                } else {
                    Vec::new()
                };
                c.bcast(root, &mut data);
                assert_eq!(data, vec![42u8; 10]);
            });
        }
    }

    #[test]
    fn gather_collects_in_rank_order() {
        run(4, |c| {
            let mine = vec![c.rank() as u8; c.rank() + 1];
            match c.gather(2, &mine) {
                Some(parts) => {
                    assert_eq!(c.rank(), 2);
                    for (r, p) in parts.iter().enumerate() {
                        assert_eq!(*p, vec![r as u8; r + 1]);
                    }
                }
                None => assert_ne!(c.rank(), 2),
            }
        });
    }

    #[test]
    fn allgather_everyone_sees_everything() {
        run(6, |c| {
            let parts = c.allgather(&[c.rank() as u8]);
            let want: Vec<Vec<u8>> = (0..6).map(|r| vec![r as u8]).collect();
            assert_eq!(parts, want);
        });
    }

    #[test]
    fn scatter_distributes() {
        run(3, |c| {
            let payload = if c.rank() == 0 {
                Some(vec![vec![0u8], vec![1u8, 1], vec![2u8, 2, 2]])
            } else {
                None
            };
            let got = c.scatter(0, payload.as_deref());
            assert_eq!(got, vec![c.rank() as u8; c.rank() + 1]);
        });
    }

    #[test]
    fn alltoall_permutes() {
        run(4, |c| {
            let parts: Vec<Vec<u8>> =
                (0..4).map(|d| vec![(c.rank() * 10 + d) as u8]).collect();
            let got = c.alltoall(&parts);
            for (src, p) in got.iter().enumerate() {
                assert_eq!(*p, vec![(src * 10 + c.rank()) as u8]);
            }
        });
    }

    #[test]
    fn reductions_and_scan() {
        run(5, |c| {
            let r = c.rank() as i64;
            assert_eq!(c.allreduce_i64(ReduceOp::Sum, r), 0 + 1 + 2 + 3 + 4);
            assert_eq!(c.allreduce_i64(ReduceOp::Max, r), 4);
            assert_eq!(c.allreduce_i64(ReduceOp::Min, r), 0);
            assert_eq!(c.scan_i64(ReduceOp::Sum, r), (0..=r).sum::<i64>());
            assert_eq!(c.exscan_sum_i64(r), (0..r).sum::<i64>());
            let f = c.allreduce_f64(ReduceOp::Sum, 0.5);
            assert!((f - 2.5).abs() < 1e-12);
        });
    }

    #[test]
    fn single_rank_collectives_are_trivial() {
        run(1, |c| {
            c.barrier();
            let mut d = vec![1u8];
            c.bcast(0, &mut d);
            assert_eq!(c.allgather(&d), vec![vec![1u8]]);
            assert_eq!(c.allreduce_i64(ReduceOp::Sum, 9), 9);
        });
    }

    #[test]
    fn large_message_roundtrip() {
        run(2, |c| {
            let big = vec![0xABu8; 8 << 20];
            if c.rank() == 0 {
                c.send(1, 3, &big);
            } else {
                let got = c.recv(0, 3);
                assert_eq!(got.len(), 8 << 20);
                assert!(got.iter().all(|&b| b == 0xAB));
            }
        });
    }
}
